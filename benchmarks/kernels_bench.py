"""Benchmark: codec kernel throughput, fused vs unfused.

Rows cover (a) the jitted pure-JAX reference codec, (b) the unfused
kernel pipeline (separate quantize, encode, decode, dequantize
dispatches) and (c) the fused Pallas pipeline (quantize+encode and
decode+dequantize as one dispatch each). On CPU the kernels run in
interpret mode — numbers there validate plumbing and relative fused
gain, NOT hardware throughput; on TPU the same rows measure the
compiled kernels.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TABLE1, build_tables, codec, distributions
from repro.kernels import ops
from repro.quant import e4m3


def _time(fn, repeats=3):
    fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(n: int = 1 << 18):
    counts = distributions.ffn1_counts(1 << 16)
    tables = build_tables(counts, TABLE1)
    syms = distributions.ffn1_symbols(n, seed=7)
    k = 1024
    chunks = jnp.asarray(syms.reshape(-1, k))
    cap = codec.worst_case_words(k, tables.max_code_length)

    rows = []

    def row(name, t, **derived):
        rows.append({"name": name, "us_per_call": t * 1e6,
                     "symbols_per_s": round(n / t), **derived})

    # --- jitted pure-JAX reference codec --------------------------------
    enc = jax.jit(lambda c: codec.encode_chunks(c, tables, cap))
    t_enc = _time(lambda: jax.block_until_ready(enc(chunks)))
    row("encode_jit_cpu", t_enc)
    words, _ = enc(chunks)
    dec = jax.jit(lambda w: codec.decode_chunks(w, tables, k))
    t_dec = _time(lambda: jax.block_until_ready(dec(words)))
    row("decode_jit_cpu", t_dec)

    vals = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    q = jax.jit(lambda v: e4m3.quantize_block32(v))
    t_q = _time(lambda: jax.block_until_ready(q(vals)))
    row("quantize_block32_cpu", t_q)

    # --- unfused kernel pipeline (separate dispatches) ------------------
    # jit the whole unfused chain so both sides pay identical dispatch
    # cost and the rows isolate the fusion effect, not eager overhead.
    x = vals.reshape(-1, k)

    @jax.jit
    def unfused_qe(v):
        codes, scales = e4m3.quantize_block32(v)
        w, nb = ops.encode(codes, tables, cap)
        return w, nb, scales
    t_uqe = _time(lambda: jax.block_until_ready(unfused_qe(x)))
    row("unfused_quantize_encode", t_uqe)

    kwords, _, kscales = unfused_qe(x)

    @jax.jit
    def unfused_dd(w, s):
        sym = ops.decode(w, tables, k)
        return e4m3.dequantize_block32(sym, s)
    t_udd = _time(lambda: jax.block_until_ready(unfused_dd(kwords, kscales)))
    row("unfused_decode_dequantize", t_udd)

    # --- fused kernel pipeline (one dispatch per direction) -------------
    # Outer-jitted like the unfused chain (and like the production
    # callers — collectives and the weight wire run these inside jit).
    fused_qe = jax.jit(lambda v: ops.quantize_encode(v, tables, cap))
    t_fqe = _time(lambda: jax.block_until_ready(fused_qe(x)))
    row("fused_quantize_encode", t_fqe,
        speedup_vs_unfused=round(t_uqe / t_fqe, 3))

    fused_dd = jax.jit(
        lambda w, s: ops.decode_dequantize(w, s, tables, k))
    t_fdd = _time(lambda: jax.block_until_ready(fused_dd(kwords, kscales)))
    row("fused_decode_dequantize", t_fdd,
        speedup_vs_unfused=round(t_udd / t_fdd, 3))

    # sanity: fused output must match the unfused pipeline bit-exactly
    fw, fnb, fsc = fused_qe(x)
    uw, unb, usc = unfused_qe(x)
    assert (np.asarray(fw) == np.asarray(uw)).all()
    assert (np.asarray(fsc) == np.asarray(usc)).all()

    # --- channel dispatch overhead (Channel API vs direct call) ---------
    # The Channel resolves codec/config at CONSTRUCTION, so inside jit a
    # channel method must trace to the IDENTICAL computation as the
    # direct functional call. The gated metric (check_regression
    # METRIC_GATES: channel_vs_direct_ratio <= 1.02) is the measured
    # interleaved min-of-N time ratio — except when the two compiled
    # programs are verified bit-identical (normalized HLO text match),
    # where the structural overhead is exactly zero and the metric
    # reports 1.0: on a shared CI box the timer noise on one executable
    # exceeds 2%, and re-timing the same program must not flake the
    # gate. The raw measurement stays in the row (measured_ratio) under
    # the usual 10x timing rule.
    import re
    from repro.comm.channel import Channel, ChannelSpec
    from repro.comm.compressed import (CommConfig, _compress_values,
                                       _decompress_values)
    ccfg = CommConfig(chunk_symbols=k, capacity_words=cap)
    ch = Channel(ChannelSpec(codec=tables, cfg=ccfg))
    flat = vals

    @jax.jit
    def direct_rt(v):
        p, s = _compress_values(v, tables, ccfg)
        return _decompress_values(p, s, tables, ccfg)[0]

    @jax.jit
    def channel_rt(v):
        p, s = ch.compress(v)
        return ch.decompress(p, s)[0]

    def _norm_hlo(f):                    # function name is the only
        text = f.lower(flat).compile().as_text()      # allowed delta
        return re.sub(r"(direct_rt|channel_rt)", "F", text)

    hlo_identical = _norm_hlo(direct_rt) == _norm_hlo(channel_rt)
    jax.block_until_ready(direct_rt(flat))          # warm both
    jax.block_until_ready(channel_rt(flat))
    t_direct, t_channel = float("inf"), float("inf")
    for _ in range(10):                             # interleaved min-of-N
        t0 = time.perf_counter()
        jax.block_until_ready(direct_rt(flat))
        t_direct = min(t_direct, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(channel_rt(flat))
        t_channel = min(t_channel, time.perf_counter() - t0)
    measured = t_channel / t_direct
    row("channel_dispatch", t_channel,
        direct_us_per_call=round(t_direct * 1e6, 1),
        hlo_identical=int(hlo_identical),
        measured_ratio=round(measured, 4),
        channel_vs_direct_ratio=(1.0 if hlo_identical
                                 else round(measured, 4)))
    np.testing.assert_array_equal(np.asarray(direct_rt(flat)),
                                  np.asarray(channel_rt(flat)))

    return rows
