"""Benchmark: codec kernel throughput (jitted reference path on CPU;
on TPU the Pallas kernels take over — interpret-mode numbers are NOT
hardware-indicative and are reported only for plumbing validation)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TABLE1, build_tables, codec, distributions


def _time(fn, repeats=3):
    fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(n: int = 1 << 18):
    counts = distributions.ffn1_counts(1 << 16)
    tables = build_tables(counts, TABLE1)
    syms = distributions.ffn1_symbols(n, seed=7)
    k = 1024
    chunks = jnp.asarray(syms.reshape(-1, k))
    cap = codec.worst_case_words(k, tables.max_code_length)

    enc = jax.jit(lambda c: codec.encode_chunks(c, tables, cap))
    t_enc = _time(lambda: jax.block_until_ready(enc(chunks)))
    words, _ = enc(chunks)
    dec = jax.jit(lambda w: codec.decode_chunks(w, tables, k))
    t_dec = _time(lambda: jax.block_until_ready(dec(words)))

    from repro.quant import e4m3
    vals = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    q = jax.jit(lambda v: e4m3.quantize_block32(v))
    t_q = _time(lambda: jax.block_until_ready(q(vals)))

    return [
        {"name": "encode_jit_cpu", "us_per_call": t_enc * 1e6,
         "symbols_per_s": round(n / t_enc)},
        {"name": "decode_jit_cpu", "us_per_call": t_dec * 1e6,
         "symbols_per_s": round(n / t_dec)},
        {"name": "quantize_block32_cpu", "us_per_call": t_q * 1e6,
         "symbols_per_s": round(n / t_q)},
    ]
