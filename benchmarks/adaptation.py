"""Online-adaptation benchmark: drift injection -> recovered bits/sym.

A channel calibrated on a smooth Gaussian stream (paper Table 1
territory) is fed a mid-run distribution shift to a 40% zero spike
(post-nonlinearity, Table 2 territory). The adaptive loop —
fused-encode histograms -> TrafficMonitor -> DriftPolicy ->
Recalibrator hot-swap — must recover the coding rate on its own
accumulated telemetry.

Gated metric: ``adapted_vs_fresh_bits_ratio`` — the post-swap measured
bits/symbol over a FRESH calibration's expected bits/symbol on the
shifted distribution (<= 1.05 in check_regression.METRIC_GATES; the
exhaustive-search recalibrator typically lands BELOW 1.0 because the
fresh reference restricts itself to the paper's Table 1/2 choice).

``us_per_call`` times one full recalibration (scheme search + LUT
build + empirical plan + registry registration) — the off-hot-path
cost a background swap pays.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.adaptive import AdaptiveController, DriftConfig
from repro.comm.calibrate import calibrate_for_tensor
from repro.comm.channel import Channel, ChannelSpec
from repro.core.registry import CodecRegistry

CHUNK = 512
ROUNDS = 12
SHIFT_ROUND = 4


def _stream(round_: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(7 + round_)
    x = rng.normal(0.0, 1.0, size=n).astype(np.float32)
    if round_ >= SHIFT_ROUND:
        x[rng.random(size=n) < 0.4] = 0.0
    return x


def run(n: int = 1 << 18):
    n = max(CHUNK * 8, (n // CHUNK) * CHUNK)

    registry = CodecRegistry()
    tables, plan = calibrate_for_tensor(jnp.asarray(_stream(0, n)),
                                        chunk_symbols=CHUNK)
    entry0 = registry.register_tables("acts", tables, plan)
    ctl = AdaptiveController(
        registry,
        drift=DriftConfig(min_events=2, hysteresis=2, cooldown=2,
                          min_symbols=float(CHUNK)))
    ch = ctl.wrap(Channel(ChannelSpec(codec="acts"), registry=registry))

    pre_bits = drift_bits = adapted_bits = float("nan")
    swap_round = -1
    recal_us = 0.0
    for r in range(ROUNDS):
        x = jnp.asarray(_stream(r, n))
        _payload, _scales, hist = ch.compress(x, with_hist=True)
        ctl.observe("acts", np.asarray(hist))
        t0 = time.perf_counter()
        events = ctl.check()
        dt = time.perf_counter() - t0
        if events:
            swap_round = r
            recal_us = dt * 1e6
        m = ctl.monitor.measured_bits("acts")
        if m is not None:
            if r == SHIFT_ROUND - 1:
                pre_bits = m
            if swap_round < 0:
                drift_bits = m         # last reading on the old codec
            adapted_bits = m
    swapped = registry["acts"].scheme_id != entry0.scheme_id

    _t, fresh_plan = calibrate_for_tensor(
        jnp.asarray(_stream(ROUNDS, n)), chunk_symbols=CHUNK)
    fresh_bits = fresh_plan.expected_bits_per_symbol

    return [{
        "name": "codec_adaptation",
        "us_per_call": recal_us,
        "pre_shift_bits": round(pre_bits, 4),
        "drifted_bits": round(drift_bits, 4),
        "adapted_bits": round(adapted_bits, 4),
        "fresh_bits": round(fresh_bits, 4),
        "adapted_vs_fresh_bits_ratio": (
            round(adapted_bits / fresh_bits, 4) if swapped else 99.0),
        "swapped": int(swapped),
        "swap_round": swap_round,
    }]


if __name__ == "__main__":
    for row in run(1 << 16):
        print(row)
