"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived...`` CSV rows.
"""
from __future__ import annotations


def main() -> None:
    from benchmarks import (collective_model, compressibility, decode_speed,
                            kernels_bench, multi_lut, scheme_search)
    modules = [compressibility, decode_speed, collective_model,
               scheme_search, multi_lut, kernels_bench]
    all_rows = []
    for mod in modules:
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness running
            rows = [{"name": f"{mod.__name__}_ERROR", "us_per_call": -1,
                     "error": str(e)[:200]}]
        all_rows.extend(rows)

    for row in all_rows:
        name = row.pop("name")
        us = row.pop("us_per_call")
        derived = ";".join(f"{k}={v}" for k, v in row.items())
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
