"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived...`` CSV rows. ``--smoke`` runs every
module at reduced sizes (seconds, not minutes — the CI gate), exits
nonzero if any module errored, and ``--json out.json`` additionally
emits machine-readable rows for ``check_regression.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# Self-sufficient when invoked as ``python benchmarks/run.py`` from a
# clean checkout: put the repo root (benchmarks package) and src
# (repro package) on the path.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

# Per-module element counts: (full, smoke).
SIZES = {
    "benchmarks.compressibility": (1 << 20, 1 << 15),
    "benchmarks.decode_speed": (1 << 16, 1 << 14),
    "benchmarks.collective_model": (1 << 20, 1 << 15),
    "benchmarks.scheme_search": (1 << 20, 1 << 15),
    "benchmarks.multi_lut": (1 << 19, 1 << 15),
    "benchmarks.kernels_bench": (1 << 18, 1 << 15),
    "benchmarks.transport_overlap": (1 << 20, 1 << 15),
    "benchmarks.kv_cache_bench": (1 << 19, 1 << 15),
    "benchmarks.moe_dispatch": (1 << 19, 1 << 15),
    "benchmarks.adaptation": (1 << 18, 1 << 15),
}


def collect_rows(smoke: bool = False):
    from benchmarks import (adaptation, collective_model, compressibility,
                            decode_speed, kernels_bench, kv_cache_bench,
                            moe_dispatch, multi_lut, scheme_search,
                            transport_overlap)
    modules = [compressibility, decode_speed, collective_model,
               scheme_search, multi_lut, kernels_bench, transport_overlap,
               kv_cache_bench, moe_dispatch, adaptation]
    all_rows = []
    for mod in modules:
        try:
            n = SIZES[mod.__name__][1 if smoke else 0]
            rows = mod.run(n=n)
        except Exception as e:  # keep the harness running
            rows = [{"name": f"{mod.__name__}_ERROR", "us_per_call": -1,
                     "error": str(e)[:200]}]
        all_rows.extend(rows)
    return all_rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; exit nonzero on any *_ERROR row")
    ap.add_argument("--json", metavar="PATH",
                    help="also write rows as JSON to PATH")
    args = ap.parse_args(argv)

    all_rows = collect_rows(smoke=args.smoke)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "rows": all_rows}, f, indent=1)

    errors = 0
    for row in all_rows:
        row = dict(row)
        name = row.pop("name")
        us = row.pop("us_per_call")
        derived = ";".join(f"{k}={v}" for k, v in row.items())
        print(f"{name},{us:.1f},{derived}")
        if name.endswith("_ERROR"):
            errors += 1

    if errors and args.smoke:
        print(f"{errors} benchmark module(s) errored", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
