"""Benchmark: compressed KV-cache paging (serving decode states).

Builds REAL decode states (a reduced attention arch, bf16 cache — the
production dtype — prefilled from its own prompt), calibrates the
per-layer ``kv/layer{i}`` codecs, and pushes one full block per layer
through the paged cache's encode → container → decode round trip.

Rows:

* ``kv_cache_wire`` — compressed vs dense cold-cache bytes/token
  through the real container wire. The gated metric
  (``kv_compressed_vs_dense_ratio``) is the lossless byte-plane mode:
  it must beat the dense cache or the subsystem has no reason to
  exist. The e4m3 mode's ratio (quantized cache, the paper's native
  symbols) rides along as ``e4m3_vs_dense_ratio``.
* ``kv_block_decode`` — block decode-on-access latency (container →
  dense arrays), the per-token hot-path cost of a cache miss, split
  into ``host_frame_ms`` (header parse + section slicing) and
  ``device_decode_ms`` (the decode dispatch itself).
* ``kv_prefetch_overlap`` — sync vs async (device-resident arena +
  DMA-prefetched block decode) serving over the same request mix:
  per-token decode time ratio and the trace-derived fraction of block
  decode time hidden behind model compute. Both are gated
  (``check_regression.METRIC_GATES``).
* ``kv_concurrent_capacity`` — the serving engine's capacity win: N
  requests (with shared prompts, the realistic serving mix) run
  through ``repro.serving.Engine`` over ONE shared compressed
  :class:`~repro.serving.BlockPool`; the gated metric
  (``concurrent_capacity_ratio``) is peak DENSE bytes a per-sequence
  dense cache would pin divided by peak compressed bytes the pool
  actually pins (codec ratio × prefix-sharing dedup) — i.e. how many
  more concurrent sequences fit per device at fixed HBM. Engine
  ms/token prefill + decode ride along.
"""
from __future__ import annotations

import time

import numpy as np


def _states(cfg, batch, prompt_len, max_len):
    import jax
    from repro.models import init_decode_states, init_params
    from repro.serving import prefill
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size)
    states = init_decode_states(cfg, batch, max_len)
    _, states = prefill(params, cfg, prompts, states)
    return jax.block_until_ready(states)


def _blocks(cache, cfg, states, block_tokens):
    from repro.serving.kv_cache import calibration_arrays
    arrays = calibration_arrays(cfg, states, block_tokens)
    out = []
    for i in range(len(cfg.layer_kinds())):
        key = f"l{i}"
        out.append(cache.encode_block_arrays(
            cache.spec.layer_codec(i), key, arrays[key],
            start=0, tokens=block_tokens))
    return out, arrays


def run(n: int = 1 << 19):
    from repro.configs import get_config, reduced
    from repro.core.registry import CodecRegistry
    from repro.serving import KVCacheSpec, PagedKVCache, calibrate_cache

    cfg = reduced(get_config("phi3-mini-3.8b"), frontend=None,
                  frontend_prefix_len=0, dtype="bfloat16")
    block_tokens = max(16, min(256, int(n) // 512))
    prompt_len = block_tokens + 16
    states = _states(cfg, 2, prompt_len, prompt_len + 8)

    rows = []
    caches = {}
    for mode in ("qlc", "e4m3"):
        reg = CodecRegistry()
        spec = KVCacheSpec(block_tokens=block_tokens, mode=mode)
        calibrate_cache(reg, cfg, states, prompt_len, spec)
        caches[mode] = PagedKVCache(spec, cfg, reg)

    # ---- wire accounting (+ lossless round-trip check) -------------------
    t0 = time.perf_counter()
    blocks, arrays = _blocks(caches["qlc"], cfg, states, block_tokens)
    for b in blocks:
        decoded = caches["qlc"].decode_block_arrays(b)
        for orig, got in zip(arrays[b.layer], decoded):
            np.testing.assert_array_equal(
                np.asarray(orig).view(np.uint8),
                np.asarray(got).view(np.uint8))
    roundtrip_us = (time.perf_counter() - t0) * 1e6

    wire = sum(b.wire_bytes for b in blocks)
    dense = sum(b.dense_bytes for b in blocks)
    blocks_q, _ = _blocks(caches["e4m3"], cfg, states, block_tokens)
    wire_q = sum(b.wire_bytes for b in blocks_q)

    rows.append({
        "name": "kv_cache_wire",
        "us_per_call": roundtrip_us,
        "tokens_per_block": block_tokens,
        "compressed_bytes_per_token": round(wire / block_tokens, 1),
        "dense_bytes_per_token": round(dense / block_tokens, 1),
        "kv_compressed_vs_dense_ratio": round(wire / dense, 4),
        "e4m3_vs_dense_ratio": round(wire_q / dense, 4),
        "layers": len(blocks),
        "raw_sections": caches["qlc"].raw_sections,
    })

    # ---- decode-on-access latency ----------------------------------------
    # Split into its two halves (they regress independently): the HOST
    # framing walk (header parse + section slicing, pure numpy) and the
    # device decode dispatch (total minus framing). The old single
    # number hid host-side framing regressions behind decode noise.
    from repro.comm import container as qc

    def _host_frame_walk(b):
        buf = np.asarray(b.container)
        offset = 0
        while offset < buf.size:
            _, _, _, offset = qc.unpack_payload(buf, offset)

    cache = caches["qlc"]
    for b in blocks:                                   # warm
        cache.decode_block_arrays(b)
    reps = 3
    best = float("inf")
    best_frame = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for b in blocks:
            cache.decode_block_arrays(b)
        best = min(best, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for b in blocks:
            _host_frame_walk(b)
        best_frame = min(best_frame, time.perf_counter() - t0)
    n_blocks = max(1, len(blocks))
    rows.append({
        "name": "kv_block_decode",
        "us_per_call": best * 1e6 / n_blocks,
        "host_frame_ms": round(best_frame * 1e3 / n_blocks, 4),
        "device_decode_ms": round((best - best_frame) * 1e3 / n_blocks,
                                  4),
        "blocks": len(blocks),
        "mb_per_s": round(dense / best / 1e6, 1),
    })

    # ---- concurrent capacity through the serving engine ------------------
    # A realistic serving mix (most requests share a prompt or a prompt
    # prefix) through one Engine over ONE shared pool. The capacity
    # ratio divides the dense bytes a per-sequence cache would pin at
    # peak by the compressed bytes the pool actually pins — the factor
    # by which concurrent residency grows at fixed HBM.
    import jax
    from repro.models import init_params
    from repro.serving import BlockPool, Engine, GenerationRequest

    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt_len, max_new, max_batch = 12, 6, 4
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, prompt_len)
    prompts = [shared.copy() for _ in range(3)]
    prompts.append(np.concatenate([          # shared prefix, new tail
        shared[:prompt_len - 4],
        rng.integers(0, cfg.vocab_size, 4)]))
    prompts = [p.astype(np.int32) for p in prompts]

    pool = BlockPool(1 << 30)
    eng = Engine(params, cfg, max_seq_len=prompt_len + max_new + 4,
                 max_batch=max_batch,
                 kv_spec=KVCacheSpec(block_tokens=4, mode="qlc",
                                     hot_blocks=1),
                 registry=CodecRegistry(), pool=pool)
    t0 = time.perf_counter()
    handles = [eng.submit(GenerationRequest(prompt=p,
                                            max_new_tokens=max_new))
               for p in prompts]
    eng.run()
    wall = time.perf_counter() - t0
    assert all(eng.poll(h).state == "finished" for h in handles)

    st = eng.stats()
    ps = st["pool"]
    dense_peak = st["peak_dense_logical_bytes"]
    pinned_peak = max(1, ps["peak_referenced_bytes"])
    # per-sequence footprints at peak (all slots resident), used to
    # express the ratio as sequences-per-device at a fixed HBM budget
    budget = 1 << 20
    dense_per_seq = max(1, dense_peak // max_batch)
    comp_per_seq = max(1, pinned_peak // max_batch)
    rows.append({
        "name": "kv_concurrent_capacity",
        "us_per_call": wall * 1e6 / max(1, len(prompts)),
        "requests": len(prompts),
        "engine_slots": max_batch,
        "peak_dense_bytes": dense_peak,
        "peak_compressed_bytes": ps["peak_referenced_bytes"],
        "concurrent_capacity_ratio": round(dense_peak / pinned_peak, 4),
        "seqs_per_mib_dense": budget // dense_per_seq,
        "seqs_per_mib_compressed": budget // comp_per_seq,
        "dedup_hits": ps["dedup_hits"],
        "unique_blocks": ps["unique_blocks"],
        "ms_per_token_prefill": round(st["ms_per_token_prefill"], 2),
        "ms_per_token_decode": round(st["ms_per_token_decode"], 2),
    })

    # ---- sync vs prefetched (async) paging -------------------------------
    # The SAME request mix through two engines sharing one fixed-
    # geometry spec: host-driven sync paging (decode on the block-
    # boundary critical path) vs device-resident async paging (jitted
    # window scan + DMA-prefetched block decodes consumed one window
    # later). Gated: the prefetched path may not be slower per decoded
    # token, and the trace-derived overlap fraction (decode time hidden
    # behind model compute / total decode wait) must stay majority-
    # hidden.
    fixed_spec = KVCacheSpec(block_tokens=4, mode="qlc", hot_blocks=1,
                             exact_capacity=False)

    def _drive(kv_paging):
        eng = Engine(params, cfg, max_seq_len=prompt_len + max_new + 4,
                     max_batch=max_batch, kv_spec=fixed_spec,
                     registry=CodecRegistry(), pool=BlockPool(1 << 30),
                     kv_paging=kv_paging)
        t0 = time.perf_counter()
        hs = [eng.submit(GenerationRequest(prompt=p,
                                           max_new_tokens=max_new))
              for p in prompts]
        eng.run()
        wall = time.perf_counter() - t0
        assert all(eng.poll(h).state == "finished" for h in hs)
        return eng, wall

    _drive("sync")      # warm the jit caches (the step fn and every
    _drive("async")     # window length this mix produces)
    eng_sync, _ = _drive("sync")
    eng_async, wall_async = _drive("async")
    st_s, st_a = eng_sync.stats(), eng_async.stats()
    for h_s, h_a in zip(
            (eng_sync.poll(h).tokens for h in
             [s.rid for s in eng_sync._seqs.values()]),
            (eng_async.poll(h).tokens for h in
             [s.rid for s in eng_async._seqs.values()])):
        np.testing.assert_array_equal(h_s, h_a)   # token identity
    sync_ms = st_s["ms_per_token_decode"]
    async_ms = st_a["ms_per_token_decode"]
    pf = st_a["prefetch"]
    rows.append({
        "name": "kv_prefetch_overlap",
        "us_per_call": wall_async * 1e6 / max(1, len(prompts)),
        "sync_ms_per_token": round(sync_ms, 3),
        "prefetched_ms_per_token": round(async_ms, 3),
        "prefetched_vs_sync_ratio": round(async_ms / max(sync_ms, 1e-9),
                                          4),
        "overlap_fraction": round(pf["overlap_fraction"], 4),
        "prefetch_scheduled": pf["scheduled"],
        "prefetch_hits": pf["hits"],
        "prefetch_stalled": pf["stalled"],
        "bytes_prefetched": pf["bytes_prefetched"],
        "windows": st_a["async"]["windows"],
        "d2h_per_window": st_a["async"]["d2h_per_window"],
    })
    return rows


if __name__ == "__main__":
    for row in run(n=1 << 15):
        row = dict(row)
        name = row.pop("name")
        us = row.pop("us_per_call")
        derived = ";".join(f"{k}={v}" for k, v in row.items())
        print(f"{name},{us:.1f},{derived}")
