"""Benchmark: beyond-paper optimal scheme search (paper §8 future work).

The paper: "Our coding schemes were obtained empirically. It is possible
to tweak the number of areas, the number of symbols in each area, and
the number of unique code lengths to achieve a better compression ratio
... we want to develop a mathematical formulation."

This is that formulation (core/scheme_search.py): exhaustive search over
area-size multisets, provably optimal within the family. Reported: gain
over the paper's tables per distribution, plus the unconstrained-length
variant and other prefix widths.
"""
from __future__ import annotations

import time


from repro.core import TABLE1, TABLE2, distributions, entropy
from repro.core.scheme_search import optimal_scheme


def run(n: int = 1 << 20):
    rows = []
    dists = {
        "ffn1": distributions.ffn1_counts(n),
        "ffn2": distributions.ffn2_counts(n),
        "grad": distributions.grad_counts(n),
    }
    for name, counts in dists.items():
        pmf, _ = entropy.sort_pmf_desc(counts)
        t0 = time.perf_counter()
        quad, quad_bits = optimal_scheme(pmf, prefix_bits=3,
                                         max_distinct_lengths=4)
        dt_quad = time.perf_counter() - t0
        free, free_bits = optimal_scheme(pmf, prefix_bits=3,
                                         max_distinct_lengths=None)
        p2, p2_bits = optimal_scheme(pmf, prefix_bits=2,
                                     max_distinct_lengths=4)
        best_table = min(TABLE1.expected_bits(pmf),
                         TABLE2.expected_bits(pmf))
        h = entropy.shannon_entropy(pmf)
        rows.append({
            "name": f"scheme_search_{name}",
            "us_per_call": dt_quad * 1e6,
            "entropy_bits": round(h, 4),
            "best_paper_table_bits": round(best_table, 4),
            "opt_quad_bits": round(quad_bits, 4),
            "opt_anylen_bits": round(free_bits, 4),
            "opt_prefix2_bits": round(p2_bits, 4),
            "gain_vs_tables_pct": round(
                100 * (best_table - quad_bits) / 8, 3),
            "gap_to_entropy_bits": round(quad_bits - h, 4),
            "opt_quad_areas": str(quad.areas),
        })
    return rows
