"""Benchmark: compressibility tables (paper §4-§6, Figs 1-6, Tables 1-2).

Reports, for FFN1-like and FFN2-like e4m3 streams:
  ideal (entropy bound), Huffman, QLC Table-1, QLC Table-2, and the
  beyond-paper searched optimal quad scheme.

Paper reference points (Gemma-2B SFT traces): FFN1 — 16.3 / 15.9 / 13.9;
FFN2 — 23.6 / 23.2 / 16.7 (T1) / 19.0 (T2). Our streams are synthetic
reconstructions (DESIGN.md §6), so absolute numbers differ; the claims
under test are the orderings and gaps.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (TABLE1, TABLE2, distributions, entropy, huffman)
from repro.core.scheme_search import optimal_scheme

PAPER = {
    "ffn1": {"ideal": 16.3, "huffman": 15.9, "qlc_t1": 13.9,
             "qlc_t2": None},
    "ffn2": {"ideal": 23.6, "huffman": 23.2, "qlc_t1": 16.7,
             "qlc_t2": 19.0},
}


def run(n: int = 1 << 20):
    rows = []
    for name, counts_fn in (("ffn1", distributions.ffn1_counts),
                            ("ffn2", distributions.ffn2_counts)):
        t0 = time.perf_counter()
        counts = counts_fn(n)
        pmf, _ = entropy.sort_pmf_desc(counts)
        h = entropy.shannon_entropy(pmf)
        ideal = 100 * (8 - h) / 8
        hc = huffman.HuffmanCodec(np.maximum(counts, 1e-9))
        huff = 100 * hc.compressibility(np.maximum(counts, 1e-9))
        t1 = 100 * TABLE1.compressibility(pmf)
        t2 = 100 * TABLE2.compressibility(pmf)
        opt, bits = optimal_scheme(pmf, max_distinct_lengths=4)
        opt_c = 100 * (8 - bits) / 8
        dt = (time.perf_counter() - t0) * 1e6
        p = PAPER[name]
        rows.append({
            "name": f"compressibility_{name}",
            "us_per_call": dt,
            "entropy_bits": round(h, 3),
            "ideal_pct": round(ideal, 2),
            "huffman_pct": round(huff, 2),
            "qlc_t1_pct": round(t1, 2),
            "qlc_t2_pct": round(t2, 2),
            "opt_quad_pct": round(opt_c, 2),
            "paper_ideal": p["ideal"],
            "paper_huffman": p["huffman"],
            "paper_qlc_t1": p["qlc_t1"],
            "paper_qlc_t2": p["qlc_t2"],
            "huffman_lengths": f"{hc.lengths[hc.lengths > 0].min()}"
                               f"-{hc.lengths.max()}",
            "qlc_distinct_lengths": 4,
        })
    return rows
