"""Benchmark: transport selection — modeled ring vs one-shot times.

Measures the local decode throughput (the beta_decode the planner's
alpha-beta model needs) on real compressed payloads, then reports the
modeled one-shot vs ring collective times at a production-sized payload
(above the ring/one-shot crossover) plus the crossover itself.

The ``collective_overlap`` row carries the CI quality gate: for
payloads above the crossover, the modeled ring time (decode overlapping
the wire) must never exceed the modeled one-shot time (decode strictly
after the wire) — if it does, the cost model or the transport layer
regressed.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.comm import (AlphaBetaModel, CommConfig, choose_transport,
                        measure_decode_Bps, modeled_oneshot_time,
                        modeled_ring_time, transport_crossover_bytes)
from repro.comm.calibrate import calibrate_for_tensor
from repro.comm.planner import HOP_CHUNK_CANDIDATES, payload_wire_bytes
from repro.core import distributions
from repro.quant import e4m3

AXIS_SIZE = 8
PROD_SHARD_VALUE_BYTES = 256e6     # 64M f32 gradients per shard


def _measure_decode_Bps(n: int) -> tuple[float, float, CommConfig]:
    """Measure beta_decode on a calibrated grad-stream payload.

    Calibrates a grad codec, then delegates the timing to the shared
    :func:`repro.comm.channel.measure_decode_Bps` probe — the same
    measurement ``Channel.autotune`` runs. Returns ``(decode_Bps,
    measured_us, cfg)``; throughput is in decoded f32 value bytes/s.
    """
    syms = distributions.grad_symbols(n)
    vals = e4m3.e4m3_decode(jnp.asarray(syms))
    tables, plan = calibrate_for_tensor(vals, chunk_symbols=1024)
    cfg = CommConfig.from_plan(plan)
    counts = np.bincount(np.asarray(syms), minlength=256)
    bps, secs = measure_decode_Bps(tables, cfg, n, counts=counts)
    return bps, secs * 1e6, cfg


def run(n: int = 1 << 20):
    decode_Bps, measured_us, cfg = _measure_decode_Bps(n)
    model = AlphaBetaModel(decode_Bps=decode_Bps)

    ratio = payload_wire_bytes(
        n, cfg.chunk_symbols, cfg.capacity_words,
        cfg.pool_slots_per_1k) / (4.0 * n)
    value_bytes = PROD_SHARD_VALUE_BYTES
    wire = value_bytes * ratio
    one = modeled_oneshot_time(model, wire, value_bytes, AXIS_SIZE)
    # Ring time straight from the model (NOT via choose_transport,
    # which by construction only reports ring when ring < one-shot and
    # would make the gate below tautological).
    ring = min(modeled_ring_time(model, wire, value_bytes, AXIS_SIZE, h)
               for h in HOP_CHUNK_CANDIDATES)
    t = choose_transport(wire, value_bytes, AXIS_SIZE, model=model)
    # Physical floor: the compressed bytes must cross the wire no
    # matter how well decode overlaps — a modeled ring time BELOW this
    # means the overlap model lost a term (gated >= 1.0).
    wire_floor = (AXIS_SIZE - 1) * wire / model.wire_Bps
    cross = transport_crossover_bytes(
        AXIS_SIZE, model=model, compression_ratio=1.0 / ratio)

    rows = [{
        "name": "collective_overlap",
        "us_per_call": measured_us,
        "measured_decode_GBps": round(decode_Bps / 1e9, 3),
        "shard_value_MB": round(value_bytes / 1e6, 1),
        "axis_size": AXIS_SIZE,
        "modeled_oneshot_us": round(one * 1e6, 1),
        "modeled_ring_us": round(ring * 1e6, 1),
        # CI gates: above the crossover, overlap must win (<= 1.0)
        # without undercutting the pure wire time (>= 1.0)
        "ring_vs_oneshot_modeled_ratio": round(ring / one, 4),
        "ring_vs_wire_floor_ratio": round(ring / wire_floor, 4),
        "chosen_transport": t.kind,
        "hop_chunks": t.hop_chunks,
        "crossover_value_bytes": round(cross, 0),
    }]

    # And the small-payload side of the crossover — informational: with
    # hardware-like wire/decode rates one-shot wins here (per-message
    # alpha dominates); in a decode-bound regime (CPU interpret mode)
    # the crossover collapses and ring wins everywhere.
    small = max(1024.0, cross / 16)
    one_s = modeled_oneshot_time(model, small * ratio, small, AXIS_SIZE)
    t_s = choose_transport(small * ratio, small, AXIS_SIZE, model=model)
    rows.append({
        "name": "collective_overlap_small",
        "us_per_call": round(one_s * 1e6, 2),
        "shard_value_bytes": round(small, 0),
        "chosen_transport": t_s.kind,
    })
    return rows
