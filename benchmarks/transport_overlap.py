"""Benchmark: transport selection — modeled ring vs one-shot times.

Measures the local decode throughput (the beta_decode the planner's
alpha-beta model needs) on real compressed payloads, then reports the
modeled one-shot vs ring collective times at a production-sized payload
(above the ring/one-shot crossover) plus the crossover itself.

The ``collective_overlap`` row carries the CI quality gate: for
payloads above the crossover, the modeled ring time (decode overlapping
the wire) must never exceed the modeled one-shot time (decode strictly
after the wire) — if it does, the cost model or the transport layer
regressed.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.comm import (AlphaBetaModel, CommConfig, choose_transport,
                        measure_decode_Bps, modeled_flat_ring_time,
                        modeled_hierarchical_oneshot_time,
                        modeled_hierarchical_time, modeled_oneshot_time,
                        modeled_ring_time, transport_crossover_bytes)
from repro.comm.calibrate import calibrate_for_tensor
from repro.comm.planner import HOP_CHUNK_CANDIDATES, payload_wire_bytes
from repro.core import distributions
from repro.quant import e4m3

AXIS_SIZE = 8
PROD_SHARD_VALUE_BYTES = 256e6     # 64M f32 gradients per shard
# The multi-host row: a 2-pod x 4-local group, the CI-simulated
# topology (tests/test_hierarchical.py runs the same 2 x 4 split).
POD_SIZE = 2
LOCAL_SIZE = 4


def _measure_decode_Bps(n: int) -> tuple[float, float, CommConfig]:
    """Measure beta_decode on a calibrated grad-stream payload.

    Calibrates a grad codec, then delegates the timing to the shared
    :func:`repro.comm.channel.measure_decode_Bps` probe — the same
    measurement ``Channel.autotune`` runs. Returns ``(decode_Bps,
    measured_us, cfg)``; throughput is in decoded f32 value bytes/s.
    """
    syms = distributions.grad_symbols(n)
    vals = e4m3.e4m3_decode(jnp.asarray(syms))
    tables, plan = calibrate_for_tensor(vals, chunk_symbols=1024)
    cfg = CommConfig.from_plan(plan)
    counts = np.bincount(np.asarray(syms), minlength=256)
    bps, secs = measure_decode_Bps(tables, cfg, n, counts=counts)
    return bps, secs * 1e6, cfg


def run(n: int = 1 << 20):
    decode_Bps, measured_us, cfg = _measure_decode_Bps(n)
    model = AlphaBetaModel(decode_Bps=decode_Bps)

    ratio = payload_wire_bytes(
        n, cfg.chunk_symbols, cfg.capacity_words,
        cfg.pool_slots_per_1k) / (4.0 * n)
    value_bytes = PROD_SHARD_VALUE_BYTES
    wire = value_bytes * ratio
    one = modeled_oneshot_time(model, wire, value_bytes, AXIS_SIZE)
    # Ring time straight from the model (NOT via choose_transport,
    # which by construction only reports ring when ring < one-shot and
    # would make the gate below tautological).
    ring = min(modeled_ring_time(model, wire, value_bytes, AXIS_SIZE, h)
               for h in HOP_CHUNK_CANDIDATES)
    t = choose_transport(wire, value_bytes, AXIS_SIZE, model=model)
    # Physical floor: the compressed bytes must cross the wire no
    # matter how well decode overlaps — a modeled ring time BELOW this
    # means the overlap model lost a term (gated >= 1.0).
    wire_floor = (AXIS_SIZE - 1) * wire / model.wire_Bps
    cross = transport_crossover_bytes(
        AXIS_SIZE, model=model, compression_ratio=1.0 / ratio)

    rows = [{
        "name": "collective_overlap",
        "us_per_call": measured_us,
        "measured_decode_GBps": round(decode_Bps / 1e9, 3),
        "shard_value_MB": round(value_bytes / 1e6, 1),
        "axis_size": AXIS_SIZE,
        "modeled_oneshot_us": round(one * 1e6, 1),
        "modeled_ring_us": round(ring * 1e6, 1),
        # CI gates: above the crossover, overlap must win (<= 1.0)
        # without undercutting the pure wire time (>= 1.0)
        "ring_vs_oneshot_modeled_ratio": round(ring / one, 4),
        "ring_vs_wire_floor_ratio": round(ring / wire_floor, 4),
        "chosen_transport": t.kind,
        "hop_chunks": t.hop_chunks,
        "crossover_value_bytes": round(cross, 0),
    }]

    # Multi-host (DCN-tier) transports over a pod x local group, all
    # straight from the per-link-class cost model (NOT choose_transport
    # — same anti-tautology rule as above). The flat ring is the
    # modeled baseline only: it gates every hop at DCN speed and is not
    # even executable over a two-axis group, which is exactly why the
    # hierarchical schedule exists — it must never model slower.
    hier = min(modeled_hierarchical_time(
        model, wire, value_bytes, LOCAL_SIZE, POD_SIZE, h)
        for h in HOP_CHUNK_CANDIDATES)
    flat_ring = min(modeled_flat_ring_time(
        model, wire, value_bytes, LOCAL_SIZE, POD_SIZE, h)
        for h in HOP_CHUNK_CANDIDATES)
    one_h = modeled_hierarchical_oneshot_time(
        model, wire, value_bytes, LOCAL_SIZE, POD_SIZE)
    t_h = choose_transport(wire, value_bytes, LOCAL_SIZE, model=model,
                           pod_size=POD_SIZE)
    # Physical floor: every hop group's bridge still moves (P-1) copies
    # of the shard over the DCN, L times — modeling below that means
    # the bridge lost its steady-state term.
    dcn_floor = LOCAL_SIZE * (POD_SIZE - 1) * wire / model.link_Bps("dcn")
    rows.append({
        "name": "hierarchical_transport",
        "us_per_call": measured_us,
        "pod_size": POD_SIZE,
        "local_size": LOCAL_SIZE,
        "shard_value_MB": round(value_bytes / 1e6, 1),
        "modeled_hierarchical_us": round(hier * 1e6, 1),
        "modeled_flat_ring_us": round(flat_ring * 1e6, 1),
        "modeled_oneshot_us": round(one_h * 1e6, 1),
        # CI gates: ringing within the pod + one compressed bridge per
        # hop group must never model slower than DCN-gating every hop
        # (<= 1.0), without undercutting the DCN bridge floor (>= 1.0)
        "hierarchical_vs_flat_ring_modeled_ratio":
            round(hier / flat_ring, 4),
        "hierarchical_vs_dcn_floor_ratio": round(hier / dcn_floor, 4),
        "hierarchical_vs_oneshot_modeled_ratio": round(hier / one_h, 4),
        "chosen_transport": t_h.kind,
        "hop_chunks": t_h.hop_chunks,
    })

    # And the small-payload side of the crossover — informational: with
    # hardware-like wire/decode rates one-shot wins here (per-message
    # alpha dominates); in a decode-bound regime (CPU interpret mode)
    # the crossover collapses and ring wins everywhere.
    small = max(1024.0, cross / 16)
    one_s = modeled_oneshot_time(model, small * ratio, small, AXIS_SIZE)
    t_s = choose_transport(small * ratio, small, AXIS_SIZE, model=model)
    rows.append({
        "name": "collective_overlap_small",
        "us_per_call": round(one_s * 1e6, 2),
        "shard_value_bytes": round(small, 0),
        "chosen_transport": t_s.kind,
    })
    return rows
