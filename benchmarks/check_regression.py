"""CI benchmark gate: compare a --smoke --json run against the
committed baseline.

Usage:
    python benchmarks/check_regression.py out.json \
        [--baseline benchmarks/BENCH_baseline.json] [--threshold 10]

Fails (exit 1) when
  * any row in the current run is an ``*_ERROR`` row,
  * a baseline row is missing from the current run (a benchmark was
    silently dropped),
  * a row's ``us_per_call`` exceeds ``threshold`` x its baseline, or
  * a quality metric in ``METRIC_GATES`` violates its absolute bound
    (these are correctness-adjacent ratios, not timings — e.g. the
    per-tensor-type registry wire must never be bigger than the global
    LUT wire; see benchmarks/multi_lut.py).

The threshold is deliberately generous (default 10x): CI machines are
noisy and interpret-mode kernel timings vary a lot; the gate exists to
catch order-of-magnitude regressions and silently-deleted coverage,
not single-digit-percent drift. Refresh the baseline with --update
after intentional changes.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys


# row name -> {metric: (op, bound)}; machine-independent quality gates
# checked against the CURRENT run (timings stay under the x-factor rule).
METRIC_GATES = {
    "multi_lut_container_wire": {
        # per-tensor-type LUTs must never cost more wire than the
        # global LUT (1.005 absorbs per-section container headers)
        "per_type_vs_global_wire_ratio": ("<=", 1.005),
        # and the paper's multi-LUT setup needs >= 2 distinct schemes
        "distinct_schemes": (">=", 2),
    },
    "channel_dispatch": {
        # the Channel API resolves everything at construction, so a
        # jitted channel call must cost within 2% of the direct
        # functional call (min-of-N interleaved timing — see
        # benchmarks/kernels_bench.py).
        "channel_vs_direct_ratio": ("<=", 1.02),
    },
    "collective_overlap": {
        # above the ring/one-shot crossover, the modeled ring time
        # (decode overlapping the wire) must never exceed the modeled
        # one-shot time (decode strictly after the wire) — see
        # benchmarks/transport_overlap.py. Both times come straight
        # from the cost model (not from choose_transport, which would
        # make this tautological)...
        "ring_vs_oneshot_modeled_ratio": ("<=", 1.0),
        # ...and the ring model may not undercut the physical wire
        # floor either (catches a lost pipeline-fill/steady-state term
        # that would make ring look impossibly fast).
        "ring_vs_wire_floor_ratio": (">=", 1.0),
    },
    "hierarchical_transport": {
        # the multi-host schedule's reason to exist: at a pod x local
        # group, ringing within the pod and bridging pods with ONE
        # compressed exchange per hop group must never model slower
        # than a flat ring that gates every hop at DCN speed — both
        # times straight from the per-link-class cost model, not from
        # choose_transport (tautology) — see
        # benchmarks/transport_overlap.py ...
        "hierarchical_vs_flat_ring_modeled_ratio": ("<=", 1.0),
        # ... and it may not undercut the DCN bridge floor (L x (P-1)
        # shard copies still cross the slow link).
        "hierarchical_vs_dcn_floor_ratio": (">=", 1.0),
    },
    "kv_cache_wire": {
        # the lossless byte-plane KV cache must beat the dense cache
        # through the REAL container wire (bf16 attention KV, the
        # production cache dtype) or the subsystem has no reason to
        # exist — see benchmarks/kv_cache_bench.py ...
        "kv_compressed_vs_dense_ratio": ("<=", 0.98),
        # ... and the e4m3-quantized cache must keep a decisive
        # margin (symbols are the paper's native regime there).
        "e4m3_vs_dense_ratio": ("<=", 0.75),
    },
    "moe_dispatch": {
        # the compressed expert-dispatch wire's reason to exist: QLC
        # coding on the routed-token a2a buffers must beat the dense
        # e4m3 wire (1 B/value + block-32 scales) on BOTH directions
        # (the row reports the worse of dispatch/combine) ...
        "compressed_vs_dense_e4m3_ratio": ("<=", 0.95),
        # ... and at the measured decode throughput the distance-
        # charged a2a ring (decode overlapping the ppermute hops,
        # planner.modeled_a2a_ring_time) must never be slower than
        # one-shot — straight from the cost model, not from
        # choose_a2a_transport (tautology) — see moe_dispatch.py.
        "ring_vs_oneshot_modeled_ratio": ("<=", 1.0),
    },
    "kv_concurrent_capacity": {
        # the serving engine's reason to exist: at fixed pool bytes, a
        # shared-prompt request mix must fit at least 1.5x the
        # concurrent sequences of per-sequence dense caches (codec
        # ratio x prefix-sharing dedup) — see kv_cache_bench.py.
        "concurrent_capacity_ratio": (">=", 1.5),
    },
    "codec_adaptation": {
        # the adaptive subsystem's reason to exist: after a mid-run
        # distribution shift, the drift-triggered hot-swap must
        # recover the coding rate to within 5% of a FRESH calibration
        # on the shifted distribution (measured bits/sym over fresh
        # expected bits/sym; 99.0 is the no-swap sentinel, so a loop
        # that never triggers fails loudly) — see
        # benchmarks/adaptation.py ...
        "adapted_vs_fresh_bits_ratio": ("<=", 1.05),
        # ... and the swap itself must actually have happened.
        "swapped": (">=", 1),
    },
    "kv_prefetch_overlap": {
        # async paging's reason to exist: the jitted-window +
        # DMA-prefetched path must never be slower per decoded token
        # than host-driven sync paging over the same request mix...
        "prefetched_vs_sync_ratio": ("<=", 1.0),
        # ...and the majority of block decode wait must actually be
        # hidden behind model compute (measured from the schedule →
        # consume trace, not assumed) — see kv_cache_bench.py.
        "overlap_fraction": (">=", 0.5),
    },
}

_OPS = {"<=": lambda a, b: a <= b, ">=": lambda a, b: a >= b}


def check_metric_gates(current):
    failures = []
    for row_name, gates in METRIC_GATES.items():
        row = current.get(row_name)
        if row is None:
            continue            # missing-row failure is reported elsewhere
        for metric, (op, bound) in gates.items():
            val = row.get(metric)
            if val is None:
                failures.append(f"metric gate: {row_name} lacks {metric}")
            elif not _OPS[op](val, bound):
                failures.append(
                    f"metric gate: {row_name}.{metric} = {val} "
                    f"violates {op} {bound}")
    return failures


def _rows_by_name(payload):
    return {r["name"]: r for r in payload["rows"]}


def write_report(path, current, baseline, failures, threshold):
    """Readable markdown diff of a run vs the baseline — uploaded as a
    PR artifact so perf diffs are reviewable from the run page without
    opening the raw JSON."""
    skip = {"name", "us_per_call", "error"}
    lines = ["# Benchmark smoke — regression report", "",
             f"{len(current)} rows vs {len(baseline)} baseline rows, "
             f"timing threshold {threshold:.1f}x.", ""]
    if failures:
        lines += ["## FAILURES", ""]
        lines += [f"- {f}" for f in failures]
        lines.append("")
    else:
        lines += ["All gates passed.", ""]
    lines += ["| row | baseline us | current us | ratio | metrics |",
              "|---|---:|---:|---:|---|"]
    for name in sorted(set(baseline) | set(current)):
        b, c = baseline.get(name), current.get(name)
        bus = f"{b['us_per_call']:.1f}" if b else "—"
        cus = f"{c['us_per_call']:.1f}" if c else "MISSING"
        ratio = "—"
        if b and c and b["us_per_call"] > 0:
            ratio = f"{c['us_per_call'] / b['us_per_call']:.2f}x"
        metrics = "" if not c else " ".join(
            f"{k}={v}" for k, v in c.items() if k not in skip)
        lines.append(f"| {name} | {bus} | {cus} | {ratio} | {metrics} |")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="JSON from benchmarks/run.py --json")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument("--threshold", type=float, default=10.0,
                    help="allowed slowdown factor vs baseline")
    ap.add_argument("--update", action="store_true",
                    help="overwrite the baseline with the current run")
    ap.add_argument("--report", metavar="PATH",
                    help="also write a readable markdown report of the "
                         "diff vs baseline (CI uploads it as an artifact)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = _rows_by_name(json.load(f))

    failures = []
    for name in current:
        if name.endswith("_ERROR"):
            failures.append(f"ERROR row: {name}: "
                            f"{current[name].get('error', '')}")
    failures.extend(check_metric_gates(current))

    if args.update:
        if failures:
            print("\n".join(failures), file=sys.stderr)
            print("refusing to --update from a run with errors",
                  file=sys.stderr)
            return 1
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated: {args.baseline}")
        return 0

    with open(args.baseline) as f:
        baseline = _rows_by_name(json.load(f))

    for name, base_row in baseline.items():
        if name.endswith("_ERROR"):
            continue                      # never canonize an error row
        cur = current.get(name)
        if cur is None:
            failures.append(f"missing row vs baseline: {name}")
            continue
        base_us, cur_us = base_row["us_per_call"], cur["us_per_call"]
        if base_us > 0 and cur_us > args.threshold * base_us:
            failures.append(
                f"regression: {name}: {cur_us:.1f}us vs baseline "
                f"{base_us:.1f}us (> {args.threshold:.1f}x)")

    if args.report:
        write_report(args.report, current, baseline, failures,
                     args.threshold)

    if failures:
        print("\n".join(failures), file=sys.stderr)
        return 1
    print(f"benchmark gate OK ({len(baseline)} baseline rows, "
          f"threshold {args.threshold:.1f}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
