"""Benchmark: compressed MoE expert dispatch (the ``shardmap_a2a`` wire).

Builds a tiny deepseek-moe-style layer, routes a real token batch
through the gspmd dispatch math (``moe.dispatch_traffic``) to get the
actual expert-wire buffers, calibrates the ``moe/dispatch`` /
``moe/combine`` codecs from them — the same pipeline
``comm.calibrate.calibrate_moe_entries`` runs on a training batch —
and reports:

* ``compressed_vs_dense_e4m3_ratio`` — compressed expert wire bytes per
  a2a row vs the dense-e4m3 wire (1 B/value + its block-32 bf16
  scales, which a dense fp8 wire must also carry). Gated <= 0.95: the
  QLC coding must beat a plain fp8 wire.
* ``ring_vs_oneshot_modeled_ratio`` — the distance-charged a2a ring
  model (``modeled_a2a_ring_time``, decode overlapping the ppermute
  hops) vs one-shot, at the MEASURED decode throughput. Gated <= 1.0:
  in the decode-bound regime the probe measures, the overlap must win
  — straight from the cost model, NOT ``choose_a2a_transport`` (which
  only reports ring when ring wins and would make the gate
  tautological).
"""
from __future__ import annotations

import numpy as np

import jax

from repro.comm import measure_decode_Bps
from repro.comm.calibrate import empirical_plan, kv_symbol_stream
from repro.comm.compressed import CommConfig
from repro.comm.planner import (HOP_CHUNK_CANDIDATES, AlphaBetaModel,
                                choose_a2a_transport,
                                modeled_a2a_ring_time,
                                modeled_oneshot_time, payload_wire_bytes,
                                plan_for_tables)
from repro.configs import get_config, reduced
from repro.core import adapt
from repro.models import moe

CHUNK_SYMBOLS = 1024

#: The modeled mesh: 2 dp groups x 4-way expert parallelism (the
#: fake-device topology the parity test runs on).
_MESH_SHAPE = {"data": 2, "model": 4}


class _Mesh:
    axis_names = tuple(_MESH_SHAPE)
    shape = _MESH_SHAPE


def _calibrate(stream: np.ndarray):
    """e4m3 symbol stream -> (tables, empirically-sized plan) — the
    same sizing ``calibrate_moe_entries`` applies (quarter-bit drift
    margin: routed-token chunk sums plateau at the all-token mode)."""
    counts = np.maximum(
        np.bincount(stream, minlength=256).astype(np.float64), 1e-6)
    tables = adapt.calibrate_tables(counts)
    plan = plan_for_tables(tables, counts, chunk_symbols=CHUNK_SYMBOLS,
                           target_escape_prob=1e-4)
    plan = empirical_plan(tables, stream, plan,
                          chunk_symbols=CHUNK_SYMBOLS,
                          target_escape_prob=1e-4,
                          max_pool_slots_per_1k=64,
                          drift_margin_bits=0.25)
    return tables, plan, counts


def run(n: int = 1 << 19):
    import dataclasses

    from repro.models import init_params, next_token_loss

    cfg = reduced(get_config("deepseek-moe-16b"))
    # Token count scaled from the element budget, dp*model- and
    # seq-divisible. Rows must be production-shaped (tens of KB+): the
    # escape pool is a fixed row-level cost, so a toy row would measure
    # pool overhead instead of coding efficiency.
    seq = 512
    n_tokens = max(8192, min(16384, n // 8)) // seq * seq
    # a REAL routed batch: forward the reduced model with traffic
    # capture on (the calibrate_moe_entries flow) and take the first
    # MoE layer's dispatch/combine buffers — iid noise would overstate
    # the symbol entropy vs actual activations.
    eager_cfg = dataclasses.replace(cfg, use_scan=False, remat="none")
    params = init_params(eager_cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1),
                             (n_tokens // seq, seq), 0, cfg.vocab_size)
    captured: list = []
    with moe.capture_moe_traffic(captured):
        next_token_loss(params, eager_cfg, tok, tok)
    layer_params, x = captured[0]

    buf, out_e = moe.dispatch_traffic(layer_params, x, eager_cfg)
    geo = moe.shardmap_a2a_geometry(cfg, n_tokens, _Mesh())
    d = geo["axis_size"]
    row_values = geo["row_values"]
    row_value_bytes = 4.0 * row_values

    rows = []
    ratios = {}
    for name, arr in (("dispatch", buf), ("combine", out_e)):
        stream = kv_symbol_stream([arr], mode="e4m3")
        tables, plan, counts = _calibrate(stream)
        wire = payload_wire_bytes(row_values, plan.chunk_symbols,
                                  plan.capacity_words,
                                  plan.pool_slots_per_1k)
        # dense e4m3 wire: 1 B/value + block-32 bf16 scales (2 B / 32)
        dense = row_values * (1.0 + 2.0 / 32.0)
        ratios[name] = (wire / dense, tables, plan, counts, stream)
        rows.append((name, wire, dense, plan))

    # measured decode throughput on the dispatch codec's payloads — the
    # beta_decode the a2a transport choice actually sees
    tables, plan, counts = (ratios["dispatch"][1], ratios["dispatch"][2],
                            ratios["dispatch"][3])
    cfg_wire = CommConfig.from_plan(plan)
    probe_symbols = min(len(ratios["dispatch"][4]), 1 << 16)
    decode_Bps, secs = measure_decode_Bps(tables, cfg_wire, probe_symbols,
                                          counts=counts)
    model = AlphaBetaModel(decode_Bps=decode_Bps)

    disp_wire = rows[0][1]
    one = modeled_oneshot_time(model, disp_wire, row_value_bytes, d)
    # ring straight from the cost model (see module docstring)
    ring = min(modeled_a2a_ring_time(model, disp_wire, row_value_bytes,
                                     d, h) for h in HOP_CHUNK_CANDIDATES)
    chosen = choose_a2a_transport(disp_wire, row_value_bytes, d,
                                  model=model)

    return [{
        "name": "moe_dispatch",
        "us_per_call": secs * 1e6,
        "n_tokens": n_tokens,
        "axis_size": d,
        "tokens_per_rank": geo["ng"],
        "row_value_bytes": int(row_value_bytes),
        "measured_decode_GBps": round(decode_Bps / 1e9, 3),
        # bytes/token/collective each rank puts on the expert wire
        "dispatch_wire_bytes_per_token": round(
            d * disp_wire / geo["ng"], 1),
        "combine_wire_bytes_per_token": round(
            d * rows[1][1] / geo["ng"], 1),
        "dispatch_bits_per_symbol": round(
            rows[0][3].expected_bits_per_symbol, 3),
        "combine_bits_per_symbol": round(
            rows[1][3].expected_bits_per_symbol, 3),
        # CI gates
        "compressed_vs_dense_e4m3_ratio": round(
            max(ratios["dispatch"][0], ratios["combine"][0]), 4),
        "ring_vs_oneshot_modeled_ratio": round(ring / one, 4),
        "modeled_oneshot_us": round(one * 1e6, 1),
        "modeled_ring_us": round(ring * 1e6, 1),
        "chosen_transport": chosen.kind,
        "hop_chunks": chosen.hop_chunks,
    }]
