"""Benchmark: decode speed — the paper's headline claim (§1, §8).

Compares, on the same FFN1-like e4m3 stream:
  * huffman_bitseq  — bit-sequential Huffman tree walk (the baseline the
    paper criticizes: latency ∝ encoded bits, deep trees).
  * qlc_python_seq  — QLC decoded sequentially in Python (isolates the
    per-symbol O(1) area-code lookup from vectorization).
  * qlc_chunk_parallel — the framework codec: chunk-parallel jitted
    decode (the TPU-native formulation; here timed on CPU via XLA).

Throughput in symbols/s; derived column reports speedup over Huffman.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TABLE1, build_tables, codec, distributions, huffman


def _time(fn, repeats=3):
    fn()  # warmup / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def qlc_decode_python(words: np.ndarray, tables, n: int) -> np.ndarray:
    """Sequential QLC decode (reference for the per-symbol O(1) claim)."""
    out = np.empty(n, dtype=np.uint8)
    sb_t = tables.area_symbol_bits
    st_t = tables.area_starts
    dec = tables.dec_lut
    flat = words.reshape(-1)
    bitpos = 0
    for i in range(n):
        w = bitpos >> 5
        sh = bitpos & 31
        window = (int(flat[w]) >> sh)
        if sh:
            window |= int(flat[min(w + 1, len(flat) - 1)]) << (32 - sh)
        area = window & 7
        sb = int(sb_t[area])
        payload = (window >> 3) & ((1 << sb) - 1)
        out[i] = dec[st_t[area] + payload]
        bitpos += 3 + sb
    return out


def run(n: int = 1 << 16):
    counts = distributions.ffn1_counts(1 << 18)
    tables = build_tables(counts, TABLE1)
    syms = distributions.ffn1_symbols(n, seed=42)

    # Huffman bit-sequential
    hc = huffman.HuffmanCodec(np.maximum(counts, 1e-9))
    n_h = min(n, 1 << 14)   # python tree walk is slow; subsample + scale
    data_h, nbits = hc.encode(syms[:n_h])
    t_huff = _time(lambda: hc.decode(data_h, nbits, n_h), repeats=1)
    huff_sps = n_h / t_huff

    # QLC python-sequential (single chunk stream)
    chunk = min(1 << 14, n)
    one = syms[:chunk].reshape(1, chunk)
    cap = codec.worst_case_words(chunk, tables.max_code_length)
    words1, _ = codec.encode_chunks(jnp.asarray(one), tables, cap)
    w1 = np.asarray(words1)[0]
    t_seq = _time(lambda: qlc_decode_python(w1, tables, chunk), repeats=1)
    seq_sps = chunk / t_seq

    # QLC chunk-parallel (jitted)
    k = 1024
    chunks = syms.reshape(-1, k)
    capk = codec.worst_case_words(k, tables.max_code_length)
    words, _ = codec.encode_chunks(jnp.asarray(chunks), tables, capk)
    dec = jax.jit(lambda w: codec.decode_chunks(w, tables, k))
    t_par = _time(lambda: jax.block_until_ready(dec(words)))
    par_sps = n / t_par

    return [
        {"name": "decode_huffman_bitseq", "us_per_call": t_huff * 1e6,
         "symbols_per_s": round(huff_sps), "speedup_vs_huffman": 1.0},
        {"name": "decode_qlc_python_seq", "us_per_call": t_seq * 1e6,
         "symbols_per_s": round(seq_sps),
         "speedup_vs_huffman": round(seq_sps / huff_sps, 2)},
        {"name": "decode_qlc_chunk_parallel", "us_per_call": t_par * 1e6,
         "symbols_per_s": round(par_sps),
         "speedup_vs_huffman": round(par_sps / huff_sps, 2)},
    ]
