"""Benchmark: collective-traffic model (paper §1 motivation).

For each tensor-type stream, reports the static wire bytes per symbol of
the compressed-collective format (QLC slot + flags + pool + bf16 scales)
vs the bf16 and raw-e4m3 baselines, and the end-to-end ratio — the
number that scales the roofline collective term — plus the planner's
modeled one-shot vs ring transport times for this stream's wire at its
evaluated size (the measured-throughput crossover study lives in
``benchmarks.transport_overlap``).
"""
from __future__ import annotations

import time

import numpy as np

from repro.comm import (AlphaBetaModel, CommConfig, choose_transport,
                        compress_codes, modeled_oneshot_time,
                        modeled_ring_time, wire_bytes)
from repro.comm.planner import HOP_CHUNK_CANDIDATES
from repro.comm.calibrate import calibrate_for_tensor
from repro.core import distributions
import jax.numpy as jnp


STREAMS = {
    "ffn1_act": distributions.ffn1_symbols,
    "ffn2_act": distributions.ffn2_symbols,
    "grad": distributions.grad_symbols,
}


def run(n: int = 1 << 20):
    rows = []
    for name, gen in STREAMS.items():
        t0 = time.perf_counter()
        syms = gen(n)
        # calibrate on the first half, evaluate wire size on the second
        from repro.quant import e4m3
        vals = e4m3.e4m3_decode(jnp.asarray(syms[: n // 2]))
        tables, plan = calibrate_for_tensor(vals, chunk_symbols=1024)
        cfg = CommConfig.from_plan(plan)
        test = syms[n // 2:]
        m = (len(test) // cfg.chunk_symbols) * cfg.chunk_symbols
        payload = compress_codes(jnp.asarray(test[:m]), tables, cfg)
        scale_bytes = 2 * (m // 32)           # bf16 scale per 32 symbols
        wire = wire_bytes(payload) + scale_bytes
        bf16 = 2 * m
        e4m3_raw = 1 * m + scale_bytes
        dt = (time.perf_counter() - t0) * 1e6

        # Transport model for THIS stream's wire at the evaluated size:
        # each of d=8 peers ships `wire` compressed bytes decoding to
        # 4*m value bytes. Report the BEST ring configuration (min over
        # the hop-chunk candidates choose_transport compares) so the
        # two columns show the margin the planner actually decided on.
        model = AlphaBetaModel()
        one_t = modeled_oneshot_time(model, wire, 4.0 * m, 8)
        tcfg = choose_transport(wire, 4.0 * m, 8, model=model)
        ring_t = min(modeled_ring_time(model, wire, 4.0 * m, 8, h)
                     for h in HOP_CHUNK_CANDIDATES)
        rows.append({
            "name": f"collective_wire_{name}",
            "us_per_call": dt,
            "wire_bytes_per_symbol": round(wire / m, 4),
            "vs_bf16_ratio": round(bf16 / wire, 3),
            "vs_raw_e4m3_ratio": round(e4m3_raw / wire, 3),
            "escapes": int(np.asarray(payload.pool_count).sum()),
            "capacity_bits_per_symbol": round(
                plan.capacity_words * 32 / plan.chunk_symbols, 3),
            "expected_bits_per_symbol": round(
                plan.expected_bits_per_symbol, 3),
            "modeled_oneshot_us": round(one_t * 1e6, 2),
            "modeled_ring_us": round(ring_t * 1e6, 2),
            "chosen_transport": tcfg.kind,
        })
    return rows
