"""Benchmark: per-tensor-type LUTs (paper §7: "multiple LUTs, one for
each tensor type ... can be obtained apriori").

Mixes three tensor-type streams (FFN1-act-like, FFN2-act-like,
grad-like) and compares the average bits/symbol of (a) one global LUT
calibrated on the mixture vs (b) one LUT per type — quantifying what
the paper's multi-LUT deployment buys. Also reports the chunk-escape
effect: per-type calibration shrinks per-chunk variance, so the static
wire slot tightens (the planner effect measured in
tests/test_train_integration's heterogeneous-gradient case).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import adapt, distributions
from repro.core.lut import build_tables


def run(n: int = 1 << 19):
    t0 = time.perf_counter()
    streams = {
        "ffn1_act": distributions.ffn1_symbols(n, seed=11),
        "ffn2_act": distributions.ffn2_symbols(n, seed=12),
        "grad": distributions.grad_symbols(n, seed=13),
    }
    mixture = np.concatenate(list(streams.values()))

    # (a) one global LUT on the mixture
    gcounts = np.maximum(distributions.histogram256(mixture), 1e-6)
    gscheme = adapt.select_scheme(gcounts).scheme
    gtables = build_tables(gcounts, gscheme)
    global_bits = float(
        gtables.enc_len[mixture.astype(np.int64)].mean(dtype=np.float64))

    # (b) one LUT per tensor type (paper §7)
    per_type_bits = {}
    for name, syms in streams.items():
        counts = np.maximum(distributions.histogram256(syms), 1e-6)
        res = adapt.select_scheme(counts)
        tables = build_tables(counts, res.scheme)
        per_type_bits[name] = float(
            tables.enc_len[syms.astype(np.int64)].mean(dtype=np.float64))
    multi_bits = float(np.mean(list(per_type_bits.values())))

    dt = (time.perf_counter() - t0) * 1e6
    return [{
        "name": "multi_lut_vs_global",
        "us_per_call": dt,
        "global_lut_bits": round(global_bits, 4),
        "per_type_lut_bits": round(multi_bits, 4),
        "gain_pct_of_byte": round(100 * (global_bits - multi_bits) / 8, 3),
        **{f"{k}_bits": round(v, 4) for k, v in per_type_bits.items()},
    }]
