"""Benchmark: per-tensor-type LUTs (paper §7: "multiple LUTs, one for
each tensor type ... can be obtained apriori").

Mixes three tensor-type streams (FFN1-act-like, FFN2-act-like,
grad-like) and reports two rows:

* ``multi_lut_vs_global`` — the offline bits/symbol comparison of one
  global LUT calibrated on the mixture vs one LUT per type.

* ``multi_lut_container_wire`` — the same comparison through the REAL
  entry points: per-type registry entries, planner-sized wire slots,
  self-describing containers (``repro.comm.container``), and ONE
  multi-LUT batched decode through the Pallas kernel path
  (``repro.kernels.ops.decode`` with per-group LUT operands). Reports
  actual wire bytes/symbol for both configurations, the per-type /
  global wire ratio (the gated metric: per-type must never lose), and
  the batched decode time.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import adapt, distributions
from repro.core.lut import build_tables
from repro.core.registry import CodecRegistry


def _streams(n: int):
    return {
        "ffn1_act": distributions.ffn1_symbols(n, seed=11),
        "ffn2_act": distributions.ffn2_symbols(n, seed=12),
        "grad": distributions.grad_symbols(n, seed=13),
    }


def _offline_row(streams) -> dict:
    t0 = time.perf_counter()
    mixture = np.concatenate(list(streams.values()))

    # (a) one global LUT on the mixture
    gcounts = np.maximum(distributions.histogram256(mixture), 1e-6)
    gscheme = adapt.select_scheme(gcounts).scheme
    gtables = build_tables(gcounts, gscheme)
    global_bits = float(
        gtables.enc_len[mixture.astype(np.int64)].mean(dtype=np.float64))

    # (b) one LUT per tensor type (paper §7)
    per_type_bits = {}
    for name, syms in streams.items():
        counts = np.maximum(distributions.histogram256(syms), 1e-6)
        res = adapt.select_scheme(counts)
        tables = build_tables(counts, res.scheme)
        per_type_bits[name] = float(
            tables.enc_len[syms.astype(np.int64)].mean(dtype=np.float64))
    multi_bits = float(np.mean(list(per_type_bits.values())))

    dt = (time.perf_counter() - t0) * 1e6
    return {
        "name": "multi_lut_vs_global",
        "us_per_call": dt,
        "global_lut_bits": round(global_bits, 4),
        "per_type_lut_bits": round(multi_bits, 4),
        "gain_pct_of_byte": round(100 * (global_bits - multi_bits) / 8, 3),
        **{f"{k}_bits": round(v, 4) for k, v in per_type_bits.items()},
    }


def _container_row(streams) -> dict:
    """Global vs per-type registry through containers + kernel decode."""
    import jax
    from repro.comm import container as qc

    n_total = sum(s.size for s in streams.values())
    reg = CodecRegistry()
    for name, syms in streams.items():
        reg.register(name, np.bincount(syms, minlength=256),
                     chunk_symbols=1024)
    mixture = np.concatenate(list(streams.values()))
    reg.register("global", np.bincount(mixture, minlength=256),
                 chunk_symbols=1024)

    per_type = [qc.encode_codes(s, reg[name])
                for name, s in streams.items()]
    global_ = [qc.encode_codes(s, reg["global"])
               for s in streams.values()]
    per_type_bytes = sum(qc.container_bytes(b) for b in per_type)
    global_bytes = sum(qc.container_bytes(b) for b in global_)
    stream = qc.pack_stream(per_type)

    # ONE multi-LUT batched kernel decode of the mixed-scheme stream
    def decode():
        outs = qc.decode_codes_stream(stream, reg, use_kernels=True)
        return jax.block_until_ready(outs[-1][0])

    decode()                                   # compile / warm caches
    t0 = time.perf_counter()
    outs = qc.decode_codes_stream(stream, reg, use_kernels=True)
    jax.block_until_ready([o for o, _ in outs])
    dt = (time.perf_counter() - t0) * 1e6

    for (name, syms), (got, ok) in zip(streams.items(), outs):
        assert bool(ok), name
        np.testing.assert_array_equal(np.asarray(got), syms)

    return {
        "name": "multi_lut_container_wire",
        "us_per_call": dt,
        "global_wire_bytes_per_sym": round(global_bytes / n_total, 4),
        "per_type_wire_bytes_per_sym": round(per_type_bytes / n_total, 4),
        "per_type_vs_global_wire_ratio": round(
            per_type_bytes / global_bytes, 4),
        "decode_symbols_per_s": int(n_total / (dt / 1e6)),
        "distinct_schemes": len(
            {reg[n_].scheme_id for n_ in streams}),
    }


def run(n: int = 1 << 19):
    streams = _streams(n)
    return [_offline_row(streams), _container_row(streams)]
