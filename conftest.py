"""Root conftest: make `pytest tests/` work from a clean checkout
(src/ layout + `tests.` package imports) regardless of PYTHONPATH."""
import os
import sys

ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (ROOT, os.path.join(ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
