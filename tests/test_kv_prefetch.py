"""Device-resident async KV paging: device framing parity, prefetch
decode bit-identity, the eviction-under-prefetch race, the jitted
window step's zero-host-transfer contract, and SSM prefix sharing.

The async path's contract is the sync path's, minus the host: a block
framed by ``encode_block_device`` is BIT-identical to the sync
``encode_block_arrays`` container (same digests — that identity is
what lets sync and async engines share one block pool), and every
decode route (device plan decode, prefetch-kernel stream decode) is
bit-identical to ``decode_block_arrays``. Races never return stale
data: an arena slot freed between schedule and consume surfaces a
typed :class:`ArenaStale`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.calibrate import byte_planes, kv_symbol_stream
from repro.configs import get_config, reduced
from repro.core.registry import CodecRegistry
from repro.models import init_decode_states, init_params
from repro.serving import (ArenaStale, BlockArena, KVCacheSpec,
                           PagedKVCache, ServeConfig, calibrate_cache,
                           prefill)
from repro.serving.engine import _generate_scanned, _window_step
from repro.serving.kv_cache import (calibration_arrays,
                                    device_byte_planes,
                                    device_symbol_stream)
from repro.serving.scheduler import Engine, GenerationRequest

KEY = jax.random.PRNGKey(0)
ARCHS = ["phi3-mini-3.8b", "xlstm-125m"]


@pytest.fixture(scope="module", params=ARCHS)
def setup(request):
    cfg = reduced(get_config(request.param), frontend=None,
                  frontend_prefix_len=0)
    params = init_params(cfg, KEY)
    prompts = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    states = init_decode_states(cfg, 2, 64)
    _, states = prefill(params, cfg, prompts, states)
    return cfg, params, jax.block_until_ready(states)


def _cache(cfg, states, use_kernels=False, **spec_kw):
    reg = CodecRegistry()
    spec_kw.setdefault("exact_capacity", False)
    spec = KVCacheSpec(block_tokens=4, mode="qlc",
                       use_kernels=use_kernels, **spec_kw)
    calibrate_cache(reg, cfg, states, 12, spec)
    return PagedKVCache(spec, cfg, reg), reg


def _shared_prefix_prompts(cfg, n=3, length=10, shared=8):
    out = [np.array(jax.random.randint(jax.random.PRNGKey(i), (length,),
                                       0, cfg.vocab_size))
           for i in range(n)]
    for p in out[2:]:
        p[:shared] = out[1][:shared]
    return out


class TestDeviceFraming:
    def test_device_planes_match_host(self, setup):
        """The bitcast byte planes / symbol stream are bit-identical to
        the numpy-view host versions — the precondition for digest
        parity."""
        cfg, _, states = setup
        arrays = calibration_arrays(cfg, states, 4)["l0"]
        host = byte_planes(arrays)
        dev = device_byte_planes(arrays)
        assert set(host) == set(dev)
        for k in host:
            np.testing.assert_array_equal(np.asarray(host[k]),
                                          np.asarray(dev[k]))
        np.testing.assert_array_equal(
            np.asarray(kv_symbol_stream(arrays, "qlc")),
            np.asarray(device_symbol_stream(arrays)))

    def test_device_frame_matches_sync_container(self, setup):
        """Digest parity: the device-framed words equal the sync host
        container byte-for-byte for every layer, and the static-offset
        device decode round-trips exactly."""
        cfg, _, states = setup
        cache, _ = _cache(cfg, states)
        arrays = calibration_arrays(cfg, states, 4)
        for i in range(len(cfg.layer_kinds())):
            key = f"l{i}"
            name = cache.spec.layer_codec(i)
            host = cache.encode_block_arrays(name, key, arrays[key],
                                             start=0, tokens=4)
            dev = cache.encode_block_device(name, key, arrays[key],
                                            start=0, tokens=4)
            assert dev is not None
            np.testing.assert_array_equal(host.container,
                                          np.asarray(dev.words))
            assert dev.coded == host.coded
            decoded, oks = cache.decode_block_device(dev.plan, dev.words)
            for orig, got in zip(arrays[key], decoded):
                assert str(np.asarray(orig).dtype) == str(got.dtype)
                np.testing.assert_array_equal(
                    np.asarray(orig).view(np.uint8),
                    np.asarray(got).view(np.uint8))
            for ok in oks:
                assert bool(ok)

    @pytest.mark.parametrize("use_kernels", [False, True],
                             ids=["pure", "fused"])
    def test_prefetch_decode_bit_identical(self, setup, use_kernels):
        """``decode_block_arrays_async`` (DMA prefetch kernel) equals
        ``decode_block_arrays`` bit-for-bit on the same container, for
        both container decode paths and every layer kind."""
        cfg, _, states = setup
        cache, _ = _cache(cfg, states, use_kernels=use_kernels)
        arrays = calibration_arrays(cfg, states, 4)
        for i in range(len(cfg.layer_kinds())):
            key = f"l{i}"
            block = cache.encode_block_arrays(
                cache.spec.layer_codec(i), key, arrays[key],
                start=0, tokens=4)
            sync = cache.decode_block_arrays(block)
            pref = cache.decode_block_arrays_async(block)
            for a, b in zip(sync, pref):
                np.testing.assert_array_equal(
                    np.asarray(a).view(np.uint8),
                    np.asarray(b).view(np.uint8))

    def test_frame_plan_requires_fixed_geometry(self, setup):
        cfg, _, states = setup
        cache, _ = _cache(cfg, states, exact_capacity=True)
        with pytest.raises(ValueError, match="exact_capacity"):
            cache.frame_plan(cache.spec.layer_codec(0), ((2, 4),),
                             ("float32",))


class TestPrefetchRace:
    def test_eviction_under_prefetch_raises_stale(self, setup):
        """A block evicted from the arena between schedule and consume
        surfaces a typed ``ArenaStale`` — never stale data."""
        cfg, _, states = setup
        cache, _ = _cache(cfg, states)
        arrays = calibration_arrays(cfg, states, 4)["l0"]
        name = cache.spec.layer_codec(0)
        dev = cache.encode_block_device(name, "l0", arrays,
                                        start=0, tokens=4)
        arena = BlockArena(2, int(dev.words.shape[0]))
        cache.arena = arena
        slot, gen = arena.alloc()
        arena.write(slot, dev.words)
        dev.slot, dev.gen = slot, gen
        handle = cache.prefetcher.schedule(dev)
        arena.free(slot)                 # the race: reclaim in between
        with pytest.raises(ArenaStale):
            cache.prefetcher.consume(handle)
        assert arena.stale_reads >= 1

    def test_consume_counts_hit(self, setup):
        cfg, _, states = setup
        cache, _ = _cache(cfg, states)
        arrays = calibration_arrays(cfg, states, 4)["l0"]
        dev = cache.encode_block_device(cache.spec.layer_codec(0), "l0",
                                        arrays, start=0, tokens=4)
        handle = cache.prefetcher.schedule(dev)
        jax.block_until_ready(handle.arrays)
        out = cache.prefetcher.consume(handle)
        assert cache.prefetcher.hits == 1
        assert cache.stats()["prefetch"]["scheduled"] == 1
        for orig, got in zip(arrays, out):
            np.testing.assert_array_equal(
                np.asarray(orig).view(np.uint8),
                np.asarray(got).view(np.uint8))


class TestAsyncEngine:
    def test_async_requires_qlc_fixed_geometry(self, setup):
        cfg, params, _ = setup
        for spec in (None,
                     KVCacheSpec(mode="e4m3", exact_capacity=False),
                     KVCacheSpec(mode="qlc", exact_capacity=True)):
            with pytest.raises(ValueError, match="async"):
                Engine(params, cfg, max_seq_len=64, kv_spec=spec,
                       kv_paging="async")
        with pytest.raises(ValueError, match="kv_paging"):
            Engine(params, cfg, max_seq_len=64, kv_paging="weird")

    def test_token_identity_and_prefix_sharing(self, setup):
        """The async engine is token-identical to the dense oracle AND
        the sync engine over a shared-prefix mix; shared prompt-prefix
        blocks dedup in the pool for BOTH layer architectures (SSM via
        boundary-state re-basing), and the jitted window loop does its
        constant 2-up/1-down host transfers per window."""
        cfg, params, _ = setup
        prompts = _shared_prefix_prompts(cfg)
        new = 10

        oracle = [np.asarray(_generate_scanned(
            params, cfg, jnp.asarray(p[None, :]),
            ServeConfig(max_seq_len=64, max_new_tokens=new)))[0]
            for p in prompts]

        spec = KVCacheSpec(block_tokens=4, mode="qlc",
                           exact_capacity=False)

        def drive(kv_paging):
            eng = Engine(params, cfg, max_seq_len=64, max_batch=4,
                         kv_spec=spec, kv_paging=kv_paging)
            hs = [eng.submit(GenerationRequest(prompt=p,
                                               max_new_tokens=new))
                  for p in prompts]
            eng.run()
            return eng, [eng.poll(h).tokens for h in hs]

        eng_sync, sync_toks = drive("sync")
        eng_async, async_toks = drive("async")
        for o, s, a in zip(oracle, sync_toks, async_toks):
            np.testing.assert_array_equal(o, s)
            np.testing.assert_array_equal(o, a)

        # prefix sharing fires on both paths (SSM layers via re-basing)
        assert eng_sync.stats()["pool"]["dedup_hits"] > 0
        st = eng_async.stats()
        assert st["pool"]["dedup_hits"] > 0
        # window transfer contract + measured prefetch overlap
        assert st["async"]["windows"] >= 1
        assert st["async"]["h2d_per_window"] == 2.0
        assert st["async"]["d2h_per_window"] == 1.0
        pf = st["prefetch"]
        assert pf["scheduled"] > 0
        assert pf["hits"] + pf["stalled"] == pf["scheduled"]
        assert pf["bytes_prefetched"] > 0

    def test_window_step_disallows_host_transfers(self, setup):
        """The probe behind the engine's counters: a whole 8-token
        window dispatches under ``jax.transfer_guard("disallow")`` —
        any per-token host callback or implicit transfer inside the
        scan would raise."""
        cfg, params, _ = setup
        states = init_decode_states(cfg, 2, 64)
        tok = jnp.zeros((2, 1), jnp.int32)
        pos = jnp.zeros((2, 1), jnp.int32)
        wf = _window_step(cfg, 8)
        with jax.transfer_guard("disallow"):
            toks, states = wf(params, tok, pos, states)
        assert np.asarray(toks).shape == (2, 8)
