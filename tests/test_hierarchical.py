"""Multi-host hierarchical transport tests (PR 10).

In-process (single CPU device): the per-link-class cost model
(``LINK_CLASSES``, ``AlphaBetaModel.with_link``/``wire_time(link=)``),
the ``TRANSPORT_KINDS`` validation messages, the ``choose_transport``
pod branch (flat ring is never a candidate over a two-axis group),
pod-binding validation on ``ChannelSpec``/``Channel``, the transport
layer's ``_resolve_pod`` normalization, and the registry's per-axis
link-constant cache (validation + JSON round-trip + the
``Channel._linked_model`` fold).

Multi-device (8 fake CPU devices in a subprocess): the acceptance
invariant — on a simulated 2-pod x 4-local mesh, all four collectives
through a pod-bound Channel are BIT-IDENTICAL across {one-shot over
the combined group, hierarchical, hierarchical with hop chunking}, and
the pod-bound psum matches the uncompressed sum to codec precision.
"""
import dataclasses
import json

import pytest

from repro.comm import (AlphaBetaModel, Channel, ChannelSpec,
                        TransportConfig, HIERARCHICAL, LINK_CLASSES,
                        TRANSPORT_KINDS, choose_transport,
                        modeled_flat_ring_time,
                        modeled_hierarchical_oneshot_time,
                        modeled_hierarchical_time, modeled_ring_time,
                        resolve_transport)
from repro.comm.transport import _resolve_pod
from repro.core import distributions
from repro.core.registry import TRANSPORT_CACHE_KEY, CodecRegistry
from repro.roofline import hw
from tests.md_util import run_md


@pytest.fixture()
def registry():
    reg = CodecRegistry()
    reg.register("grads", distributions.grad_counts(1 << 16))
    return reg


class TestLinkClassModel:
    def test_link_classes_and_defaults(self):
        assert LINK_CLASSES == ("ici", "dcn")
        m = AlphaBetaModel()
        # the DCN tier must default slower on both constants — that
        # asymmetry is the hierarchical schedule's reason to exist
        assert m.link_Bps("dcn") < m.link_Bps("ici")
        assert m.link_alpha("dcn") > m.link_alpha("ici")
        assert m.link_Bps("dcn") == hw.DCN_LINK_BW
        assert m.link_alpha("dcn") == hw.DCN_LATENCY_S

    def test_wire_time_charges_the_named_link(self):
        m = AlphaBetaModel(alpha_s=0.0, wire_Bps=100.0,
                           dcn_alpha_s=0.0, dcn_wire_Bps=10.0)
        assert m.wire_time(100.0) == pytest.approx(1.0)
        assert m.wire_time(100.0, link="dcn") == pytest.approx(10.0)
        with pytest.raises(ValueError, match="link class"):
            m.wire_time(1.0, link="pcie")

    def test_with_link_substitutes_one_class_only(self):
        m = AlphaBetaModel()
        m2 = m.with_link("dcn", wire_Bps=1e9, alpha_s=5e-6)
        assert m2.link_Bps("dcn") == 1e9
        assert m2.link_alpha("dcn") == 5e-6
        assert m2.link_Bps("ici") == m.link_Bps("ici")
        m3 = m.with_link("ici", wire_Bps=7e9)
        assert m3.wire_Bps == 7e9
        assert m3.dcn_wire_Bps == m.dcn_wire_Bps
        assert m.with_link("ici") is m    # no-op stays the same object


class TestTransportKinds:
    def test_kinds_snapshot(self):
        assert TRANSPORT_KINDS == ("oneshot", "ring", "hierarchical")
        assert HIERARCHICAL == TransportConfig("hierarchical")

    def test_bad_kind_message_enumerates_kinds(self):
        with pytest.raises(ValueError) as e:
            TransportConfig(kind="mesh")
        for k in TRANSPORT_KINDS:
            assert repr(k) in str(e.value)

    def test_resolve_transport_strings_and_errors(self):
        assert resolve_transport("hierarchical").kind == "hierarchical"
        with pytest.raises(ValueError) as e:
            resolve_transport("rings")
        for k in TRANSPORT_KINDS:
            assert repr(k) in str(e.value)


class TestHierarchicalModel:
    # hardware-like wire-bound regime: wire terms dominate decode
    WIRE_BOUND = AlphaBetaModel(decode_Bps=1e15, dispatch_s=0.0)

    def test_degenerates_to_flat_ring_at_one_pod(self):
        m = AlphaBetaModel(decode_Bps=1e9)
        for h in (1, 2, 4):
            ring = modeled_ring_time(m, 1e6, 4e6, 8, h)
            assert modeled_hierarchical_time(m, 1e6, 4e6, 8, 1, h) == ring
            assert modeled_flat_ring_time(m, 1e6, 4e6, 8, 1, h) == ring

    def test_wire_bound_hierarchical_beats_flat_ring(self):
        """The headline claim: batching DCN crossings into per-hop-group
        bridges beats gating every neighbor hop at DCN speed. For L=4,
        P=2 the steady-state wire ratio approaches L(P-1)/(LP-1) = 4/7."""
        m = self.WIRE_BOUND
        for L, P in ((4, 2), (8, 2), (4, 4)):
            hier = min(modeled_hierarchical_time(m, 160e6, 256e6, L, P, h)
                       for h in (1, 2, 4, 8))
            flat = min(modeled_flat_ring_time(m, 160e6, 256e6, L, P, h)
                       for h in (1, 2, 4, 8))
            assert hier < flat
        ratio = (modeled_hierarchical_time(m, 160e6, 256e6, 4, 2, 8)
                 / modeled_flat_ring_time(m, 160e6, 256e6, 4, 2, 8))
        assert ratio == pytest.approx(4 / 7, rel=0.05)

    def test_decode_bound_charges_flat_ring_decode_work(self):
        """In a decode-bound regime the topology vanishes: both
        schedules decode d-1 foreign rows (own row hidden in fill), so
        the models must agree — a hierarchical model charging L*P
        decodes would spuriously lose the benchmark gate."""
        m = AlphaBetaModel(decode_Bps=1e8)    # CPU-like, decode-bound
        hier = modeled_hierarchical_time(m, 160e6, 256e6, 4, 2, 8)
        flat = modeled_flat_ring_time(m, 160e6, 256e6, 4, 2, 8)
        assert hier <= flat * (1 + 1e-9)

    def test_never_undercuts_dcn_bridge_floor(self):
        """L*(P-1) shard copies must cross the DCN no matter how well
        the bridges pipeline — same invariant the benchmark gates."""
        for m in (self.WIRE_BOUND, AlphaBetaModel(decode_Bps=1e8)):
            for h in (1, 2, 4, 8):
                t = modeled_hierarchical_time(m, 160e6, 256e6, 4, 2, h)
                floor = 4 * (2 - 1) * 160e6 / m.link_Bps("dcn")
                assert t >= floor

    def test_choose_transport_pod_branch_never_picks_ring(self):
        """Over a two-axis group the flat ring has no executable
        schedule — the planner may only return one-shot or
        hierarchical."""
        for decode_Bps in (1e8, 1e12, 1e15):
            for wire in (1e3, 1e6, 160e6):
                t = choose_transport(wire, wire * 1.6, 4,
                                     model=AlphaBetaModel(
                                         decode_Bps=decode_Bps),
                                     pod_size=2)
                assert t.kind in ("oneshot", "hierarchical")

    def test_choose_transport_pod_branch_picks_hierarchical_when_it_wins(
            self):
        m = AlphaBetaModel(decode_Bps=1e8)    # decode-bound: overlap wins
        t = choose_transport(160e6, 256e6, 4, model=m, pod_size=2)
        assert t.kind == "hierarchical"
        one = modeled_hierarchical_oneshot_time(m, 160e6, 256e6, 4, 2)
        hier = modeled_hierarchical_time(m, 160e6, 256e6, 4, 2,
                                         t.hop_chunks)
        assert hier < one


class TestResolvePod:
    def test_hierarchical_downgrades_to_ring_without_pod(self):
        t, ax, P = _resolve_pod(TransportConfig("hierarchical", 4),
                                None, 1)
        assert (t.kind, t.hop_chunks, ax, P) == ("ring", 4, None, 1)
        t, ax, P = _resolve_pod(TransportConfig("hierarchical"), "pod", 1)
        assert (t.kind, ax, P) == ("ring", None, 1)

    def test_ring_rejected_on_pod_bound_exchange(self):
        with pytest.raises(ValueError, match="one axis"):
            _resolve_pod(TransportConfig("ring"), "pod", 2)

    def test_oneshot_and_hierarchical_keep_the_binding(self):
        for kind in ("oneshot", "hierarchical"):
            t, ax, P = _resolve_pod(TransportConfig(kind), "pod", 2)
            assert (t.kind, ax, P) == (kind, "pod", 2)


class TestChannelPodBinding:
    def _spec(self, **kw):
        return ChannelSpec(codec="grads", transport="hierarchical",
                           axis="data", axis_size=4, **kw)

    def test_pod_bound_channel_constructs(self, registry):
        ch = Channel(self._spec(pod_axis="pod", pod_axis_size=2),
                     registry=registry)
        assert (ch.pod_axis, ch.pod_size, ch.group_size) == ("pod", 2, 8)

    def test_flat_channel_reports_pod_size_one(self, registry):
        ch = Channel(ChannelSpec(codec="grads", transport="ring",
                                 axis="data", axis_size=4),
                     registry=registry)
        assert (ch.pod_axis, ch.pod_size, ch.group_size) == (None, 1, 4)

    def test_pod_axis_must_differ_from_axis(self, registry):
        with pytest.raises(ValueError, match="differ"):
            Channel(self._spec(pod_axis="data", pod_axis_size=2),
                    registry=registry)

    def test_pod_axis_needs_static_size(self, registry):
        with pytest.raises(ValueError, match="pod_axis_size"):
            Channel(self._spec(pod_axis="pod"), registry=registry)
        with pytest.raises(ValueError, match=">= 1"):
            Channel(self._spec(pod_axis="pod", pod_axis_size=0),
                    registry=registry)

    def test_pod_axis_size_without_pod_axis_rejected(self, registry):
        with pytest.raises(ValueError, match="without pod_axis"):
            Channel(ChannelSpec(codec="grads", transport="oneshot",
                                axis="data", axis_size=4,
                                pod_axis_size=2), registry=registry)

    def test_ring_rejected_with_multi_pod_binding(self, registry):
        with pytest.raises(ValueError):
            Channel(ChannelSpec(codec="grads", transport="ring",
                                axis="data", axis_size=4,
                                pod_axis="pod", pod_axis_size=2),
                    registry=registry)

    def test_spec_json_roundtrip_and_legacy_shape(self, registry):
        from repro.comm.channel import spec_from_json, spec_to_json
        spec = self._spec(pod_axis="pod", pod_axis_size=2)
        d = spec_to_json(spec)
        assert (d["pod_axis"], d["pod_axis_size"]) == ("pod", 2)
        back = spec_from_json(d, codec="grads")
        assert (back.pod_axis, back.pod_axis_size) == ("pod", 2)
        # flat specs keep their pre-pod manifest shape byte for byte
        flat = spec_to_json(ChannelSpec(codec="grads", transport="ring",
                                        axis="data", axis_size=4))
        assert "pod_axis" not in flat and "pod_axis_size" not in flat


class TestLinkConstantCache:
    def test_cache_key_snapshot(self):
        assert TRANSPORT_CACHE_KEY == ("scheme_id", "axis",
                                       "payload_bucket", "is_reduce")

    def test_validation(self, registry):
        with pytest.raises(ValueError, match="link class"):
            registry.cache_link_constants("data", "pcie", wire_Bps=1e9)
        with pytest.raises(ValueError, match="positive"):
            registry.cache_link_constants("data", "ici", wire_Bps=0.0)

    def test_json_roundtrip(self, registry):
        registry.cache_link_constants("data", "ici", wire_Bps=9e9)
        registry.cache_link_constants("pod", "dcn", wire_Bps=1.25e9,
                                      alpha_s=2e-5)
        blob = json.dumps(registry.to_json_dict())
        back = CodecRegistry.from_json_dict(json.loads(blob))
        assert back.link_cache() == registry.link_cache()
        assert back.cached_link_constants("pod")["alpha_s"] == 2e-5
        assert back.cached_link_constants("elsewhere") is None

    def test_flat_registry_json_has_no_link_section(self, registry):
        assert "link_cache" not in registry.to_json_dict()

    def test_linked_model_folds_cached_constants(self, registry):
        registry.cache_link_constants("data", "ici", wire_Bps=9e9)
        registry.cache_link_constants("pod", "dcn", wire_Bps=1.25e9,
                                      alpha_s=2e-5)
        ch = Channel(ChannelSpec(codec="grads", transport="hierarchical",
                                 axis="data", axis_size=4,
                                 pod_axis="pod", pod_axis_size=2),
                     registry=registry)
        m = ch._linked_model()
        assert m.link_Bps("ici") == 9e9
        assert m.link_Bps("dcn") == 1.25e9
        assert m.link_alpha("dcn") == 2e-5
        # a flat channel on the same registry only folds its own axis
        flat = Channel(ChannelSpec(codec="grads", transport="auto",
                                   axis="data", axis_size=4),
                       registry=registry)
        fm = flat._linked_model()
        assert fm.link_Bps("ici") == 9e9
        assert fm.link_Bps("dcn") == AlphaBetaModel().link_Bps("dcn")


MD_HIER_EQUIV = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core import TABLE1, build_tables, distributions
from repro.comm import (Channel, ChannelSpec, CommConfig, TransportConfig,
                        plan_for_tables)

devs = jax.devices()
assert len(devs) == 8, devs
mesh = Mesh(np.array(devs).reshape(2, 4), ("pod", "d"))
counts = distributions.ffn1_counts(1 << 16)
tables = build_tables(counts, TABLE1)
plan = plan_for_tables(tables, counts, chunk_symbols=256)
cfg = CommConfig.from_plan(plan)

transports = {
    "oneshot": TransportConfig("oneshot"),
    "hier": TransportConfig("hierarchical"),
    "hier2": TransportConfig("hierarchical", 2),
}
rng = np.random.default_rng(0)
X = rng.standard_normal((8, 4096)).astype(np.float32)
X3 = rng.standard_normal((8, 8, 512)).astype(np.float32)

def run(f, x, three=False):
    inspec = P(("pod", "d"), None, None) if three else P(("pod", "d"), None)
    def g(v):
        out, ok = f(v[0])
        return out[None], ok[None]
    return jax.jit(shard_map(g, mesh=mesh, in_specs=inspec,
                             out_specs=(inspec, P(("pod", "d"))),
                             check_rep=False))(x)

outs = {}
for tname, t in transports.items():
    ch = Channel(ChannelSpec(codec=tables, cfg=cfg, transport=t,
                             axis="d", axis_size=4,
                             pod_axis="pod", pod_axis_size=2))
    cases = [
        ("all_gather", ch.all_gather, X, False),
        ("reduce_scatter",
         lambda v: (lambda r: (r.segment, r.ok))(ch.reduce_scatter(v)),
         X, False),
        ("psum", ch.psum, X, False),
        ("all_to_all", ch.all_to_all, X3, True),
    ]
    for name, chf, x, three in cases:
        o, ok = run(chf, x, three)
        assert np.asarray(ok).all(), (tname, name)
        outs[(tname, name)] = np.asarray(o)
        print(tname, name, "ok")

for name in ("all_gather", "reduce_scatter", "psum", "all_to_all"):
    for tname in ("hier", "hier2"):
        np.testing.assert_array_equal(outs[("oneshot", name)],
                                      outs[(tname, name)])
    print(name, "bit-identical across transports")

# sanity vs uncompressed semantics: psum close to the true sum
true = X.sum(axis=0, keepdims=True).repeat(8, 0)
err = np.abs(outs[("oneshot", "psum")] - true).max() / np.abs(true).max()
assert err < 0.1, err
print("HIER EQUIV OK")
"""


MD_HIER_TRAIN = """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.comm import calibrate_for_gradients
from repro.comm.calibrate import histogram_of_tree
from repro.configs import get_config, reduced
from repro.core import CodecRegistry
from repro.data import DataConfig, SyntheticDataset
from repro.launch.mesh import make_test_mesh
from repro.models import init_params
from repro.parallel import sharding as shd
from repro.training import (OptConfig, TrainConfig,
                            init_compressed_opt_state,
                            make_compressed_step)

cfg = reduced(get_config("gemma-2b-sft"))
mesh = make_test_mesh(pods=2)
assert mesh.axis_names == ("pod", "data", "model"), mesh.axis_names
opt_cfg = OptConfig(lr=3e-4, total_steps=4, warmup_steps=1)
train_cfg = TrainConfig(batch_axes=("pod", "data"))
data = SyntheticDataset(DataConfig(
    vocab_size=cfg.vocab_size, seq_len=128 - cfg.frontend_prefix_len,
    global_batch=8))

with shd.use_mesh(mesh):
    params = init_params(cfg, jax.random.PRNGKey(0))
    b0 = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    tables, plan = calibrate_for_gradients(cfg, params, b0)
    # this reduced model's flat gradient holds only tens of chunks per
    # rank, so the planner's ~1-slot escape pool can overflow on heavy-
    # tailed steps (see tests/test_train_integration.py) — make the
    # wire unconditionally lossless so ok reflects routing, not sizing
    plan = dataclasses.replace(plan, pool_slots_per_1k=1024)
    registry = CodecRegistry()
    registry.register_tables("grads", tables, plan)
    registry.register("params", histogram_of_tree(params),
                      chunk_symbols=plan.chunk_symbols,
                      pool_slots_per_1k=1024)
    step = jax.jit(make_compressed_step(
        cfg, opt_cfg, train_cfg, mesh, registry,
        transport="hierarchical", hierarchical_wire=True))
    opt_state = init_compressed_opt_state(
        cfg, mesh, train_cfg, registry, opt_cfg)
    losses = []
    for i in range(3):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt_state, metrics = step(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        assert bool(metrics["ok"]), metrics
print("losses", losses)
assert losses[-1] < losses[0], losses
print("HIER TRAIN OK")
"""


class TestHierarchicalCollectives:
    def test_bit_identical_to_oneshot_all_collectives(self):
        """Acceptance: on a 2-pod x 4-local mesh all four collectives
        through a pod-bound Channel match the combined-group one-shot
        bit for bit, with and without hop chunking."""
        out = run_md(MD_HIER_EQUIV, timeout=1800)
        assert "HIER EQUIV OK" in out

    def test_training_step_over_pod_mesh(self):
        """The --pods wire end to end: a compressed train step on a
        (2, 2, 2) pod x data x model mesh with hierarchical_wire=True
        runs, keeps comm_ok, and the loss decreases."""
        out = run_md(MD_HIER_TRAIN, timeout=1800)
        assert "HIER TRAIN OK" in out
