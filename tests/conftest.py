"""Shared fixtures. NOTE: no XLA device-count override here — smoke tests
and benches must see the single real CPU device. Multi-device tests spawn
subprocesses that set XLA_FLAGS themselves (see tests/md_util.py)."""
import numpy as np
import pytest

from repro.core import TABLE1, TABLE2, build_tables
from repro.core import distributions


@pytest.fixture(scope="session")
def ffn1_counts():
    return distributions.ffn1_counts(1 << 18, seed=0)


@pytest.fixture(scope="session")
def ffn2_counts():
    return distributions.ffn2_counts(1 << 18, seed=1)


@pytest.fixture(scope="session")
def t1_tables(ffn1_counts):
    return build_tables(ffn1_counts, TABLE1)


@pytest.fixture(scope="session")
def t2_tables(ffn2_counts):
    return build_tables(ffn2_counts, TABLE2)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)
