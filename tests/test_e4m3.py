"""e4m3 quantization substrate tests (paper §3 pipeline)."""
import jax
import jax.numpy as jnp
import numpy as np
from tests._hypothesis_compat import given, settings, st

from repro.quant import e4m3


class TestCodeTable:
    def test_all_256_finite(self):
        table = e4m3.decode_table()
        assert np.isfinite(table).all()          # eXmY all-finite variant
        assert table.max() == 480.0
        assert table.min() == -480.0

    def test_sign_symmetry(self):
        t = e4m3.decode_table()
        np.testing.assert_array_equal(-t[:128], t[128:])

    def test_monotone_magnitudes(self):
        t = e4m3.decode_table()[:128]
        assert (np.diff(t) > 0).all()

    def test_encode_decode_identity_on_grid(self):
        codes = jnp.arange(256, dtype=jnp.uint8)
        vals = e4m3.e4m3_decode(codes)
        back = e4m3.e4m3_encode(vals)
        # -0.0 and +0.0 coincide in value; both map to a zero code
        v2 = e4m3.e4m3_decode(back)
        np.testing.assert_array_equal(np.asarray(v2), np.asarray(vals))

    def test_round_to_nearest_even(self):
        t = e4m3.decode_table()
        # midpoint between code 8 and 9 must round to the even code 8
        mid = (t[8] + t[9]) / 2
        c = int(e4m3.e4m3_encode(jnp.asarray([mid]))[0])
        assert c == 8

    def test_saturation(self):
        c = e4m3.e4m3_encode(jnp.asarray([1e9, -1e9, np.inf]))
        v = np.asarray(e4m3.e4m3_decode(c))
        assert v[0] == 480.0 and v[1] == -480.0 and v[2] == 480.0


class TestBlockScaling:
    @given(seed=st.integers(0, 2**31 - 1),
           scale=st.floats(1e-3, 1e3))
    @settings(max_examples=20, deadline=None)
    def test_quantization_error_bound(self, seed, scale):
        rng = np.random.default_rng(seed)
        x = (rng.standard_normal(256) * scale).astype(np.float32)
        codes, scales = e4m3.quantize_block32(jnp.asarray(x))
        back = np.asarray(e4m3.dequantize_block32(codes, scales))
        # relative error bounded by half a mantissa step (2^-4 at 3 bits)
        err = np.abs(back - x)
        amax = np.abs(x).reshape(-1, 32).max(axis=1)
        bound = np.repeat(amax, 32) * (2 ** -3)  # conservative
        assert (err <= bound + 1e-7).all()

    def test_zero_block(self):
        x = jnp.zeros((64,), jnp.float32)
        codes, scales = e4m3.quantize_block32(x)
        back = e4m3.dequantize_block32(codes, scales)
        np.testing.assert_array_equal(np.asarray(back), np.zeros(64))

    def test_fn_variant_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (128,), jnp.float32)
        codes, scales = e4m3.quantize_block32_fn(x)
        back = np.asarray(e4m3.dequantize_block32_fn(codes, scales))
        assert np.isfinite(back).all()
        err = np.abs(back - np.asarray(x)) / np.maximum(np.abs(x), 1e-3)
        assert np.median(err) < 0.08
