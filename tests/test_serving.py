"""Serving engine: prefill consistency, batched generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import forward, init_decode_states, init_params
from repro.serving import ServeConfig, generate, prefill

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module", params=["phi3-mini-3.8b", "xlstm-125m"])
def setup(request):
    cfg = reduced(get_config(request.param), frontend=None,
                  frontend_prefix_len=0, dtype="float32")
    params = init_params(cfg, KEY)
    return cfg, params


class TestPrefill:
    def test_prefill_matches_forward_last_logits(self, setup):
        cfg, params = setup
        b, s = 2, 12
        tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        states = init_decode_states(cfg, b, 32)
        last, _ = prefill(params, cfg, tokens, states)
        full = forward(params, cfg, tokens)
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)


class TestGenerate:
    def test_shapes_and_determinism(self, setup):
        cfg, params = setup
        sc = ServeConfig(max_seq_len=48, max_new_tokens=8)
        prompts = jax.random.randint(KEY, (3, 10), 0, cfg.vocab_size)
        out1 = generate(params, cfg, prompts, sc)
        out2 = generate(params, cfg, prompts, sc)
        assert out1.shape == (3, 8)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert (np.asarray(out1) < cfg.vocab_size).all()

    def test_greedy_continuation_consistency(self, setup):
        """Generating t tokens then continuing == generating t+k direct.

        Greedy decode is deterministic, so prefill(prompt + first gen
        tokens) must produce the same continuation."""
        cfg, params = setup
        sc_long = ServeConfig(max_seq_len=64, max_new_tokens=6)
        prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        full = np.asarray(generate(params, cfg, prompts, sc_long))
        ext = jnp.concatenate([prompts, jnp.asarray(full[:, :3])], axis=1)
        sc_short = ServeConfig(max_seq_len=64, max_new_tokens=3)
        cont = np.asarray(generate(params, cfg, ext, sc_short))
        np.testing.assert_array_equal(cont, full[:, 3:])
