"""Direct units for ``core/adapt.py`` — scheme selection on dominant-
symbol, uniform, and drifted synthetic histograms (previously only
exercised indirectly through calibration)."""
import numpy as np

from repro.core import adapt
from repro.core.distributions import ffn1_counts, ffn2_counts
from repro.core.schemes import TABLE1, TABLE2


def _dominant_counts(frac: float = 0.4, n: int = 1 << 16) -> np.ndarray:
    counts = np.full(256, (1 - frac) * n / 255.0)
    counts[0] = frac * n
    return counts


class TestHasDominantSymbol:
    def test_dominant_spike_detected(self):
        assert adapt.has_dominant_symbol(_dominant_counts(0.4))

    def test_uniform_has_no_dominant(self):
        assert not adapt.has_dominant_symbol(np.full(256, 100.0))

    def test_threshold_boundary(self):
        # pmf.max() >= threshold is inclusive
        c = _dominant_counts(0.15)
        assert adapt.has_dominant_symbol(c, threshold=0.15)
        assert not adapt.has_dominant_symbol(c, threshold=0.16)

    def test_smooth_gaussian_not_dominant(self):
        assert not adapt.has_dominant_symbol(ffn1_counts(1 << 15, 0))

    def test_zero_spiked_ffn2_dominant(self):
        assert adapt.has_dominant_symbol(ffn2_counts(1 << 15, 0))


class TestDefaultSchemeFor:
    def test_dominant_gets_table2(self):
        assert adapt.default_scheme_for(_dominant_counts()) is TABLE2

    def test_smooth_gets_table1(self):
        assert adapt.default_scheme_for(ffn1_counts(1 << 15, 0)) is TABLE1


class TestSelectScheme:
    def test_dominant_symbol_prefers_table2(self):
        r = adapt.select_scheme(ffn2_counts(1 << 16, 1))
        assert r.scheme_name == "table2"
        assert r.scheme == TABLE2

    def test_smooth_prefers_table1(self):
        r = adapt.select_scheme(ffn1_counts(1 << 16, 1))
        assert r.scheme_name == "table1"
        assert r.scheme == TABLE1

    def test_uniform_no_scheme_beats_entropy(self):
        # Uniform over 256 symbols: entropy 8 bits, nothing compresses.
        r = adapt.select_scheme(np.full(256, 1000.0))
        assert abs(r.entropy_bits - 8.0) < 1e-9
        assert r.expected_bits >= 8.0
        assert r.compressibility <= 0.0
        assert abs(r.ideal_compressibility) < 1e-12

    def test_expected_bits_bounded_by_entropy(self):
        for seed in range(3):
            counts = ffn1_counts(1 << 14, seed)
            r = adapt.select_scheme(counts)
            assert r.expected_bits >= r.entropy_bits - 1e-9
            assert r.compressibility <= r.ideal_compressibility + 1e-9

    def test_drifted_histogram_changes_choice(self):
        # Drift a smooth stream toward a zero spike: the selected
        # scheme flips from Table 1 to Table 2 along the way.
        smooth = adapt.select_scheme(ffn1_counts(1 << 15, 2))
        spiked = ffn1_counts(1 << 15, 2)
        spiked[0] += 0.5 * spiked.sum()
        drifted = adapt.select_scheme(spiked)
        assert smooth.scheme_name == "table1"
        assert drifted.scheme_name == "table2"

    def test_allow_search_never_worse(self):
        for counts in (ffn1_counts(1 << 14, 5), ffn2_counts(1 << 14, 5),
                       _dominant_counts(0.3)):
            base = adapt.select_scheme(counts, allow_search=False)
            searched = adapt.select_scheme(counts, allow_search=True)
            assert searched.expected_bits <= base.expected_bits + 1e-9


class TestCalibrateTables:
    def test_tables_follow_selection(self):
        counts = ffn2_counts(1 << 15, 3)
        t = adapt.calibrate_tables(counts)
        assert t.scheme == adapt.select_scheme(counts).scheme

    def test_explicit_scheme_respected(self):
        t = adapt.calibrate_tables(ffn2_counts(1 << 14, 4), scheme=TABLE1)
        assert t.scheme == TABLE1
