"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch instantiates a REDUCED same-family config (small
width/depth, few experts, tiny vocab) and runs one forward + one train
step on CPU, asserting output shapes and finiteness. Full configs are
exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, reduced, shapes_for
from repro.configs.base import LONG_500K
from repro.models import (decode_step, forward, init_decode_states,
                          init_params, next_token_loss)
from repro.models.multimodal import stub_prefix_embeddings

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, batch=2, seq=32):
    st = seq - cfg.frontend_prefix_len
    tokens = jax.random.randint(KEY, (batch, st), 0, cfg.vocab_size)
    labels = jax.random.randint(KEY, (batch, st), 0, cfg.vocab_size)
    prefix = (stub_prefix_embeddings(KEY, cfg, batch)
              if cfg.frontend else None)
    return tokens, labels, prefix


@pytest.mark.parametrize("arch", ASSIGNED)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = reduced(get_config(arch))
        params = init_params(cfg, KEY)
        tokens, _, prefix = _inputs(cfg)
        logits = forward(params, cfg, tokens, prefix)
        assert logits.shape == (2, 32, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_train_step_reduces_loss(self, arch):
        cfg = reduced(get_config(arch))
        params = init_params(cfg, KEY)
        tokens, labels, prefix = _inputs(cfg)

        loss_fn = lambda p: next_token_loss(p, cfg, tokens, labels, prefix)
        l0, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        assert np.isfinite(float(l0))
        gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                    for g in jax.tree.leaves(grads)) ** 0.5
        assert np.isfinite(gnorm) and gnorm > 0
        # one SGD step on the same batch must reduce the loss
        params2 = jax.tree.map(
            lambda p, g: p - 0.03 * g.astype(p.dtype), params, grads)
        l1 = float(jax.jit(loss_fn)(params2))
        assert l1 < float(l0), (arch, float(l0), l1)

    def test_decode_step(self, arch):
        cfg = reduced(get_config(arch))
        params = init_params(cfg, KEY)
        states = init_decode_states(cfg, batch=2, max_len=64)
        tok = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
        logits, ns = decode_step(params, cfg, tok, states,
                                 jnp.zeros((2, 1), jnp.int32))
        logits2, _ = decode_step(params, cfg, tok, ns,
                                 jnp.ones((2, 1), jnp.int32))
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits2, np.float32)).all()

    def test_decode_consistent_with_forward(self, arch):
        """Greedy decode logits must match teacher-forced forward logits.

        Run in float32: this test validates the decode state machine;
        under bf16 the tiny rounding differences between the batched and
        step-wise paths can flip MoE routing decisions, which is inherent
        numeric noise, not a state bug (verified: f32 agrees to ~5e-6).
        MoE capacity is raised so no tokens drop (forward and decode see
        different token counts, hence different capacities otherwise).
        """
        import dataclasses
        cfg = reduced(get_config(arch), frontend_prefix_len=0, frontend=None,
                      dtype="float32")
        if cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(
                    cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
        params = init_params(cfg, KEY)
        b, s = 2, 8
        tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        full = forward(params, cfg, tokens)          # [B, S, V]

        states = init_decode_states(cfg, batch=b, max_len=16)
        outs = []
        for t in range(s):
            lg, states = decode_step(
                params, cfg, tokens[:, t:t + 1], states,
                jnp.full((b, 1), t, jnp.int32))
            outs.append(lg[:, 0])
        step = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(full, np.float32), np.asarray(step, np.float32),
            rtol=1e-3, atol=1e-3)


class TestShapeAssignments:
    def test_long_context_only_for_subquadratic(self):
        for arch in ASSIGNED:
            cfg = get_config(arch)
            shapes = shapes_for(cfg)
            if cfg.family in ("ssm", "hybrid"):
                assert LONG_500K in shapes, arch
            else:
                assert LONG_500K not in shapes, arch

    def test_cell_count_is_40(self):
        # 10 archs x 4 assigned shapes = 40 cells; 32 runnable + 8
        # documented long-context skips.
        total = sum(4 for _ in ASSIGNED)
        runnable = sum(len(shapes_for(get_config(a))) for a in ASSIGNED)
        assert total == 40
        assert runnable == 32

    def test_param_counts_match_published_sizes(self):
        expect = {
            "deepseek-coder-33b": 33e9,
            "chatglm3-6b": 6e9,
            "nemotron-4-340b": 340e9,
            "phi3-mini-3.8b": 3.8e9,
            "phi-3-vision-4.2b": 3.8e9,   # backbone only (stub frontend)
            "musicgen-medium": 1.5e9,
            "jamba-1.5-large-398b": 398e9,
            "deepseek-moe-16b": 16e9,
            "mixtral-8x22b": 141e9,
            "xlstm-125m": 125e6,
        }
        for arch, n in expect.items():
            got = get_config(arch).param_count()
            assert 0.75 * n <= got <= 1.3 * n, (arch, got, n)

    def test_moe_active_counts(self):
        assert get_config("mixtral-8x22b").active_param_count() < 45e9
        assert get_config("deepseek-moe-16b").active_param_count() < 4e9
        j = get_config("jamba-1.5-large-398b")
        assert 80e9 < j.active_param_count() < 110e9
