"""MoE dispatch parity: gspmd / grouped_local / shardmap_a2a.

The contract under test (ISSUE 8 acceptance):

* routing (expert indices, gates, capacity drops) is bit-identical
  across impls — shardmap_a2a reconstructs gspmd's global cumsum
  positions from an integer counts gather, so this holds exactly even
  on the compressed wire;
* uncompressed shardmap_a2a output is bit-identical to gspmd;
* the compressed wire is bit-identical to its ``enabled=False``
  raw-e4m3 twin (the repo's lossless contract) and within e4m3
  tolerance of gspmd;
* the ring-pipelined a2a transport is bit-identical to one-shot.

Multi-device checks run in a fake-device subprocess (``md_util``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.planner import (AlphaBetaModel, choose_a2a_transport,
                                modeled_a2a_ring_time,
                                modeled_oneshot_time)
from repro.configs import get_config, reduced
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe

from md_util import run_md


def tiny_cfg(**moe_over) -> ModelConfig:
    m = MoEConfig(num_experts=4, top_k=2, d_expert=8,
                  num_shared_experts=1)
    if moe_over:
        m = dataclasses.replace(m, **moe_over)
    return ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                       num_heads=2, num_kv_heads=2, d_ff=32,
                       vocab_size=64, moe=m)


def with_impl(cfg: ModelConfig, impl: str, **moe_over) -> ModelConfig:
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl=impl, **moe_over))


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    return cfg, params, x


class TestRouting:
    def test_unknown_impl_is_typed_error(self, setup):
        cfg, params, x = setup
        with pytest.raises(ValueError, match="supported impls"):
            moe.moe_block(params, x, with_impl(cfg, "bogus"))

    def test_route_returns_probs_matching_logits(self, setup):
        cfg, params, x = setup
        x_flat = x.reshape(-1, cfg.d_model)
        idx, gates, probs = moe._route(params, x_flat, cfg.moe)
        ref = jax.nn.softmax(moe._router_logits(params, x_flat), axis=-1)
        np.testing.assert_array_equal(np.asarray(probs), np.asarray(ref))
        assert idx.shape == (32, 2) and gates.shape == (32, 2)

    def test_aux_loss_from_routing_artifacts(self, setup):
        cfg, params, x = setup
        x_flat = x.reshape(-1, cfg.d_model)
        idx, _gates, probs = moe._route(params, x_flat, cfg.moe)
        aux = moe.aux_load_balance_loss(probs, idx, cfg.moe)
        # reference: Switch-style balance from a fresh einsum
        logits = jnp.einsum("nd,de->ne", x_flat, params["router"])
        ref_probs = jax.nn.softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(idx, 4, dtype=jnp.float32).sum(1)
        ref = 4 * jnp.sum(onehot.mean(0) * ref_probs.mean(0))
        np.testing.assert_allclose(float(aux), float(ref), rtol=1e-6)
        # perfectly uniform routing -> loss ~= top_k
        uni = jnp.full((32, 4), 0.25)
        uidx = jnp.tile(jnp.arange(2), (32, 1))
        np.testing.assert_allclose(
            float(moe.aux_load_balance_loss(uni, uidx, cfg.moe)),
            cfg.moe.top_k, rtol=1e-6)

    def test_gspmd_vs_grouped_local_single_group(self, setup):
        cfg, params, x = setup
        y_g = jax.jit(lambda: moe.moe_block(params, x, cfg))()
        y_1 = jax.jit(lambda: moe.moe_block(
            params, x, with_impl(cfg, "grouped_local",
                                 dispatch_groups=1)))()
        np.testing.assert_array_equal(np.asarray(y_g), np.asarray(y_1))

    def test_dispatch_traffic_shapes(self, setup):
        cfg, params, x = setup
        buf, out_e = moe.dispatch_traffic(params, x, cfg)
        c = moe._capacity(32, cfg.moe)
        assert buf.shape == (4, c, 16) and out_e.shape == (4, c, 16)


class TestShardmapGeometry:
    def test_needs_mesh(self, setup):
        cfg, params, x = setup
        with pytest.raises(ValueError, match="mesh with a 'model' axis"):
            moe.moe_block(params, x, with_impl(cfg, "shardmap_a2a"))

    def test_divisibility_errors(self):
        class M:
            axis_names = ("data", "model")
            shape = {"data": 2, "model": 4}
        with pytest.raises(ValueError, match="divisible"):
            moe.shardmap_a2a_geometry(tiny_cfg(), 33, M())

        class M8:
            axis_names = ("model",)
            shape = {"model": 8}
        with pytest.raises(ValueError, match="num_experts"):
            moe.shardmap_a2a_geometry(tiny_cfg(), 32, M8())

    def test_geometry_row_values(self):
        from jax.sharding import Mesh
        # geometry is mesh-shape math only; fake a 2x4 mesh via a
        # 1-device mesh is impossible, so compute on an abstract stand-in
        class M:
            axis_names = ("data", "model")
            shape = {"data": 2, "model": 4}
        g = moe.shardmap_a2a_geometry(tiny_cfg(), 32, M())
        # ng = 32/(2*4) = 4; C = 32*2*1.25//4 = 20; c_send = min(4,20)=4
        assert g == {"ng": 4, "capacity": 20, "c_send": 4,
                     "row_values": 1 * 4 * 16, "axis_size": 4}


class TestA2ATransportModel:
    def test_degenerate_axis(self):
        m = AlphaBetaModel()
        assert modeled_a2a_ring_time(m, 100, 400, 1) == \
            m.decode_time(400)
        assert choose_a2a_transport(100, 400, 1).kind == "oneshot"

    def test_decode_bound_prefers_ring(self):
        slow = AlphaBetaModel(decode_Bps=1e9)
        t = choose_a2a_transport(1 << 20, 4 << 20, 8, model=slow)
        assert t.kind == "ring"
        ring = modeled_a2a_ring_time(slow, 1 << 20, 4 << 20, 8,
                                     t.hop_chunks)
        one = modeled_oneshot_time(slow, 1 << 20, 4 << 20, 8)
        assert ring < one

    def test_wire_bound_prefers_oneshot(self):
        # the a2a ring's distance-s hops move ~d/2x more link traffic,
        # so a fast decoder must fall back to one-shot
        fast = AlphaBetaModel(decode_Bps=1e13)
        assert choose_a2a_transport(
            1 << 20, 4 << 20, 8, model=fast).kind == "oneshot"

    def test_distance_charging_monotone_in_axis(self):
        m = AlphaBetaModel()
        ts = [modeled_a2a_ring_time(m, 1 << 16, 4 << 16, d)
              for d in (2, 4, 8)]
        assert ts[0] < ts[1] < ts[2]


class TestCalibration:
    def test_calibrate_moe_entries(self):
        from repro.comm import calibrate_moe_entries
        from repro.core.registry import CodecRegistry
        from repro.models import init_params
        cfg = reduced(get_config("deepseek-moe-16b"))
        params = init_params(cfg, jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                 cfg.vocab_size)
        batch = {"tokens": tok, "labels": tok}
        reg = CodecRegistry()
        entries = calibrate_moe_entries(reg, cfg, params, batch,
                                        chunk_symbols=256)
        assert set(entries) == {"moe/dispatch", "moe/combine"}
        for e in entries.values():
            assert 0 < e.plan.expected_bits_per_symbol <= 8.0
        # idempotent: names already registered are kept as-is
        again = calibrate_moe_entries(reg, cfg, params, batch,
                                      chunk_symbols=256)
        assert all(again[n].scheme_id == entries[n].scheme_id
                   for n in entries)


def test_compressed_step_rejects_shardmap_a2a_on_old_jax():
    if hasattr(jax, "shard_map"):
        pytest.skip("new jax: stage 1 nests the expert shard_map fine")
    from jax.sharding import Mesh
    from repro.core.registry import CodecRegistry
    from repro.training import train_step as ts
    cfg = dataclasses.replace(reduced(get_config("deepseek-moe-16b")),
                              moe=dataclasses.replace(
                                  reduced(get_config(
                                      "deepseek-moe-16b")).moe,
                                  impl="shardmap_a2a"))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with pytest.raises(NotImplementedError, match="make_baseline_step"):
        ts.make_compressed_step(cfg, None, ts.TrainConfig(), mesh,
                                CodecRegistry())


MD_PARITY = r"""
import contextlib
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe
from repro.parallel import sharding as shd
from repro.core.registry import CodecRegistry
from repro.comm.channel import Channel, ChannelSpec
from repro.comm.calibrate import histogram_of_quantized

cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                  num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                  moe=MoEConfig(num_experts=4, top_k=2, d_expert=8,
                                num_shared_experts=1))
params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4),
            ("data", "model"))

def with_impl(c, impl, **over):
    return dataclasses.replace(
        c, moe=dataclasses.replace(c.moe, impl=impl, **over))

buf, out_e = moe.dispatch_traffic(params, x, cfg)
reg = CodecRegistry()
reg.register("moe/dispatch",
              np.maximum(histogram_of_quantized(buf), 1e-6),
              chunk_symbols=256)
reg.register("moe/combine",
              np.maximum(histogram_of_quantized(out_e), 1e-6),
              chunk_symbols=256)

def chans(transport, enabled=True):
    out = {}
    for name in (moe.MOE_DISPATCH, moe.MOE_COMBINE):
        ch = Channel(ChannelSpec(codec=name, transport=transport,
                                 axis="model", axis_size=4),
                     registry=reg)
        if not enabled:
            ch = Channel(ChannelSpec(
                codec=name, transport=transport,
                cfg=dataclasses.replace(ch.cfg, enabled=False),
                axis="model", axis_size=4), registry=reg)
        out[name] = ch
    return out

def run(c, channels=None):
    ctx = (moe.bind_moe_channels(channels) if channels
           else contextlib.nullcontext())
    with shd.use_mesh(mesh), ctx:
        return np.asarray(
            jax.jit(lambda p, t: moe.moe_block(p, t, c))(params, x))

# 1) uncompressed parity, shared-experts path included, same mesh
y_g = run(cfg)
y_raw = run(with_impl(cfg, "shardmap_a2a"))
assert (y_raw == y_g).all(), "raw shardmap_a2a != gspmd bitwise"

# 2) capacity-overflow drop determinism (cf=0.25 forces drops)
c_of = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
y_gof = run(c_of)
y_aof = run(with_impl(c_of, "shardmap_a2a"))
assert (y_aof == y_gof).all(), "overflow drops differ"
# the tiny capacity really dropped assignments (outputs change)
assert (y_gof != y_g).any(), "cf=0.25 dropped nothing -- test is vacuous"

# 3) compressed wire: lossless vs its raw-e4m3 twin, ring == oneshot,
#    auto resolves consistently, and e4m3-level closeness to gspmd
c_a = with_impl(cfg, "shardmap_a2a")
y_c1 = run(c_a, chans("oneshot"))
y_off = run(c_a, chans("oneshot", enabled=False))
assert (y_c1 == y_off).all(), "QLC wire != raw-e4m3 twin (lossy!)"
y_cr = run(c_a, chans("ring"))
assert (y_cr == y_c1).all(), "ring a2a != one-shot a2a"
y_auto = run(c_a, chans("auto"))
assert (y_auto == y_c1).all(), "auto transport changed numerics"
rel = np.linalg.norm(y_c1 - y_g) / np.linalg.norm(y_g)
assert rel < 0.15, f"compressed vs gspmd rel l2 {rel}"
assert rel > 0, "compressed output identical to f32 -- not quantizing?"

# 4) grouped_local agrees bitwise at one dispatch group
y_grp = run(with_impl(cfg, "grouped_local", dispatch_groups=1))
assert (y_grp == y_g).all(), "grouped_local(1) != gspmd"

# 5) gradients: raw a2a close to gspmd (backward graphs differ, so
#    allclose not bitwise); compressed grads finite + nonzero through
#    the custom_vjp (raw a2a backward)
def loss(c, channels=None):
    def f(p):
        ctx = (moe.bind_moe_channels(channels) if channels
               else contextlib.nullcontext())
        with ctx:
            return jnp.sum(moe.moe_block(p, x, c) ** 2)
    return f

with shd.use_mesh(mesh):
    g_g = jax.jit(jax.grad(loss(cfg)))(params)
    g_raw = jax.jit(jax.grad(loss(c_a)))(params)
    g_c = jax.jit(jax.grad(loss(c_a, chans("oneshot"))))(params)
flat_g = jax.tree_util.tree_leaves_with_path(g_g)
flat_raw = jax.tree.leaves(g_raw)
assert len(flat_g) == len(flat_raw)
for (path, leaf_g), leaf_raw in zip(flat_g, flat_raw):
    np.testing.assert_allclose(np.asarray(leaf_raw), np.asarray(leaf_g),
                               rtol=1e-5, atol=1e-6,
                               err_msg=jax.tree_util.keystr(path))
for path, v in jax.tree_util.tree_leaves_with_path(g_c):
    assert bool(jnp.isfinite(v).all()), \
        f"nonfinite compressed grad {jax.tree_util.keystr(path)}"
assert any(bool((v != 0).any()) for v in jax.tree.leaves(g_c))
print("MOE_PARITY_OK")
"""


def test_shardmap_a2a_parity_multidevice():
    out = run_md(MD_PARITY, n_devices=8)
    assert "MOE_PARITY_OK" in out
