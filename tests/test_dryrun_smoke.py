"""Launch-layer smoke: lower+compile train/prefill/decode cells for
reduced archs on a small (2,2,2) mesh — in-subprocess miniatures of the
production dry-run (the full 512-device sweep lives in results/)."""

from tests.md_util import run_md

PRELUDE = """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.dryrun import build_lowering
from repro.parallel import sharding as shd
from repro.roofline import hlo_walk

# importing repro.launch.dryrun forces the 512-placeholder-device flag
# (its first two lines, per the dry-run brief); use 8 of them here.
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 2, 2),
            ("pod", "data", "model"))

def lower_cell(arch, kind, comm="baseline", **ov):
    cfg = reduced(get_config(arch), **ov)
    shape = ShapeConfig("smoke_" + kind, 64, 8, kind)
    with shd.use_mesh(mesh):
        jitted, args = build_lowering(cfg, shape, mesh, comm)
        compiled = jitted.lower(*args).compile()
        cost = compiled.cost_analysis()
        walked = hlo_walk.analyze(compiled.as_text())
    assert walked.flops > 0, (arch, kind)
    return walked
"""


class TestDryrunSmoke:
    def test_train_prefill_decode_dense(self):
        run_md(PRELUDE + """
for kind in ("train", "prefill", "decode"):
    w = lower_cell("deepseek-coder-33b", kind)
    print(kind, "flops=%.2e coll=%.2e" % (w.flops, w.coll_total))
print("DENSE OK")
""", n_devices=8, timeout=1500)

    def test_train_moe_and_hybrid(self):
        run_md(PRELUDE + """
lower_cell("mixtral-8x22b", "train")
lower_cell("jamba-1.5-large-398b", "train")
print("MOE/HYBRID OK")
""", n_devices=8, timeout=1500)

    def test_compressed_comm_lowering(self):
        run_md(PRELUDE + """
w = lower_cell("chatglm3-6b", "train", comm="qlc")
assert w.coll_total > 0
print("QLC OK")
""", n_devices=8, timeout=1500)

    def test_padded_heads_lowering(self):
        run_md(PRELUDE + """
# 4 heads forced to pad to 8 => shardable over model axis (2)
w = lower_cell("deepseek-coder-33b", "train", pad_heads_multiple=8)
print("PAD OK")
""", n_devices=8, timeout=1500)
