"""Checkpoint manager: atomicity, integrity, GC, resume pointers."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 8)),
                                    jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(8), jnp.bfloat16)},
        "opt": {"m": jnp.zeros((8, 8)), "step": jnp.int32(seed)},
    }


class TestCheckpointManager:
    def test_save_restore_bit_exact(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        s = _state(1)
        cm.save(10, s, extra={"step": 10})
        restored, extra = cm.restore(s)
        assert extra["step"] == 10
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_pointer(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        for step in (5, 17, 9):
            cm.save(step, _state(step))
        assert cm.latest_step() == 9  # pointer follows save order

    def test_gc_keeps_n(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=2)
        for step in range(5):
            cm.save(step, _state(step))
        assert cm.all_steps() == [3, 4]

    def test_checksum_detects_corruption(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        s = _state(3)
        cm.save(1, s)
        cdir = os.path.join(str(tmp_path), "step_0000000001")
        manifest = json.load(open(os.path.join(cdir, "manifest.json")))
        victim = next(iter(manifest["leaves"].values()))["file"]
        path = os.path.join(cdir, victim)
        arr = np.load(path)
        arr = arr.copy().astype(arr.dtype)
        flat = arr.reshape(-1).copy()
        # numeric leaf: flip a value
        flat[0] = flat[0] + 1 if np.issubdtype(arr.dtype, np.number) else 0
        np.save(path, flat.reshape(arr.shape))
        with pytest.raises(IOError):
            cm.restore(s)

    def test_missing_leaf_detected(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, {"a": jnp.zeros(3)})
        with pytest.raises(KeyError):
            cm.restore({"a": jnp.zeros(3), "b": jnp.zeros(3)})

    def test_shape_mismatch_detected(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, {"a": jnp.zeros(3)})
        with pytest.raises(ValueError):
            cm.restore({"a": jnp.zeros(4)})

    def test_qlc_leaf_roundtrip_and_shrink(self, tmp_path):
        """Byte-width leaves are QLC-compressed on disk, losslessly."""
        from repro.core import distributions
        cm = CheckpointManager(str(tmp_path))
        codes = distributions.ffn1_symbols(1 << 15, seed=3).reshape(128, 256)
        st = {"codes": jnp.asarray(codes, jnp.uint8),
              "w": jnp.asarray(np.ones((8, 8)), jnp.float32)}
        cm.save(1, st)
        cdir = os.path.join(str(tmp_path), "step_0000000001")
        manifest = json.load(open(os.path.join(cdir, "manifest.json")))
        meta = manifest["leaves"]["codes"]
        assert "qlc" in meta                      # stored compressed
        assert "qlc" not in manifest["leaves"]["w"]  # floats stay raw
        stored = os.path.getsize(os.path.join(cdir, meta["file"]))
        assert stored < codes.size                # strictly smaller
        restored, _ = cm.restore(st)
        np.testing.assert_array_equal(
            np.asarray(restored["codes"]), codes)

    def test_qlc_incompressible_leaf_stays_raw(self, tmp_path, rng):
        """Uniform random bytes can't compress — must fall back to raw."""
        cm = CheckpointManager(str(tmp_path))
        hard = rng.integers(0, 256, 1 << 14, dtype=np.uint8)
        cm.save(1, {"hard": jnp.asarray(hard)})
        cdir = os.path.join(str(tmp_path), "step_0000000001")
        manifest = json.load(open(os.path.join(cdir, "manifest.json")))
        assert "qlc" not in manifest["leaves"]["hard"]
        restored, _ = cm.restore({"hard": jnp.asarray(hard)})
        np.testing.assert_array_equal(np.asarray(restored["hard"]), hard)

    def test_qlc_corruption_detected(self, tmp_path):
        """Flipping a stored QLC word must fail the original-bytes
        checksum on restore."""
        from repro.core import distributions
        cm = CheckpointManager(str(tmp_path))
        codes = distributions.ffn1_symbols(1 << 13, seed=5)
        st = {"codes": jnp.asarray(codes, jnp.uint8)}
        cm.save(1, st)
        cdir = os.path.join(str(tmp_path), "step_0000000001")
        manifest = json.load(open(os.path.join(cdir, "manifest.json")))
        meta = manifest["leaves"]["codes"]
        assert "qlc" in meta
        path = os.path.join(cdir, meta["file"])
        arr = np.load(path)
        arr.reshape(-1)[0] ^= np.uint32(0xFFFF)
        np.save(path, arr)
        with pytest.raises(IOError):
            cm.restore(st)

    def test_qlc_opt_out(self, tmp_path):
        from repro.core import distributions
        cm = CheckpointManager(str(tmp_path), qlc_codes=False)
        codes = distributions.ffn1_symbols(1 << 13, seed=5)
        cm.save(1, {"codes": jnp.asarray(codes, jnp.uint8)})
        cdir = os.path.join(str(tmp_path), "step_0000000001")
        manifest = json.load(open(os.path.join(cdir, "manifest.json")))
        assert "qlc" not in manifest["leaves"]["codes"]

    def test_no_partial_checkpoint_on_crash(self, tmp_path):
        """A failed save must not disturb the previous checkpoint."""
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, _state(1))

        class Boom:
            def __array__(self):
                raise RuntimeError("simulated serialization crash")

        with pytest.raises(Exception):
            cm.save(2, {"x": Boom()})
        assert cm.latest_step() == 1
        cm.restore(_state(1))  # still loadable


import jax  # noqa: E402  (used in tree.leaves above)
