"""Data pipeline determinism/host-sharding + optimizer unit tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticDataset
from repro.training import optimizer as opt


class TestSyntheticData:
    def test_deterministic_and_resumable(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        d1 = SyntheticDataset(cfg)
        d2 = SyntheticDataset(cfg)
        b1 = d1.batch_at(7)
        b2 = d2.batch_at(7)   # fresh instance, same step -> same batch
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_host_sharding_partitions_global_batch(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
        hosts = [SyntheticDataset(cfg, host_index=i, host_count=4)
                 for i in range(4)]
        batches = [h.batch_at(3)["tokens"] for h in hosts]
        assert all(b.shape == (2, 8) for b in batches)
        # different hosts -> different data (replaceable, not duplicated)
        assert not np.array_equal(batches[0], batches[1])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
        b = SyntheticDataset(cfg).batch_at(0)
        assert b["tokens"].shape == b["labels"].shape

    def test_learnable_structure(self):
        # motif planting => token t+1 is a function of token t half the
        # time; verify the deterministic map appears frequently.
        cfg = DataConfig(vocab_size=97, seq_len=64, global_batch=8)
        b = SyntheticDataset(cfg).batch_at(0)
        toks = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
        pred = (toks[:, :-1] * 31 + 7) % 97
        frac = (pred == toks[:, 1:]).mean()
        assert frac > 0.2


class TestAdamW:
    def test_matches_reference_adam(self):
        cfg = opt.OptConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                            warmup_steps=0, total_steps=10**9,
                            grad_clip=1e9, min_lr_frac=1.0)
        params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
        g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
        state = opt.init_state(params, cfg)
        p1, s1, _ = opt.apply_update(params, g, state, cfg)
        # hand-computed Adam step 1: m=g*(1-b1)/bc1=g; v=g^2 -> delta=g/|g|
        expect = np.asarray([1.0, -2.0, 3.0]) - 1e-2 * np.sign(
            np.asarray([0.1, 0.2, -0.3]))
        np.testing.assert_allclose(np.asarray(p1["w"]), expect, rtol=1e-4)

    def test_grad_clip(self):
        g = {"w": jnp.asarray([30.0, 40.0])}   # norm 50
        clipped, norm = opt.clip_by_global_norm(g, 5.0)
        assert float(norm) == pytest.approx(50.0)
        got = np.asarray(clipped["w"])
        np.testing.assert_allclose(got, [3.0, 4.0], rtol=1e-5)

    def test_flat_matches_pytree_update(self):
        """ZeRO-1 flat-slice AdamW == pytree AdamW on the same values."""
        cfg = opt.OptConfig(lr=3e-3, warmup_steps=0, grad_clip=1e9,
                            total_steps=10**9, min_lr_frac=1.0)
        rng = np.random.default_rng(0)
        p = jnp.asarray(rng.standard_normal(64), jnp.float32)
        g = jnp.asarray(rng.standard_normal(64), jnp.float32)
        tree_p, tree_s, _ = opt.apply_update(
            {"w": p}, {"w": g}, opt.init_state({"w": p}, cfg), cfg)
        flat_s = opt.init_flat_state(64, cfg)
        gnorm = jnp.sqrt(jnp.sum(g * g))
        flat_p, _, _ = opt.apply_flat_update(p, g, flat_s, cfg, gnorm)
        np.testing.assert_allclose(np.asarray(tree_p["w"]),
                                   np.asarray(flat_p), rtol=1e-6)

    def test_lr_schedule(self):
        cfg = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
        assert float(opt.lr_at(cfg, jnp.int32(0))) == 0.0
        assert float(opt.lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(opt.lr_at(cfg, jnp.int32(110))) == pytest.approx(0.1)
