"""Compressed-collective tests.

Single-device: payload format, escapes, losslessness, wire accounting.
Multi-device (8 fake CPU devices in a subprocess): shard_map collectives —
the central invariant is that QLC compression changes NOTHING numerically
vs the raw-e4m3 wire (coding is lossless), and tracks the bf16 reference
within quantization error.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import TABLE1, build_tables, distributions
from repro.comm import (CommConfig, compress_codes,
                        decompress_codes, plan_for_tables, wire_bytes)
from tests.md_util import run_md


@pytest.fixture(scope="module")
def tables():
    return build_tables(distributions.ffn1_counts(1 << 16), TABLE1)


class TestPayload:
    def test_lossless_easy_and_adversarial(self, tables, rng):
        cfg = CommConfig(chunk_symbols=256, capacity_words=60,
                         pool_slots_per_1k=1024)
        easy = distributions.ffn1_symbols(4096, seed=1)
        hard = rng.integers(0, 256, 4096, dtype=np.uint8)
        for data in (easy, hard):
            p = compress_codes(jnp.asarray(data), tables, cfg)
            out, ok = decompress_codes(p, tables, cfg)
            assert bool(ok)
            np.testing.assert_array_equal(np.asarray(out), data)

    def test_adversarial_data_escapes(self, tables, rng):
        cfg = CommConfig(chunk_symbols=256, capacity_words=60,
                         pool_slots_per_1k=1024)
        hard = rng.integers(0, 256, 4096, dtype=np.uint8)
        p = compress_codes(jnp.asarray(hard), tables, cfg)
        assert int(p.pool_count.sum()) > 0  # uniform bytes can't compress

    def test_pool_overflow_flagged_not_silent(self, tables, rng):
        # Tiny pool + incompressible data => ok=False (caller retries raw).
        cfg = CommConfig(chunk_symbols=256, capacity_words=60,
                         pool_slots_per_1k=1)  # 1 slot for 16 chunks
        hard = rng.integers(0, 256, 4096, dtype=np.uint8)
        p = compress_codes(jnp.asarray(hard), tables, cfg)
        out, ok = decompress_codes(p, tables, cfg)
        assert not bool(ok)

    def test_typical_data_zero_escapes_at_planned_capacity(self, tables):
        counts = distributions.ffn1_counts(1 << 16)
        plan = plan_for_tables(tables, counts, chunk_symbols=1024,
                               target_escape_prob=1e-6)
        cfg = CommConfig.from_plan(plan)
        data = distributions.ffn1_symbols(1 << 16, seed=9)
        p = compress_codes(jnp.asarray(data), tables, cfg)
        assert int(p.pool_count.sum()) == 0
        out, ok = decompress_codes(p, tables, cfg)
        assert bool(ok)
        np.testing.assert_array_equal(np.asarray(out), data)

    def test_wire_smaller_than_raw(self, tables):
        counts = distributions.ffn1_counts(1 << 16)
        plan = plan_for_tables(tables, counts, chunk_symbols=1024)
        cfg = CommConfig.from_plan(plan)
        data = distributions.ffn1_symbols(1 << 16, seed=9)
        p = compress_codes(jnp.asarray(data), tables, cfg)
        raw_bytes = data.size  # 1B/symbol e4m3
        assert wire_bytes(p) < raw_bytes
        # and materially so (>5% saving even with flag/pool overhead)
        assert wire_bytes(p) < 0.95 * raw_bytes

    def test_disabled_is_raw_bitcast(self, tables):
        cfg = CommConfig(enabled=False, chunk_symbols=256)
        data = distributions.ffn1_symbols(2048, seed=2)
        p = compress_codes(jnp.asarray(data), tables, cfg)
        out, ok = decompress_codes(p, tables, cfg)
        assert bool(ok)
        np.testing.assert_array_equal(np.asarray(out), data)
        assert p.words.size * 4 == data.size


MD_PRELUDE = """
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core import TABLE1, build_tables, distributions
from repro.comm import (CommConfig, plan_for_tables, qlc_all_gather,
                        qlc_all_to_all, qlc_psum, qlc_reduce_scatter)
from repro.quant import e4m3

devs = jax.devices()
assert len(devs) == 8, devs
mesh = Mesh(np.array(devs), ("d",))
counts = distributions.ffn1_counts(1 << 16)
tables = build_tables(counts, TABLE1)
plan = plan_for_tables(tables, counts, chunk_symbols=256)
cfg = CommConfig.from_plan(plan)
cfg_kern = CommConfig.from_plan(plan, use_kernels=True)
cfg_raw = CommConfig(enabled=False, chunk_symbols=256)

rng = np.random.default_rng(0)
X = rng.standard_normal((8, 4096)).astype(np.float32)
"""


class TestMultiDevice:
    def test_psum_matches_raw_e4m3_exactly_and_ref_approximately(self):
        run_md(MD_PRELUDE + """
def mk(c):
    def f(x):
        out, ok = qlc_psum(x[0], "d", 8, tables, c)
        return out[None], ok[None]
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                             out_specs=(P("d", None), P("d"))))

out_c, ok_c = mk(cfg)(X)
out_r, ok_r = mk(cfg_raw)(X)
np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_r))
assert np.asarray(ok_c).all()

ref = X.sum(axis=0)
got = np.asarray(out_c)[0]
# two e4m3 quantization stages; bf16 scales => few % relative error
denom = np.maximum(np.abs(ref), 1e-3)
assert np.median(np.abs(got - ref) / denom) < 0.10
print("psum OK")
""")

    def test_all_gather_lossless_vs_local_quantization(self):
        run_md(MD_PRELUDE + """
def f(x):
    out, ok = qlc_all_gather(x[0], "d", tables, cfg)
    return out[None], ok[None]
g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                      out_specs=(P("d", None), P("d"))))
out, ok = g(X)
assert np.asarray(ok).all()
# AG is single-quantization: result must EXACTLY equal local
# quantize-dequantize of each shard (QLC coding adds zero error).
got = np.asarray(out)[0].reshape(8, 4096)
for i in range(8):
    c, s = e4m3.quantize_block32(jnp.asarray(X[i]))
    want = np.asarray(e4m3.dequantize_block32(
        c, s.astype(jnp.bfloat16).astype(jnp.float32)))
    np.testing.assert_array_equal(got[i], want)
print("all_gather OK")
""")

    def test_reduce_scatter_matches_raw_e4m3(self):
        run_md(MD_PRELUDE + """
def mk(c):
    def f(x):
        seg, valid, ok = qlc_reduce_scatter(x[0], "d", 8, tables, c)
        # 8 * 4096 input, segment = 512: every entry is real data
        return seg[None], valid[None], ok[None]
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                             out_specs=(P("d", None), P("d"), P("d"))))
seg_c, valid_c, ok_c = mk(cfg)(X)
np.testing.assert_array_equal(np.asarray(valid_c), 512)
seg_r, _, _ = mk(cfg_raw)(X)
np.testing.assert_array_equal(np.asarray(seg_c), np.asarray(seg_r))
assert np.asarray(ok_c).all()
# vs float reference, within quantization error
full = np.concatenate([np.asarray(seg_c)[i] for i in range(8)])
ref = X.sum(axis=0)
denom = np.maximum(np.abs(ref), 1e-3)
assert np.median(np.abs(full[:4096] - ref) / denom) < 0.10
print("reduce_scatter OK")
""")

    def test_kernel_path_matches_pure_jax_exactly(self):
        """use_kernels=True (fused Pallas pipeline inside shard_map)
        must be bit-identical to the pure-JAX path for every
        collective. pallas_call has no shard_map replication rule, so
        the kernel variant needs check_rep=False."""
        run_md(MD_PRELUDE + """
def mk(c, fn):
    def f(x):
        out, ok = fn(x[0], c)
        return out[None], ok[None]
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                             out_specs=(P("d", None), P("d")),
                             check_rep=False))

for name, fn in [
    ("all_gather", lambda x, c: qlc_all_gather(x, "d", tables, c)),
    ("reduce_scatter",
     lambda x, c: (lambda r: (r.segment, r.ok))(
         qlc_reduce_scatter(x, "d", 8, tables, c))),
    ("psum", lambda x, c: qlc_psum(x, "d", 8, tables, c)),
]:
    o1, ok1 = mk(cfg, fn)(X)
    o2, ok2 = mk(cfg_kern, fn)(X)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert np.asarray(ok1).all() and np.asarray(ok2).all()
    print(name, "kernel==pure OK")
""")

    def test_all_to_all_lossless(self):
        run_md(MD_PRELUDE + """
def f(x):
    out, ok = qlc_all_to_all(x[0], "d", tables, cfg)
    return out[None], ok[None]
g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None, None),
                      out_specs=(P("d", None, None), P("d"))))
X3 = rng.standard_normal((8, 8, 512)).astype(np.float32)
out, ok = g(X3)
assert np.asarray(ok).all()
got = np.asarray(out)
# row j of device i == quantized row i of device j
for i in range(8):
    for j in range(8):
        c, s = e4m3.quantize_block32(jnp.asarray(X3[j, i]))
        want = np.asarray(e4m3.dequantize_block32(
            c, s.astype(jnp.bfloat16).astype(jnp.float32)))
        np.testing.assert_array_equal(got[i, j], want)
print("all_to_all OK")
""")
