"""API-surface snapshots for ``repro.comm`` and ``repro.serving``.

The PR-4 channel redesign collapsed three duplicated resolution
codepaths into ONE seam (`Channel`); this test freezes the packages'
exported names so a surface can only grow (or shrink) through a
deliberate, reviewed edit of the snapshot below — accidental re-export
sprawl fails CI. PR 5 extended the frozen set to ``repro.serving``
when the compressed KV cache landed there.

Deprecated names (the legacy functional wrappers) are tracked in their
own set: they must keep existing until a removal PR deletes them from
both the package and this snapshot together.
"""
import inspect

import repro.comm as comm
import repro.serving as serving

#: The channel-first surface (PR 4).
EXPECTED = {
    # channel API — the binding seam
    "Channel", "ChannelSpec", "open_channels", "measure_decode_Bps",
    "measure_wire_Bps",
    # wire format / local codec machinery
    "CommConfig", "CommPlan", "WirePayload", "ReduceScatterResult",
    "wire_bytes", "pad_to_multiple", "resolve_codec", "plan_for_tables",
    # transport planning (PR 10: per-link-class multi-host model)
    "AlphaBetaModel", "TransportConfig", "ONESHOT", "RING",
    "HIERARCHICAL", "TRANSPORT_KINDS", "LINK_CLASSES",
    "choose_transport", "modeled_oneshot_time", "modeled_ring_time",
    "choose_a2a_transport", "modeled_a2a_ring_time",
    "modeled_hierarchical_time", "modeled_hierarchical_oneshot_time",
    "modeled_flat_ring_time",
    "resolve_transport", "transport_crossover_bytes",
    # container wire (self-describing payloads)
    "ContainerHeader", "parse_header", "pack_stream", "stream_headers",
    "container_encode_values", "container_decode_values",
    "container_encode_codes", "container_decode_codes",
    "decode_values_stream", "decode_codes_stream",
    # calibration
    "calibrate_for_gradients", "calibrate_for_tensor",
    "calibrate_kv_entries", "calibrate_moe_entries", "empirical_plan",
    "histogram_of_quantized", "histogram_of_tree", "kv_symbol_stream",
    # weight wire
    "GroupWireCodec", "compress_groups", "wire_shape_structs",
    # digest-addressed block pool (PR 6: serving engine substrate;
    # PR 7: the device-resident arena under async paging)
    "BlockPool", "PoolExhausted", "container_digest",
    "ArenaExhausted", "ArenaStale", "BlockArena",
    # references
    "ref_all_gather", "ref_psum", "ref_reduce_scatter",
}

#: Legacy functional API: kept for compatibility, warns on use.
DEPRECATED = {
    "qlc_all_gather", "qlc_all_to_all", "qlc_psum", "qlc_reduce_scatter",
    "compress_values", "decompress_values", "compress_codes",
    "decompress_codes", "accumulate_values",
}


#: The serving surface (PR 5: compressed KV-cache paging; PR 6: the
#: request-based continuous-batching engine).
SERVING_EXPECTED = {
    # engine (PR 6 request API)
    "Engine", "GenerationRequest", "RequestStatus",
    "BlockPool", "PoolExhausted",
    "ServeConfig", "prefill",
    # compressed-weight serving + manifest
    "codec_from_manifest", "compress_params_for_serving", "open_params",
    "serving_manifest",
    # paged KV cache
    "KVBlock", "KVCacheOverflowError", "KVCacheSpec", "PagedKVCache",
    "all_gather_block_wire", "calibrate_cache", "kv_cache_manifest",
    "kv_spec_from_manifest", "open_kv_channels",
    # device-resident async paging (PR 7)
    "ArenaExhausted", "ArenaStale", "BlockArena", "BlockPrefetcher",
    "DeviceBlock", "LayerFramePlan", "SSMBoundaryTracker",
}

#: Legacy batch-function serving API: thin Engine wrappers, warn on use.
SERVING_DEPRECATED = {
    "generate", "generate_from_wire", "generate_paged",
}


def _surface(pkg):
    return {n for n in dir(pkg)
            if not n.startswith("_")
            and not inspect.ismodule(getattr(pkg, n))}


def test_comm_surface_is_frozen():
    got = _surface(comm)
    want = EXPECTED | DEPRECATED
    added = sorted(got - want)
    removed = sorted(want - got)
    assert not added and not removed, (
        f"repro.comm surface drifted — added {added}, removed "
        f"{removed}. If intentional, update tests/test_api_surface.py "
        "in the same PR.")


def test_serving_surface_is_frozen():
    got = _surface(serving)
    want = SERVING_EXPECTED | SERVING_DEPRECATED
    added = sorted(got - want)
    removed = sorted(want - got)
    assert not added and not removed, (
        f"repro.serving surface drifted — added {added}, removed "
        f"{removed}. If intentional, update tests/test_api_surface.py "
        "in the same PR.")


def test_deprecated_names_warn():
    """Everything in DEPRECATED must actually be deprecated (so the
    snapshot's removal path stays honest)."""
    import warnings
    import jax.numpy as jnp
    import numpy as np
    from repro.core import TABLE1, build_tables, distributions
    tables = build_tables(distributions.ffn1_counts(1 << 14), TABLE1)
    cfg = comm.CommConfig(chunk_symbols=256, capacity_words=64)
    x = jnp.asarray(np.zeros(256, np.float32))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        payload, scales = comm.compress_values(x, tables, cfg)
        comm.decompress_values(payload, scales, tables, cfg)
        comm.accumulate_values(x, payload, scales, tables, cfg)
        p = comm.compress_codes(x.astype(jnp.uint8), tables, cfg)
        comm.decompress_codes(p, tables, cfg)
    hit = {str(i.message).split(" ", 1)[0] for i in w
           if issubclass(i.category, DeprecationWarning)}
    assert {"compress_values", "decompress_values", "accumulate_values",
            "compress_codes", "decompress_codes"} <= hit
    # the qlc_* wrappers need a mesh; their warning behavior is covered
    # by tests/test_channel.py::TestDeprecationWarnings.
    assert DEPRECATED <= _surface(comm)


def test_serving_deprecated_names_warn_once_per_call_site(monkeypatch):
    """The legacy generate functions warn under the default filter
    exactly ONCE per call site — loud enough to notice in a log, quiet
    enough not to flood a serving loop. The engine body is stubbed out:
    running real JAX between calls re-enters ``warnings.catch_warnings``
    internally, which resets the per-call-site dedup registry and would
    make the count nondeterministic."""
    import warnings
    from repro.serving import engine as engine_mod
    monkeypatch.setattr(engine_mod, "_engine_generate",
                        lambda *a, **k: None)
    params, cfg, prompts = {}, None, None
    scfg = serving.ServeConfig(max_seq_len=8, max_new_tokens=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("default")
        for _ in range(2):   # one call site, two calls -> one warning
            serving.generate(params, cfg, prompts, scfg)
        serving.generate(params, cfg, prompts, scfg)  # second call site
    dep = [i for i in w if issubclass(i.category, DeprecationWarning)
           and "generate" in str(i.message)]
    assert len(dep) == 2, [str(i.message) for i in dep]
    assert all("repro.serving.Engine" in str(i.message) for i in dep)
    assert SERVING_DEPRECATED <= _surface(serving)
