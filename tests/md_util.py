"""Helper to run multi-device (fake-device CPU) checks in a subprocess.

jax fixes the device count at first init, so tests needing N>1 devices
spawn a fresh interpreter with XLA_FLAGS set before importing jax.
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_md(code: str, n_devices: int = 8, timeout: int = 900) -> str:
    """Run ``code`` in a subprocess with ``n_devices`` fake CPU devices.

    The snippet should raise/assert on failure. Returns captured stdout.
    """
    import re as _re
    env = dict(os.environ)
    old = _re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                  env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + old).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"multi-device subprocess failed\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout
