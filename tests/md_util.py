"""Helpers for multi-device subprocess checks and markdown tooling.

jax fixes the device count at first init, so tests needing N>1 devices
spawn a fresh interpreter with XLA_FLAGS set before importing jax
(:func:`run_md`). The markdown helpers back ``tests/test_docs.py``:
the docs/ book's code blocks execute through the same subprocess
harness.
"""
import os
import re
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_md(code: str, n_devices: int = 8, timeout: int = 900) -> str:
    """Run ``code`` in a subprocess with ``n_devices`` fake CPU devices.

    The snippet should raise/assert on failure. Returns captured stdout.
    """
    import re as _re
    env = dict(os.environ)
    old = _re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                  env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + old).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"multi-device subprocess failed\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout


_FENCE_RE = re.compile(r"^```(\w*)\s*$")


def extract_code_blocks(path: str, lang: str = "python"):
    """Fenced ```lang blocks of a markdown file as [(lineno, code)].

    ``lineno`` is the 1-based line of the opening fence — enough to
    point a failure back at the doc. Unterminated fences raise.
    """
    blocks, cur, start = [], None, 0
    with open(path) as f:
        for i, line in enumerate(f, 1):
            m = _FENCE_RE.match(line.strip())
            if cur is None:
                if m and m.group(1) == lang:
                    cur, start = [], i
            elif line.strip() == "```":
                blocks.append((start, "".join(cur)))
                cur = None
            else:
                cur.append(line)
    if cur is not None:
        raise ValueError(f"{path}:{start}: unterminated ``` fence")
    return blocks


_LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")


def heading_anchors(path: str):
    """GitHub-style anchor slugs of a markdown file's headings."""
    anchors = set()
    with open(path) as f:
        in_fence = False
        for line in f:
            if line.strip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = _HEADING_RE.match(line)
            if m:
                text = re.sub(r"[`*_]", "", m.group(1)).strip().lower()
                slug = re.sub(r"[^a-z0-9 -]", "", text)
                anchors.add(re.sub(r" ", "-", slug))
    return anchors


def markdown_links(path: str):
    """Intra-repo links of a markdown file as [(lineno, target)].

    External (``http``/``https``/``mailto``) links are skipped — CI
    must not depend on the network.
    """
    links = []
    with open(path) as f:
        in_fence = False
        for i, line in enumerate(f, 1):
            if line.strip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in _LINK_RE.finditer(line):
                t = m.group(1)
                if t.startswith(("http://", "https://", "mailto:")):
                    continue
                links.append((i, t))
    return links
