"""Channel API tests (the PR-4 unified binding: codec + transport +
mesh axis bound once).

In-process (single CPU device): construction-time validation (ring
without axis_size is a ValueError, not a mid-trace surprise),
immutability, local compress/decompress bit-equality with the legacy
functional API, DeprecationWarning assertions on every legacy wrapper,
"auto" transport resolution + ring hop clamping, and the autotune
cache: Channel.autotune persists a TransportConfig into the registry,
the registry JSON round-trips it, and a reloaded registry's auto
channels reuse it.

Multi-device (8 fake CPU devices in a subprocess): the acceptance
invariant — all four collectives through Channel are BIT-IDENTICAL
(values and ok flags) to the legacy functional calls, across
{pure, fused-kernel} x {oneshot, ring}.
"""
import dataclasses
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.comm import (Channel, ChannelSpec, CommConfig, TransportConfig,
                        open_channels)
from repro.comm.planner import payload_wire_bytes
from repro.core import TABLE1, build_tables, distributions
from repro.core.registry import CodecRegistry
from tests.md_util import run_md


@pytest.fixture(scope="module")
def tables():
    return build_tables(distributions.ffn1_counts(1 << 16), TABLE1)


@pytest.fixture(scope="module")
def cfg():
    return CommConfig(chunk_symbols=256, capacity_words=60,
                      pool_slots_per_1k=8)


@pytest.fixture()
def registry():
    reg = CodecRegistry()
    reg.register("grads", distributions.grad_counts(1 << 16))
    reg.register("params", distributions.ffn1_counts(1 << 16))
    return reg


def _legacy(fn, *args, **kw):
    """Call a deprecated wrapper with its warning silenced."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kw)


class TestConstruction:
    def test_ring_without_axis_size_raises(self, tables, cfg):
        with pytest.raises(ValueError, match="axis_size"):
            Channel(ChannelSpec(codec=tables, cfg=cfg, transport="ring",
                                axis="d"))

    def test_ring_without_axis_raises(self, tables, cfg):
        with pytest.raises(ValueError, match="axis"):
            Channel(ChannelSpec(codec=tables, cfg=cfg, transport="ring"))

    def test_auto_with_axis_needs_size(self, tables, cfg):
        with pytest.raises(ValueError, match="axis_size"):
            Channel(ChannelSpec(codec=tables, cfg=cfg, transport="auto",
                                axis="d"))

    def test_legacy_all_gather_ring_without_axis_size_raises(
            self, tables, cfg):
        """The satellite: the legacy call path must surface the same
        construction-time error instead of silently misbehaving."""
        from repro.comm import qlc_all_gather
        with pytest.raises(ValueError, match="axis_size"):
            _legacy(qlc_all_gather, jnp.zeros(512), "d", tables, cfg,
                    transport="ring")

    def test_bad_transport_kind(self, tables, cfg):
        with pytest.raises(ValueError):
            Channel(ChannelSpec(codec=tables, cfg=cfg,
                                transport="carrier-pigeon"))
        with pytest.raises(TypeError):
            Channel(ChannelSpec(codec=tables, cfg=cfg, transport=3.14))

    def test_bare_tables_need_cfg(self, tables):
        with pytest.raises(TypeError, match="CommConfig"):
            Channel(ChannelSpec(codec=tables))

    def test_named_codec_needs_registry(self):
        with pytest.raises(TypeError, match="registry"):
            Channel(ChannelSpec(codec="grads"))

    def test_registry_entry_and_overrides(self, registry):
        ch = Channel(ChannelSpec(codec="grads", use_kernels=True),
                     registry=registry)
        assert ch.cfg.use_kernels
        assert ch.cfg.chunk_symbols == \
            registry["grads"].plan.chunk_symbols
        assert ch.entry.scheme_id == registry["grads"].scheme_id

    def test_immutable_but_replaceable(self, registry):
        ch = Channel(ChannelSpec(codec="grads"), registry=registry)
        with pytest.raises(AttributeError):
            ch.axis = "d"
        ch2 = ch.replace(axis="d", axis_size=4)
        assert ch2.axis == "d" and ch2.axis_size == 4
        assert ch.axis is None                      # original untouched
        assert ch2.registry is registry

    def test_collectives_require_axis(self, registry):
        ch = Channel(ChannelSpec(codec="grads"), registry=registry)
        with pytest.raises(ValueError, match="axis"):
            ch.all_gather(jnp.zeros(1024))


class TestLocalTransforms:
    def test_compress_matches_legacy(self, tables, cfg, rng):
        from repro.comm import compress_values, decompress_values
        x = jnp.asarray(rng.standard_normal(8 * 256), jnp.float32)
        ch = Channel(ChannelSpec(codec=tables, cfg=cfg))
        p1, s1 = ch.compress(x)
        p2, s2 = _legacy(compress_values, x, tables, cfg)
        for a, b in zip(p1, p2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        v1, ok1 = ch.decompress(p1, s1)
        v2, ok2 = _legacy(decompress_values, p2, s2, tables, cfg)
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        assert bool(ok1) == bool(ok2)

    def test_kernel_toggle_matches(self, tables, cfg, rng):
        x = jnp.asarray(rng.standard_normal(8 * 256), jnp.float32)
        ch = Channel(ChannelSpec(codec=tables, cfg=cfg))
        chk = Channel(ChannelSpec(codec=tables, cfg=cfg,
                                  use_kernels=True))
        assert chk.cfg.use_kernels and not ch.cfg.use_kernels
        (p1, s1), (p2, s2) = ch.compress(x), chk.compress(x)
        for a, b in zip(p1, p2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        v1, _ = ch.decompress(p1, s1)
        v2, _ = chk.decompress(p2, s2)
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))

    def test_codes_roundtrip(self, tables, cfg):
        ch = Channel(ChannelSpec(codec=tables, cfg=cfg))
        codes = jnp.asarray(distributions.ffn1_symbols(4 * 256, seed=3))
        payload = ch.compress_codes(codes)
        out, ok = ch.decompress_codes(payload)
        assert bool(ok)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))

    def test_wire_bytes(self, tables, cfg, rng):
        from repro.comm import wire_bytes
        n = 8 * 256
        x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        ch = Channel(ChannelSpec(codec=tables, cfg=cfg))
        payload, scales = ch.compress(x)
        got = ch.wire_bytes(payload, scales)
        assert got == wire_bytes(payload, scales)
        assert got == ch.modeled_wire_bytes(n)
        assert ch.modeled_wire_bytes(n) == payload_wire_bytes(
            n, cfg.chunk_symbols, cfg.capacity_words,
            cfg.pool_slots_per_1k)


class TestDeprecationWarnings:
    def test_local_transforms_warn(self, tables, cfg, rng):
        from repro.comm import (accumulate_values, compress_codes,
                                compress_values, decompress_codes,
                                decompress_values)
        x = jnp.asarray(rng.standard_normal(2 * 256), jnp.float32)
        with pytest.warns(DeprecationWarning, match="compress_values"):
            payload, scales = compress_values(x, tables, cfg)
        with pytest.warns(DeprecationWarning, match="decompress_values"):
            decompress_values(payload, scales, tables, cfg)
        with pytest.warns(DeprecationWarning, match="accumulate_values"):
            accumulate_values(jnp.zeros_like(x), payload, scales,
                              tables, cfg)
        codes = jnp.asarray(distributions.ffn1_symbols(2 * 256, seed=1))
        with pytest.warns(DeprecationWarning, match="compress_codes"):
            p = compress_codes(codes, tables, cfg)
        with pytest.warns(DeprecationWarning, match="decompress_codes"):
            decompress_codes(p, tables, cfg)

    def test_collectives_warn_and_match_channel(self, tables, cfg, rng):
        """1-device mesh: every qlc_* wrapper warns, and its output is
        bit-identical to the channel method (they share the impl)."""
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.comm import (qlc_all_gather, qlc_all_to_all, qlc_psum,
                                qlc_reduce_scatter)
        mesh = Mesh(np.array(jax.devices()[:1]), ("d",))

        def sm(f):
            return jax.jit(shard_map(f, mesh=mesh, in_specs=P(),
                                     out_specs=(P(), P()),
                                     check_rep=False))

        ch = Channel(ChannelSpec(codec=tables, cfg=cfg, axis="d",
                                 axis_size=1))
        x = jnp.asarray(rng.standard_normal(700), jnp.float32)
        x2 = x.reshape(1, -1)
        cases = [
            ("qlc_all_gather", lambda v: qlc_all_gather(
                v, "d", tables, cfg), lambda v: ch.all_gather(v), x),
            ("qlc_reduce_scatter", lambda v: (lambda r: (r.segment, r.ok))(
                qlc_reduce_scatter(v, "d", 1, tables, cfg)),
             lambda v: (lambda r: (r.segment, r.ok))(
                 ch.reduce_scatter(v)), x),
            ("qlc_psum", lambda v: qlc_psum(v, "d", 1, tables, cfg),
             lambda v: ch.psum(v), x),
            ("qlc_all_to_all", lambda v: qlc_all_to_all(
                v, "d", tables, cfg), lambda v: ch.all_to_all(v), x2),
        ]
        for name, legacy_fn, channel_fn, arg in cases:
            with pytest.warns(DeprecationWarning, match=name):
                got, ok1 = sm(legacy_fn)(arg)
            want, ok2 = sm(channel_fn)(arg)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))
            assert bool(ok1) == bool(ok2)


class TestResolvedTransport:
    def test_default_is_oneshot(self, tables, cfg):
        ch = Channel(ChannelSpec(codec=tables, cfg=cfg, axis="d",
                                 axis_size=8))
        t = ch.resolved_transport(1 << 20)
        assert t.kind == "oneshot"

    def test_ring_hop_clamped_to_tile_payload(self, tables, cfg):
        ch = Channel(ChannelSpec(codec=tables, cfg=cfg,
                                 transport=TransportConfig("ring", 4),
                                 axis="d", axis_size=2))
        # reduce path: 6 chunks per shard -> largest tiler <= 4 is 3;
        # all-gather path: the input IS the per-hop unit (6 chunks).
        t_rs = ch.resolved_transport(2 * 6 * 256, is_reduce=True)
        assert t_rs.hop_chunks == 3
        t_ag = ch.resolved_transport(6 * 256)
        assert t_ag.hop_chunks == 3
        # payload that tiles exactly keeps the requested chunking
        assert ch.resolved_transport(8 * 256).hop_chunks == 4

    def test_auto_small_oneshot_large_ring(self, registry):
        ch = Channel(ChannelSpec(codec="grads", transport="auto",
                                 axis="d", axis_size=8),
                     registry=registry)
        assert ch.resolved_transport(2048).kind == "oneshot"
        assert ch.resolved_transport(1 << 26).kind == "ring"


class TestAutotune:
    def test_autotune_caches_and_registry_roundtrips(self, registry):
        ch = Channel(ChannelSpec(codec="grads", transport="auto",
                                 axis="data", axis_size=8),
                     registry=registry)
        payload_bytes = 1 << 26
        tuned = ch.autotune(payload_bytes, probe_symbols=1 << 13,
                            repeats=1)
        assert isinstance(tuned, Channel)
        assert isinstance(tuned.transport, TransportConfig)
        sid = registry["grads"].scheme_id
        cached = registry.cached_transport(sid, "data", payload_bytes)
        assert cached == tuned.transport
        # same size class reuses the cache; the channel's own "auto"
        # resolution now resolves to the tuned config (modulo the ring
        # hop clamp, inapplicable at this payload size)
        assert ch.resolved_transport(payload_bytes // 4) \
            == dataclasses.replace(tuned.transport)

        # the tuning rides the registry JSON (the satellite's
        # round-trip contract): a RELOADED registry reuses it
        reg2 = CodecRegistry.from_json(registry.to_json())
        assert reg2.cached_transport(sid, "data", payload_bytes) \
            == tuned.transport
        ch2 = Channel(ChannelSpec(codec="grads", transport="auto",
                                  axis="data", axis_size=8),
                      registry=reg2)
        assert ch2.resolved_transport(payload_bytes // 4) \
            == tuned.transport

    def test_cache_key_is_per_axis_and_bucket(self, registry):
        from repro.comm.planner import RING, ONESHOT
        sid = registry["grads"].scheme_id
        registry.cache_transport(sid, "data", 1 << 20, RING)
        registry.cache_transport(sid, "pod", 1 << 20, ONESHOT)
        assert registry.cached_transport(sid, "data", 1 << 20).kind \
            == "ring"
        assert registry.cached_transport(sid, "pod", 1 << 20).kind \
            == "oneshot"
        # a different power-of-two size class misses
        assert registry.cached_transport(sid, "data", 1 << 24) is None
        # within the same bucket (2^19, 2^20] it hits
        assert registry.cached_transport(sid, "data",
                                         (1 << 19) + 1) is not None
        # reduce-scatter tunings live under their own key (the one-shot
        # RS pays per-rank accumulate dispatches the AG does not)
        assert registry.cached_transport(sid, "data", 1 << 20,
                                         is_reduce=True) is None
        registry.cache_transport(sid, "data", 1 << 20, ONESHOT,
                                 is_reduce=True)
        assert registry.cached_transport(
            sid, "data", 1 << 20, is_reduce=True).kind == "oneshot"
        assert registry.cached_transport(sid, "data", 1 << 20).kind \
            == "ring"
        # and the is_reduce flag survives the JSON round trip
        reg2 = CodecRegistry.from_json(registry.to_json())
        assert reg2.cached_transport(sid, "data", 1 << 20,
                                     is_reduce=True).kind == "oneshot"
        assert reg2.cached_transport(sid, "pod", 1 << 20).kind \
            == "oneshot"

    def test_autotune_requires_axis(self, registry):
        ch = Channel(ChannelSpec(codec="grads"), registry=registry)
        with pytest.raises(ValueError):
            ch.autotune(1 << 20)


class TestOpenChannels:
    def test_per_type_channels(self, registry):
        chans = open_channels(registry)
        assert set(chans) == {"grads", "params"}
        assert chans["grads"].entry.scheme_id == \
            registry["grads"].scheme_id

    def test_mesh_fills_axis_size(self, registry):
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        chans = open_channels(registry, mesh, axis="data",
                              transport="auto")
        assert all(c.axis == "data" and c.axis_size == 1
                   for c in chans.values())

    def test_spec_overrides(self, registry):
        chans = open_channels(
            registry, axis=None,
            spec_overrides={
                "grads": {"axis": "d", "axis_size": 4,
                          "transport": "ring"},
                "params": ChannelSpec(codec="params",
                                      use_kernels=True),
            })
        assert chans["grads"].transport.kind == "ring"
        assert chans["grads"].axis_size == 4
        assert chans["params"].cfg.use_kernels
        assert chans["params"].axis is None
        with pytest.raises(TypeError):
            open_channels(registry, spec_overrides={"grads": 42})


class TestServingChannel:
    def test_wire_codec_channel_and_manifest_roundtrip(self, rng):
        """GroupWireCodec.channel() binds the wire placement; the
        serving manifest round-trips transport/axis/kernel toggle."""
        from repro.comm.weights import compress_groups
        from repro.serving import (codec_from_manifest, open_params,
                                   serving_manifest)
        reg = CodecRegistry()
        reg.register("default", distributions.ffn1_counts(1 << 16))
        params = {"ffn": jnp.asarray(
            rng.standard_normal((2, 64, 1024)), jnp.float32)}
        wired, wc = compress_groups(params, reg, use_kernels=True)
        wc.transport = "ring"
        wc.axis = "data"
        m = serving_manifest(wc)
        assert m["channel"] == {"transport": "ring", "axis": "data",
                                "use_kernels": True}
        wc2 = codec_from_manifest(m)
        assert (wc2.transport, wc2.axis, wc2.use_kernels) \
            == ("ring", "data", True)
        # explicit use_kernels arg still overrides the manifest
        assert not codec_from_manifest(m, use_kernels=False).use_kernels
        # manifests predating the channel placement keep the historic
        # fused-kernel default
        legacy_m = {k: v for k, v in m.items() if k != "channel"}
        assert codec_from_manifest(legacy_m).use_kernels
        # an axis-bound channel with no recorded transport defaults to
        # ring, matching open_group_sharded's loose-kwarg default
        wc3 = codec_from_manifest(legacy_m)
        assert wc3.transport is None
        ring_ch = wc3.channel(axis_name="data", axis_size=8)
        assert ring_ch.transport.kind == "ring"
        assert wc3.channel().axis is None     # local stays transportless
        # channel-bound local open == plain open, bit for bit
        ch = wc2.channel(axis_name=None, transport="oneshot")
        ref = open_params(wired, wc)
        via = open_params(wired, wc2, channel=ch.replace(axis=None))
        np.testing.assert_array_equal(np.asarray(via["ffn"]),
                                      np.asarray(ref["ffn"]))


MD_CHANNEL_EQUIV = """
import warnings
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core import TABLE1, build_tables, distributions
from repro.comm import (Channel, ChannelSpec, CommConfig, TransportConfig,
                        plan_for_tables, qlc_all_gather, qlc_all_to_all,
                        qlc_psum, qlc_reduce_scatter)
warnings.simplefilter("ignore", DeprecationWarning)

devs = jax.devices()
assert len(devs) == 8, devs
mesh = Mesh(np.array(devs), ("d",))
counts = distributions.ffn1_counts(1 << 16)
tables = build_tables(counts, TABLE1)
plan = plan_for_tables(tables, counts, chunk_symbols=256)
cfgs = {"pure": CommConfig.from_plan(plan),
        "kern": CommConfig.from_plan(plan, use_kernels=True)}
transports = {"oneshot": None, "ring": TransportConfig("ring", 2)}
rng = np.random.default_rng(0)
X = rng.standard_normal((8, 4096)).astype(np.float32)
X3 = rng.standard_normal((8, 8, 512)).astype(np.float32)

def run(f, x, three=False):
    inspec = P("d", None, None) if three else P("d", None)
    def g(v):
        out, ok = f(v[0])
        return out[None], ok[None]
    return jax.jit(shard_map(g, mesh=mesh, in_specs=inspec,
                             out_specs=(inspec, P("d")),
                             check_rep=False))(x)

for cname, cfg in cfgs.items():
    for tname, t in transports.items():
        ch = Channel(ChannelSpec(codec=tables, cfg=cfg, transport=t,
                                 axis="d", axis_size=8))
        cases = [
            ("all_gather", ch.all_gather,
             lambda v: qlc_all_gather(v, "d", tables, cfg, transport=t,
                                      axis_size=8), X, False),
            ("reduce_scatter",
             lambda v: (lambda r: (r.segment, r.ok))(ch.reduce_scatter(v)),
             lambda v: (lambda r: (r.segment, r.ok))(
                 qlc_reduce_scatter(v, "d", 8, tables, cfg, transport=t)),
             X, False),
            ("psum", ch.psum,
             lambda v: qlc_psum(v, "d", 8, tables, cfg, transport=t),
             X, False),
            ("all_to_all", ch.all_to_all,
             lambda v: qlc_all_to_all(v, "d", tables, cfg, transport=t),
             X3, True),
        ]
        for name, chf, legf, x, three in cases:
            o1, ok1 = run(chf, x, three)
            o2, ok2 = run(legf, x, three)
            assert np.asarray(ok1).all() and np.asarray(ok2).all(), name
            np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
            print(cname, tname, name, "channel==legacy OK")
print("CHANNEL EQUIV OK")
"""


class TestChannelCollectiveEquivalence:
    def test_channel_bit_identical_to_legacy_all_collectives(self):
        """Acceptance: all four collectives through Channel produce
        outputs and ok flags bit-identical to the legacy functional
        API, across {pure, fused} x {oneshot, ring} on 8 devices."""
        out = run_md(MD_CHANNEL_EQUIV, timeout=1800)
        assert "CHANNEL EQUIV OK" in out
