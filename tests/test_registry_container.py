"""Codec registry + self-describing container tests.

Covers the PR-2 subsystem end to end: header encode/parse (incl.
fuzzing through the hypothesis-compat shim), mixed-scheme container
streams decoded with ONLY the registry (no out-of-band CommConfig) on
both the pure-JAX and Pallas/interpret kernel paths, registry
serialization -> reload -> bit-identical decode, multi-LUT batched
decode through the kernel entry points, per-leaf scheme-ids in the
weight-wire manifest, and escape-pool overflow propagating ``ok=False``
through ``decompress_values`` and the ``qlc_*`` collectives.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig, compress_values, decompress_values
from repro.comm import container as qc
from repro.core import CodecRegistry, TABLE1, TABLE2, distributions
from repro.quant import e4m3
from tests._hypothesis_compat import given, settings, st
from tests.md_util import run_md


@pytest.fixture(scope="module")
def registry():
    reg = CodecRegistry()
    reg.register("ffn1_act", distributions.ffn1_counts(1 << 16),
                 scheme=TABLE1, chunk_symbols=512)
    reg.register("ffn2_act", distributions.ffn2_counts(1 << 16),
                 scheme=TABLE2, chunk_symbols=512)
    reg.register("grad", distributions.grad_counts(1 << 16),
                 chunk_symbols=512)
    return reg


class TestRegistry:
    def test_distinct_types_distinct_ids(self, registry):
        ids = {registry[n].scheme_id for n in ("ffn1_act", "ffn2_act")}
        assert len(ids) == 2

    def test_identical_tables_dedupe_to_one_id(self):
        reg = CodecRegistry()
        counts = distributions.ffn1_counts(1 << 16)
        a = reg.register("a", counts, scheme=TABLE1)
        b = reg.register("b", counts, scheme=TABLE1)  # same tables
        assert a.scheme_id == b.scheme_id
        assert len(reg) == 1
        assert reg["b"].tables is a.tables

    def test_lookup_errors_are_informative(self, registry):
        with pytest.raises(KeyError, match="ffn1_act"):
            registry["nope"]
        with pytest.raises(KeyError):
            registry.by_id(999)

    def test_serialization_roundtrip_bit_identical(self, registry):
        reg2 = CodecRegistry.from_json(registry.to_json())
        assert reg2.names() == registry.names()
        for name in registry.names():
            a, b = registry[name], reg2[name]
            assert a.scheme_id == b.scheme_id
            np.testing.assert_array_equal(a.tables.enc_code,
                                          b.tables.enc_code)
            np.testing.assert_array_equal(a.tables.enc_len,
                                          b.tables.enc_len)
            np.testing.assert_array_equal(a.tables.dec_lut,
                                          b.tables.dec_lut)
            assert a.plan == b.plan

    def test_prebuilt_tables_survive_serialization(self, t1_tables):
        """Entries registered from pre-built tables (no histogram, e.g.
        the legacy registry_of wrap) must reload bit-identically: the
        serialized symbol RANKING, not the placeholder histogram, is
        what rebuilds the tables."""
        from repro.core import registry_of
        reg = registry_of(t1_tables)
        reg2 = CodecRegistry.from_json(reg.to_json())
        t = reg2.entries()[0].tables
        np.testing.assert_array_equal(t.dec_lut, t1_tables.dec_lut)
        np.testing.assert_array_equal(t.enc_code, t1_tables.enc_code)
        np.testing.assert_array_equal(t.enc_len, t1_tables.enc_len)

    def test_corrupted_registry_json_detected(self, registry):
        import json
        d = json.loads(registry.to_json())
        o = d["entries"][0]["order"]
        o[0], o[1] = o[1], o[0]                  # tamper with the ranking
        with pytest.raises(ValueError, match="digest"):
            CodecRegistry.from_json_dict(d)

    def test_entry_config_from_plan(self, registry):
        cfg = registry.config_for("ffn1_act", use_kernels=True)
        assert cfg.chunk_symbols == 512
        assert cfg.use_kernels
        assert cfg.capacity_words == registry["ffn1_act"].plan.capacity_words


class TestHeader:
    def _roundtrip(self, h):
        words = qc.pack_header(h)
        # feed a buffer long enough for the declared body
        buf = np.concatenate([words,
                              np.zeros(h.body_words, np.uint32)])
        return qc.parse_header(buf)

    def test_roundtrip_all_fields(self):
        h = qc.ContainerHeader(
            scheme_id=3, coded=True, chunk_symbols=512,
            capacity_words=120, n_chunks=7, pool_slots=2,
            n_valid=3500, scale_dtype="bfloat16",
            n_scales=112, prefix_bits=3)
        assert self._roundtrip(h) == h

    def test_n_valid_64bit_split(self):
        h = qc.ContainerHeader(
            scheme_id=0, coded=True, chunk_symbols=1024,
            capacity_words=1, n_chunks=1 << 26, pool_slots=1,
            n_valid=(1 << 35) + 17, scale_dtype=None,
            n_scales=0, prefix_bits=3)
        w = qc.pack_header(h)
        assert int(w[8]) == ((1 << 35) + 17) & 0xFFFFFFFF
        assert int(w[9]) == ((1 << 35) + 17) >> 32

    @settings(max_examples=30, deadline=None)
    @given(scheme_id=st.integers(0, 0xFFFF),
           coded=st.booleans(),
           log_k=st.integers(2, 10),
           capacity_words=st.integers(1, 512),
           n_chunks=st.integers(0, 2000),
           pool_slots=st.integers(1, 16),
           scale_code=st.integers(0, 2),
           prefix_bits=st.integers(1, 4))
    def test_fuzz_roundtrip(self, scheme_id, coded, log_k, capacity_words,
                            n_chunks, pool_slots, scale_code, prefix_bits):
        k = 1 << log_k
        h = qc.ContainerHeader(
            scheme_id=scheme_id, coded=coded, chunk_symbols=k,
            capacity_words=capacity_words, n_chunks=n_chunks,
            pool_slots=pool_slots, n_valid=n_chunks * k,
            scale_dtype={0: None, 1: "bfloat16", 2: "float32"}[scale_code],
            n_scales=n_chunks * k // 32, prefix_bits=prefix_bits)
        assert self._roundtrip(h) == h

    def test_bad_magic_rejected(self):
        h = qc.ContainerHeader(
            scheme_id=0, coded=True, chunk_symbols=512, capacity_words=1,
            n_chunks=0, pool_slots=1, n_valid=0, scale_dtype=None,
            n_scales=0, prefix_bits=3)
        words = qc.pack_header(h)
        buf = np.concatenate([words, np.zeros(h.body_words, np.uint32)])
        bad = buf.copy()
        bad[0] ^= np.uint32(1)
        with pytest.raises(ValueError, match="magic"):
            qc.parse_header(bad)

    def test_crc_detects_field_corruption(self):
        h = qc.ContainerHeader(
            scheme_id=1, coded=True, chunk_symbols=512, capacity_words=9,
            n_chunks=4, pool_slots=1, n_valid=2048, scale_dtype=None,
            n_scales=0, prefix_bits=3)
        buf = np.concatenate([qc.pack_header(h),
                              np.zeros(h.body_words, np.uint32)])
        for victim in (2, 4, 5, 6, 7, 8):
            bad = buf.copy()
            bad[victim] ^= np.uint32(0x10)
            with pytest.raises(ValueError):
                qc.parse_header(bad)

    def test_truncation_rejected(self):
        h = qc.ContainerHeader(
            scheme_id=1, coded=True, chunk_symbols=512, capacity_words=9,
            n_chunks=4, pool_slots=1, n_valid=2048, scale_dtype=None,
            n_scales=0, prefix_bits=3)
        buf = np.concatenate([qc.pack_header(h),
                              np.zeros(h.body_words, np.uint32)])
        with pytest.raises(ValueError, match="truncated"):
            qc.parse_header(buf[:-1])
        with pytest.raises(ValueError, match="truncated"):
            qc.parse_header(buf[:8])


class TestContainerRoundtrip:
    """The PR acceptance invariant: mixed-scheme payloads round-trip
    bit-exactly from container bytes + registry alone, on both decode
    paths."""

    def _mixed_values(self, rng):
        x1 = rng.standard_normal(5000).astype(np.float32)       # ffn1-ish
        x2 = np.where(rng.random(7100) < 0.5, 0.0,
                      rng.standard_normal(7100)).astype(np.float32)
        return x1, x2

    def _expected_e4m3(self, x, k=512):
        pad = (-len(x)) % k
        xp = jnp.pad(jnp.asarray(x), (0, pad))
        c, s = e4m3.quantize_block32(xp)
        return np.asarray(e4m3.dequantize_block32(
            c, s.astype(jnp.bfloat16).astype(jnp.float32)))[:len(x)]

    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_mixed_scheme_values_stream(self, registry, rng, use_kernels):
        x1, x2 = self._mixed_values(rng)
        stream = qc.pack_stream([
            qc.encode_values(x1, registry["ffn1_act"]),
            qc.encode_values(x2, registry["ffn2_act"]),
        ])
        # decode via a registry reloaded from JSON: nothing rides along
        # except the stream itself
        reg2 = CodecRegistry.from_json(registry.to_json())
        outs = qc.decode_values_stream(stream, reg2,
                                       use_kernels=use_kernels)
        assert [bool(ok) for _, ok in outs] == [True, True]
        np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                      self._expected_e4m3(x1))
        np.testing.assert_array_equal(np.asarray(outs[1][0]),
                                      self._expected_e4m3(x2))

    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_mixed_scheme_codes_stream_batched(self, registry, rng,
                                               use_kernels):
        """Multi-LUT batched decode: every coded section decodes in ONE
        dispatch with per-chunk scheme slots."""
        s1 = distributions.ffn1_symbols(4096, seed=1)
        s2 = distributions.ffn2_symbols(6000, seed=2)
        s3 = distributions.grad_symbols(2048, seed=3)
        stream = qc.pack_stream([
            qc.encode_codes(s1, registry["ffn1_act"]),
            qc.encode_codes(s2, registry["ffn2_act"]),
            qc.encode_codes(s3, registry["grad"]),
        ])
        got = qc.decode_codes_stream(stream, registry,
                                     use_kernels=use_kernels)
        for want, (out, ok) in zip((s1, s2, s3), got):
            assert bool(ok)
            np.testing.assert_array_equal(np.asarray(out), want)

    def test_raw_section_in_stream(self, registry):
        """enabled=False sections (raw e4m3 wire) are self-describing
        too, via the header's coded flag."""
        entry = registry["ffn1_act"]
        syms = distributions.ffn1_symbols(2048, seed=9)
        raw_cfg = entry.config(enabled=False)
        stream = qc.pack_stream([
            qc.encode_codes(syms, entry, cfg=raw_cfg),
            qc.encode_codes(syms, entry),
        ])
        hs = [h for _, h in qc.stream_headers(stream)]
        assert [h.coded for h in hs] == [False, True]
        got = qc.decode_codes_stream(stream, registry)
        for out, ok in got:
            assert bool(ok)
            np.testing.assert_array_equal(np.asarray(out), syms)

    def test_adversarial_escapes_roundtrip(self, registry, rng):
        """Escaped chunks ride the container's pool section."""
        hard = rng.integers(0, 256, 4096, dtype=np.uint8)
        entry = registry["ffn1_act"]
        cfg = entry.config(pool_slots_per_1k=1024)  # room for all
        blob = qc.encode_codes(hard, entry, cfg=cfg)
        out, ok, _ = qc.decode_codes(blob, registry)
        assert bool(ok)
        np.testing.assert_array_equal(np.asarray(out), hard)

    def test_multi_lut_kernel_matches_per_scheme(self, registry):
        """ops.decode with per-group LUT operands == per-scheme calls."""
        from repro.core import codec
        from repro.kernels import ops
        t1 = registry["ffn1_act"].tables
        t2 = registry["ffn2_act"].tables
        k, cap = 256, 70
        a = distributions.ffn1_symbols(8 * k, seed=4).reshape(8, k)
        b = distributions.ffn2_symbols(8 * k, seed=5).reshape(8, k)
        wa, _ = codec.encode_chunks(jnp.asarray(a), t1, cap)
        wb, _ = codec.encode_chunks(jnp.asarray(b), t2, cap)
        words = jnp.concatenate([wa, wb])
        sid = jnp.repeat(jnp.arange(2, dtype=jnp.int32), 8)
        got = ops.decode(words, [t1, t2], k, scheme_ids=sid)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.concatenate([a, b]))
        # interleaved order too — per-chunk, not per-block
        perm = np.random.default_rng(0).permutation(16)
        got_p = ops.decode(words[perm], [t1, t2], k,
                           scheme_ids=sid[perm])
        np.testing.assert_array_equal(
            np.asarray(got_p), np.concatenate([a, b])[perm])


class TestEscapePoolOverflow:
    """Pool exhaustion must flag ok=False — never silently corrupt."""

    def test_decompress_values_flags_overflow(self, rng):
        reg = CodecRegistry()
        entry = reg.register("t", distributions.ffn1_counts(1 << 14),
                             chunk_symbols=256)
        # tiny slots + tiny pool: uniform noise escapes everywhere
        cfg = CommConfig(chunk_symbols=256, capacity_words=60,
                         pool_slots_per_1k=1)
        x = rng.standard_normal(16 * 256).astype(np.float32) * \
            np.exp(rng.standard_normal(16 * 256)).astype(np.float32)
        for use_kernels in (False, True):
            c = dataclasses.replace(cfg, use_kernels=use_kernels)
            payload, scales = compress_values(jnp.asarray(x),
                                              entry.tables, c)
            assert int(payload.pool_count.sum()) > 1
            _, ok = decompress_values(payload, scales, entry.tables, c)
            assert not bool(ok), f"use_kernels={use_kernels}"

    def test_container_reports_overflow(self, rng):
        reg = CodecRegistry()
        entry = reg.register("t", distributions.ffn1_counts(1 << 14),
                             chunk_symbols=256)
        cfg = CommConfig(chunk_symbols=256, capacity_words=60,
                         pool_slots_per_1k=1)
        hard = rng.integers(0, 256, 4096, dtype=np.uint8)
        blob = qc.encode_codes(hard, entry, cfg=cfg)
        _, ok, _ = qc.decode_codes(blob, reg)
        assert not bool(ok)

    def test_collectives_propagate_overflow(self):
        """ok=False must surface through the qlc_* collectives under
        shard_map (the trainer's retry signal)."""
        run_md("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core import TABLE1, build_tables, distributions
from repro.comm import (CommConfig, qlc_all_gather, qlc_psum,
                        qlc_reduce_scatter)

devs = jax.devices()
mesh = Mesh(np.array(devs), ("d",))
tables = build_tables(distributions.ffn1_counts(1 << 16), TABLE1)
# undersized slots + 1-slot pool => guaranteed exhaustion on noise
cfg = CommConfig(chunk_symbols=256, capacity_words=60,
                 pool_slots_per_1k=1)

rng = np.random.default_rng(0)
X = (rng.standard_normal((8, 4096)) *
     np.exp(2 * rng.standard_normal((8, 4096)))).astype(np.float32)

for name, fn in [
    ("all_gather", lambda x: qlc_all_gather(x, "d", tables, cfg)),
    ("reduce_scatter",
     lambda x: (lambda r: (r.segment, r.ok))(
         qlc_reduce_scatter(x, "d", 8, tables, cfg))),
    ("psum", lambda x: qlc_psum(x, "d", 8, tables, cfg)),
]:
    def f(x):
        out, ok = fn(x[0])
        return out[None], ok[None]
    g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                          out_specs=(P("d", None), P("d"))))
    _, ok = g(X)
    assert not np.asarray(ok).any(), name
    print(name, "overflow flagged OK")
print("OVERFLOW OK")
""")


class TestWeightWireManifest:
    def test_per_leaf_scheme_ids_and_manifest_roundtrip(self, rng):
        from repro.comm.weights import compress_groups
        from repro.serving import codec_from_manifest, open_params, \
            serving_manifest
        reg = CodecRegistry()
        reg.register("ffn1", distributions.ffn1_counts(1 << 16))
        reg.register("ffn2", distributions.ffn2_counts(1 << 16))
        w1 = jnp.asarray(rng.standard_normal((2, 512, 256)), jnp.float32)
        w2 = jnp.asarray(
            np.where(rng.random((2, 512, 256)) < 0.6, 0.0,
                     rng.standard_normal((2, 512, 256))), jnp.float32)
        params = {"a": {"ffn1": w1}, "b": {"ffn2": w2}}
        wired, wc = compress_groups(
            params, reg, type_key_fn=lambda path: path.split("/")[-1])
        sids = {k: m.scheme_id for k, m in wc.meta.items()}
        assert sids["a/ffn1"] != sids["b/ffn2"]

        manifest = serving_manifest(wc)
        assert manifest["leaves"]["a/ffn1"]["scheme_id"] == sids["a/ffn1"]

        # rebuild the codec purely from the manifest; decode must be
        # bit-identical on both paths
        for uk in (False, True):
            wc2 = codec_from_manifest(manifest, use_kernels=uk)
            got = open_params(wired, wc2)
            ref = open_params(wired, wc)
            for x, y in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
                np.testing.assert_array_equal(np.asarray(x),
                                              np.asarray(y))

    def test_legacy_tables_still_work(self, rng, t1_tables):
        from repro.comm.weights import compress_groups
        from repro.serving import open_params
        w = jnp.asarray(rng.standard_normal((2, 512, 256)), jnp.float32)
        wired, wc = compress_groups({"w": w}, t1_tables)
        opened = open_params(wired, wc)["w"]
        assert opened.shape == w.shape


class TestCheckpointRegistry:
    def test_legacy_manifest_format_still_restores(self, tmp_path):
        """Checkpoints written by the pre-container release (histogram
        in-line in the manifest, no registry.json) must keep loading."""
        import json, math, os
        from repro.checkpoint import CheckpointManager
        from repro.checkpoint.manager import QLC_CHUNK, _checksum
        from repro.core import TABLE1, build_tables
        from repro.kernels import ops as kops

        syms = distributions.ffn1_symbols(1 << 14, seed=7)
        counts = np.bincount(syms, minlength=256)
        tables = build_tables(counts.astype(np.float64), TABLE1)
        n_chunks = -(-syms.size // QLC_CHUNK)
        padded = np.zeros(n_chunks * QLC_CHUNK, np.uint8)
        padded[:syms.size] = syms
        lens = tables.enc_len[padded]
        cap = max(1, math.ceil(
            int(lens.reshape(n_chunks, QLC_CHUNK).sum(axis=1).max()) / 32))
        words, _ = kops.encode(
            jnp.asarray(padded.reshape(n_chunks, QLC_CHUNK)), tables, cap)

        cdir = os.path.join(str(tmp_path), "step_0000000001")
        os.makedirs(cdir)
        np.save(os.path.join(cdir, "leaf.npy"), np.asarray(words))
        manifest = {"step": 1, "extra": {}, "leaves": {"codes": {
            "file": "leaf.npy", "shape": [syms.size], "dtype": "uint8",
            "sum": _checksum(syms),
            "qlc": {"counts": counts.tolist(), "n": int(syms.size),
                    "chunk": QLC_CHUNK, "capacity_words": int(cap)},
        }}}
        with open(os.path.join(cdir, "manifest.json"), "w") as f:
            json.dump(manifest, f)

        cm = CheckpointManager(str(tmp_path))
        restored, _ = cm.restore({"codes": jnp.zeros(syms.size, jnp.uint8)},
                                 step=1)
        np.testing.assert_array_equal(np.asarray(restored["codes"]), syms)

    def test_incompressible_leaf_not_registered(self, tmp_path, rng):
        """Raw-fallback leaves must not pollute registry.json."""
        import json, os
        from repro.checkpoint import CheckpointManager
        cm = CheckpointManager(str(tmp_path))
        st_ = {
            "good": jnp.asarray(
                distributions.ffn1_symbols(1 << 14, seed=1), jnp.uint8),
            "hard": jnp.asarray(
                rng.integers(0, 256, 1 << 14, dtype=np.uint8)),
        }
        cm.save(1, st_)
        cdir = os.path.join(str(tmp_path), "step_0000000001")
        manifest = json.load(open(os.path.join(cdir, "manifest.json")))
        assert "qlc" in manifest["leaves"]["good"]
        assert "qlc" not in manifest["leaves"]["hard"]
        reg = json.load(open(os.path.join(cdir, "registry.json")))
        names = {e["name"] for e in reg["entries"]}
        for e in reg["entries"]:
            names |= set(e.get("aliases", []))
        assert "good" in names and "hard" not in names

    def test_registry_file_and_scheme_ids(self, tmp_path):
        import json, os
        from repro.checkpoint import CheckpointManager
        cm = CheckpointManager(str(tmp_path))
        st_ = {
            "ffn1": jnp.asarray(
                distributions.ffn1_symbols(1 << 14, seed=1), jnp.uint8),
            "ffn2": jnp.asarray(
                distributions.ffn2_symbols(1 << 14, seed=2), jnp.uint8),
        }
        cm.save(1, st_)
        cdir = os.path.join(str(tmp_path), "step_0000000001")
        assert os.path.exists(os.path.join(cdir, "registry.json"))
        manifest = json.load(open(os.path.join(cdir, "manifest.json")))
        metas = manifest["leaves"]
        assert "scheme_id" in metas["ffn1"]["qlc"]
        restored, _ = cm.restore(st_)
        for k in st_:
            np.testing.assert_array_equal(np.asarray(restored[k]),
                                          np.asarray(st_[k]))


import jax  # noqa: E402  (jax.tree used above)
