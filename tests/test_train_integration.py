"""Training integration tests.

Multi-device (8 fake CPU devices): the compressed train step (QLC e4m3
gradient RS/AG + ZeRO-1) must track the baseline GSPMD step — same loss
trajectory within quantization error — and loss must decrease. Also:
checkpoint save/restore resume bit-exactness and elastic resharding.
"""


from tests.md_util import run_md


MD_TRAIN = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduced
from repro.core import TABLE1, build_tables, distributions
from repro.comm import CommConfig, calibrate_for_gradients, plan_for_tables
from repro.data import DataConfig, SyntheticDataset
from repro.models import init_params
from repro.parallel import sharding as shd
from repro.training import (OptConfig, TrainConfig, init_compressed_opt_state,
                            make_baseline_step, make_compressed_step)
from repro.training import optimizer as optm

mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
            ("pod", "data", "model"))
cfg = reduced(get_config("deepseek-coder-33b"), d_model=64, num_layers=2)
opt_cfg = OptConfig(lr=1e-2, warmup_steps=2, total_steps=50, grad_clip=1.0)
train_cfg = TrainConfig(microbatches=2)
data = SyntheticDataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                   global_batch=8, seed=3))

with shd.use_mesh(mesh):
    params = init_params(cfg, jax.random.PRNGKey(0))

# paper §7 workflow: calibrate the LUT on this tensor type apriori
_b0 = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
tables, plan = calibrate_for_gradients(cfg, params, _b0, chunk_symbols=256)
comm_cfg = CommConfig.from_plan(plan)
"""


class TestCompressedVsBaseline:
    def test_loss_trajectories_match(self):
        out = run_md(MD_TRAIN + """
import dataclasses
from repro.training.train_step import _manual_param_specs

# Total escape pool: this reduced model's flat gradient holds only tens
# of chunks per rank, so the planner's ~1-slot pool can overflow on
# heavy-tailed steps. The step's ok now reflects EVERY rank (a real
# overflow means retry, not a silently corrupt trajectory), so make the
# wire unconditionally lossless here.
comm_cfg = dataclasses.replace(comm_cfg, pool_slots_per_1k=1024)

base_step = jax.jit(make_baseline_step(cfg, opt_cfg, train_cfg))
comp_step = jax.jit(make_compressed_step(cfg, opt_cfg, train_cfg, mesh,
                                         tables, comm_cfg))

with shd.use_mesh(mesh):
    opt0 = optm.init_state(params, opt_cfg)
    copt0 = init_compressed_opt_state(cfg, mesh, train_cfg, comm_cfg,
                                      opt_cfg)
    pb, ob = params, opt0
    pc, oc = params, copt0
    lb, lc = [], []
    for step in range(8):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        pb, ob, mb = base_step(pb, ob, batch)
        pc, oc, mc = comp_step(pc, oc, batch)
        assert bool(np.asarray(mc["ok"])), step
        lb.append(float(np.asarray(mb["loss"])))
        lc.append(float(np.asarray(mc["loss"])))

print("baseline:", ["%.4f" % x for x in lb])
print("compressed:", ["%.4f" % x for x in lc])
# both learn
assert lb[-1] < lb[0] - 0.1
assert lc[-1] < lc[0] - 0.1
# trajectories close (e4m3 grad quantization error only)
diffs = [abs(a - b) for a, b in zip(lb, lc)]
assert max(diffs) < 0.15, diffs
print("TRAIN OK")
""", n_devices=8, timeout=1800)
        assert "TRAIN OK" in out

    def test_compressed_matches_raw_e4m3_wire_exactly(self):
        """QLC coding is lossless: compressed wire == raw-e4m3 wire,
        parameter-for-parameter, bit-for-bit."""
        out = run_md(MD_TRAIN + """
import dataclasses
# total escape pool: every chunk may escape, so the compressed wire is
# unconditionally lossless regardless of per-rank gradient statistics
full_cfg = dataclasses.replace(comm_cfg, pool_slots_per_1k=1024)
comp_step = jax.jit(make_compressed_step(cfg, opt_cfg, train_cfg, mesh,
                                         tables, full_cfg))
raw_cfg = dataclasses.replace(full_cfg, enabled=False)
raw_step = jax.jit(make_compressed_step(cfg, opt_cfg, train_cfg, mesh,
                                        tables, raw_cfg))
with shd.use_mesh(mesh):
    copt0 = init_compressed_opt_state(cfg, mesh, train_cfg, full_cfg,
                                      opt_cfg)
    pc, oc = params, copt0
    pr, orr = params, copt0
    for step in range(3):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        pc, oc, mc = comp_step(pc, oc, batch)
        pr, orr, mr = raw_step(pr, orr, batch)
        assert bool(np.asarray(mc["ok"])) and bool(np.asarray(mr["ok"]))
    for a, b in zip(jax.tree.leaves(pc), jax.tree.leaves(pr)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("LOSSLESS OK")
""", n_devices=8, timeout=1800)
        assert "LOSSLESS OK" in out


class TestCheckpointResume:
    def test_bit_exact_resume(self, tmp_path):
        out = run_md(MD_TRAIN + f"""
from repro.training import Trainer, TrainerConfig
from repro.training import optimizer as om

step_fn = jax.jit(make_baseline_step(cfg, opt_cfg, train_cfg))
ckdir = {str(tmp_path)!r}

with shd.use_mesh(mesh):
    opt0 = om.init_state(params, opt_cfg)
    # run 6 steps straight
    t1 = Trainer(TrainerConfig(total_steps=6, checkpoint_dir=ckdir + "/a",
                               checkpoint_every=3), step_fn)
    pa, oa = t1.run(params, opt0, data)

    # run 3 steps, "crash", resume from checkpoint, run 3 more
    t2 = Trainer(TrainerConfig(total_steps=3, checkpoint_dir=ckdir + "/b",
                               checkpoint_every=3), step_fn)
    pb1, ob1 = t2.run(params, opt0, data)
    del pb1, ob1
    t3 = Trainer(TrainerConfig(total_steps=6, checkpoint_dir=ckdir + "/b",
                               checkpoint_every=3), step_fn)
    p_res, o_res, start = t3.restore_or(params, opt0)
    assert start == 3, start
    pb, ob = t3.run(p_res, o_res, data, start_step=start)

for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("RESUME OK")
""", n_devices=8, timeout=1800)
        assert "RESUME OK" in out

    def test_elastic_reshard_on_load(self, tmp_path):
        """Save under a (2,2,2) mesh, restore under (1,4,2) — elastic
        pod-count change — and keep training."""
        out = run_md(MD_TRAIN + f"""
from repro.checkpoint import CheckpointManager
from repro.models import param_specs
from repro.training import optimizer as om

ckdir = {str(tmp_path)!r}
step_fn = jax.jit(make_baseline_step(cfg, opt_cfg, train_cfg))
with shd.use_mesh(mesh):
    opt0 = om.init_state(params, opt_cfg)
    batch = {{k: jnp.asarray(v) for k, v in data.batch_at(0).items()}}
    p1, o1, _ = step_fn(params, opt0, batch)
cm = CheckpointManager(ckdir)
cm.save(1, (p1, o1), extra={{"step": 1}})

mesh2 = Mesh(np.array(jax.devices()).reshape(1, 4, 2),
             ("pod", "data", "model"))
with shd.use_mesh(mesh2):
    (p2, o2), extra = cm.restore((p1, o1))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    batch = {{k: jnp.asarray(v) for k, v in data.batch_at(1).items()}}
    p3, o3, m = step_fn(p2, o2, batch)
    assert np.isfinite(float(np.asarray(m["loss"])))
print("ELASTIC OK")
""", n_devices=8, timeout=1800)
        assert "ELASTIC OK" in out
