"""Wire planner + calibration unit tests."""
import numpy as np
import pytest

from repro.comm.planner import (effective_compression_ratio,
                                hoeffding_margin_bits, plan_for_tables)
from repro.comm.calibrate import calibrate_for_tensor
from repro.core import TABLE1, build_tables, distributions



@pytest.fixture(scope="module")
def tables():
    return build_tables(distributions.ffn1_counts(1 << 18), TABLE1)


class TestHoeffding:
    def test_margin_shrinks_with_chunk_size(self):
        m256 = hoeffding_margin_bits(256, 1e-6)
        m1024 = hoeffding_margin_bits(1024, 1e-6)
        m4096 = hoeffding_margin_bits(4096, 1e-6)
        assert m256 > m1024 > m4096 > 0
        assert m1024 == pytest.approx(m256 / 2)

    def test_margin_grows_with_confidence(self):
        assert (hoeffding_margin_bits(1024, 1e-9)
                > hoeffding_margin_bits(1024, 1e-3))


class TestPlan:
    def test_capacity_between_mean_and_raw(self, tables):
        counts = distributions.ffn1_counts(1 << 18)
        plan = plan_for_tables(tables, counts, chunk_symbols=1024)
        bits = plan.capacity_words * 32 / 1024
        assert plan.expected_bits_per_symbol < bits <= 8.0 + 32 / 1024

    def test_capacity_factor_override(self, tables):
        counts = distributions.ffn1_counts(1 << 18)
        plan = plan_for_tables(tables, counts, chunk_symbols=1024,
                               capacity_factor=0.875)
        assert plan.capacity_words == int(np.ceil(0.875 * 8 * 1024 / 32))

    def test_effective_ratio_vs_bf16(self, tables):
        counts = distributions.ffn1_counts(1 << 18)
        plan = plan_for_tables(tables, counts, chunk_symbols=1024)
        r = effective_compression_ratio(plan)
        assert 1.5 < r < 2.5   # ~2x vs bf16 incl. scale/flag overhead

    def test_pool_slots_scale(self, tables):
        counts = distributions.ffn1_counts(1 << 18)
        plan = plan_for_tables(tables, counts)
        assert plan.pool_slots(1024) >= plan.pool_slots_per_1k
        assert plan.pool_slots(1) >= 1


class TestEmpiricalCalibration:
    def test_quantile_capacity_covers_chunks(self):
        import jax
        x = jax.random.normal(jax.random.PRNGKey(0), (1 << 18,))
        tables, plan = calibrate_for_tensor(x, chunk_symbols=1024)
        # encode the SAME data: escapes must be at/below the bound
        from repro.quant import e4m3
        codes, _ = e4m3.quantize_block32(x.reshape(-1))
        lens = tables.enc_len[np.asarray(codes)].astype(np.int64)
        nch = len(lens) // 1024
        sums = lens[:nch * 1024].reshape(nch, 1024).sum(1)
        esc_rate = (sums > plan.capacity_words * 32).mean()
        assert esc_rate <= max(plan.escape_prob_bound, 1e-3) + 2 / nch

    def test_returns_valid_tables(self):
        import jax
        x = jax.random.normal(jax.random.PRNGKey(1), (1 << 16,))
        tables, plan = calibrate_for_tensor(x)
        assert tables.enc_len.min() >= 4
        assert tables.enc_len.max() <= 11
        assert plan.chunk_symbols == 1024
