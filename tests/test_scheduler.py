"""Continuous-batching engine + shared block pool (PR 6).

The load-bearing claims, each pinned here:

* engine output is TOKEN-IDENTICAL to running every request alone
  through the scan oracle — continuous batching (join/leave mid-flight,
  queueing past ``max_batch``) must be a pure scheduling change;
* identical prompt prefixes dedup compressed blocks by container
  digest (prefix sharing), diverge copy-on-write, and the deduped
  bytes sit on the decode hot path (outputs stay exact);
* pool pressure degrades gracefully (LRU reclaim of zero-ref cache,
  spill to host) and exhaustion is a TYPED per-request rejection, never
  a crash of the neighbours;
* per-tenant fairness caps produce a deterministic admission trace.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.blockpool import BlockPool, PoolExhausted, container_digest
from repro.configs import get_config, reduced
from repro.core.registry import CodecRegistry
from repro.serving import Engine, GenerationRequest
from repro.serving.engine import ServeConfig, _generate_scanned
from repro.serving.kv_cache import KVCacheSpec

KEY = jax.random.PRNGKey(0)


def _model(arch):
    cfg = reduced(get_config(arch), frontend=None, frontend_prefix_len=0,
                  dtype="float32")
    return cfg, init_params_cached(arch, cfg)


_PARAMS = {}


def init_params_cached(arch, cfg):
    if arch not in _PARAMS:
        from repro.models import init_params
        _PARAMS[arch] = init_params(cfg, KEY)
    return _PARAMS[arch]


@pytest.fixture(scope="module", params=["phi3-mini-3.8b", "xlstm-125m"])
def setup(request):
    return _model(request.param)


@pytest.fixture(scope="module")
def phi3():
    return _model("phi3-mini-3.8b")


def _oracle(params, cfg, prompt, max_new):
    out = _generate_scanned(
        params, cfg, jnp.asarray(np.asarray(prompt, np.int32))[None],
        ServeConfig(max_seq_len=32, max_new_tokens=max_new))
    return list(np.asarray(out)[0])


def _prompts(cfg, lengths, seed=0, shared_prefix=0):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, shared_prefix)
    return [np.concatenate(
        [pre, rng.integers(0, cfg.vocab_size, n - shared_prefix)])
        .astype(np.int32) for n in lengths]


# ---------------------------------------------------------------------------
# BlockPool unit behavior (no model, duck-typed blocks)
# ---------------------------------------------------------------------------

class _FakeBlock:
    def __init__(self, words, layer="l0", start=0):
        self.container = np.asarray(words, np.uint32)
        self.layer, self.start, self.tokens = layer, start, 4
        self.shapes, self.dtypes = ((4,),), ("f4",)

    @property
    def wire_bytes(self):
        return 4 * self.container.size


class TestBlockPool:
    def test_dedup_refcount_and_release(self):
        pool = BlockPool(1 << 20)
        a = _FakeBlock([1, 2, 3])
        d1 = pool.put(a)
        d2 = pool.put(_FakeBlock([1, 2, 3]))      # bit-identical -> dedup
        assert d1 == d2 and pool.refs(d1) == 2
        assert pool.stats()["dedup_hits"] == 1
        assert pool.stats()["logical_bytes"] == 2 * a.wire_bytes
        assert pool.stats()["resident_bytes"] == a.wire_bytes
        pool.release(d1)
        pool.release(d1)
        # zero-ref entries stay cached for later prefix hits ...
        assert d1 in pool and pool.refs(d1) == 0
        assert pool.stats()["referenced_bytes"] == 0
        # ... and revive on the next identical put
        assert pool.put(_FakeBlock([1, 2, 3])) == d1
        assert pool.refs(d1) == 1
        pool.release(d1)
        with pytest.raises(ValueError):
            pool.release(d1)            # double-release is a bug

    def test_geometry_salts_the_digest(self):
        pool = BlockPool(1 << 20)
        d1 = pool.put(_FakeBlock([7, 7], layer="l0", start=0))
        d2 = pool.put(_FakeBlock([7, 7], layer="l1", start=0))
        d3 = pool.put(_FakeBlock([7, 7], layer="l0", start=4))
        assert len({d1, d2, d3}) == 3
        assert container_digest([7, 7]) != container_digest([7, 8])

    def test_lru_reclaims_zero_ref_before_spilling(self):
        blk = _FakeBlock([0] * 25)                # 100 bytes each
        pool = BlockPool(250)
        d1 = pool.put(_FakeBlock([1] * 25))
        d2 = pool.put(_FakeBlock([2] * 25))
        pool.release(d1)                          # zero-ref cache
        pool.put(blk)                             # needs room: d1 drops
        st = pool.stats()
        assert d1 not in pool and d2 in pool
        assert st["reclaims"] == 1 and st["spills"] == 0
        # now only referenced entries remain: next put spills LRU (d2)
        pool.put(_FakeBlock([3] * 25))
        st = pool.stats()
        assert st["spills"] == 1 and st["host_bytes"] == 100
        # touching the spilled digest promotes it back (displacing the
        # LRU resident entry to host in its place) and counts the fetch
        pool.get(d2)
        st = pool.stats()
        assert st["host_fetches"] == 1 and st["spills"] == 2
        assert st["resident_bytes"] <= pool.capacity_bytes

    def test_exhaustion_is_typed(self):
        pool = BlockPool(250, spill_host=False)
        pool.put(_FakeBlock([1] * 25))
        pool.put(_FakeBlock([2] * 25))
        with pytest.raises(PoolExhausted):
            pool.put(_FakeBlock([3] * 25))        # all 200 bytes pinned
        with pytest.raises(PoolExhausted):
            BlockPool(50).put(_FakeBlock([1] * 25))   # single block > cap
        with pytest.raises(PoolExhausted):
            pool.check_admission(200)
        pool.check_admission(10)                  # fits next to pinned
        BlockPool(250).check_admission(10 ** 9)   # spill_host: no-op


# ---------------------------------------------------------------------------
# Engine == per-sequence oracle (the API-redesign contract)
# ---------------------------------------------------------------------------

class TestEngineOracle:
    def test_continuous_batching_token_identical(self, setup):
        """More requests than slots, mixed prompt/budget lengths: every
        request's tokens match running it ALONE through the oracle."""
        cfg, params = setup
        prompts = _prompts(cfg, [12, 9, 5, 7], seed=3)
        budgets = [4, 6, 3, 5]
        eng = Engine(params, cfg, max_seq_len=32, max_batch=2)
        hs = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=m))
              for p, m in zip(prompts, budgets)]
        eng.run()
        for h, p, m in zip(hs, prompts, budgets):
            st = eng.poll(h)
            assert st.state == "finished"
            assert list(st.tokens) == _oracle(params, cfg, p, m), h
        assert eng.stats()["requests"]["finished"] == 4

    def test_compressed_paging_token_identical(self, setup):
        """Blocks round-trip through the codec + shared pool on the
        decode path and the outputs stay exact."""
        cfg, params = setup
        prompts = _prompts(cfg, [12, 10], seed=5)
        eng = Engine(params, cfg, max_seq_len=32, max_batch=2,
                     kv_spec=KVCacheSpec(block_tokens=4, hot_blocks=1),
                     registry=CodecRegistry())
        hs = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=6))
              for p in prompts]
        eng.run()
        for h, p in zip(hs, prompts):
            assert list(eng.poll(h).tokens) == _oracle(params, cfg, p, 6)
        st = eng.stats()
        assert st["pool"]["unique_blocks"] > 0
        assert st["pool"]["logical_bytes"] == 0    # all refs released

    def test_deprecated_generate_matches_scan_oracle(self, setup):
        """The legacy batch call is now an Engine wrapper; it must stay
        bit-identical to the scan implementation it replaced."""
        from repro.serving import generate
        cfg, params = setup
        prompts = jnp.asarray(np.stack(_prompts(cfg, [8, 8], seed=7)))
        scfg = ServeConfig(max_seq_len=32, max_new_tokens=5)
        with pytest.warns(DeprecationWarning, match="Engine"):
            got = generate(params, cfg, prompts, scfg)
        want = _generate_scanned(params, cfg, prompts, scfg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Prefix sharing / copy-on-write over the shared pool
# ---------------------------------------------------------------------------

class TestPrefixSharing:
    def test_identical_prompts_dedup_and_stay_exact(self, setup):
        """Two concurrent requests with IDENTICAL prompts produce
        bit-identical blocks (attention K/V slices AND cumulative SSM
        snapshots), so the pool holds each block once with refcount 2 —
        and both outputs still match the oracle."""
        cfg, params = setup
        prompts = _prompts(cfg, [12, 12], seed=9, shared_prefix=12)
        pool = BlockPool(1 << 30)
        eng = Engine(params, cfg, max_seq_len=32, max_batch=2,
                     kv_spec=KVCacheSpec(block_tokens=4, hot_blocks=1),
                     registry=CodecRegistry(), pool=pool)
        hs = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=6))
              for p in prompts]
        eng.run()
        for h, p in zip(hs, prompts):
            assert list(eng.poll(h).tokens) == _oracle(params, cfg, p, 6)
        st = pool.stats()
        n_layers = len(cfg.layer_kinds())
        assert st["dedup_hits"] >= n_layers
        assert st["peak_logical_bytes"] > st["peak_referenced_bytes"]

    def test_divergent_suffix_is_copy_on_write(self, phi3):
        """Prompts sharing an 8-token prefix but diverging in the last
        block: attention K/V rows are position-local, so the prefix
        blocks dedup while the divergent blocks get NEW digests (no
        false sharing — outputs stay exact). SSM states are cumulative,
        so this attention-only property is tested on phi3."""
        cfg, params = phi3
        prompts = _prompts(cfg, [12, 12], seed=9, shared_prefix=12)
        prompts[1][-4:] = (prompts[1][-4:] + 1) % cfg.vocab_size  # diverge
        pool = BlockPool(1 << 30)
        eng = Engine(params, cfg, max_seq_len=32, max_batch=2,
                     kv_spec=KVCacheSpec(block_tokens=4, hot_blocks=1),
                     registry=CodecRegistry(), pool=pool)
        hs = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=6))
              for p in prompts]
        eng.run()
        for h, p in zip(hs, prompts):
            assert list(eng.poll(h).tokens) == _oracle(params, cfg, p, 6)
        st = pool.stats()
        n_layers = len(cfg.layer_kinds())
        # [0,4) and [4,8) dedup per layer; [8,12) and the decode-time
        # blocks diverge copy-on-write
        assert st["dedup_hits"] >= 2 * n_layers
        assert st["unique_blocks"] > st["dedup_hits"]

    def test_finished_sequence_leaves_prefix_cache(self, phi3):
        """A finished request's blocks stay as zero-ref cache; a later
        identical-prefix request revives them (dedup against cache) and
        still decodes exactly."""
        cfg, params = phi3
        prompts = _prompts(cfg, [12, 12], seed=11, shared_prefix=12)
        pool = BlockPool(1 << 30)
        eng = Engine(params, cfg, max_seq_len=32, max_batch=1,
                     kv_spec=KVCacheSpec(block_tokens=4, hot_blocks=1),
                     registry=CodecRegistry(), pool=pool)
        h1 = eng.submit(GenerationRequest(prompt=prompts[0],
                                          max_new_tokens=3))
        eng.run()                                  # finishes, refs -> 0
        assert pool.stats()["referenced_bytes"] == 0
        hits_before = pool.stats()["dedup_hits"]
        h2 = eng.submit(GenerationRequest(prompt=prompts[1],
                                          max_new_tokens=3))
        eng.run()
        assert pool.stats()["dedup_hits"] > hits_before
        for h, p in zip((h1, h2), prompts):
            assert list(eng.poll(h).tokens) == _oracle(params, cfg, p, 3)


# ---------------------------------------------------------------------------
# Pressure: spill, reclaim, typed rejection
# ---------------------------------------------------------------------------

class TestPoolPressure:
    def test_spill_keeps_outputs_exact(self, phi3):
        """A pool far smaller than the working set spills to host; the
        device tier never exceeds capacity and outputs stay exact."""
        cfg, params = phi3
        prompts = _prompts(cfg, [12, 12, 12], seed=13)
        # blocks are ~4.25 KB here; the 2-resident working set peaks at
        # ~21 KB, so 10 KB holds any one block but not the working set
        pool = BlockPool(10_000)
        eng = Engine(params, cfg, max_seq_len=32, max_batch=2,
                     kv_spec=KVCacheSpec(block_tokens=4, hot_blocks=1),
                     registry=CodecRegistry(), pool=pool)
        hs = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=5))
              for p in prompts]
        eng.run()
        st = pool.stats()
        assert st["spills"] + st["reclaims"] > 0
        assert st["peak_resident_bytes"] <= pool.capacity_bytes
        for h, p in zip(hs, prompts):
            assert list(eng.poll(h).tokens) == _oracle(params, cfg, p, 5)

    def test_exhaustion_rejects_one_request_not_the_engine(self, phi3):
        """With spill disabled and capacity for roughly one sequence,
        the overflowing request gets a typed rejection; its neighbour
        runs to completion untouched."""
        cfg, params = phi3
        prompts = _prompts(cfg, [12, 12], seed=15)
        # 15 KB pins one sequence's ~12.8 KB of blocks; the second
        # request's projection cannot fit beside it
        pool = BlockPool(15_000, spill_host=False)
        eng = Engine(params, cfg, max_seq_len=32, max_batch=2,
                     kv_spec=KVCacheSpec(block_tokens=4, hot_blocks=1),
                     registry=CodecRegistry(), pool=pool)
        hs = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=5))
              for p in prompts]
        eng.run()                                  # must not raise
        states = [eng.poll(h) for h in hs]
        assert states[0].state == "finished"
        assert list(states[0].tokens) == _oracle(params, cfg,
                                                 prompts[0], 5)
        assert states[1].state == "rejected"
        assert "PoolExhausted" in states[1].error
        ev = [e for _, e, _ in eng.events]
        assert "reject" in ev or "reject_admission" in ev


# ---------------------------------------------------------------------------
# Fairness
# ---------------------------------------------------------------------------

class TestFairness:
    def test_tenant_cap_defers_deterministically(self, phi3):
        """fairness_cap=0.5 of max_batch=2 -> one slot per tenant: the
        second request of tenant A defers while tenant B's first request
        takes the free slot; A's second runs once A's first finishes."""
        cfg, params = phi3
        p = _prompts(cfg, [6, 6, 6], seed=17)
        eng = Engine(params, cfg, max_seq_len=32, max_batch=2,
                     fairness_cap=0.5)
        eng.submit(GenerationRequest(prompt=p[0], max_new_tokens=2,
                                     tenant="A", request_id="A1"))
        eng.submit(GenerationRequest(prompt=p[1], max_new_tokens=2,
                                     tenant="A", request_id="A2"))
        eng.submit(GenerationRequest(prompt=p[2], max_new_tokens=3,
                                     tenant="B", request_id="B1"))
        eng.run()
        assert (1, "admit", "A1") in eng.events
        assert (1, "defer_fairness", "A2") in eng.events
        assert (1, "admit", "B1") in eng.events
        a2_admit = [s for s, e, r in eng.events
                    if e == "admit" and r == "A2"]
        a1_finish = [s for s, e, r in eng.events
                     if e == "finish" and r == "A1"]
        assert a2_admit and a1_finish and a2_admit[0] > a1_finish[0]
        assert eng.stats()["requests"]["finished"] == 3
        # identity still holds under deferred admission
        assert list(eng.poll("A2").tokens) == _oracle(params, cfg,
                                                      p[1], 2)
