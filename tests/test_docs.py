"""The docs/ book is executable documentation — CI-validated.

Three layers of validation over README.md, docs/*.md, and ROADMAP.md:

* every fenced ```python block executes against the REAL API in a
  fresh 8-fake-device subprocess (the ``run_md`` harness) — a doc
  snippet that drifts from the code fails the build;
* documented constants are asserted against their source of truth
  (container header word count and magic, transport kinds and link
  classes, the autotune cache key tuple, the METRIC_GATES rows) — the
  numbers in the prose cannot silently rot;
* every intra-repo markdown link (including ``#anchor`` fragments)
  resolves.
"""
import glob
import os

import pytest

from tests.md_util import (REPO, extract_code_blocks, heading_anchors,
                           markdown_links, run_md)

DOCS = sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
BOOKS = [os.path.join(REPO, "README.md"), *DOCS,
         os.path.join(REPO, "ROADMAP.md")]


def _read(path):
    with open(path) as f:
        return f.read()


def test_docs_book_exists():
    names = {os.path.basename(p) for p in DOCS}
    assert {"architecture.md", "wire-format.md", "transports.md",
            "operations.md"} <= names


# ---- executable code blocks ---------------------------------------------

CODE_BLOCKS = [(p, ln, code)
               for p in BOOKS
               for ln, code in extract_code_blocks(p, lang="python")]


@pytest.mark.parametrize(
    "path,lineno,code",
    CODE_BLOCKS,
    ids=[f"{os.path.relpath(p, REPO)}:{ln}" for p, ln, _ in CODE_BLOCKS])
def test_doc_code_block_runs(path, lineno, code):
    """Each ```python block is self-contained and runs as written."""
    run_md(code, timeout=900)


# ---- documented constants match the source ------------------------------

class TestDocumentedConstants:
    def test_wire_format_header_spec(self):
        from repro.comm import container
        doc = _read(os.path.join(REPO, "docs", "wire-format.md"))
        assert container.HEADER_WORDS == 16
        assert "16-word" in doc or "16 little-endian" in doc
        assert f"0x{container.MAGIC:08X}" in doc
        # every header word 0..15 is documented as a table row
        for w in range(16):
            assert f"| {w} |" in doc, f"header word {w} undocumented"
        assert container.CONTAINER_VERSION == 1

    def test_transports_kinds_and_link_classes(self):
        from repro.comm import LINK_CLASSES, TRANSPORT_KINDS
        doc = _read(os.path.join(REPO, "docs", "transports.md"))

        def literal(tup):  # docs quote tuples with double quotes
            return "(" + ", ".join(f'"{k}"' for k in tup) + ")"

        assert literal(TRANSPORT_KINDS) in doc
        assert literal(LINK_CLASSES) in doc
        for kind in TRANSPORT_KINDS:
            assert kind in doc

    def test_transports_cache_key_tuple(self):
        from repro.core.registry import TRANSPORT_CACHE_KEY
        doc = _read(os.path.join(REPO, "docs", "transports.md"))
        # the documented key tuple is asserted VERBATIM against the
        # constant (whitespace-insensitive: the doc wraps lines)
        want = ", ".join(f'"{k}"' for k in TRANSPORT_CACHE_KEY)
        squashed = " ".join(doc.split())
        assert f"({want})" in squashed, (
            f"docs/transports.md must quote TRANSPORT_CACHE_KEY "
            f"({want}) exactly")

    def test_operations_metric_gates_table(self):
        import importlib.util
        spec = importlib.util.spec_from_file_location(
            "check_regression",
            os.path.join(REPO, "benchmarks", "check_regression.py"))
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        doc = _read(os.path.join(REPO, "docs", "operations.md"))
        for row, gates in mod.METRIC_GATES.items():
            assert row in doc, f"METRIC_GATES row {row!r} undocumented"
            for metric in gates:
                assert metric in doc, (
                    f"gated metric {row}.{metric} undocumented")

    def test_modeled_time_functions_documented_and_exported(self):
        import repro.comm as comm
        doc = _read(os.path.join(REPO, "docs", "transports.md"))
        for fn in ("modeled_oneshot_time", "modeled_ring_time",
                   "modeled_hierarchical_time",
                   "modeled_hierarchical_oneshot_time",
                   "modeled_flat_ring_time", "modeled_a2a_ring_time"):
            assert fn in doc, f"{fn} undocumented"
            assert hasattr(comm, fn), f"{fn} not exported"

    def test_operations_launcher_flags_exist(self):
        """Every --flag named in the operations launcher table is a
        real argparse option of repro.launch.train."""
        import re
        src = _read(os.path.join(REPO, "src", "repro", "launch",
                                 "train.py"))
        real = set(re.findall(r'add_argument\("(--[\w-]+)"', src))
        doc = _read(os.path.join(REPO, "docs", "operations.md"))
        # launcher section only — later sections name benchmark flags
        section = doc.split("## Training launcher", 1)[1]
        section = re.split(r"\n## ", section, 1)[0]
        documented = set(re.findall(r"`(--[\w-]+)", section))
        missing = documented - real
        assert not missing, f"operations.md names unknown flags {missing}"
        assert {"--pods", "--transport", "--autotune"} <= documented


# ---- link checker -------------------------------------------------------

@pytest.mark.parametrize(
    "path", BOOKS, ids=[os.path.relpath(p, REPO) for p in BOOKS])
def test_intra_repo_links_resolve(path):
    bad = []
    for lineno, target in markdown_links(path):
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        dest = (path if not target
                else os.path.normpath(
                    os.path.join(os.path.dirname(path), target)))
        if not os.path.exists(dest):
            bad.append(f"{os.path.relpath(path, REPO)}:{lineno}: "
                       f"missing {target}")
        elif frag and dest.endswith(".md") \
                and frag not in heading_anchors(dest):
            bad.append(f"{os.path.relpath(path, REPO)}:{lineno}: "
                       f"no heading #{frag} in {target or path}")
    assert not bad, "\n".join(bad)
