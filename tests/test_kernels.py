"""Pallas kernel validation: sweep shapes/schemes and compare bit-exactly
against the ref.py pure-jnp oracles (interpret=True on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import TABLE1, TABLE2, build_tables, codec, distributions
from repro.core.scheme_search import optimal_scheme
from repro.kernels import ops, ref


def _tables(scheme, seed=0):
    return build_tables(distributions.ffn1_counts(1 << 14, seed=seed), scheme)


CHUNK_SWEEP = [64, 128, 256, 1024]
NCHUNK_SWEEP = [1, 7, 8, 32]


class TestDecodeKernel:
    @pytest.mark.parametrize("chunk", CHUNK_SWEEP)
    @pytest.mark.parametrize("scheme", [TABLE1, TABLE2], ids=["t1", "t2"])
    def test_chunk_sweep(self, chunk, scheme, rng):
        tables = _tables(scheme)
        syms = rng.integers(0, 256, size=(16, chunk), dtype=np.uint8)
        cap = codec.worst_case_words(chunk, tables.max_code_length)
        words, _ = ref.encode_ref(jnp.asarray(syms), tables, cap)
        got = ops.decode(words, tables, chunk)
        want = ref.decode_ref(words, tables, chunk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(got), syms)

    @pytest.mark.parametrize("n_chunks", NCHUNK_SWEEP)
    def test_nonmultiple_tile_padding(self, n_chunks, rng):
        tables = _tables(TABLE1)
        syms = rng.integers(0, 256, size=(n_chunks, 128), dtype=np.uint8)
        cap = codec.worst_case_words(128, tables.max_code_length)
        words, _ = ref.encode_ref(jnp.asarray(syms), tables, cap)
        got = ops.decode(words, tables, 128)
        assert got.shape == (n_chunks, 128)
        np.testing.assert_array_equal(np.asarray(got), syms)

    def test_tile_chunks_variants(self, rng):
        tables = _tables(TABLE1)
        syms = rng.integers(0, 256, size=(12, 256), dtype=np.uint8)
        cap = codec.worst_case_words(256, tables.max_code_length)
        words, _ = ref.encode_ref(jnp.asarray(syms), tables, cap)
        for tc in (1, 2, 4):
            got = ops.decode(words, tables, 256, tile_chunks=tc)
            np.testing.assert_array_equal(np.asarray(got), syms)


class TestEncodeKernel:
    @pytest.mark.parametrize("chunk", CHUNK_SWEEP)
    @pytest.mark.parametrize("scheme", [TABLE1, TABLE2], ids=["t1", "t2"])
    def test_matches_ref(self, chunk, scheme, rng):
        tables = _tables(scheme, seed=1)
        syms = rng.integers(0, 256, size=(16, chunk), dtype=np.uint8)
        cap = codec.worst_case_words(chunk, tables.max_code_length)
        w_ref, nb_ref = ref.encode_ref(jnp.asarray(syms), tables, cap)
        w_k, nb_k = ops.encode(jnp.asarray(syms), tables, cap)
        np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_ref))
        np.testing.assert_array_equal(np.asarray(nb_k), np.asarray(nb_ref))

    def test_roundtrip_through_kernels_only(self, rng):
        tables = _tables(TABLE1)
        syms = distributions.ffn1_symbols(4096, seed=21).reshape(-1, 256)
        cap = codec.worst_case_words(256, tables.max_code_length)
        words, _ = ops.encode(jnp.asarray(syms), tables, cap)
        out = ops.decode(words, tables, 256)
        np.testing.assert_array_equal(np.asarray(out), syms)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_property_random_scheme_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        pmf = np.sort(rng.dirichlet(np.full(256, 0.5)))[::-1]
        scheme, _ = optimal_scheme(pmf, max_distinct_lengths=4)
        tables = build_tables(rng.permutation(pmf), scheme)
        syms = rng.integers(0, 256, size=(8, 128), dtype=np.uint8)
        cap = codec.worst_case_words(128, tables.max_code_length)
        words, _ = ops.encode(jnp.asarray(syms), tables, cap)
        out = ops.decode(words, tables, 128)
        np.testing.assert_array_equal(np.asarray(out), syms)


class TestHistogramKernel:
    @pytest.mark.parametrize("n", [128, 1024, 4096, 5000, 12345])
    def test_matches_ref(self, n, rng):
        syms = rng.integers(0, 256, size=n, dtype=np.uint8)
        got = ops.histogram(jnp.asarray(syms))
        want = ref.histogram256_ref(jnp.asarray(syms))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(got), np.bincount(syms, minlength=256))

    def test_matches_numpy_on_real_stream(self):
        syms = distributions.ffn2_symbols(1 << 14, seed=3)
        got = np.asarray(ops.histogram(jnp.asarray(syms)))
        np.testing.assert_array_equal(got, np.bincount(syms, minlength=256))

    def test_total_preserved_under_padding(self, rng):
        syms = rng.integers(0, 256, size=999, dtype=np.uint8)
        got = np.asarray(ops.histogram(jnp.asarray(syms)))
        assert got.sum() == 999


class TestCalibrationPipeline:
    def test_kernel_histogram_feeds_table_build(self):
        """End-to-end: histogram kernel -> tables -> codec round trip."""
        syms = distributions.ffn1_symbols(1 << 14, seed=5)
        counts = np.asarray(ops.histogram(jnp.asarray(syms))).astype(np.float64)
        tables = build_tables(counts, TABLE1)
        data = syms[:2048].reshape(-1, 256)
        cap = codec.worst_case_words(256, tables.max_code_length)
        words, _ = ops.encode(jnp.asarray(data), tables, cap)
        out = ops.decode(words, tables, 256)
        np.testing.assert_array_equal(np.asarray(out), data)
