"""Round-trip / property tests for the pure-JAX chunked codec.

Losslessness is THE paper property: decode(encode(x)) == x bit-exactly,
for any byte stream and any valid scheme/histogram.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import TABLE1, TABLE2, build_tables, codec, distributions
from repro.core.scheme_search import optimal_scheme
from repro.core import entropy


def roundtrip(symbols: np.ndarray, tables, chunk: int = 256) -> np.ndarray:
    words, nbits, n = codec.encode_stream(
        jnp.asarray(symbols, dtype=jnp.uint8), tables, chunk_symbols=chunk)
    out = codec.decode_stream(words, tables, chunk, n)
    return np.asarray(out)


class TestRoundTrip:
    @pytest.mark.parametrize("chunk", [64, 256, 1024])
    def test_ffn1_stream(self, t1_tables, chunk):
        syms = distributions.ffn1_symbols(4096, seed=3)
        assert (roundtrip(syms, t1_tables, chunk) == syms).all()

    def test_ffn2_stream_table2(self, t2_tables):
        syms = distributions.ffn2_symbols(4096, seed=4)
        assert (roundtrip(syms, t2_tables) == syms).all()

    def test_all_256_symbols(self, t1_tables):
        syms = np.arange(256, dtype=np.uint8)
        assert (roundtrip(syms, t1_tables, chunk=256) == syms).all()

    def test_non_multiple_length(self, t1_tables):
        syms = np.arange(1000, dtype=np.int64).astype(np.uint8)
        assert (roundtrip(syms, t1_tables, chunk=256) == syms).all()

    def test_single_symbol(self, t1_tables):
        syms = np.array([177], dtype=np.uint8)
        assert (roundtrip(syms, t1_tables, chunk=64) == syms).all()

    def test_worst_case_all_longest(self, t1_tables):
        # Stream of nothing but 11-bit codes must still fit the slot.
        rank255_sym = int(np.argmax(t1_tables.enc_len))
        syms = np.full(512, rank255_sym, dtype=np.uint8)
        assert (roundtrip(syms, t1_tables, chunk=256) == syms).all()

    @given(data=st.binary(min_size=1, max_size=2048))
    @settings(max_examples=40, deadline=None)
    def test_property_arbitrary_bytes_t1(self, data):
        tables = build_tables(np.arange(256, 0, -1, dtype=np.float64), TABLE1)
        syms = np.frombuffer(data, dtype=np.uint8)
        assert (roundtrip(syms, tables, chunk=128) == syms).all()

    @given(
        counts=st.lists(st.integers(min_value=0, max_value=10_000),
                        min_size=256, max_size=256),
        data=st.binary(min_size=1, max_size=512),
        table2=st.booleans(),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_arbitrary_histogram(self, counts, data, table2):
        # Any histogram (incl. zeros/ties) must yield a lossless codec.
        scheme = TABLE2 if table2 else TABLE1
        tables = build_tables(np.asarray(counts, dtype=np.float64), scheme)
        syms = np.frombuffer(data, dtype=np.uint8)
        assert (roundtrip(syms, tables, chunk=64) == syms).all()

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_property_searched_schemes(self, seed):
        rng = np.random.default_rng(seed)
        pmf = rng.dirichlet(np.full(256, 0.3))
        pmf_sorted = np.sort(pmf)[::-1]
        scheme, _ = optimal_scheme(pmf_sorted, max_distinct_lengths=4)
        tables = build_tables(pmf, scheme)
        syms = rng.integers(0, 256, size=777, dtype=np.uint8)
        assert (roundtrip(syms, tables, chunk=128) == syms).all()


class TestSizes:
    def test_nbits_matches_lut_lengths(self, t1_tables):
        syms = distributions.ffn1_symbols(2048, seed=5)
        words, nbits, n = codec.encode_stream(
            jnp.asarray(syms), t1_tables, chunk_symbols=256)
        expect = t1_tables.enc_len[syms.astype(np.int64)].reshape(
            -1, 256).sum(axis=1)
        assert (np.asarray(nbits) == expect).all()

    def test_worst_case_words_bound(self):
        assert codec.worst_case_words(1024, 11) == (1024 * 11 + 31) // 32 + 1
        assert codec.raw_words(1024) == 256

    def test_measured_compressibility_in_paper_band(self, t1_tables):
        # Our synthetic FFN1 stream: QLC-T1 compressibility should be
        # positive and within a few points of the paper's 13.9%.
        syms = distributions.ffn1_symbols(1 << 18, seed=0)
        c = codec.measured_compressibility(syms, t1_tables)
        assert 0.10 < c < 0.22, c

    def test_compressed_bits_helper(self, t1_tables):
        syms = jnp.asarray(np.zeros(100, dtype=np.uint8))
        bits = codec.compressed_bits(syms, t1_tables)
        assert float(bits) == 100 * int(
            t1_tables.enc_len[0])


class TestEncoderLutSemantics:
    def test_most_frequent_symbol_gets_shortest_code(self, ffn1_counts,
                                                     t1_tables):
        top = int(np.argmax(ffn1_counts))
        assert t1_tables.enc_len[top] == 6
        rare = int(np.argmin(ffn1_counts))
        assert t1_tables.enc_len[rare] == 11

    def test_dec_lut_inverts_ranking(self, ffn1_counts, t1_tables):
        pmf_sorted, order = entropy.sort_pmf_desc(ffn1_counts)
        assert (t1_tables.dec_lut == order.astype(np.uint8)).all()

    def test_deterministic_tables(self, ffn1_counts):
        a = build_tables(ffn1_counts, TABLE1)
        b = build_tables(ffn1_counts.copy(), TABLE1)
        assert (a.enc_code == b.enc_code).all()
        assert (a.dec_lut == b.dec_lut).all()
