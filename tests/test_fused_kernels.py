"""Fused-pipeline validation (deterministic, no hypothesis needed).

The fused Pallas kernels must be BIT-exact against the composed
oracles: quantize_encode ≡ e4m3.quantize_block32 + codec.encode_chunks
and decode_dequantize ≡ codec.decode_chunks + e4m3.dequantize_block32
— including escape/overflow chunks, where the slot contents and the
exact nbits must still agree so the wire format is identical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TABLE1, TABLE2, build_tables, codec, distributions
from repro.comm import (CommConfig, compress_values, decompress_values)
from repro.comm.weights import compress_groups
from repro.kernels import ops, ref
from repro.quant import e4m3
from repro.serving import open_params


def _tables(scheme, seed=0):
    return build_tables(distributions.ffn1_counts(1 << 14, seed=seed), scheme)


def _rare_symbol_values(tables, n):
    """Float array whose blocks quantize to mostly-rare (11-bit) symbols.

    Each block-32 carries one 480.0 anchor (pinning the scale to ~1) and
    31 copies of the e4m3 value of the longest-code symbol, so encoded
    chunks overflow tight slots deterministically.
    """
    rare = int(np.argmax(tables.enc_len))
    v = float(e4m3.decode_table()[rare])
    x = np.full(n, v, dtype=np.float32)
    x[::32] = 480.0
    return x


CHUNK_SWEEP = [64, 256, 1024]
NCHUNK_SWEEP = [1, 7, 8, 33]


class TestFusedQuantizeEncode:
    @pytest.mark.parametrize("chunk", CHUNK_SWEEP)
    @pytest.mark.parametrize("scheme", [TABLE1, TABLE2], ids=["t1", "t2"])
    def test_matches_oracle(self, chunk, scheme, rng):
        tables = _tables(scheme)
        x = jnp.asarray(
            rng.standard_normal((16, chunk)).astype(np.float32) * 3)
        cap = codec.worst_case_words(chunk, tables.max_code_length)
        w, nb, sc, cd = ops.quantize_encode(x, tables, cap, emit_codes=True)
        wr, nbr, scr, cdr = ref.quantize_encode_ref(x, tables, cap)
        np.testing.assert_array_equal(np.asarray(w), np.asarray(wr))
        np.testing.assert_array_equal(np.asarray(nb), np.asarray(nbr))
        np.testing.assert_array_equal(np.asarray(sc), np.asarray(scr))
        np.testing.assert_array_equal(np.asarray(cd), np.asarray(cdr))

    @pytest.mark.parametrize("n_chunks", NCHUNK_SWEEP)
    def test_nonmultiple_tile_padding(self, n_chunks, rng):
        tables = _tables(TABLE1)
        x = jnp.asarray(
            rng.standard_normal((n_chunks, 128)).astype(np.float32))
        cap = codec.worst_case_words(128, tables.max_code_length)
        w, nb, sc = ops.quantize_encode(x, tables, cap)
        wr, nbr, scr, _ = ref.quantize_encode_ref(x, tables, cap)
        assert w.shape == (n_chunks, cap)
        np.testing.assert_array_equal(np.asarray(w), np.asarray(wr))
        np.testing.assert_array_equal(np.asarray(nb), np.asarray(nbr))
        np.testing.assert_array_equal(np.asarray(sc), np.asarray(scr))

    def test_histogram_side_output(self, rng):
        tables = _tables(TABLE1)
        x = jnp.asarray(rng.standard_normal((10, 256)).astype(np.float32))
        cap = codec.worst_case_words(256, tables.max_code_length)
        _, _, _, cd, hist = ops.quantize_encode(
            x, tables, cap, emit_codes=True, emit_hist=True)
        want = np.bincount(np.asarray(cd).reshape(-1), minlength=256)
        np.testing.assert_array_equal(np.asarray(hist), want)
        assert int(np.asarray(hist).sum()) == 10 * 256  # padding removed

    def test_escape_overflow_chunks_bit_exact(self):
        """Overflowing chunks: slot contents AND nbits match the oracle."""
        tables = _tables(TABLE1)
        x = jnp.asarray(_rare_symbol_values(tables, 8 * 256).reshape(8, 256))
        tight_cap = 60                      # << needed for 11-bit symbols
        w, nb, sc = ops.quantize_encode(x, tables, tight_cap)
        wr, nbr, scr, _ = ref.quantize_encode_ref(x, tables, tight_cap)
        assert (np.asarray(nb) > tight_cap * 32).all()   # truly overflowing
        np.testing.assert_array_equal(np.asarray(w), np.asarray(wr))
        np.testing.assert_array_equal(np.asarray(nb), np.asarray(nbr))
        np.testing.assert_array_equal(np.asarray(sc), np.asarray(scr))

    def test_bf16_input(self, rng):
        tables = _tables(TABLE1)
        xb = jnp.asarray(
            rng.standard_normal((4, 256)).astype(np.float32)
        ).astype(jnp.bfloat16)
        cap = codec.worst_case_words(256, tables.max_code_length)
        w, nb, sc = ops.quantize_encode(xb, tables, cap)
        wr, nbr, scr, _ = ref.quantize_encode_ref(
            xb.astype(jnp.float32), tables, cap)
        np.testing.assert_array_equal(np.asarray(w), np.asarray(wr))
        np.testing.assert_array_equal(np.asarray(sc), np.asarray(scr))


class TestFusedDecodeDequantize:
    @pytest.mark.parametrize("chunk", CHUNK_SWEEP)
    @pytest.mark.parametrize("scheme", [TABLE1, TABLE2], ids=["t1", "t2"])
    def test_matches_oracle(self, chunk, scheme, rng):
        tables = _tables(scheme)
        x = jnp.asarray(
            rng.standard_normal((16, chunk)).astype(np.float32) * 2)
        cap = codec.worst_case_words(chunk, tables.max_code_length)
        w, _, sc = ops.quantize_encode(x, tables, cap)
        got = ops.decode_dequantize(w, sc, tables, chunk)
        want = ref.decode_dequantize_ref(w, sc, tables, chunk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_roundtrip_equals_quant_dequant(self, rng):
        """Fused encode->decode == plain quantize->dequantize (lossless)."""
        tables = _tables(TABLE1)
        x = jnp.asarray(rng.standard_normal((12, 512)).astype(np.float32))
        cap = codec.worst_case_words(512, tables.max_code_length)
        w, _, sc = ops.quantize_encode(x, tables, cap)
        got = ops.decode_dequantize(w, sc, tables, 512)
        codes, scales = e4m3.quantize_block32(x)
        want = e4m3.dequantize_block32(codes, scales)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_tile_chunks_variants(self, rng):
        tables = _tables(TABLE1)
        x = jnp.asarray(rng.standard_normal((12, 256)).astype(np.float32))
        cap = codec.worst_case_words(256, tables.max_code_length)
        w, _, sc = ops.quantize_encode(x, tables, cap)
        want = ref.decode_dequantize_ref(w, sc, tables, 256)
        for tc in (1, 2, 4):
            got = ops.decode_dequantize(w, sc, tables, 256, tile_chunks=tc)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bf16_output_dtype(self, rng):
        """In-kernel bf16 cast == external f32->bf16 cast."""
        tables = _tables(TABLE1)
        x = jnp.asarray(rng.standard_normal((4, 256)).astype(np.float32))
        cap = codec.worst_case_words(256, tables.max_code_length)
        w, _, sc = ops.quantize_encode(x, tables, cap)
        got = ops.decode_dequantize(w, sc, tables, 256,
                                    out_dtype=jnp.bfloat16)
        assert got.dtype == jnp.bfloat16
        want = ref.decode_dequantize_ref(w, sc, tables, 256).astype(
            jnp.bfloat16)
        np.testing.assert_array_equal(
            np.asarray(got).view(np.uint16), np.asarray(want).view(np.uint16))


class TestAutoTileChunks:
    def test_table_buckets(self):
        assert ops.auto_tile_chunks(64) == 32
        assert ops.auto_tile_chunks(1024) == 8
        assert ops.auto_tile_chunks(4096) == 2

    def test_capped_by_row_count(self):
        assert ops.auto_tile_chunks(64, n_chunks=1) == 1
        assert ops.auto_tile_chunks(64, n_chunks=3) == 4
        assert ops.auto_tile_chunks(1024, n_chunks=1000) == 8

    def test_unknown_bucket_falls_back_to_vmem_model(self):
        assert ops.auto_tile_chunks(1 << 15) >= 1


class TestCompressedValuesParity:
    """compress_values/decompress_values: kernels on == kernels off."""

    @pytest.mark.parametrize("cw,pool", [(240, 8), (60, 1024)],
                             ids=["planned", "tight"])
    def test_wire_and_values_identical(self, cw, pool, rng):
        tables = _tables(TABLE1)
        x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
        cfgs = [CommConfig(chunk_symbols=256, capacity_words=cw,
                           pool_slots_per_1k=pool, use_kernels=uk)
                for uk in (False, True)]
        (pa, sa), (pb, sb) = (compress_values(x, tables, c) for c in cfgs)
        for fa, fb in zip(pa, pb):
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
        np.testing.assert_array_equal(
            np.asarray(sa).view(np.uint16), np.asarray(sb).view(np.uint16))
        va, oka = decompress_values(pa, sa, tables, cfgs[0])
        vb, okb = decompress_values(pb, sb, tables, cfgs[1])
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
        assert bool(oka) == bool(okb)

    def test_escaped_chunks_identical(self):
        tables = _tables(TABLE1)
        x = jnp.asarray(_rare_symbol_values(tables, 4096))
        cfgs = [CommConfig(chunk_symbols=256, capacity_words=60,
                           pool_slots_per_1k=1024, use_kernels=uk)
                for uk in (False, True)]
        (pa, sa), (pb, sb) = (compress_values(x, tables, c) for c in cfgs)
        assert int(np.asarray(pa.pool_count).sum()) > 0   # escapes exercised
        va, oka = decompress_values(pa, sa, tables, cfgs[0])
        vb, okb = decompress_values(pb, sb, tables, cfgs[1])
        assert bool(oka) and bool(okb)
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))

    def test_disabled_ignores_kernels_flag(self, rng):
        tables = _tables(TABLE1)
        x = jnp.asarray(rng.standard_normal(1024).astype(np.float32))
        cfg = CommConfig(enabled=False, chunk_symbols=256, use_kernels=True)
        p, s = compress_values(x, tables, cfg)
        v, ok = decompress_values(p, s, tables, cfg)
        assert bool(ok)
        codes, scales = e4m3.quantize_block32(x)
        want = e4m3.dequantize_block32(
            codes, scales.astype(jnp.bfloat16).astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(v), np.asarray(want))


class TestServingWire:
    def test_open_params_fused_equals_plain(self, rng):
        tables = _tables(TABLE1)
        params = {
            "blk": {"w1": jnp.asarray(
                        rng.standard_normal((1, 256, 256)), jnp.float32),
                    "norm": jnp.asarray(rng.standard_normal(64),
                                        jnp.float32)},
        }
        wired, codec_plain = compress_groups(params, tables,
                                             use_kernels=False)
        _, codec_fused = compress_groups(params, tables, use_kernels=True)
        assert codec_fused.use_kernels
        p1 = jax.tree.leaves(open_params(wired, codec_plain))
        p2 = jax.tree.leaves(open_params(wired, codec_fused))
        for a, b in zip(p1, p2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_open_params_roundtrips_quantized_values(self, rng):
        tables = _tables(TABLE1)
        w = jnp.asarray(rng.standard_normal((1, 256, 256)), jnp.float32)
        wired, wc = compress_groups({"w": w}, tables, use_kernels=True)
        opened = open_params(wired, wc)["w"]
        codes, scales = e4m3.quantize_block32(w.reshape(1, -1))
        want = e4m3.dequantize_block32(
            codes, scales.astype(jnp.bfloat16).astype(jnp.float32)
        ).reshape(w.shape)
        np.testing.assert_array_equal(np.asarray(opened), np.asarray(want))

    def test_open_params_multi_group(self, rng):
        """Stacked (g>1) leaves must decode EVERY group, not group 0."""
        tables = _tables(TABLE1)
        w = jnp.asarray(rng.standard_normal((3, 256, 256)), jnp.float32)
        for uk in (False, True):
            wired, wc = compress_groups({"w": w}, tables, use_kernels=uk)
            opened = open_params(wired, wc)["w"]
            assert opened.shape == w.shape
            codes, scales = e4m3.quantize_block32(w.reshape(3, -1))
            want = e4m3.dequantize_block32(
                codes, scales.astype(jnp.bfloat16).astype(jnp.float32)
            ).reshape(w.shape)
            np.testing.assert_array_equal(np.asarray(opened),
                                          np.asarray(want))
