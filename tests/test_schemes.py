"""Unit tests for QLC scheme definitions (paper §5-§6, Tables 1-2)."""
import numpy as np
import pytest

from repro.core.schemes import (
    NUM_SYMBOLS, QLCScheme, TABLE1, TABLE2, scheme_from_area_sizes)


class TestPaperTables:
    def test_table1_matches_paper(self):
        # Paper Table 1: 5 areas of 8 (6b), 16 (7b), 32 (8b), 168 (11b).
        assert TABLE1.areas == (
            (8, 3), (8, 3), (8, 3), (8, 3), (8, 3), (16, 4), (32, 5), (168, 8))
        assert TABLE1.distinct_lengths == (6, 7, 8, 11)
        lengths = TABLE1.code_lengths
        assert (lengths[:40] == 6).all()
        assert (lengths[40:56] == 7).all()
        assert (lengths[56:88] == 8).all()
        assert (lengths[88:] == 11).all()

    def test_table2_matches_paper(self):
        assert TABLE2.areas == (
            (2, 1), (8, 3), (8, 3), (8, 3), (8, 3), (32, 5), (32, 5), (158, 8))
        assert TABLE2.distinct_lengths == (4, 6, 8, 11)
        lengths = TABLE2.code_lengths
        assert (lengths[:2] == 4).all()
        assert (lengths[2:34] == 6).all()
        assert (lengths[34:98] == 8).all()
        assert (lengths[98:] == 11).all()

    def test_quadness(self):
        # "Quad": exactly 4 distinct code lengths (vs Huffman's 13 in Fig 2).
        assert len(TABLE1.distinct_lengths) == 4
        assert len(TABLE2.distinct_lengths) == 4


class TestSchemeInvariants:
    def test_codes_are_prefix_free(self):
        for scheme in (TABLE1, TABLE2):
            codes, lens = scheme.rank_codes()
            seen = set()
            for c, l in zip(codes, lens):
                # LSB-first: the first l bits are the codeword.
                key = (int(c) & ((1 << int(l)) - 1), int(l))
                assert key not in seen
                seen.add(key)
            # Prefix-freeness: no codeword is a prefix of another.
            by_bits = sorted(seen, key=lambda t: t[1])
            for i, (c1, l1) in enumerate(by_bits):
                for c2, l2 in by_bits[i + 1:]:
                    if l1 < l2:
                        assert (c2 & ((1 << l1) - 1)) != c1 or l1 == l2

    def test_area_code_determines_length(self):
        # The paper's decode-speed claim hinges on this.
        for scheme in (TABLE1, TABLE2):
            codes, lens = scheme.rank_codes()
            area_of = codes & 7
            for a in range(8):
                area_lens = lens[area_of == a]
                if area_lens.size:
                    assert (area_lens == area_lens[0]).all()

    def test_kraft_inequality(self):
        for scheme in (TABLE1, TABLE2):
            lengths = scheme.code_lengths.astype(np.float64)
            assert (2.0 ** -lengths).sum() <= 1.0 + 1e-12

    def test_validation_rejects_bad_layouts(self):
        with pytest.raises(ValueError):
            QLCScheme(areas=((8, 2),) + ((8, 3),) * 7)  # 8 > 2**2
        with pytest.raises(ValueError):
            QLCScheme(areas=((8, 3),) * 8)  # covers only 64
        with pytest.raises(ValueError):
            QLCScheme(areas=((0, 3), (256, 8)) + ((8, 3),) * 6)

    def test_expected_bits_monotone_in_scheme_fit(self):
        # Degenerate distribution: all mass on rank 0 -> T2 (4-bit head) wins.
        pmf = np.zeros(NUM_SYMBOLS)
        pmf[0] = 1.0
        assert TABLE2.expected_bits(pmf) < TABLE1.expected_bits(pmf)
        # Slowly decaying distribution (no dominant symbol — FFN1-like
        # flat head): T1's 40-symbol 6-bit head beats T2's short head.
        decay = 0.97 ** np.arange(NUM_SYMBOLS)
        decay /= decay.sum()
        assert TABLE1.expected_bits(decay) < TABLE2.expected_bits(decay)

    def test_scheme_from_area_sizes(self):
        s = scheme_from_area_sizes([8, 8, 8, 8, 8, 16, 32, 168])
        assert s.areas == TABLE1.areas

    def test_describe(self):
        txt = TABLE1.describe()
        assert "000" in txt and "168" in txt
