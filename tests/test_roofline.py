"""Roofline analysis: loop-aware HLO walker + term math."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis, hlo_walk, hw


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


class TestWalkerFlops:
    def test_plain_matmul(self):
        m = 64
        hlo = _compile(lambda a, b: a @ b, jnp.ones((m, m)),
                       jnp.ones((m, m)))
        c = hlo_walk.analyze(hlo)
        assert abs(c.flops / (2 * m ** 3) - 1) < 0.05

    def test_scan_multiplies_by_trip_count(self):
        m, t = 64, 12

        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=t)
            return y

        c = hlo_walk.analyze(_compile(f, jnp.ones((m, m)), jnp.ones((m, m))))
        assert abs(c.flops / (2 * m ** 3 * t) - 1) < 0.05

    def test_nested_scans(self):
        m, t1, t2 = 32, 3, 5

        def f(x, w):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                y, _ = jax.lax.scan(inner, c, None, length=t2)
                return y, None
            z, _ = jax.lax.scan(outer, x, None, length=t1)
            return z

        c = hlo_walk.analyze(_compile(f, jnp.ones((m, m)), jnp.ones((m, m))))
        assert abs(c.flops / (2 * m ** 3 * t1 * t2) - 1) < 0.05

    def test_scan_xs_bytes_are_slice_sized(self):
        """Reads of stacked scan inputs must be charged per slice, not
        per full array (fidelity fix for every scanned model)."""
        m, t = 64, 50

        def g(xs, w):
            def body(c, x_t):
                return c + x_t @ w, None
            y, _ = jax.lax.scan(body, jnp.zeros((m, m)), xs)
            return y

        c = hlo_walk.analyze(
            _compile(g, jnp.ones((t, m, m)), jnp.ones((m, m))))
        per_iter = 3 * m * m * 4        # read slice + w... order of mag
        naive = t * (t * m * m * 4)     # full-xs charging
        assert c.bytes < naive / 5
        assert c.bytes > per_iter       # sanity lower bound


class TestCollectiveParse:
    def test_collective_in_scan_multiplied(self):
        txt = """
HloModule test

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8]{0} get-tuple-element(%p), index=1
  %c1 = s32[] constant(1)
  %ni = s32[] add(%i, %c1)
  %ar = f32[8]{0} all-reduce(%x), to_apply=%sum
  ROOT %t = (s32[], f32[8]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(9)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[8]) tuple(%c0, %x)
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
}
"""
        c = hlo_walk.analyze(txt)
        assert c.coll.get("all-reduce") == 8 * 4 * 9  # 32B x 9 trips


class TestTerms:
    def test_term_math(self):
        from repro.configs.base import TRAIN_4K
        from repro.configs import get_config
        cfg = get_config("phi3-mini-3.8b")
        t = analysis.RooflineTerms(
            arch="phi3-mini-3.8b", shape="train_4k", mesh="m", chips=256,
            flops_per_device=hw.PEAK_FLOPS_BF16,       # 1s compute
            bytes_per_device=hw.HBM_BW * 2,            # 2s memory
            coll_bytes_per_device=hw.ICI_LINK_BW / 2,  # 0.5s coll
            model_flops=6.0 * cfg.active_param_count() * 256 * 4096)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(2.0)
        assert t.collective_s == pytest.approx(0.5)
        assert t.dominant == "memory"
        assert 0 < t.roofline_fraction <= 1.5

    def test_model_flops_kinds(self):
        from repro.configs.base import TRAIN_4K, DECODE_32K, PREFILL_32K
        from repro.configs import get_config
        cfg = get_config("mixtral-8x22b")
        f_train = analysis.model_flops_for(cfg, TRAIN_4K)
        f_prefill = analysis.model_flops_for(cfg, PREFILL_32K)
        f_decode = analysis.model_flops_for(cfg, DECODE_32K)
        assert f_train > f_prefill > f_decode
        # MoE uses ACTIVE params
        n_act = cfg.active_param_count()
        assert f_train == 6.0 * n_act * 256 * 4096
