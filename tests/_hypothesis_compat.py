"""Hypothesis compatibility shim.

When ``hypothesis`` is installed the real ``given``/``settings``/``st``
are re-exported and the property tests run unchanged. When it is not
(minimal CI images, the seed container), a deterministic fallback runs
each ``@given`` test over a small, seeded set of drawn examples so the
suite still collects and exercises the property bodies.

The fallback implements exactly the strategy surface this repo uses:
``st.integers``, ``st.floats``, ``st.booleans``, ``st.binary``,
``st.lists``. Draws are seeded from the test's qualified name, so runs
are reproducible and independent of test order.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import functools
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_MAX_EXAMPLES = 6

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def binary(min_size=0, max_size=64):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()
            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=16):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _St()

    def settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            fn._hc_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            limit = getattr(fn, "_hc_max_examples", None)
            n_examples = min(limit or _FALLBACK_MAX_EXAMPLES,
                             _FALLBACK_MAX_EXAMPLES)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n_examples):
                    draws = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **draws)

            # Hide the strategy parameters from pytest's fixture
            # resolution: it must only see the remaining (e.g. ``self``)
            # parameters, exactly as real hypothesis does.
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper
        return deco
