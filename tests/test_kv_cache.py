"""Compressed KV-cache paging: round-trip invariants, registry reload,
overflow surfacing, token-identity, and cross-rank block migration.

The lossless contract under test: a ``"qlc"``-mode block encode→decode
is BIT-identical to the dense cache for both the pure-JAX and
fused-kernel container decode paths, for both attention KV and SSM
state — so a paged serving run produces token-identical output to the
dense-cache run through the same decode loop.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.registry import CodecRegistry
from repro.models import init_decode_states, init_params
from repro.serving import (KVCacheOverflowError, KVCacheSpec, PagedKVCache,
                           ServeConfig, calibrate_cache, generate_paged,
                           kv_cache_manifest, kv_spec_from_manifest,
                           prefill, serving_manifest)
from repro.serving.kv_cache import calibration_arrays
from tests.md_util import run_md

KEY = jax.random.PRNGKey(0)
ARCHS = ["phi3-mini-3.8b", "xlstm-125m"]


@pytest.fixture(scope="module", params=ARCHS)
def setup(request):
    cfg = reduced(get_config(request.param), frontend=None,
                  frontend_prefix_len=0)     # bf16 cache (production dtype)
    params = init_params(cfg, KEY)
    sc = ServeConfig(max_seq_len=64, max_new_tokens=8)
    prompts = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    states = init_decode_states(cfg, 2, sc.max_seq_len)
    _, states = prefill(params, cfg, prompts, states)
    return cfg, params, sc, prompts, jax.block_until_ready(states)


def _cache(cfg, states, mode="qlc", use_kernels=False, block_tokens=4,
           reg=None, **spec_kw):
    reg = CodecRegistry() if reg is None else reg
    spec = KVCacheSpec(block_tokens=block_tokens, mode=mode,
                       use_kernels=use_kernels, **spec_kw)
    calibrate_cache(reg, cfg, states, 12, spec)
    return PagedKVCache(spec, cfg, reg), reg


class TestBlockRoundTrip:
    @pytest.mark.parametrize("use_kernels", [False, True],
                             ids=["pure", "fused"])
    def test_bit_identity_all_layers(self, setup, use_kernels):
        """encode→container→decode is byte-exact for every layer kind
        (attention KV slices AND SSM state snapshots), both container
        decode paths."""
        cfg, _, _, _, states = setup
        cache, _ = _cache(cfg, states, use_kernels=use_kernels)
        arrays = calibration_arrays(cfg, states, 4)
        for i in range(len(cfg.layer_kinds())):
            key = f"l{i}"
            block = cache.encode_block_arrays(
                cache.spec.layer_codec(i), key, arrays[key],
                start=0, tokens=4)
            decoded = cache.decode_block_arrays(block)
            assert len(decoded) == len(arrays[key])
            for orig, got in zip(arrays[key], decoded):
                assert str(np.asarray(orig).dtype) == str(got.dtype)
                np.testing.assert_array_equal(
                    np.asarray(orig).view(np.uint8),
                    np.asarray(got).view(np.uint8))

    def test_e4m3_mode_roundtrip_is_e4m3_exact(self, setup):
        """e4m3 mode: decode equals the quantize→dequantize reference
        bit-for-bit — the QLC coding adds zero error on top of the one
        fp8 rounding (the wire's bf16 scales and the state dtype cast
        included)."""
        from repro.quant import e4m3
        cfg, _, _, _, states = setup
        cache, _ = _cache(cfg, states, mode="e4m3")
        arrays = calibration_arrays(cfg, states, 4)["l0"]
        block = cache.encode_block_arrays(
            cache.spec.layer_codec(0), "l0", arrays, start=0, tokens=4)
        decoded = cache.decode_block_arrays(block)

        flat = jnp.concatenate(
            [jnp.asarray(a, jnp.float32).reshape(-1) for a in arrays])
        pad = (-flat.shape[0]) % cache.spec.chunk_symbols
        ref_codes, ref_scales = e4m3.quantize_block32(
            jnp.pad(flat, (0, pad)))
        ref = e4m3.dequantize_block32(
            ref_codes, jnp.asarray(ref_scales, jnp.float32).astype(
                jnp.bfloat16).astype(jnp.float32))[:flat.shape[0]]
        ref = np.asarray(jnp.asarray(ref).astype(arrays[0].dtype)
                         .astype(jnp.float32))
        got = np.concatenate([np.asarray(d, np.float32).reshape(-1)
                              for d in decoded])
        np.testing.assert_array_equal(ref, got)


class TestRegistryReload:
    def test_reloaded_registry_decodes_bit_exact(self, setup):
        """Registry JSON round trip: a reloaded registry reuses the
        ``kv/layer{i}`` entries (same scheme-ids, bit-identical tables)
        and decodes a container written before the reload byte-exactly."""
        cfg, _, _, _, states = setup
        cache, reg = _cache(cfg, states)
        arrays = calibration_arrays(cfg, states, 4)["l0"]
        block = cache.encode_block_arrays(
            cache.spec.layer_codec(0), "l0", arrays, start=0, tokens=4)

        reg2 = CodecRegistry.from_json(reg.to_json())
        kv_names = [n for n in reg.names() if n.startswith("kv/")]
        assert kv_names and all(
            reg2[n].scheme_id == reg[n].scheme_id for n in kv_names)
        # re-calibrating against the reloaded registry is a no-op reuse
        calibrate_cache(reg2, cfg, states, 12, cache.spec)
        assert sorted(n for n in reg2.names() if n.startswith("kv/")) \
            == sorted(kv_names)
        cache2 = PagedKVCache(cache.spec, cfg, reg2)
        for orig, got in zip(arrays, cache2.decode_block_arrays(block)):
            np.testing.assert_array_equal(
                np.asarray(orig).view(np.uint8),
                np.asarray(got).view(np.uint8))

    def test_manifest_roundtrip_carries_kv_scheme_ids(self, setup):
        """The serving manifest carries the KV recipe next to the
        weight placement, resolved against the shared registry."""
        cfg, params, _, _, states = setup
        from repro.comm.calibrate import histogram_of_tree
        from repro.serving import compress_params_for_serving
        cache, reg = _cache(cfg, states)
        m = kv_cache_manifest(cache.spec, reg)
        spec2, sids = kv_spec_from_manifest(m)
        assert spec2 == cache.spec
        assert sids == {n: reg[n].scheme_id for n in reg.names()
                        if n.startswith("kv/")}
        reg.register("default", histogram_of_tree(params))
        _, wc = compress_params_for_serving(params, reg)
        full = serving_manifest(wc, kv_spec=cache.spec)
        assert full["kv"]["scheme_ids"] == sids
        spec3, sids3 = kv_spec_from_manifest(full["kv"])
        assert spec3 == cache.spec and sids3 == sids


class TestOverflowSurfacing:
    def _adversarial_cache(self, cfg, states):
        """Calibrate on real states, then make the plan capacity
        pathologically small so adversarial blocks escape-overflow."""
        reg = CodecRegistry()
        spec = KVCacheSpec(block_tokens=4, exact_capacity=False)
        calibrate_cache(reg, cfg, states, 12, spec)
        return PagedKVCache(spec, cfg, reg), reg

    def test_encode_overflow_falls_back_to_raw_not_corrupt(self, setup):
        """Pool overflow at encode surfaces (raw fallback + counter)
        instead of silently dropping escaped chunks."""
        cfg, _, _, _, states = setup
        cache, reg = self._adversarial_cache(cfg, states)
        # shrink every coded entry's capacity to force escapes
        for name in list(reg.names()):
            if name.startswith("kv/"):
                e = reg[name]
                object.__setattr__(e, "plan", dataclasses.replace(
                    e.plan, capacity_words=1, pool_slots_per_1k=1,
                    expected_bits_per_symbol=0.1, escape_prob_bound=0.0))
        cache = PagedKVCache(cache.spec, cfg, reg)
        arrays = calibration_arrays(cfg, states, 4)["l0"]
        block = cache.encode_block_arrays(
            cache.spec.layer_codec(0), "l0", arrays, start=0, tokens=4)
        assert cache.overflow_sections > 0
        assert not block.coded
        for orig, got in zip(arrays, cache.decode_block_arrays(block)):
            np.testing.assert_array_equal(
                np.asarray(orig).view(np.uint8),
                np.asarray(got).view(np.uint8))

    def test_decode_overflowed_container_raises(self, setup):
        """A coded container whose pool overflowed on the wire raises
        through the paged cache instead of returning garbage."""
        from repro.comm import container as qc
        cfg, _, _, _, states = setup
        cache, reg = _cache(cfg, states)
        # craft an overflowing coded section directly: capacity 1 word
        # forces every chunk to escape; 1 pool slot can't hold them
        name = next(n for n in sorted(reg.names())
                    if n.startswith("kv/"))
        entry = reg[name]
        buf = qc.encode_codes(
            np.random.default_rng(0).integers(
                0, 256, 4 * cache.spec.chunk_symbols, dtype=np.uint8),
            entry, capacity_words=1, pool_slots_per_1k=1,
            chunk_symbols=cache.spec.chunk_symbols)
        h = qc.parse_header(buf)
        assert h.coded
        fake = dataclasses.replace(
            cache.encode_block_arrays(
                cache.spec.layer_codec(0), "l0",
                calibration_arrays(cfg, states, 4)["l0"],
                start=0, tokens=4),
            container=buf,
            shapes=((4 * cache.spec.chunk_symbols,),),
            dtypes=("uint8",))
        # route the crafted section through the single-stream decode
        cache._split_cache[cache.spec.layer_codec(0)] = False
        with pytest.raises(KVCacheOverflowError):
            cache.decode_block_arrays(fake)


class TestGeneratePaged:
    @pytest.mark.parametrize("use_kernels", [False, True],
                             ids=["pure", "fused"])
    def test_token_identical_to_dense(self, setup, use_kernels):
        """The acceptance invariant: qlc-paged generation produces
        token-identical output to the dense-cache run through the same
        decode loop, for attention AND SSM archs, both decode paths."""
        cfg, params, sc, prompts, states = setup
        cache, _ = _cache(cfg, states, use_kernels=use_kernels)
        out_paged = generate_paged(params, cfg, prompts, sc, cache)
        out_dense = generate_paged(params, cfg, prompts, sc, None)
        np.testing.assert_array_equal(np.asarray(out_paged),
                                      np.asarray(out_dense))
        assert cache.cold or cache.snapshots       # genuinely paged
        s = cache.stats()
        assert s["evicted_tokens"] > 0
        assert s["overflow_sections"] == 0

    def test_matches_scanned_generate(self, setup):
        """The host-driven loop is step-for-step the scanned generate.

        Compared against the scan oracle directly: the public
        ``generate`` is itself an Engine wrapper since PR 6, so going
        through it here would make this a tautology."""
        from repro.serving.engine import _generate_scanned
        cfg, params, sc, prompts, _ = setup
        out_scan = _generate_scanned(params, cfg, prompts, sc)
        out_loop = generate_paged(params, cfg, prompts, sc, None)
        np.testing.assert_array_equal(np.asarray(out_scan),
                                      np.asarray(out_loop))

    def test_hot_blocks_delays_eviction(self, setup):
        cfg, _, _, _, states = setup
        reg = CodecRegistry()
        spec = KVCacheSpec(block_tokens=4, hot_blocks=2)
        calibrate_cache(reg, cfg, states, 12, spec)
        cache = PagedKVCache(spec, cfg, reg)
        states2 = cache.note_tokens(states, 11)
        assert cache.evicted_tokens == 0           # 2 hot blocks pending
        cache.note_tokens(states2, 12)
        assert cache.evicted_tokens == 4


class TestMigration:
    def test_all_gather_block_wire_8dev(self):
        """Cross-rank cache migration: every rank's cold-block container
        words all-gather over the cache axis (compressed bytes on the
        wire) and decode bit-exactly on every receiver."""
        run_md("""
            import numpy as np
            import jax, jax.numpy as jnp
            from jax.sharding import Mesh, PartitionSpec as P
            from repro.configs import get_config, reduced
            from repro.core.registry import CodecRegistry
            from repro.models import init_decode_states, init_params
            from repro.serving import (KVCacheSpec, PagedKVCache, prefill,
                                       ServeConfig, calibrate_cache,
                                       all_gather_block_wire)
            from repro.serving.kv_cache import calibration_arrays
            from jax.experimental.shard_map import shard_map

            cfg = reduced(get_config("phi3-mini-3.8b"), frontend=None,
                          frontend_prefix_len=0)
            params = init_params(cfg, jax.random.PRNGKey(0))
            states = init_decode_states(cfg, 2, 32)
            prompts = jax.random.randint(
                jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
            _, states = prefill(params, cfg, prompts, states)
            reg = CodecRegistry()
            # migration needs STATIC container geometry across ranks:
            # plan capacity, not per-block measured capacity
            spec = KVCacheSpec(block_tokens=4, axis="cache",
                               exact_capacity=False)
            calibrate_cache(reg, cfg, states, 12, spec)
            mesh = Mesh(np.array(jax.devices()), ("cache",))
            cache = PagedKVCache(spec, cfg, reg, mesh=mesh)
            arrays = calibration_arrays(cfg, states, 4)["l0"]
            # one block per "rank": a mild distribution-preserving
            # perturbation so payloads differ but stay within the
            # calibrated plan, then stack the per-rank container words
            blocks = []
            for r in range(8):
                arrs = [jnp.asarray(a) * (1.0 + r / 64.0)
                        for a in arrays]
                blocks.append(cache.encode_block_arrays(
                    "kv/layer0", "l0", arrs, start=0, tokens=4))
            W = {b.container.size for b in blocks}
            assert len(W) == 1, ("static container geometry", W)
            stacked = jnp.asarray(np.stack(
                [b.container for b in blocks]))
            ch = cache.channels[sorted(cache.channels)[0]]
            gathered = jax.jit(shard_map(
                lambda w: all_gather_block_wire(w[0], ch),
                mesh=mesh, in_specs=P("cache"), out_specs=P(),
                check_rep=False))(stacked)
            got = np.asarray(gathered)
            for r in range(8):
                np.testing.assert_array_equal(got[r],
                                              blocks[r].container)
                import dataclasses as dc
                dec = cache.decode_block_arrays(
                    dc.replace(blocks[r], container=got[r]))
                np.testing.assert_array_equal(
                    np.asarray(dec[0]),
                    np.asarray(jnp.asarray(arrays[0])
                               * (1.0 + r / 64.0)))
            print("migration OK")
        """)


class TestCalibration:
    def test_identical_layers_dedupe_scheme_ids(self):
        """Table-digest dedup: layers with identical state statistics
        share one scheme-id under distinct kv/layer{i} names."""
        reg = CodecRegistry()
        from repro.comm.calibrate import calibrate_kv_entries
        rng = np.random.default_rng(0)
        a = rng.normal(size=4096).astype(np.float32)
        entries = calibrate_kv_entries(
            reg, {"l0": [a], "l1": [a.copy()]}, chunk_symbols=256)
        by_layer = {}
        for name, e in entries.items():
            layer = name.split("/")[1]
            by_layer.setdefault(layer, set()).add(
                (name.split("/")[-1], e.scheme_id))
        ids0 = {p: s for p, s in by_layer["layer0"]}
        ids1 = {p: s for p, s in by_layer["layer1"]}
        assert ids0 == ids1

    def test_similar_histograms_merge_within_tolerance(self):
        """Cross-layer LUT sharing (PR 6): planes whose normalized
        histograms sit within ``merge_tol`` total-variation distance
        share ONE set of tables (= one scheme-id), while genuinely
        different distributions keep their own; ``merge_tol=0`` falls
        back to bit-identical-only dedup."""
        from repro.comm.calibrate import calibrate_kv_entries
        rng = np.random.default_rng(0)
        base = rng.normal(0, 1, 20000).astype(np.float16)
        near = (base + rng.normal(0, 0.01, base.shape)
                .astype(np.float16)).astype(np.float16)
        far = rng.integers(0, 1 << 16, 20000).astype(np.uint16) \
            .view(np.float16)
        reg = CodecRegistry()
        entries = calibrate_kv_entries(
            reg, {"l0": [base], "l1": [near], "l2": [far]},
            chunk_symbols=256)
        sid = {n: e.scheme_id for n, e in entries.items()}
        # the structured (high) byte plane of l0/l1 merges; l2 never does
        assert sid["kv/layer0/w2b1"] == sid["kv/layer1/w2b1"]
        assert sid["kv/layer0/w2b1"] != sid["kv/layer2/w2b1"]
        assert sid["kv/layer0/w2b0"] != sid["kv/layer2/w2b0"]
        # merging shares TABLES, not plans: every name keeps its own
        # empirically-sized entry in the registry
        assert len(entries) == 6
        # tol=0 disables similarity merging entirely
        reg0 = CodecRegistry()
        e0 = calibrate_kv_entries(
            reg0, {"l0": [base], "l1": [near]}, chunk_symbols=256,
            merge_tol=0.0)
        assert e0["kv/layer0/w2b1"].scheme_id \
            != e0["kv/layer1/w2b1"].scheme_id
