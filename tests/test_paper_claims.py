"""Validation of the paper's quantitative claims on our reproduced
distributions (DESIGN.md §6 documents why exact trace numbers differ).

Paper numbers: FFN1 — ideal 16.3%, Huffman 15.9%, QLC-T1 13.9%;
FFN2 — ideal 23.6%, Huffman 23.2%, QLC-T1 16.7%, QLC-T2 19.0%.
"""
import numpy as np
import pytest

from repro.core import TABLE1, TABLE2, entropy, huffman, select_scheme
from repro.core import distributions
from repro.core.scheme_search import optimal_scheme


@pytest.fixture(scope="module")
def ffn1(ffn1_counts):
    pmf, _ = entropy.sort_pmf_desc(ffn1_counts)
    return ffn1_counts, pmf


@pytest.fixture(scope="module")
def ffn2(ffn2_counts):
    pmf, _ = entropy.sort_pmf_desc(ffn2_counts)
    return ffn2_counts, pmf


def _huffman_comp(counts):
    counts = np.maximum(counts, 1e-9)
    return huffman.HuffmanCodec(counts).compressibility(counts)


class TestFFN1Claims:
    def test_entropy_near_paper(self, ffn1):
        _, pmf = ffn1
        h = entropy.shannon_entropy(pmf)
        assert 6.2 < h < 7.0  # paper: 6.69

    def test_ordering_ideal_ge_huffman_ge_qlc(self, ffn1):
        counts, pmf = ffn1
        ideal = entropy.ideal_compressibility(pmf)
        huff = _huffman_comp(counts)
        qlc = TABLE1.compressibility(pmf)
        assert ideal >= huff >= qlc > 0

    def test_qlc_within_3pts_of_huffman(self, ffn1):
        # Paper: 13.9% vs 15.9% — QLC gives up ~2 points for decode speed.
        counts, pmf = ffn1
        gap = _huffman_comp(counts) - TABLE1.compressibility(pmf)
        assert 0.0 <= gap < 0.035, gap

    def test_t1_beats_t2_on_ffn1(self, ffn1):
        _, pmf = ffn1
        assert TABLE1.compressibility(pmf) > TABLE2.compressibility(pmf)

    def test_huffman_tree_is_deep(self, ffn1):
        # Paper Fig 2: lengths 6..18 — deep trees motivate QLC.
        counts, _ = ffn1
        lens = huffman.code_lengths(np.maximum(counts, 1e-9))
        assert lens.max() >= 11
        assert len(np.unique(lens[lens > 0])) > 4  # vs QLC's exactly 4


class TestFFN2AdaptationClaims:
    def test_entropy_near_paper(self, ffn2):
        _, pmf = ffn2
        h = entropy.shannon_entropy(pmf)
        assert 5.4 < h < 6.6  # paper: 6.11

    def test_dominant_symbol_exists(self, ffn2):
        _, pmf = ffn2
        assert pmf[0] > 0.10  # the zero spike of Fig 4

    def test_adaptation_improves(self, ffn2):
        # Paper §6: Table 2 improves on Table 1 by ~2.3 points on FFN2.
        _, pmf = ffn2
        gain = TABLE2.compressibility(pmf) - TABLE1.compressibility(pmf)
        assert gain > 0.01, gain

    def test_select_scheme_picks_table2(self, ffn2):
        counts, _ = ffn2
        res = select_scheme(counts)
        assert res.scheme_name == "table2"

    def test_select_scheme_picks_table1_on_ffn1(self, ffn1_counts):
        res = select_scheme(ffn1_counts)
        assert res.scheme_name == "table1"


class TestBeyondPaperSearch:
    def test_search_at_least_matches_tables(self, ffn1, ffn2):
        for counts, pmf in (ffn1, ffn2):
            opt, bits = optimal_scheme(pmf, max_distinct_lengths=4)
            best_table = min(TABLE1.expected_bits(pmf),
                             TABLE2.expected_bits(pmf))
            assert bits <= best_table + 1e-12

    def test_search_respects_quad_constraint(self, ffn2):
        _, pmf = ffn2
        opt, _ = optimal_scheme(pmf, max_distinct_lengths=4)
        assert len(opt.distinct_lengths) <= 4

    def test_unconstrained_at_least_as_good(self, ffn1):
        _, pmf = ffn1
        _, quad_bits = optimal_scheme(pmf, max_distinct_lengths=4)
        _, free_bits = optimal_scheme(pmf, max_distinct_lengths=None)
        assert free_bits <= quad_bits + 1e-12

    def test_search_never_beats_entropy(self, ffn1):
        _, pmf = ffn1
        _, bits = optimal_scheme(pmf, max_distinct_lengths=None)
        assert bits >= entropy.shannon_entropy(pmf) - 1e-9


class TestHuffmanBaseline:
    def test_huffman_roundtrip(self, ffn1_counts):
        codec_ = huffman.HuffmanCodec(np.maximum(ffn1_counts, 1e-9))
        syms = distributions.ffn1_symbols(2000, seed=9)
        data, nbits = codec_.encode(syms)
        out = codec_.decode(data, nbits, len(syms))
        assert (out == syms).all()

    def test_huffman_is_optimal_prefix_code(self, ffn1_counts):
        # Huffman expected length within [H, H+1).
        counts = np.maximum(ffn1_counts, 1e-9)
        pmf = counts / counts.sum()
        h = entropy.shannon_entropy(pmf)
        avg = huffman.HuffmanCodec(counts).expected_bits(counts)
        assert h <= avg + 1e-9 < h + 1.0

    def test_kraft_equality(self, ffn1_counts):
        lens = huffman.code_lengths(np.maximum(ffn1_counts, 1e-9))
        assert abs((2.0 ** -lens[lens > 0].astype(float)).sum() - 1.0) < 1e-9
