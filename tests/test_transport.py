"""Transport-layer tests: one-shot vs ring compressed collectives.

In-process (single CPU device): padding properties, 1-device collective
round-trips on non-multiple lengths (property tests via the hypothesis
shim), the fused decode→dequantize→accumulate kernel, and the planner's
alpha-beta transport model.

Multi-device (8 fake CPU devices in a subprocess): the central
invariant — ring and one-shot transports are BIT-IDENTICAL on all four
qlc_* collectives (outputs and ok flags), pure-JAX and fused-kernel
paths alike, escape-pool overflow included; plus the sharded ring
weight-open and the train step's per-collective transport keys.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from tests._hypothesis_compat import given, settings, st
from repro.core import TABLE1, build_tables, distributions
from repro.comm import (AlphaBetaModel, CommConfig, TransportConfig,
                        choose_transport, modeled_oneshot_time,
                        modeled_ring_time, pad_to_multiple,
                        qlc_all_gather, qlc_all_to_all,
                        qlc_psum, qlc_reduce_scatter,
                        transport_crossover_bytes)
from repro.comm.planner import payload_wire_bytes, resolve_transport
from repro.quant import e4m3
from tests.md_util import run_md


@pytest.fixture(scope="module")
def tables():
    return build_tables(distributions.ffn1_counts(1 << 16), TABLE1)


@pytest.fixture(scope="module")
def cfg():
    return CommConfig(chunk_symbols=256, capacity_words=60,
                      pool_slots_per_1k=8)


def _mesh1():
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("d",))


def _shard_map1(f, out_specs):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    return shard_map(f, mesh=_mesh1(), in_specs=P(),
                     out_specs=out_specs, check_rep=False)


def _qq(x):
    """Reference e4m3 block-32 quantize→dequantize (bf16 scales),
    zero-padded to the block like the collectives pad the wire."""
    flat = np.asarray(x, np.float32).reshape(-1)
    n = flat.shape[0]
    flat = np.pad(flat, (0, (-n) % e4m3.BLOCK))
    c, s = e4m3.quantize_block32(jnp.asarray(flat))
    out = np.asarray(e4m3.dequantize_block32(
        c, s.astype(jnp.bfloat16).astype(jnp.float32)))[:n]
    return out.reshape(np.shape(x))


class TestPadToMultiple:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(0, 3000), multiple=st.integers(1, 700))
    def test_properties(self, n, multiple):
        x = jnp.arange(1, n + 1, dtype=jnp.float32)
        flat, n_out = pad_to_multiple(x, multiple)
        assert n_out == n
        assert flat.shape[0] % multiple == 0
        assert flat.shape[0] - n < multiple
        got = np.asarray(flat)
        np.testing.assert_array_equal(got[:n], np.asarray(x))
        np.testing.assert_array_equal(got[n:], 0.0)

    @settings(max_examples=6, deadline=None)
    @given(lead=st.integers(1, 4), n=st.integers(1, 257))
    def test_flattens_leading_dims(self, lead, n):
        x = jnp.ones((lead, n), jnp.float32)
        flat, n_out = pad_to_multiple(x, 32)
        assert n_out == lead * n
        assert flat.ndim == 1 and flat.shape[0] % 32 == 0


class TestRoundTripNonMultipleLengths:
    """1-device-mesh collective round trips: the padding/slicing logic
    must be exact for lengths that are NOT chunk multiples (property
    tests; the 8-device bit-identity lives in TestTransportEquivalence).
    """

    @settings(max_examples=6, deadline=None)
    @given(n=st.integers(1, 2000), ring=st.booleans())
    def test_all_gather(self, tables, cfg, n, ring):
        t = TransportConfig("ring") if ring else None
        x = jnp.asarray(np.random.default_rng(n).standard_normal(n),
                        jnp.float32)

        def f(v):
            out, ok = qlc_all_gather(v, "d", tables, cfg, transport=t,
                                     axis_size=1)
            return out, ok
        out, ok = jax.jit(_shard_map1(f, out_specs=(
            jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec())))(x)
        assert bool(ok)
        assert out.shape == (n,)
        np.testing.assert_array_equal(np.asarray(out), _qq(x))

    @settings(max_examples=6, deadline=None)
    @given(n=st.integers(1, 2000), ring=st.booleans())
    def test_reduce_scatter_valid_length(self, tables, cfg, n, ring):
        from jax.sharding import PartitionSpec as P
        t = TransportConfig("ring") if ring else None
        x = jnp.asarray(np.random.default_rng(n).standard_normal(n),
                        jnp.float32)

        def f(v):
            seg, valid, ok = qlc_reduce_scatter(
                v, "d", 1, tables, cfg, transport=t)
            return seg, valid, ok
        seg, valid, ok = jax.jit(_shard_map1(f, (P(), P(), P())))(x)
        assert bool(ok)
        assert int(valid) == n            # the satellite's contract
        assert seg.shape[0] % cfg.chunk_symbols == 0
        got = np.asarray(seg)
        np.testing.assert_array_equal(got[:n], _qq(x))
        np.testing.assert_array_equal(got[n:], 0.0)

    @settings(max_examples=6, deadline=None)
    @given(n=st.integers(1, 1200), ring=st.booleans())
    def test_all_to_all(self, tables, cfg, n, ring):
        from jax.sharding import PartitionSpec as P
        t = TransportConfig("ring") if ring else None
        x = jnp.asarray(
            np.random.default_rng(n).standard_normal((1, n)), jnp.float32)

        def f(v):
            out, ok = qlc_all_to_all(v, "d", tables, cfg, transport=t)
            return out, ok
        out, ok = jax.jit(_shard_map1(f, (P(), P())))(x)
        assert bool(ok)
        assert out.shape == (1, n)
        np.testing.assert_array_equal(np.asarray(out)[0], _qq(x[0]))

    @settings(max_examples=4, deadline=None)
    @given(n=st.integers(1, 1500))
    def test_psum_shape_preserved(self, tables, cfg, n):
        from jax.sharding import PartitionSpec as P
        x = jnp.asarray(np.random.default_rng(n).standard_normal(n),
                        jnp.float32)

        def f(v):
            return qlc_psum(v, "d", 1, tables, cfg)
        out, ok = jax.jit(_shard_map1(f, (P(), P())))(x)
        assert bool(ok)
        assert out.shape == x.shape
        # d=1 psum: quantize twice (RS then AG wires)
        np.testing.assert_array_equal(np.asarray(out), _qq(_qq(x)))


class TestFusedAccumulateKernel:
    def test_zero_acc_is_exact_decode(self, tables, rng):
        """fma(val, scale, 0) rounds once, like a plain multiply — so a
        zero accumulator must reproduce decode_dequantize bit for bit."""
        from repro.kernels import ops as kops
        n_chunks, k, cap = 16, 256, 64
        x = rng.standard_normal((n_chunks, k)).astype(np.float32)
        words, _, scales = kops.quantize_encode(jnp.asarray(x), tables,
                                                cap)
        dec = kops.decode_dequantize(words, scales, tables, k)
        got = kops.decode_dequantize_accumulate(
            jnp.zeros((n_chunks, k), jnp.float32), words, scales, tables,
            k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(dec))

    def test_accumulate_within_float_ulp(self, tables, rng):
        """acc + decode: the fused kernel may keep excess precision
        (FMA-contract the dequantize multiply into the accumulate), so
        it is only required to match a separate decode-then-add to one
        f32 ulp. Bit-identity across TRANSPORTS is guaranteed
        structurally instead — both run the identical accumulate op
        sequence (see transport._accumulate_row_pieces) — and is asserted
        by TestTransportEquivalence."""
        from repro.kernels import ops as kops
        n_chunks, k, cap = 16, 256, 64
        x = rng.standard_normal((n_chunks, k)).astype(np.float32)
        acc = rng.standard_normal((n_chunks, k)).astype(np.float32)
        words, _, scales = kops.quantize_encode(jnp.asarray(x), tables,
                                                cap)
        ref = np.asarray(jnp.asarray(acc)
                         + kops.decode_dequantize(words, scales, tables,
                                                  k))
        got = np.asarray(kops.decode_dequantize_accumulate(
            jnp.asarray(acc), words, scales, tables, k))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)

    def test_accumulate_values_escape_merge(self, tables, rng):
        """Escaped chunks must fold their POOL values (not the garbage
        decoded slot) into the accumulator on both codec paths."""
        import dataclasses
        from repro.comm import accumulate_values, compress_values
        cfg = CommConfig(chunk_symbols=256, capacity_words=60,
                         pool_slots_per_1k=1024)
        x = (rng.standard_normal(16 * 256) *
             np.exp(2 * rng.standard_normal(16 * 256))).astype(np.float32)
        acc = rng.standard_normal(16 * 256).astype(np.float32)
        payload, scales = compress_values(jnp.asarray(x), tables, cfg)
        n_esc = int(payload.pool_count.sum())
        assert n_esc > 0                            # escapes exercised
        esc_rows = np.asarray(payload.flags).astype(bool)
        want = acc + _qq(x)
        outs = {}
        for uk in (False, True):
            c = dataclasses.replace(cfg, use_kernels=uk)
            out, ok = accumulate_values(jnp.asarray(acc), payload, scales,
                                        tables, c)
            assert bool(ok)
            got = np.asarray(out).reshape(16, 256)
            # escaped chunks take the eager pool epilogue on both paths:
            # exactly acc + dequantized raw symbols
            np.testing.assert_array_equal(
                got[esc_rows], want.reshape(16, 256)[esc_rows])
            np.testing.assert_allclose(got, want.reshape(16, 256),
                                       rtol=1e-5, atol=1e-6)
            outs[uk] = got
        # pure vs kernel agree to excess-precision tolerance everywhere
        np.testing.assert_allclose(outs[False], outs[True], rtol=1e-5,
                                   atol=1e-6)


class TestAlphaBetaModel:
    def test_ring_wins_large_payloads(self):
        m = AlphaBetaModel()
        wire, vals = 64e6, 128e6            # 128 MB shard, ~2x compressed
        one = modeled_oneshot_time(m, wire, vals, 8)
        ring = modeled_ring_time(m, wire, vals, 8)
        assert ring < one                   # decode hides behind the wire
        t = choose_transport(wire, vals, 8, model=m)
        assert t.kind == "ring"

    def test_oneshot_wins_tiny_payloads(self):
        m = AlphaBetaModel()
        wire, vals = 2e3, 4e3               # alpha-dominated
        assert modeled_oneshot_time(m, wire, vals, 8) \
            < modeled_ring_time(m, wire, vals, 8)
        assert choose_transport(wire, vals, 8, model=m).kind == "oneshot"

    def test_axis_size_one_stays_oneshot(self):
        assert choose_transport(1e9, 2e9, 1).kind == "oneshot"

    def test_crossover_monotonic(self):
        m = AlphaBetaModel()
        cross = transport_crossover_bytes(8, model=m)
        assert 0 < cross < 1 << 40
        for factor, want in ((4.0, "ring"), (0.25, "oneshot")):
            vb = cross * factor
            t = choose_transport(vb / 2.1, vb, 8, model=m)
            assert t.kind == want, (factor, t)

    def test_hop_chunks_bounded_and_modeled(self):
        m = AlphaBetaModel()
        t = choose_transport(64e6, 128e6, 8, model=m,
                             hop_chunk_candidates=(1, 2, 4, 8))
        assert 1 <= t.hop_chunks <= 8
        # more pieces than the model's best never beats it
        best = modeled_ring_time(m, 64e6, 128e6, 8, t.hop_chunks)
        for h in (1, 2, 4, 8):
            assert best <= modeled_ring_time(m, 64e6, 128e6, 8, h) + 1e-12

    def test_wire_bytes_model_matches_payload(self, tables, cfg):
        from repro.comm import compress_values, wire_bytes
        n = 8 * cfg.chunk_symbols
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n),
                        jnp.float32)
        payload, scales = compress_values(x, tables, cfg)
        got = wire_bytes(payload, scales)
        want = payload_wire_bytes(n, cfg.chunk_symbols, cfg.capacity_words,
                                  cfg.pool_slots_per_1k)
        assert got == want

    def test_resolve_transport(self):
        assert resolve_transport(None).kind == "oneshot"
        assert resolve_transport("ring").kind == "ring"
        t = TransportConfig("ring", hop_chunks=4)
        assert resolve_transport(t) is t
        with pytest.raises(ValueError):
            TransportConfig("carrier-pigeon")


MD_PRELUDE = """
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core import TABLE1, build_tables, distributions
from repro.comm import (CommConfig, TransportConfig, plan_for_tables,
                        qlc_all_gather, qlc_all_to_all, qlc_psum,
                        qlc_reduce_scatter)

devs = jax.devices()
assert len(devs) == 8, devs
mesh = Mesh(np.array(devs), ("d",))
counts = distributions.ffn1_counts(1 << 16)
tables = build_tables(counts, TABLE1)
plan = plan_for_tables(tables, counts, chunk_symbols=256)
cfg = CommConfig.from_plan(plan)
cfg_kern = CommConfig.from_plan(plan, use_kernels=True)
RING1 = TransportConfig("ring", 1)
RING2 = TransportConfig("ring", 2)

rng = np.random.default_rng(0)
X = rng.standard_normal((8, 4096)).astype(np.float32)

def run(fn, transport):
    def f(x):
        out, ok = fn(x[0], transport)
        return out[None], ok[None]
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                             out_specs=(P("d", None), P("d")),
                             check_rep=False))(X)
"""


class TestTransportEquivalence:
    def test_ring_bit_identical_to_oneshot_all_collectives(self):
        """The acceptance invariant: ring (hop_chunks 1 and 2) and
        one-shot produce bit-identical outputs and identical ok flags
        on every collective, pure-JAX and fused-kernel paths."""
        run_md(MD_PRELUDE + """
for cname, c in [("pure", cfg), ("kern", cfg_kern)]:
    for name, fn in [
        ("all_gather", lambda x, t, c=c: qlc_all_gather(
            x, "d", tables, c, transport=t, axis_size=8)),
        ("reduce_scatter", lambda x, t, c=c: (lambda r: (r.segment, r.ok))(
            qlc_reduce_scatter(x, "d", 8, tables, c, transport=t))),
        ("psum", lambda x, t, c=c: qlc_psum(
            x, "d", 8, tables, c, transport=t)),
    ]:
        o1, ok1 = run(fn, None)
        assert np.asarray(ok1).all()
        for t in (RING1, RING2):
            o2, ok2 = run(fn, t)
            np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
            np.testing.assert_array_equal(np.asarray(ok1), np.asarray(ok2))
        print(cname, name, "ring==oneshot OK")

X3 = rng.standard_normal((8, 8, 512)).astype(np.float32)
def run_a2a(c, t):
    def f(x):
        out, ok = qlc_all_to_all(x[0], "d", tables, c, transport=t)
        return out[None], ok[None]
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None, None),
                             out_specs=(P("d", None, None), P("d")),
                             check_rep=False))(X3)
for cname, c in [("pure", cfg), ("kern", cfg_kern)]:
    o1, ok1 = run_a2a(c, None)
    assert np.asarray(ok1).all()
    for t in (RING1, RING2):
        o2, ok2 = run_a2a(c, t)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        np.testing.assert_array_equal(np.asarray(ok1), np.asarray(ok2))
    print(cname, "all_to_all ring==oneshot OK")
print("EQUIV OK")
""")

    def test_non_multiple_lengths_match_across_transports(self):
        """Sliced outputs agree even when the transports pad to
        different internal lengths (hop pieces vs one chunk)."""
        run_md(MD_PRELUDE + """
Xn = rng.standard_normal((8, 3700)).astype(np.float32)  # not 256-mult
def run_n(fn, transport):
    def f(x):
        out, ok = fn(x[0], transport)
        return out[None], ok[None]
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                             out_specs=(P("d", None), P("d"))))(Xn)
for name, fn in [
    ("all_gather", lambda x, t: qlc_all_gather(
        x, "d", tables, cfg, transport=t, axis_size=8)),
    ("psum", lambda x, t: qlc_psum(x, "d", 8, tables, cfg, transport=t)),
]:
    o1, _ = run_n(fn, None)
    for t in (RING1, RING2):
        o2, _ = run_n(fn, t)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    print(name, "non-multiple OK")
print("NONMULT OK")
""")

    def test_overflow_ok_false_parity(self):
        """Escape-pool overflow must flag ok=False identically on both
        transports (the trainer's retry signal)."""
        run_md(MD_PRELUDE + """
bad = CommConfig(chunk_symbols=256, capacity_words=60, pool_slots_per_1k=1)
Xh = (rng.standard_normal((8, 4096)) *
      np.exp(2 * rng.standard_normal((8, 4096)))).astype(np.float32)
def run_h(fn, transport):
    def f(x):
        out, ok = fn(x[0], transport)
        return out[None], ok[None]
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                             out_specs=(P("d", None), P("d"))))(Xh)
for name, fn in [
    ("all_gather", lambda x, t: qlc_all_gather(
        x, "d", tables, bad, transport=t, axis_size=8)),
    ("reduce_scatter", lambda x, t: (lambda r: (r.segment, r.ok))(
        qlc_reduce_scatter(x, "d", 8, tables, bad, transport=t))),
    ("psum", lambda x, t: qlc_psum(x, "d", 8, tables, bad, transport=t)),
]:
    _, ok1 = run_h(fn, None)
    _, ok2 = run_h(fn, RING1)
    assert not np.asarray(ok1).any(), name
    np.testing.assert_array_equal(np.asarray(ok1), np.asarray(ok2))
    print(name, "overflow parity OK")
print("OVERFLOW OK")
""")

    def test_hop_chunks_ok_parity_with_clustered_escapes(self):
        """hop_chunks=2 splits each row into pieces; escapes clustered
        in ONE piece must not flip ok vs one-shot (the ROADMAP parity
        gap): pieces carry row-sized pools and ok is judged on the
        summed row count, so a row whose total fits its escape budget
        is ok=True on every transport — and decodes bit-identically."""
        run_md(MD_PRELUDE + """
from repro.comm import compress_values

# pool slots are per 1024 CHUNKS: a 4096-symbol row is 16 chunks, so
# 512/1k gives an 8-slot row pool (and a 4-slot half-row piece pool)
tight = CommConfig(chunk_symbols=256, capacity_words=60,
                   pool_slots_per_1k=512)
rng2 = np.random.default_rng(42)
Xc = rng2.standard_normal((8, 4096)).astype(np.float32)
# heavy-tail chunks 8..13 (all inside piece 2 of an h=2 split): their
# coded length blows past capacity_words, so each escapes to the pool
Xc[:, 8 * 256:14 * 256] *= np.exp(
    2 * rng2.standard_normal((8, 6 * 256))).astype(np.float32)
# precondition, per row: escapes live ONLY in piece 2, and the total
# fits the 8-slot row budget but overflows the 4-slot HALF-row budget
# a piece-local predicate would use
for r in range(8):
    flags = np.asarray(compress_values(
        jnp.asarray(Xc[r]), tables, tight)[0].flags)
    assert flags[:8].sum() == 0 and 4 < flags.sum() <= 8, (r, flags)

def run_c(fn, transport, x):
    def f(v):
        out, ok = fn(v[0], transport)
        return out[None], ok[None]
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                             out_specs=(P("d", None), P("d"))))(x)
for name, fn in [
    ("all_gather", lambda x, t: qlc_all_gather(
        x, "d", tables, tight, transport=t, axis_size=8)),
    ("reduce_scatter", lambda x, t: (lambda r: (r.segment, r.ok))(
        qlc_reduce_scatter(x, "d", 8, tables, tight, transport=t))),
    ("psum", lambda x, t: qlc_psum(x, "d", 8, tables, tight,
                                   transport=t)),
]:
    o1, ok1 = run_c(fn, None, Xc)
    o2, ok2 = run_c(fn, RING2, Xc)
    np.testing.assert_array_equal(np.asarray(ok1), np.asarray(ok2))
    if np.asarray(ok1).all():
        # outputs are only contractual when ok says lossless
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    if name == "all_gather":
        # the all_gather wire is the whole row, so its 8-slot budget
        # absorbs the clustered burst — ok must be True, which the old
        # piece-local predicate (4-slot half-row pools) flipped False.
        # reduce_scatter/psum wire 512-symbol SEGMENTS (1 slot), where
        # the burst genuinely overflows: ok parity, not ok=True, is
        # their contract here.
        assert np.asarray(ok1).all(), name
    print(name, "clustered-escape parity OK")

# and a genuinely overflowing row still flags False on BOTH
Xo = np.array(Xc)
Xo[:, :8 * 256] *= np.exp(
    2 * rng2.standard_normal((8, 8 * 256))).astype(np.float32)
for t in (None, RING2):
    _, ok = run_c(lambda x, tr: qlc_psum(
        x, "d", 8, tables, tight, transport=tr), t, Xo)
    assert not np.asarray(ok).any(), t
print("HOPPAR OK")
""")


class TestShardedWeightOpen:
    def test_ring_open_matches_full_open(self):
        """open_params on a chunk-sharded wire (ring and one-shot
        transports, pure and kernel decode) == the unsharded open,
        bit for bit."""
        run_md("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core import distributions
from repro.core.registry import CodecRegistry
from repro.comm import TransportConfig
from repro.comm.weights import compress_groups
from repro.serving import open_params

mesh = Mesh(np.array(jax.devices()), ("d",))
reg = CodecRegistry()
reg.register("default", distributions.ffn1_counts(1 << 16))
rng = np.random.default_rng(0)
params = {"ffn": jnp.asarray(rng.standard_normal((2, 128, 1024)),
                             jnp.float32)}
for use_kernels in (False, True):
    wired, wc = compress_groups(params, reg, use_kernels=use_kernels)
    ref = open_params(wired, wc)
    assert wc.meta["ffn"].n_chunks % 8 == 0
    specs = {"ffn": {"words": P(None, "d", None), "scales": P(None, "d")}}
    for t in ("ring", TransportConfig("ring", 2), "oneshot"):
        g = jax.jit(shard_map(
            lambda w, t=t: open_params(w, wc, axis_name="d",
                                       axis_size=8, transport=t),
            mesh=mesh, in_specs=(specs,), out_specs={"ffn": P()},
            check_rep=False))
        np.testing.assert_array_equal(np.asarray(g(wired)["ffn"]),
                                      np.asarray(ref["ffn"]))
        print(f"kernels={use_kernels} {t} sharded open OK")
print("WEIGHTS OK")
""")


class TestTrainStepTransportKeys:
    def test_ring_step_bit_identical_to_oneshot_step(self):
        """make_compressed_step with per-collective ring transport keys
        must produce bit-identical parameters to the one-shot step."""
        run_md("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config, reduced
from repro.comm import CommConfig, TransportConfig, calibrate_for_gradients
from repro.data import DataConfig, SyntheticDataset
from repro.models import init_params
from repro.parallel import sharding as shd
from repro.training import (OptConfig, TrainConfig,
                            init_compressed_opt_state,
                            make_compressed_step)

mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
            ("pod", "data", "model"))
cfg = reduced(get_config("deepseek-coder-33b"), d_model=32, num_layers=1)
opt_cfg = OptConfig(lr=1e-2, warmup_steps=2, total_steps=50)
train_cfg = TrainConfig()
data = SyntheticDataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                   global_batch=8, seed=3))
with shd.use_mesh(mesh):
    params = init_params(cfg, jax.random.PRNGKey(0))
b0 = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
tables, plan = calibrate_for_gradients(cfg, params, b0, chunk_symbols=256)
# Total escape pool: the tiny model's segments hold only tens of
# chunks, so the default ~1-slot pool can overflow on heavy-tailed
# gradient steps — and overflowed payloads decode to transport-specific
# unspecified values (ok=False -> trainer retries, tested elsewhere).
# Bit-identity is asserted in the ok=True regime.
comm_cfg = CommConfig.from_plan(plan, pool_slots_per_1k=1024)

ring = {"grads": TransportConfig("ring", 2), "params": "ring"}
steps = {}
for name, transport in [("oneshot", None), ("ring", ring),
                        ("auto", "auto")]:
    step = jax.jit(make_compressed_step(cfg, opt_cfg, train_cfg, mesh,
                                        tables, comm_cfg,
                                        transport=transport))
    with shd.use_mesh(mesh):
        oc = init_compressed_opt_state(cfg, mesh, train_cfg, comm_cfg,
                                       opt_cfg)
        p = params
        for s in range(2):
            batch = {k: jnp.asarray(v)
                     for k, v in data.batch_at(s).items()}
            p, oc, m = step(p, oc, batch)
            assert bool(np.asarray(m["ok"])), (name, s)
    steps[name] = p
for name in ("ring", "auto"):
    for a, b in zip(jax.tree.leaves(steps["oneshot"]),
                    jax.tree.leaves(steps[name])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(name, "== oneshot OK")
print("TRAINSTEP OK")
""", timeout=1800)
