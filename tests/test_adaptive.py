"""Online codec adaptation: telemetry -> drift -> hot-swap.

The contract under test (ISSUE 9 acceptance):
(a) a container encoded under the pre-swap scheme-id decodes
    bit-exactly after the hot-swap — old entries retained, never
    mutated, and the registry JSON round-trips every revision;
(b) telemetry is a pure side output — a compressed train step with
    ``telemetry=True`` is bit-identical to ``telemetry=False`` when no
    swap triggers (multi-device subprocess);
(c) the full loop converges: drift on a shifted distribution flags,
    recalibration rebinds to a NEW scheme-id, and matched traffic
    never re-flags (thrash-free).
"""
import numpy as np
import pytest

from repro.adaptive import (AdaptiveChannel, AdaptiveController, DriftConfig,
                            DriftPolicy, Recalibrator, TrafficMonitor,
                            TrainingAdapter)
from repro.comm import container as qc
from repro.comm.channel import Channel, ChannelSpec
from repro.comm.planner import plan_for_tables
from repro.core import adapt
from repro.core.distributions import ffn1_counts, ffn2_counts
from repro.core.registry import CodecEntry, CodecRegistry
from tests.md_util import run_md

CHUNK = 512


def _registry_with(name="acts", counts=None, **plan_kw):
    """Registry with one entry calibrated on ``counts`` (default: the
    smooth Gaussian ffn1 stream — paper Table 1 territory)."""
    counts = ffn1_counts(1 << 15, 0) if counts is None else counts
    reg = CodecRegistry()
    tables = adapt.calibrate_tables(counts, allow_search=False)
    plan = plan_for_tables(tables, counts, chunk_symbols=CHUNK, **plan_kw)
    entry = reg.register_tables(name, tables, plan, counts=counts)
    return reg, entry


def _hostile_counts(entry, n=1 << 15):
    """Histogram concentrated on the deployed codec's LONGEST codes —
    guaranteed to measure far over the plan's expectation."""
    enc_len = np.asarray(entry.tables.enc_len, np.float64)
    counts = np.zeros(256)
    counts[np.argsort(enc_len)[-8:]] = n / 8.0
    return counts


class TestTrafficMonitor:
    def test_observe_accumulates_with_decay(self):
        reg, _ = _registry_with()
        mon = TrafficMonitor(reg, decay=0.5)
        h = ffn1_counts(1 << 14, 1)
        t1 = mon.observe("acts", h)
        assert t1.events == 1
        assert t1.symbols == pytest.approx(h.sum())
        t2 = mon.observe("acts", h)
        assert t2 is t1
        assert t2.symbols == pytest.approx(1.5 * h.sum())
        np.testing.assert_allclose(t2.counts, 1.5 * h)

    def test_decay_washes_out_old_phase(self):
        # After a shift, the pre-shift mass must decay away so a
        # recalibration on ``counts`` sees the NEW distribution.
        reg, _ = _registry_with()
        mon = TrafficMonitor(reg, decay=0.5)
        spike_old = np.zeros(256)
        spike_old[7] = 1e6
        mon.observe("acts", spike_old)
        new = ffn1_counts(1 << 14, 2)
        for _ in range(30):
            t = mon.observe("acts", new)
        assert t.counts[7] / t.counts.sum() < 1e-3

    def test_measured_bits_matches_manual_dot(self):
        reg, entry = _registry_with()
        mon = TrafficMonitor(reg)
        h = ffn1_counts(1 << 14, 3)
        mon.observe("acts", h)
        want = float(np.dot(h, np.asarray(entry.tables.enc_len,
                                          np.float64)) / h.sum())
        assert mon.measured_bits("acts") == pytest.approx(want)
        # matched traffic should sit near the plan's expectation
        assert abs(mon.excess_bits("acts")) < 0.25

    def test_escape_and_overflow_rates(self):
        reg, _ = _registry_with()
        mon = TrafficMonitor(reg, decay=1.0)
        h = np.full(256, 16.0)
        mon.observe("acts", h, escaped_chunks=3, chunks=100,
                    overflow=True, containers=1.0)
        mon.observe("acts", h, escaped_chunks=1, chunks=100,
                    overflow=False, containers=1.0)
        t = mon.traffic("acts")
        assert t.escape_rate == pytest.approx(4 / 200)
        assert t.overflow_rate == pytest.approx(0.5)

    def test_ledger_keyed_by_scheme_id(self):
        reg, entry = _registry_with()
        mon = TrafficMonitor(reg)
        mon.observe("acts", np.full(256, 4.0))
        mon.observe("acts", np.full(256, 9.0), scheme_id=999)
        assert mon.traffic("acts").scheme_id == entry.scheme_id
        assert mon.traffic("acts", 999).counts[0] == pytest.approx(9.0)
        assert mon.names() == ["acts"]
        mon.reset("acts")
        assert mon.traffic("acts") is None
        assert mon.traffic("acts", 999) is not None

    def test_bad_histogram_rejected(self):
        reg, _ = _registry_with()
        mon = TrafficMonitor(reg)
        with pytest.raises(ValueError, match="bins"):
            mon.observe("acts", np.zeros(128))
        with pytest.raises(ValueError, match="decay"):
            TrafficMonitor(reg, decay=0.0)

    def test_snapshot_rows(self):
        reg, entry = _registry_with()
        mon = TrafficMonitor(reg)
        mon.observe("acts", ffn1_counts(1 << 14, 4))
        (row,) = mon.snapshot()
        assert row["name"] == "acts"
        assert row["scheme_id"] == entry.scheme_id
        assert row["measured_bits"] > 0
        assert row["expected_bits"] == \
            entry.plan.expected_bits_per_symbol


class TestDriftPolicy:
    def test_matched_traffic_never_flags(self):
        reg, _ = _registry_with()
        mon = TrafficMonitor(reg)
        pol = DriftPolicy(mon, DriftConfig())
        for _ in range(10):
            mon.observe("acts", ffn1_counts(1 << 14, 5))
            assert not pol.update("acts")

    def test_drift_flags_after_hysteresis(self):
        reg, entry = _registry_with()
        mon = TrafficMonitor(reg)
        pol = DriftPolicy(mon, DriftConfig(hysteresis=2, cooldown=0))
        bad = _hostile_counts(entry)
        mon.observe("acts", bad)
        mon.observe("acts", bad)
        assert not pol.update("acts")     # over once — below hysteresis
        assert pol.update("acts")         # over twice — flagged

    def test_min_symbols_and_events_guard(self):
        reg, entry = _registry_with()
        mon = TrafficMonitor(reg)
        pol = DriftPolicy(mon, DriftConfig(min_symbols=1e6, cooldown=0))
        for _ in range(5):
            mon.observe("acts", _hostile_counts(entry))
            assert not pol.update("acts")   # never enough symbols

    def test_cooldown_suppresses_fresh_binding(self):
        reg, entry = _registry_with()
        mon = TrafficMonitor(reg)
        pol = DriftPolicy(mon, DriftConfig(hysteresis=1, cooldown=3))
        pol.notify_swapped("acts")
        bad = _hostile_counts(entry)
        flags = []
        for _ in range(5):
            mon.observe("acts", bad)
            mon.observe("acts", bad)
            flags.append(pol.update("acts"))
        assert flags[:3] == [False, False, False]   # immune
        assert any(flags[3:])                       # then judged again

    def test_escape_spike_triggers_alone(self):
        # Mean code length stays on-plan but the tail blows the pool.
        reg, _ = _registry_with()
        mon = TrafficMonitor(reg)
        pol = DriftPolicy(mon, DriftConfig(hysteresis=1, cooldown=0))
        good = ffn1_counts(1 << 14, 6)
        mon.observe("acts", good, escaped_chunks=50, chunks=100)
        mon.observe("acts", good, escaped_chunks=50, chunks=100)
        assert pol.update("acts")

    def test_overflow_triggers_alone(self):
        reg, _ = _registry_with()
        mon = TrafficMonitor(reg)
        pol = DriftPolicy(mon, DriftConfig(hysteresis=1, cooldown=0))
        good = ffn1_counts(1 << 14, 6)
        mon.observe("acts", good, overflow=True, containers=1.0)
        mon.observe("acts", good, overflow=True, containers=1.0)
        assert pol.update("acts")


class TestRecalibrator:
    def test_produces_new_revision_preserving_geometry(self):
        reg, old = _registry_with(drift_margin_bits=0.25,
                                  pool_slots_per_1k=16)
        rc = Recalibrator(reg)
        new = rc.recalibrate("acts", ffn2_counts(1 << 15, 7))
        assert new.scheme_id != old.scheme_id
        assert reg["acts"] is new
        assert reg.by_id(old.scheme_id) is old        # retained
        # jitted geometry survives; headroom policy carries over
        assert new.plan.chunk_symbols == old.plan.chunk_symbols
        assert new.plan.drift_margin_bits == old.plan.drift_margin_bits

    def test_converged_recalibration_is_noop(self):
        reg, _ = _registry_with()
        rc = Recalibrator(reg)
        shifted = ffn2_counts(1 << 15, 7)
        first = rc.recalibrate("acts", shifted)
        again = rc.recalibrate("acts", shifted)
        assert again is first                 # register_revision no-op
        assert len(reg) == 2                  # no id churn

    def test_revision_beats_stale_codec_on_shifted_traffic(self):
        reg, old = _registry_with()
        shifted = ffn2_counts(1 << 15, 8)
        stale = float(np.dot(shifted, np.asarray(old.tables.enc_len,
                                                 np.float64))
                      / shifted.sum())
        new = Recalibrator(reg).recalibrate("acts", shifted)
        fresh = float(np.dot(shifted, np.asarray(new.tables.enc_len,
                                                 np.float64))
                      / shifted.sum())
        assert fresh < stale

    def test_empty_histogram_rejected(self):
        reg, _ = _registry_with()
        with pytest.raises(ValueError, match="empty"):
            Recalibrator(reg).recalibrate("acts", np.zeros(256))


class TestRegistryRevisions:
    def test_revision_json_round_trip(self):
        reg, old = _registry_with(drift_margin_bits=0.25)
        new = Recalibrator(reg).recalibrate("acts", ffn2_counts(1 << 15, 9))
        reg2 = CodecRegistry.from_json(reg.to_json())
        assert reg2["acts"].scheme_id == new.scheme_id   # newest wins
        assert len(reg2) == len(reg)
        for e in reg.entries():
            e2 = reg2.by_id(e.scheme_id)
            np.testing.assert_array_equal(
                np.asarray(e.tables.enc_code), np.asarray(e2.tables.enc_code))
            assert e2.plan == e.plan                     # margin included
        assert reg2.by_id(old.scheme_id).plan.drift_margin_bits == 0.25

    def test_get_entry_default(self):
        reg, entry = _registry_with()
        assert reg.get("missing") is None
        assert reg.get("missing", "acts") is entry       # key fallback
        assert reg.get("missing", entry) is entry        # entry fallback
        assert isinstance(reg.get("acts", entry), CodecEntry)

    def test_plain_reregistration_still_raises(self):
        # register_revision is the ONLY name-moving path; a plain
        # register_tables collision stays an error.
        reg, _ = _registry_with()
        counts = ffn2_counts(1 << 15, 9)
        tables = adapt.calibrate_tables(counts)
        plan = plan_for_tables(tables, counts, chunk_symbols=CHUNK)
        with pytest.raises(ValueError, match="acts"):
            reg.register_tables("acts", tables, plan)


class TestAdaptiveChannel:
    def test_forwarding_and_atomic_rebind(self):
        reg, old = _registry_with()
        ch = Channel(ChannelSpec(codec="acts"), registry=reg)
        ach = AdaptiveChannel(ch)
        assert ach.entry is old                  # attribute forwarding
        before = ach.channel
        x = np.random.default_rng(0).normal(size=CHUNK * 4) \
            .astype(np.float32)
        p1, s1 = ach.compress(x)

        new = Recalibrator(reg).recalibrate("acts", ffn2_counts(1 << 15, 1))
        ach.rebind(new)
        assert ach.entry is new
        assert ach.channel is not before
        assert before.entry is old               # old view consistent
        p2, _ = ach.compress(x)                  # new binding encodes
        assert p2.words is not p1.words


class TestHotSwapLossless:
    """Acceptance (a): encode under scheme A, drift -> swap to B,
    decode the old in-flight container bit-exactly."""

    def test_old_container_decodes_after_swap(self):
        reg, entry_a = _registry_with()
        ctl = AdaptiveController(
            reg, drift=DriftConfig(min_events=2, hysteresis=2, cooldown=0,
                                   min_symbols=1024))
        ach = ctl.wrap(Channel(ChannelSpec(codec="acts"), registry=reg))

        values = np.random.default_rng(3).normal(
            size=CHUNK * 8).astype(np.float32)
        container = qc.encode_values(values, entry_a)
        ref, ok, _ = qc.decode_values(container, reg)
        assert bool(ok)
        ref = np.asarray(ref)

        shifted = ffn2_counts(1 << 15, 2)
        swaps = []
        for _ in range(4):
            ctl.observe("acts", shifted)
            swaps += ctl.check()
        assert swaps, "drift never triggered a swap"
        assert swaps == ctl.events
        entry_b = reg["acts"]
        assert entry_b.scheme_id != entry_a.scheme_id
        assert ach.entry is entry_b              # channel rebound

        # the old container is self-describing: still bit-exact
        post, ok, _ = qc.decode_values(container, reg)
        assert bool(ok)
        np.testing.assert_array_equal(np.asarray(post), ref)

        # new containers under the new binding round-trip too (total
        # pool: the probe data is deliberately mismatched to codec B)
        c2 = qc.encode_values(values, entry_b, pool_slots_per_1k=1024)
        got, ok, _ = qc.decode_values(c2, reg)
        assert bool(ok)
        np.testing.assert_array_equal(
            np.asarray(got), ref)                # same e4m3 values

    def test_no_thrash_after_swap(self):
        """Acceptance (c) convergence: post-swap matched traffic never
        re-flags — one shift, one swap."""
        reg, entry_a = _registry_with()
        ctl = AdaptiveController(
            reg, drift=DriftConfig(min_events=2, hysteresis=2, cooldown=2,
                                   min_symbols=1024))
        ctl.wrap(Channel(ChannelSpec(codec="acts"), registry=reg))
        shifted = ffn2_counts(1 << 15, 4)
        for _ in range(4):
            ctl.observe("acts", shifted)
            ctl.check()
        assert len(ctl.events) == 1
        for _ in range(12):
            ctl.observe("acts", shifted)
            ctl.check()
        assert len(ctl.events) == 1              # still exactly one swap

    def test_converged_recalibration_does_not_swap(self):
        """A re-flag whose recalibration lands back on the deployed
        codec must NOT allocate a new scheme-id (no id churn) — the
        policy is reset instead so the same ledger can't loop."""
        reg, _ = _registry_with()
        # margin -10 marks ANY traffic as drifted — forces the
        # recalibration path on every check
        ctl = AdaptiveController(
            reg, drift=DriftConfig(margin_bits=-10.0, hysteresis=1,
                                   cooldown=0, min_events=1,
                                   min_symbols=1024))
        shifted = ffn2_counts(1 << 15, 4)
        ctl.observe("acts", shifted)
        assert len(ctl.check()) == 1             # genuine swap
        n_ids = len(reg)
        # fresh post-swap ledger sees the SAME distribution: the forced
        # recalibration converges onto the deployed codec -> no-op
        ctl.observe("acts", shifted)
        assert ctl.check() == []
        assert len(reg) == n_ids
        assert len(ctl.events) == 1


class TestTrainingAdapter:
    def _controller(self):
        reg, entry = _registry_with(name="grads")
        ctl = AdaptiveController(
            reg, drift=DriftConfig(min_events=2, hysteresis=2, cooldown=0,
                                   min_symbols=1024))
        return reg, entry, ctl

    def test_checks_only_on_boundary_and_rebuilds(self):
        reg, entry, ctl = self._controller()
        builds, swaps = [], []
        adapter = TrainingAdapter(
            ctl, lambda: builds.append(1) or "new_step_fn",
            grad_key="grads", check_every=4, on_swap=swaps.append)
        bad = _hostile_counts(entry)
        out = None
        for step in range(8):
            out = adapter(step, {TrainingAdapter.GRADS_HIST: bad})
            if step in (0, 1, 2, 4, 5, 6):       # off-boundary steps
                assert out is None
        assert out == "new_step_fn"              # swap on a boundary
        assert builds == [1]
        assert len(swaps) == 1 and swaps[0].name == "grads"
        assert reg["grads"].scheme_id != entry.scheme_id

    def test_no_swap_returns_none(self):
        reg, entry, ctl = self._controller()
        adapter = TrainingAdapter(ctl, lambda: "rebuilt",
                                  grad_key="grads", check_every=2)
        good = np.asarray(entry.counts, np.float64)
        for step in range(6):
            assert adapter(
                step, {TrainingAdapter.GRADS_HIST: good}) is None
        assert ctl.events == []


MD_TELEMETRY = """
import numpy as np, jax, jax.numpy as jnp, dataclasses
from jax.sharding import Mesh
from repro.configs import get_config, reduced
from repro.comm import CommConfig, calibrate_for_gradients
from repro.data import DataConfig, SyntheticDataset
from repro.models import init_params
from repro.parallel import sharding as shd
from repro.training import (OptConfig, TrainConfig,
                            init_compressed_opt_state,
                            make_compressed_step)

mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
            ("pod", "data", "model"))
cfg = reduced(get_config("deepseek-coder-33b"), d_model=64, num_layers=2)
opt_cfg = OptConfig(lr=1e-2, warmup_steps=2, total_steps=50, grad_clip=1.0)
train_cfg = TrainConfig(microbatches=2)
data = SyntheticDataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                   global_batch=8, seed=3))
with shd.use_mesh(mesh):
    params = init_params(cfg, jax.random.PRNGKey(0))
_b0 = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
tables, plan = calibrate_for_gradients(cfg, params, _b0, chunk_symbols=256)
comm_cfg = dataclasses.replace(CommConfig.from_plan(plan),
                               pool_slots_per_1k=1024)

plain = jax.jit(make_compressed_step(cfg, opt_cfg, train_cfg, mesh,
                                     tables, comm_cfg))
telem = jax.jit(make_compressed_step(cfg, opt_cfg, train_cfg, mesh,
                                     tables, comm_cfg, telemetry=True))
with shd.use_mesh(mesh):
    opt0 = init_compressed_opt_state(cfg, mesh, train_cfg, comm_cfg,
                                     opt_cfg)
    pp, op = params, opt0
    pt, ot = params, opt0
    for step in range(3):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        pp, op, mp = plain(pp, op, batch)
        pt, ot, mt = telem(pt, ot, batch)
        assert bool(np.asarray(mp["ok"])) and bool(np.asarray(mt["ok"]))
        gh = np.asarray(mt["adapt/grads_hist"])
        ph = np.asarray(mt["adapt/params_hist"])
        assert gh.shape == (256,) and ph.shape == (256,)
        assert gh.sum() > 0 and ph.sum() > 0
        assert "adapt/grads_hist" not in mp

# telemetry is a pure side output: params AND opt state bit-identical
for a, b in zip(jax.tree.leaves(pp), jax.tree.leaves(pt)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(jax.tree.leaves(op), jax.tree.leaves(ot)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("TELEMETRY OK")
"""


class TestTelemetryEquivalence:
    def test_telemetry_step_bit_identical(self):
        """Acceptance (b): ``telemetry=True`` changes ONLY the metrics
        dict — params and optimizer state stay bit-identical to the
        non-adaptive step over multiple steps on 8 devices."""
        out = run_md(MD_TELEMETRY, n_devices=8, timeout=1800)
        assert "TELEMETRY OK" in out
