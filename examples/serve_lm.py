"""Batched serving example: prefill a batch of prompts, decode greedily.

The decode step here is exactly what the decode_32k / long_500k dry-run
cells lower at production scale. With ``--wire qlc`` the weights are
served from QLC wire: a codec registry calibrates per-parameter codecs,
the wire codec binds a Channel (kernel toggle + placement made once),
and the serving manifest round-trips the whole recipe through JSON
before the wire is opened in-graph.

With ``--kv-cache qlc`` the decode states are block-paged through the
compressed KV cache (``repro.serving.kv_cache``): per-layer codecs are
calibrated from a prefill snapshot into the same registry, full blocks
are encoded into QLC containers on eviction and decoded on access, and
the output is asserted TOKEN-IDENTICAL to the dense-cache run — the
lossless contract. (``--kv-cache e4m3`` additionally quantizes blocks
to e4m3 on eviction: smaller, but lossy like any fp8 cache.)

Run:  PYTHONPATH=src python examples/serve_lm.py --arch xlstm-125m
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_decode_states, init_params
from repro.serving import ServeConfig, generate, generate_paged, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-coder-33b",
                    help="any assigned arch; a reduced config is served")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--wire", default="none", choices=["none", "qlc"],
                    help="'qlc' serves from compressed weights opened "
                         "through a channel-bound wire codec")
    ap.add_argument("--kv-cache", default="none",
                    choices=["none", "qlc", "e4m3"],
                    help="'qlc' pages decode states through lossless "
                         "QLC containers (token-identical); 'e4m3' "
                         "also quantizes blocks on eviction (lossy)")
    ap.add_argument("--kv-block", type=int, default=128,
                    help="tokens per paged-cache block")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), frontend_prefix_len=0,
                  frontend=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(
        max_seq_len=args.prompt_len + args.new_tokens + 8,
        max_new_tokens=args.new_tokens)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)

    reg = None
    wc2 = None
    if args.wire == "qlc":
        from repro.comm.calibrate import histogram_of_tree
        from repro.core import CodecRegistry
        from repro.serving import (codec_from_manifest,
                                   compress_params_for_serving,
                                   open_params, serving_manifest)
        reg = CodecRegistry()
        reg.register("default", histogram_of_tree(params))
        wired, wc = compress_params_for_serving(params, reg)
        # manifest round trip — what a serving host reloads (registry,
        # per-leaf scheme-ids, AND the channel placement)
        wc2 = codec_from_manifest(serving_manifest(wc))
        ch = wc2.channel()
        print(f"serving {len(wc2.meta)} QLC-wired leaves via {ch}")
        gen = jax.jit(lambda w, pr: generate(
            open_params(w, wc2, channel=ch), cfg, pr, serve_cfg))
        serve_params = wired
    else:
        gen = jax.jit(lambda p, pr: generate(p, cfg, pr, serve_cfg))
        serve_params = params
    t0 = time.time()
    out = jax.block_until_ready(gen(serve_params, prompts))
    t_compile = time.time() - t0
    t0 = time.time()
    out = jax.block_until_ready(gen(serve_params, prompts))
    t_run = time.time() - t0

    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"compile {t_compile:.1f}s; decode {t_run*1e3:.0f}ms "
          f"({toks / t_run:.0f} tok/s on CPU)")
    print("sample:", np.asarray(out[0])[:12], "...")
    assert out.shape == (args.batch, args.new_tokens)
    assert (np.asarray(out) >= 0).all()

    if args.kv_cache != "none":
        from repro.core import CodecRegistry
        from repro.serving import (KVCacheSpec, PagedKVCache,
                                   calibrate_cache, kv_spec_from_manifest,
                                   serving_manifest)
        # per-layer KV codecs calibrate from a prefill-state snapshot
        # into the (shared, when --wire qlc) registry
        states = init_decode_states(cfg, args.batch, serve_cfg.max_seq_len)
        _, states = prefill(params, cfg, prompts, states)
        if reg is None:
            reg = CodecRegistry()
        spec = KVCacheSpec(block_tokens=args.kv_block, mode=args.kv_cache)
        calibrate_cache(reg, cfg, states, args.prompt_len, spec)
        if wc2 is not None:
            # KV scheme-ids round-trip next to the weight placement
            manifest = serving_manifest(wc2, kv_spec=spec, kv_registry=reg)
            spec, sids = kv_spec_from_manifest(manifest["kv"])
            print(f"kv manifest: {len(sids)} per-layer codecs "
                  f"{sorted(set(sids.values()))}")
        cache = PagedKVCache(spec, cfg, reg)
        # dense-cache baseline through the SAME host-driven decode loop
        out_dense = generate_paged(params, cfg, prompts, serve_cfg, None)
        out_paged = generate_paged(params, cfg, prompts, serve_cfg, cache)
        stats = cache.stats()
        print(f"kv-cache={args.kv_cache} block={args.kv_block}: "
              f"{stats['cold_blocks']} cold blocks, "
              f"{stats['compressed_bytes_per_token']:.0f} vs "
              f"{stats['dense_bytes_per_token']:.0f} dense B/token "
              f"(ratio {stats['compressed_vs_dense_ratio']:.3f}, "
              f"{stats['raw_sections']} raw sections)")
        if args.kv_cache == "qlc":
            # the lossless contract: byte-exact round trip => tokens
            # identical to the dense cache
            assert np.array_equal(np.asarray(out_paged),
                                  np.asarray(out_dense)), \
                "qlc KV cache changed tokens (lossless contract broken)"
            print("paged == dense: token-identical OK")
    print("OK")


if __name__ == "__main__":
    main()
