"""Batched serving example: prefill a batch of prompts, decode greedily.

The decode step here is exactly what the decode_32k / long_500k dry-run
cells lower at production scale. With ``--wire qlc`` the weights are
served from QLC wire: a codec registry calibrates per-parameter codecs,
the wire codec binds a Channel (kernel toggle + placement made once),
and the serving manifest round-trips the whole recipe through JSON
before the wire is opened in-graph.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch xlstm-125m
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serving import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-coder-33b",
                    help="any assigned arch; a reduced config is served")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--wire", default="none", choices=["none", "qlc"],
                    help="'qlc' serves from compressed weights opened "
                         "through a channel-bound wire codec")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), frontend_prefix_len=0,
                  frontend=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(
        max_seq_len=args.prompt_len + args.new_tokens + 8,
        max_new_tokens=args.new_tokens)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)

    if args.wire == "qlc":
        from repro.comm.calibrate import histogram_of_tree
        from repro.core import CodecRegistry
        from repro.serving import (codec_from_manifest,
                                   compress_params_for_serving,
                                   open_params, serving_manifest)
        reg = CodecRegistry()
        reg.register("default", histogram_of_tree(params))
        wired, wc = compress_params_for_serving(params, reg)
        # manifest round trip — what a serving host reloads (registry,
        # per-leaf scheme-ids, AND the channel placement)
        wc2 = codec_from_manifest(serving_manifest(wc))
        ch = wc2.channel()
        print(f"serving {len(wc2.meta)} QLC-wired leaves via {ch}")
        gen = jax.jit(lambda w, pr: generate(
            open_params(w, wc2, channel=ch), cfg, pr, serve_cfg))
        params = wired
    else:
        gen = jax.jit(lambda p, pr: generate(p, cfg, pr, serve_cfg))
    t0 = time.time()
    out = jax.block_until_ready(gen(params, prompts))
    t_compile = time.time() - t0
    t0 = time.time()
    out = jax.block_until_ready(gen(params, prompts))
    t_run = time.time() - t0

    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"compile {t_compile:.1f}s; decode {t_run*1e3:.0f}ms "
          f"({toks / t_run:.0f} tok/s on CPU)")
    print("sample:", np.asarray(out[0])[:12], "...")
    assert out.shape == (args.batch, args.new_tokens)
    assert (np.asarray(out) >= 0).all()
    print("OK")


if __name__ == "__main__":
    main()
