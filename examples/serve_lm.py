"""Batched serving example: prefill a batch of prompts, decode greedily.

The decode step here is exactly what the decode_32k / long_500k dry-run
cells lower at production scale.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch xlstm-125m
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serving import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-coder-33b",
                    help="any assigned arch; a reduced config is served")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), frontend_prefix_len=0,
                  frontend=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(
        max_seq_len=args.prompt_len + args.new_tokens + 8,
        max_new_tokens=args.new_tokens)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)

    gen = jax.jit(lambda p, pr: generate(p, cfg, pr, serve_cfg))
    t0 = time.time()
    out = jax.block_until_ready(gen(params, prompts))
    t_compile = time.time() - t0
    t0 = time.time()
    out = jax.block_until_ready(gen(params, prompts))
    t_run = time.time() - t0

    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"compile {t_compile:.1f}s; decode {t_run*1e3:.0f}ms "
          f"({toks / t_run:.0f} tok/s on CPU)")
    print("sample:", np.asarray(out[0])[:12], "...")
    assert out.shape == (args.batch, args.new_tokens)
    assert (np.asarray(out) >= 0).all()
    print("OK")


if __name__ == "__main__":
    main()
