"""Continuous-batching serving example over the request-based Engine.

Requests are submitted to ``repro.serving.Engine`` and join/leave the
padded decode batch mid-flight — the request-based API that replaced
the legacy ``generate`` batch calls in PR 6. The driver below staggers
``--concurrent`` submissions across engine steps (two tenants, a
fairness cap) and asserts each request's tokens are IDENTICAL to
running it alone in a fresh single-slot engine: continuous batching is
a pure scheduling change.

With ``--wire qlc`` the weights are served from QLC wire: a codec
registry calibrates per-parameter codecs, the wire codec binds a
Channel (kernel toggle + placement made once), the serving manifest
round-trips the recipe through JSON, and the wire is opened through
the channel before serving.

With ``--kv-cache qlc`` every resident sequence block-pages its decode
states through ONE shared compressed :class:`~repro.serving.BlockPool`
(capacity measured in compressed bytes): per-layer codecs calibrate
lazily from the first prefill, identical prompt prefixes dedup pooled
blocks by container digest, and the per-request identity assert above
doubles as the lossless contract. (``--kv-cache e4m3`` additionally
quantizes blocks on eviction: smaller, but lossy like any fp8 cache.)

``--kv-paging async`` (with ``--kv-cache qlc``) moves paging off the
host: evicted blocks live in a device-resident arena, block decodes
are DMA-prefetched one admission window ahead, and the decode loop
runs as one jitted scan per window (two host-to-device transfers and
one device-to-host per window, regardless of window length). Tokens
stay identical to sync paging; the prefetch hit/stall counters print
at the end.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch xlstm-125m
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serving import (BlockPool, Engine, GenerationRequest,
                           KVCacheSpec)


def run_requests(params, cfg, prompts, budgets, tenants, *, max_seq_len,
                 max_batch, kv_spec=None, registry=None, pool=None,
                 stagger=2, fairness_cap=0.5, kv_paging="sync"):
    """Drive one engine over staggered submissions; returns the tokens
    per request plus the engine (for stats)."""
    eng = Engine(params, cfg, max_seq_len=max_seq_len,
                 max_batch=max_batch, kv_spec=kv_spec, registry=registry,
                 pool=pool, fairness_cap=fairness_cap, kv_paging=kv_paging)
    handles = []
    pending = list(zip(prompts, budgets, tenants))
    while pending or (handles and any(
            eng.poll(h).state in ("waiting", "running") for h in handles)):
        for prompt, budget, tenant in pending[:stagger]:
            handles.append(eng.submit(GenerationRequest(
                prompt=prompt, max_new_tokens=budget, tenant=tenant)))
        pending = pending[stagger:]
        eng.step()
    return [eng.poll(h).tokens for h in handles], eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-coder-33b",
                    help="any assigned arch; a reduced config is served")
    ap.add_argument("--batch", type=int, default=4,
                    help="engine slots (max concurrent sequences)")
    ap.add_argument("--concurrent", type=int, default=None,
                    help="requests to submit (default: batch + 2, so "
                         "requests queue and join mid-flight)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--wire", default="none", choices=["none", "qlc"],
                    help="'qlc' serves from compressed weights opened "
                         "through a channel-bound wire codec")
    ap.add_argument("--kv-cache", default="none",
                    choices=["none", "qlc", "e4m3"],
                    help="'qlc' pages decode states through a shared "
                         "compressed block pool (token-identical); "
                         "'e4m3' also quantizes blocks (lossy)")
    ap.add_argument("--kv-block", type=int, default=4,
                    help="tokens per paged-cache block")
    ap.add_argument("--kv-paging", default="sync",
                    choices=["sync", "async"],
                    help="'async' pages blocks through the device-"
                         "resident arena: jitted window decode + DMA-"
                         "prefetched block decodes (requires "
                         "--kv-cache qlc)")
    args = ap.parse_args()
    if args.kv_paging == "async" and args.kv_cache != "qlc":
        ap.error("--kv-paging async requires --kv-cache qlc")
    n_req = args.concurrent or args.batch + 2

    cfg = reduced(get_config(args.arch), frontend_prefix_len=0,
                  frontend=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_seq_len = args.prompt_len + args.new_tokens + 8

    reg = None
    if args.wire == "qlc":
        from repro.comm.calibrate import histogram_of_tree
        from repro.core import CodecRegistry
        from repro.serving import (codec_from_manifest,
                                   compress_params_for_serving,
                                   open_params, serving_manifest)
        reg = CodecRegistry()
        reg.register("default", histogram_of_tree(params))
        wired, wc = compress_params_for_serving(params, reg)
        # manifest round trip — what a serving host reloads (registry,
        # per-leaf scheme-ids, AND the channel placement)
        wc2 = codec_from_manifest(serving_manifest(wc))
        ch = wc2.channel()
        print(f"serving {len(wc2.meta)} QLC-wired leaves via {ch}")
        params = jax.jit(lambda w: open_params(w, wc2, channel=ch))(wired)

    # staggered multi-tenant request mix: half the prompts share a
    # prefix (the prefix-sharing dedup case), budgets vary
    rng = np.random.default_rng(1)
    shared = rng.integers(0, cfg.vocab_size, args.prompt_len)
    prompts, budgets, tenants = [], [], []
    for i in range(n_req):
        if i % 2 == 0:
            p = shared.copy()
        else:
            p = np.concatenate([shared[:args.prompt_len // 2],
                                rng.integers(0, cfg.vocab_size,
                                             args.prompt_len -
                                             args.prompt_len // 2)])
        prompts.append(p.astype(np.int32))
        budgets.append(args.new_tokens - (i % 3))
        tenants.append("alice" if i % 2 == 0 else "bob")

    kv_spec = None
    pool = None
    kv_reg = None
    if args.kv_cache != "none":
        from repro.core import CodecRegistry
        # async paging needs the fixed-geometry wire (compile-time
        # container offsets), so it forces exact_capacity=False
        kv_spec = KVCacheSpec(block_tokens=args.kv_block,
                              mode=args.kv_cache,
                              exact_capacity=args.kv_paging != "async")
        pool = BlockPool(1 << 30)
        kv_reg = reg if reg is not None else CodecRegistry()

    outs, eng = run_requests(
        params, cfg, prompts, budgets, tenants, max_seq_len=max_seq_len,
        max_batch=args.batch, kv_spec=kv_spec, registry=kv_reg, pool=pool,
        kv_paging=args.kv_paging)
    st = eng.stats()
    print(f"arch={cfg.name} slots={args.batch} requests={n_req} "
          f"prompt={args.prompt_len}")
    print(f"engine: {st['steps']} steps, "
          f"{st['ms_per_token_prefill']:.1f} ms/tok prefill, "
          f"{st['ms_per_token_decode']:.1f} ms/tok decode "
          f"(batched, CPU)")
    assert st["requests"]["finished"] == n_req, st["requests"]

    # the serving contract: each request's tokens are identical to
    # running it ALONE (single-slot dense engine) — continuous batching
    # and, for --kv-cache qlc, pooled compressed paging change nothing
    check = args.kv_cache != "e4m3"   # e4m3 paging is deliberately lossy
    if check:
        for prompt, budget, got in zip(prompts, budgets, outs):
            solo, _ = run_requests(params, cfg, [prompt], [budget],
                                   ["solo"], max_seq_len=max_seq_len,
                                   max_batch=1)
            assert np.array_equal(got, solo[0]), \
                "engine output diverged from isolated run"
        print(f"{n_req} requests token-identical to isolated runs OK")

    if pool is not None:
        ps = st["pool"]
        dense = st["peak_dense_logical_bytes"]
        print(f"kv-cache={args.kv_cache} block={args.kv_block}: "
              f"peak {ps['peak_referenced_bytes']} compressed B pinned "
              f"vs {dense} dense B "
              f"({ps['dedup_hits']} prefix dedup hits, "
              f"{ps['unique_blocks']} unique blocks, "
              f"{st['kv']['raw_sections']} raw sections)")
        if ps["peak_referenced_bytes"]:
            print(f"concurrent-capacity ratio "
                  f"{dense / ps['peak_referenced_bytes']:.2f}x")
        if args.kv_paging == "async":
            pf = st["prefetch"]
            print(f"async paging: {st['async']['windows']} jitted "
                  f"windows ({st['async']['d2h_per_window']:.0f} d2h "
                  f"per window), prefetch {pf['hits']}/{pf['scheduled']} "
                  f"hits ({pf['stalled']} stalled, "
                  f"{pf['bytes_prefetched']} B prefetched, "
                  f"overlap {pf['overlap_fraction']:.3f})")
    print("sample:", np.asarray(outs[0])[:12], "...")
    print("OK")


if __name__ == "__main__":
    main()
