"""Online codec adaptation, end to end: telemetry -> drift -> hot-swap.

A compressed all-gather channel runs over the "data" axis while the
activation distribution SHIFTS mid-run (Gaussian -> post-nonlinearity
zero spike, the paper's §6 Table 1 vs Table 2 scenario). The fused
encode pass's histogram side output feeds a TrafficMonitor; the
DriftPolicy flags the mismatch; the Recalibrator re-runs scheme
selection + empirical plan sizing on the accumulated histogram and the
controller hot-swaps the channel to a NEW scheme-id.

Verified here (and gated in CI):
* a container encoded under the OLD scheme-id decodes bit-exactly
  after the swap — old registry entries are retained, never mutated;
* the post-shift measured bits/symbol under the swapped codec is
  within 5% of a FRESH calibration on the shifted distribution.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/online_adaptation.py
"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.adaptive import AdaptiveController, DriftConfig
from repro.comm import container as qc
from repro.comm.calibrate import calibrate_for_tensor
from repro.comm.channel import Channel, ChannelSpec
from repro.core import CodecRegistry
from repro.parallel import sharding as shd

N_PER_DEV = 16384
SHIFT_STEP = 4
STEPS = 14
CHUNK = 512


def batch(step: int, n_dev: int) -> np.ndarray:
    """Per-device activation rows; the distribution shifts at
    SHIFT_STEP from smooth Gaussian to a 40% zero spike (a relu-like
    dominant-symbol stream the startup codec is mis-matched to)."""
    rng = np.random.default_rng(100 + step)
    x = rng.normal(0.0, 1.0, size=(n_dev, N_PER_DEV)).astype(np.float32)
    if step >= SHIFT_STEP:
        x[rng.random(size=x.shape) < 0.4] = 0.0
    return x


def main():
    n_dev = len(jax.devices())
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("data",))

    # Startup calibration on the PRE-shift distribution.
    registry = CodecRegistry()
    tables, plan = calibrate_for_tensor(
        jnp.asarray(batch(0, n_dev).reshape(-1)), chunk_symbols=CHUNK)
    entry_a = registry.register_tables("acts", tables, plan)
    print(f"startup codec: scheme-id {entry_a.scheme_id}, "
          f"{plan.expected_bits_per_symbol:.2f} bits/sym expected")

    ctl = AdaptiveController(
        registry,
        drift=DriftConfig(min_events=2, hysteresis=2, cooldown=2,
                          min_symbols=4096))
    ach = ctl.wrap(Channel(ChannelSpec(codec="acts", axis="data",
                                       axis_size=n_dev),
                           registry=registry))

    # An in-flight container under the startup scheme-id, decoded now
    # as the bit-exactness reference.
    ref_values = batch(1, n_dev)[0]
    ref_container = qc.encode_values(ref_values, entry_a)
    ref_decoded, ok, _ = qc.decode_values(ref_container, registry)
    assert bool(ok)
    ref_decoded = np.asarray(ref_decoded)

    def make_roundtrip(channel):
        # The channel binding is captured at TRACE time — rebuilt after
        # every hot-swap, exactly like a jitted train step would be.
        def body(x):
            vals, ok, hist = channel.all_gather(x.reshape(-1),
                                                with_hist=True)
            return (vals.reshape(n_dev, -1),
                    jax.lax.psum(jnp.int32(0), "data") + jnp.int32(ok),
                    jax.lax.psum(hist, "data"))
        return jax.jit(shd.shard_map_compat(
            body, mesh=mesh, in_specs=(P("data"),),
            out_specs=(P("data"), P(), P())))

    roundtrip = make_roundtrip(ach)
    swap_steps = []
    for step in range(STEPS):
        x = jnp.asarray(batch(step, n_dev))
        _vals, _ok, hist = roundtrip(x)
        ctl.observe("acts", np.asarray(hist))
        events = ctl.check()
        for ev in events:
            swap_steps.append(step)
            print(f"step {step}: hot-swap scheme-id {ev.old_scheme_id} "
                  f"-> {ev.new_scheme_id} ({ev.measured_bits:.2f} "
                  f"measured vs {ev.old_expected_bits:.2f} planned "
                  f"bits/sym; new plan {ev.new_expected_bits:.2f})")
            roundtrip = make_roundtrip(ach)
        m = ctl.monitor.measured_bits("acts")
        if m is not None:
            print(f"step {step:2d}: scheme-id "
                  f"{registry['acts'].scheme_id}, "
                  f"{m:.2f} measured bits/sym")

    assert swap_steps, "drift never triggered a hot-swap"
    assert registry["acts"].scheme_id != entry_a.scheme_id

    # (a) Old in-flight containers decode bit-exactly after the swap.
    post, ok, _ = qc.decode_values(ref_container, registry)
    assert bool(ok)
    assert np.array_equal(np.asarray(post), ref_decoded), \
        "old-scheme container changed after hot-swap"
    print(f"old scheme-id {entry_a.scheme_id} container: bit-exact "
          "after swap")

    # (c) Recovered bits/symbol vs a fresh calibration on the shifted
    # distribution.
    adapted = ctl.monitor.measured_bits("acts")
    _t2, fresh_plan = calibrate_for_tensor(
        jnp.asarray(batch(STEPS, n_dev).reshape(-1)),
        chunk_symbols=CHUNK)
    ratio = adapted / fresh_plan.expected_bits_per_symbol
    print(f"adapted {adapted:.3f} vs fresh "
          f"{fresh_plan.expected_bits_per_symbol:.3f} bits/sym "
          f"(ratio {ratio:.3f})")
    assert ratio <= 1.05, f"adaptation did not recover: {ratio:.3f}"
    print("OK")


if __name__ == "__main__":
    main()
