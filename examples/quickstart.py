"""Quickstart: calibrate QLC tables on an e4m3 tensor, compress a
payload losslessly, and inspect the compression stats.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, compress_codes, decompress_codes, wire_bytes
from repro.comm.calibrate import calibrate_for_tensor
from repro.core import codec, entropy
from repro.quant import e4m3


def main():
    # 1) Some activation-like data (pretend this came out of FFN1).
    key = jax.random.PRNGKey(0)
    acts = jax.random.normal(key, (1 << 20,), jnp.float32)

    # 2) Calibrate: histogram of block-32 e4m3 symbols -> scheme + LUTs
    #    + static wire plan (paper §7: one LUT per tensor type, apriori).
    tables, plan = calibrate_for_tensor(acts, chunk_symbols=1024)
    print("scheme:", tables.scheme.areas)
    print(f"expected bits/symbol: {plan.expected_bits_per_symbol:.3f}  "
          f"slot capacity: {plan.capacity_words * 32 / 1024:.3f} bits/sym")

    # 3) Quantize fresh data and compress it.
    fresh = jax.random.normal(jax.random.PRNGKey(1), (1 << 18,))
    codes, scales = e4m3.quantize_block32(fresh)
    cfg = CommConfig.from_plan(plan)
    payload = compress_codes(codes, tables, cfg)

    raw_bytes = codes.size
    wire = wire_bytes(payload) + scales.size * 2  # bf16 scales
    print(f"wire bytes/symbol: {wire / codes.size:.4f} "
          f"(vs 1.0 raw e4m3, 2.0 bf16)")
    print(f"escaped chunks: {int(np.asarray(payload.pool_count).sum())}")

    # 4) Decompress — bit-exact lossless.
    out, ok = decompress_codes(payload, tables, cfg)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))
    print("lossless roundtrip: OK")

    # 5) Compressibility metric (paper's headline number).
    comp = codec.measured_compressibility(np.asarray(codes), tables)
    pmf, _ = entropy.sort_pmf_desc(
        np.bincount(np.asarray(codes), minlength=256))
    print(f"compressibility: {100 * comp:.1f}%  "
          f"(ideal {100 * entropy.ideal_compressibility(pmf):.1f}%)")


if __name__ == "__main__":
    main()
