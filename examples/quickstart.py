"""Quickstart: build a per-tensor-type codec registry, open a wire
Channel per tensor type, compress payloads into self-describing QLC
containers, and decode them back bit-exactly with nothing but the
container bytes + the registry.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import container as qc, open_channels
from repro.core import CodecRegistry, codec, entropy
from repro.quant import e4m3


def main():
    # 1) Two tensor types with different statistics (pretend these came
    #    out of FFN1 and FFN2 of a real model).
    key1, key2 = jax.random.split(jax.random.PRNGKey(0))
    acts = jax.random.normal(key1, (1 << 20,))
    gated = jax.random.normal(key2, (1 << 20,))
    gated = gated * (gated > 0)          # zero spike, Table-2 territory

    # 2) Calibrate ONE registry entry per tensor type (paper §7: one
    #    LUT per tensor type, apriori). Each entry = scheme + LUTs +
    #    static wire plan under a stable integer scheme-id.
    from repro.comm.calibrate import histogram_of_quantized
    reg = CodecRegistry()
    for name, x in [("ffn1_act", acts), ("ffn2_act", gated)]:
        entry = reg.register(name, histogram_of_quantized(x))
        print(f"{name}: scheme-id {entry.scheme_id} "
              f"({entry.scheme.areas}), "
              f"{entry.plan.expected_bits_per_symbol:.2f} bits/sym")

    # 3) One wire Channel per tensor type (the Channel API): codec +
    #    transport policy + kernel toggle bound ONCE, then the whole
    #    wire surface is methods. Local compress/decompress round trip:
    channels = open_channels(reg)
    ch = channels["ffn1_act"]
    x = jax.random.normal(jax.random.PRNGKey(3),
                          (8 * ch.cfg.chunk_symbols,))
    payload, scales = ch.compress(x)
    back, ok = ch.decompress(payload, scales)
    assert bool(ok)
    c, s = e4m3.quantize_block32(x.astype(jnp.float32))
    want = e4m3.dequantize_block32(c, s.astype(jnp.bfloat16)
                                   .astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(want))
    print(f"channel {ch}: {ch.wire_bytes(payload, scales)} wire bytes "
          f"for {x.size} values, lossless vs e4m3: OK")

    # 4) Compress fresh payloads of each type into one mixed stream of
    #    self-describing containers: each section's header carries its
    #    scheme-id + chunk geometry, so no CommConfig rides along.
    fresh1 = jax.random.normal(jax.random.PRNGKey(1), (1 << 18,))
    fresh2 = jax.random.normal(jax.random.PRNGKey(2), (1 << 18,))
    fresh2 = fresh2 * (fresh2 > 0)
    stream = qc.pack_stream([
        qc.encode_values(fresh1, reg["ffn1_act"]),
        qc.encode_values(fresh2, reg["ffn2_act"]),
    ])
    n_syms = fresh1.size + fresh2.size
    print(f"stream: {qc.container_bytes(stream)} bytes for {n_syms} "
          f"symbols = {qc.container_bytes(stream) / n_syms:.4f} B/sym "
          f"(vs 1.0 raw e4m3, 2.0 bf16)")
    for off, h in qc.stream_headers(stream):
        print(f"  section @{off}: scheme-id {h.scheme_id}, "
              f"{h.n_chunks} chunks x {h.capacity_words} words")

    # 5) Decode with ONLY the stream + a registry reloaded from JSON —
    #    e.g. on a different host. Bit-exact lossless vs the e4m3 values.
    reg2 = CodecRegistry.from_json(reg.to_json())
    outs = qc.decode_values_stream(stream, reg2)
    assert all(bool(ok) for _, ok in outs)
    for x, (vals, _) in zip((fresh1, fresh2), outs):
        c, s = e4m3.quantize_block32(x.astype(jnp.float32))
        want = e4m3.dequantize_block32(           # bf16 scales on the wire
            c, s.astype(jnp.bfloat16).astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(vals), np.asarray(want))
    print("mixed-scheme lossless roundtrip: OK")

    # 6) Compressibility metric (paper's headline number) per type.
    for name, x in [("ffn1_act", acts), ("ffn2_act", gated)]:
        codes, _ = e4m3.quantize_block32(x.astype(np.float32))
        tables = reg.tables_for(name)
        comp = codec.measured_compressibility(np.asarray(codes), tables)
        pmf, _ = entropy.sort_pmf_desc(
            np.bincount(np.asarray(codes), minlength=256))
        print(f"{name} compressibility: {100 * comp:.1f}%  "
              f"(ideal {100 * entropy.ideal_compressibility(pmf):.1f}%)")


if __name__ == "__main__":
    main()
