"""End-to-end training driver: LM training with QLC-compressed gradient
collectives, checkpointing, and fault-tolerant step retry.

Defaults run a small model for a quick CPU demo; --preset 100m trains a
~100M-param model for a few hundred steps (same code path — expect
hours on CPU, minutes on real accelerators).

Multi-device (recommended, exercises the real compressed collectives):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python examples/train_lm.py --comm qlc --steps 50

Run:  PYTHONPATH=src python examples/train_lm.py --steps 30
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.comm import calibrate_for_gradients
from repro.comm.calibrate import calibrate_moe_entries, histogram_of_tree
from repro.comm.channel import Channel, ChannelSpec
from repro.configs import get_config, reduced
from repro.core import CodecRegistry
from repro.models import moe as moe_mod
from repro.data import DataConfig, SyntheticDataset
from repro.launch.mesh import make_test_mesh
from repro.models import init_params
from repro.parallel import sharding as shd
from repro.training import (OptConfig, Trainer, TrainerConfig, TrainConfig,
                            init_compressed_opt_state, make_baseline_step,
                            make_compressed_step, step_channels)
from repro.training import optimizer as optm


def build_cfg(preset: str):
    base = get_config("gemma-2b-sft")   # the paper's own model family
    if preset == "tiny":
        return reduced(base, d_model=128, num_layers=4, num_heads=4,
                       num_kv_heads=1, d_ff=512, vocab_size=512)
    if preset == "100m":
        return dataclasses.replace(
            base, name="gemma-100m", num_layers=8, d_model=768,
            num_heads=8, num_kv_heads=1, head_dim=96, d_ff=3072,
            vocab_size=32768, remat="none")
    if preset == "moe":
        # expert-parallel MoE over the compressed a2a expert wire
        cfg = reduced(get_config("deepseek-moe-16b"))
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, impl="shardmap_a2a"))
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny",
                    choices=["tiny", "100m", "moe"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--comm", default="qlc", choices=["baseline", "qlc"])
    ap.add_argument("--transport", default="auto",
                    choices=["auto", "oneshot", "ring"],
                    help="wire transport policy bound into the step's "
                         "channels (auto = per-payload planner choice)")
    ap.add_argument("--adapt", action="store_true",
                    help="online codec adaptation (with --comm qlc): "
                         "the step emits fused encode histograms; a "
                         "drifted codec is recalibrated off the hot "
                         "path and hot-swapped under a new scheme-id")
    ap.add_argument("--adapt-every", type=int, default=5,
                    help="steps between drift checks with --adapt")
    ap.add_argument("--pool-slots", type=int, default=None,
                    help="escape-pool slots per 1k symbols for the "
                         "grad/param codecs (reduced smoke models have "
                         "few chunks per rank, so the planner's ~1-slot "
                         "pool can overflow into per-step fallback; "
                         "1024 makes the wire unconditionally lossless)")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    mesh = make_test_mesh(model=2 if len(jax.devices()) > 1 else 1)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"model: {cfg.name} params~{cfg.param_count()/1e6:.1f}M")

    opt_cfg = OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    train_cfg = TrainConfig(microbatches=1, batch_axes=("data",))
    data = SyntheticDataset(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, seed=0))

    with shd.use_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch0 = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

        # MoE expert wire: calibrate the dispatch/combine codecs from
        # the actual routed-token traffic of batch0 and bind one
        # Channel per direction on the expert ("model") axis — the
        # step's forward routes every expert all_to_all through them.
        moe_channels = None
        if (cfg.moe is not None and cfg.moe.impl == "shardmap_a2a"
                and "model" in mesh.axis_names):
            moe_registry = CodecRegistry()
            calibrate_moe_entries(moe_registry, cfg, params, batch0)
            dm = int(mesh.shape["model"])
            geo = moe_mod.shardmap_a2a_geometry(
                cfg, args.batch * args.seq_len, mesh)
            moe_channels = {}
            for name in (moe_mod.MOE_DISPATCH, moe_mod.MOE_COMBINE):
                ch = Channel(ChannelSpec(codec=name,
                                         transport=args.transport,
                                         axis="model", axis_size=dm),
                             registry=moe_registry)
                moe_channels[name] = ch
                entry = moe_registry[name]
                wire = ch.modeled_wire_bytes(geo["row_values"])
                print(f"moe codec {name}: scheme-id {entry.scheme_id}, "
                      f"{entry.plan.expected_bits_per_symbol:.2f} "
                      f"bits/sym, "
                      f"{dm * wire / geo['ng']:.0f} wire B/token "
                      f"per collective")

        if (args.comm == "qlc" and moe_channels
                and not hasattr(jax, "shard_map")):
            print("note: this jax lacks jax.shard_map — compressed "
                  "grad collectives can't wrap the shardmap_a2a MoE "
                  "forward; running the baseline grad wire with the "
                  "compressed MoE expert wire")
            args.comm = "baseline"

        baseline = jax.jit(make_baseline_step(cfg, opt_cfg, train_cfg,
                                              moe_channels=moe_channels))
        on_step = None
        if args.comm == "qlc":
            # Per-tensor-type registry (paper §7): one codec for the
            # gradient reduce-scatter, one for the updated-parameter
            # all-gather — the two collectives see very different
            # symbol statistics.
            tables, plan = calibrate_for_gradients(
                cfg, params, batch0, chunk_symbols=512)
            if args.pool_slots is not None:
                plan = dataclasses.replace(
                    plan, pool_slots_per_1k=args.pool_slots)
            registry = CodecRegistry()
            registry.register_tables("grads", tables, plan)
            registry.register("params", histogram_of_tree(params),
                              chunk_symbols=512,
                              pool_slots_per_1k=args.pool_slots or 8)
            for name in ("grads", "params"):
                e = registry[name]
                print(f"calibrated {name}: scheme-id {e.scheme_id}, "
                      f"{e.plan.expected_bits_per_symbol:.2f} bits/sym, "
                      f"slot {e.plan.capacity_words * 32 / 512:.2f}")
            comm_cfg = registry["grads"].config()
            # The step binds codec x transport x axis ONCE per
            # (collective, dp axis) as Channel objects — inspect the
            # same binding it will open:
            rs_ch, _ag_ch, _cfg = step_channels(
                registry, dp_sizes={a: mesh.shape[a]
                                    for a in train_cfg.batch_axes
                                    if a in mesh.axis_names},
                rs_order=tuple(a for a in ("data", "pod")
                               if a in mesh.axis_names),
                transport=args.transport)
            for ax, ch in rs_ch.items():
                print(f"grad RS channel over {ax!r}: {ch}")
            def build_step():
                return jax.jit(make_compressed_step(
                    cfg, opt_cfg, train_cfg, mesh, registry,
                    transport=args.transport,
                    moe_channels=moe_channels, telemetry=args.adapt))

            step = build_step()
            opt_state = init_compressed_opt_state(
                cfg, mesh, train_cfg, registry, opt_cfg)
            fallback = baseline_adapter(baseline, cfg, mesh, train_cfg,
                                        comm_cfg, opt_cfg)
            if args.adapt:
                # Telemetry -> drift policy -> hot-swap: the step's
                # adapt/*_hist metrics feed the controller; a swap
                # registers a NEW scheme-id (old entries stay
                # decodable) and the adapter rebuilds the jitted step
                # against the updated registry.
                from repro.adaptive import (AdaptiveController,
                                            TrainingAdapter)
                controller = AdaptiveController(registry)
                on_step = TrainingAdapter(
                    controller, build_step,
                    grad_key="grads", param_key="params",
                    check_every=args.adapt_every,
                    on_swap=lambda ev: print(
                        f"hot-swap {ev.name}: scheme-id "
                        f"{ev.old_scheme_id} -> {ev.new_scheme_id} "
                        f"({ev.measured_bits:.2f} measured vs "
                        f"{ev.old_expected_bits:.2f} planned bits/sym)"))
        else:
            step = baseline
            opt_state = optm.init_state(params, opt_cfg)
            fallback = None

        trainer = Trainer(
            TrainerConfig(total_steps=args.steps,
                          checkpoint_dir=args.checkpoint_dir,
                          checkpoint_every=max(10, args.steps // 3),
                          log_every=5),
            step, fallback_step_fn=fallback, on_step=on_step)
        params, opt_state, start = trainer.restore_or(params, opt_state)
        params, opt_state = trainer.run(params, opt_state, data,
                                        start_step=start)

    losses = [h["loss"] for h in trainer.history]
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over "
          f"{len(losses)} steps (fallbacks: {trainer.comm_fallbacks})")
    assert losses[-1] < losses[0], "training did not reduce the loss"
    print("OK")


def baseline_adapter(baseline, cfg, mesh, train_cfg, comm_cfg, opt_cfg):
    """Comm-failure fallback: rerun the step uncompressed. The ZeRO-1
    flat opt state stays authoritative; the fallback recomputes grads
    and applies the same update through the raw-e4m3 wire (enabled=False
    => identical numerics to a lossless compressed step)."""
    import dataclasses as dc
    from repro.comm import calibrate_for_gradients  # noqa: F401
    from repro.core import TABLE1, build_tables, distributions
    tables = build_tables(distributions.grad_counts(1 << 16), TABLE1)
    raw_cfg = dc.replace(comm_cfg, enabled=False)
    from repro.training import make_compressed_step as mk
    return jax.jit(mk(cfg, opt_cfg, train_cfg, mesh, tables, raw_cfg))


if __name__ == "__main__":
    main()
