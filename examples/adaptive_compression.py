"""Scheme adaptation demo (paper §6) + beyond-paper optimal search.

Shows how the right scheme depends on the tensor's distribution:
Table 1 for FFN1-like streams, Table 2 for zero-spiked FFN2-like
streams, and the searched scheme beating both (paper §8 future work).

Run:  PYTHONPATH=src python examples/adaptive_compression.py
"""
import numpy as np

from repro.core import (TABLE1, TABLE2, distributions, entropy,
                        huffman, select_scheme)
from repro.core.scheme_search import optimal_scheme


def report(name, counts):
    pmf, _ = entropy.sort_pmf_desc(counts)
    h = entropy.shannon_entropy(pmf)
    hc = huffman.HuffmanCodec(np.maximum(counts, 1e-9))
    picked = select_scheme(counts)
    opt, opt_bits = optimal_scheme(pmf, max_distinct_lengths=4)
    print(f"\n=== {name} ===")
    print(f"entropy {h:.2f}b  p(top symbol)={pmf[0]:.3f}")
    print(f"{'ideal':>22}: {100 * (8 - h) / 8:5.1f}%")
    print(f"{'huffman':>22}: "
          f"{100 * hc.compressibility(np.maximum(counts, 1e-9)):5.1f}%  "
          f"(lengths {hc.lengths[hc.lengths > 0].min()}"
          f"-{hc.lengths.max()} — deep tree)")
    print(f"{'qlc table1':>22}: {100 * TABLE1.compressibility(pmf):5.1f}%")
    print(f"{'qlc table2':>22}: {100 * TABLE2.compressibility(pmf):5.1f}%")
    print(f"{'auto-selected':>22}: {picked.scheme_name} "
          f"({100 * picked.compressibility:5.1f}%)")
    print(f"{'searched optimal quad':>22}: {100 * (8 - opt_bits) / 8:5.1f}%"
          f"   areas={opt.areas}")


def main():
    report("FFN1 activations (no dominant symbol, Fig 1)",
           distributions.ffn1_counts(1 << 20))
    report("FFN2 activations (zero spike, Fig 4)",
           distributions.ffn2_counts(1 << 20))
    report("weight gradients (heavy tails)",
           distributions.grad_counts(1 << 20))


if __name__ == "__main__":
    main()
