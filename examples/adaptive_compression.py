"""Scheme adaptation demo (paper §6) + per-tensor-type registry demo
(paper §7), end to end.

Part 1 shows how the right scheme depends on the tensor's distribution:
Table 1 for FFN1-like streams, Table 2 for zero-spiked FFN2-like
streams, and the searched scheme beating both (paper §8 future work).

Part 2 runs the deployment story end to end: one registry entry per
tensor type, a mixed stream of self-describing containers, one
multi-LUT batched decode — then the same data under a single global
LUT, quantifying what per-type adaptation buys on the real wire.

Run:  PYTHONPATH=src python examples/adaptive_compression.py
"""
import numpy as np

from repro.comm import container as qc, open_channels
from repro.core import (CodecRegistry, TABLE1, TABLE2, distributions,
                        entropy, huffman, select_scheme)
from repro.core.scheme_search import optimal_scheme


def report(name, counts):
    pmf, _ = entropy.sort_pmf_desc(counts)
    h = entropy.shannon_entropy(pmf)
    hc = huffman.HuffmanCodec(np.maximum(counts, 1e-9))
    picked = select_scheme(counts)
    opt, opt_bits = optimal_scheme(pmf, max_distinct_lengths=4)
    print(f"\n=== {name} ===")
    print(f"entropy {h:.2f}b  p(top symbol)={pmf[0]:.3f}")
    print(f"{'ideal':>22}: {100 * (8 - h) / 8:5.1f}%")
    print(f"{'huffman':>22}: "
          f"{100 * hc.compressibility(np.maximum(counts, 1e-9)):5.1f}%  "
          f"(lengths {hc.lengths[hc.lengths > 0].min()}"
          f"-{hc.lengths.max()} — deep tree)")
    print(f"{'qlc table1':>22}: {100 * TABLE1.compressibility(pmf):5.1f}%")
    print(f"{'qlc table2':>22}: {100 * TABLE2.compressibility(pmf):5.1f}%")
    print(f"{'auto-selected':>22}: {picked.scheme_name} "
          f"({100 * picked.compressibility:5.1f}%)")
    print(f"{'searched optimal quad':>22}: {100 * (8 - opt_bits) / 8:5.1f}%"
          f"   areas={opt.areas}")


def registry_demo():
    """Per-tensor-type codecs through the real container wire."""
    streams = {
        "ffn1_act": distributions.ffn1_symbols(1 << 17, seed=11),
        "ffn2_act": distributions.ffn2_symbols(1 << 17, seed=12),
        "grad": distributions.grad_symbols(1 << 17, seed=13),
    }
    n_total = sum(s.size for s in streams.values())

    # one registry entry per tensor type (auto scheme selection), plus
    # one entry calibrated on the mixture (the global-LUT strawman)
    reg = CodecRegistry()
    for name, syms in streams.items():
        reg.register(name, np.bincount(syms, minlength=256))
    mixture = np.concatenate(list(streams.values()))
    reg.register("global", np.bincount(mixture, minlength=256))

    def wire_bytes(sections):
        return sum(qc.container_bytes(s) for s in sections)

    per_type = [qc.encode_codes(s, reg[name])
                for name, s in streams.items()]
    global_ = [qc.encode_codes(s, reg["global"])
               for s in streams.values()]

    print("\n=== per-tensor-type registry vs one global LUT "
          "(real container wire) ===")
    print(f"{'global LUT':>22}: {wire_bytes(global_) / n_total:.4f} B/sym")
    print(f"{'per-type LUTs':>22}: {wire_bytes(per_type) / n_total:.4f} "
          f"B/sym")
    saved = wire_bytes(global_) - wire_bytes(per_type)
    print(f"{'saving':>22}: {saved} bytes "
          f"({100 * saved / wire_bytes(global_):.1f}% of the wire)")

    # the mixed stream decodes in ONE multi-LUT batched pass, using
    # only the container headers + the registry
    stream = qc.pack_stream(per_type)
    outs = qc.decode_codes_stream(stream, reg)
    for (name, syms), (got, ok) in zip(streams.items(), outs):
        assert bool(ok)
        np.testing.assert_array_equal(np.asarray(got), syms)
    print("mixed-scheme batched decode: lossless OK "
          f"({len(outs)} sections, "
          f"{len({h.scheme_id for _, h in qc.stream_headers(stream)})} "
          "distinct schemes)")

    # and the Channel API binds each type's wire decision once: codec +
    # transport policy + mesh axis. With transport="auto" the channel
    # picks one-shot vs ring per payload size (planner model, or a
    # cached Channel.autotune measurement when one exists).
    channels = open_channels(reg, axis="data", transport="auto",
                             spec_overrides={n: {"axis_size": 8}
                                             for n in reg.names()})
    print("\n=== per-type channels (transport resolved per payload) ===")
    for name in streams:
        ch = channels[name]
        small, big = ch.resolved_transport(1 << 12), \
            ch.resolved_transport(1 << 26)
        print(f"{name:>22}: 16KiB -> {small.kind}, "
              f"256MiB -> {big.kind} (hop_chunks={big.hop_chunks})")


def main():
    report("FFN1 activations (no dominant symbol, Fig 1)",
           distributions.ffn1_counts(1 << 20))
    report("FFN2 activations (zero spike, Fig 4)",
           distributions.ffn2_counts(1 << 20))
    report("weight gradients (heavy tails)",
           distributions.grad_counts(1 << 20))
    registry_demo()


if __name__ == "__main__":
    main()
