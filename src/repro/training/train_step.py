"""Train steps.

Two interchangeable implementations:

* **baseline** — GSPMD end to end: FSDP+TP sharding rules, XLA inserts
  all collectives (bf16/f32 wire). This is the roofline baseline and the
  path that runs every dry-run cell.

* **compressed** — the paper's technique integrated into training.
  Stage 1 computes per-data-shard gradients under ``jax.shard_map`` with
  only the dp axes manual (the model axis stays under GSPMD). Stage 2 is
  a fully-manual shard_map that flattens each rank's local gradient
  shard and performs a **hierarchical QLC-compressed reduce-scatter**
  (intra-pod over "data", then cross-pod over "pod" — the cross-pod hop,
  the scarcest bandwidth, moves 1/d_data of the data after the intra-pod
  RS), a ZeRO-1 sharded AdamW update on the owned slice, and the
  mirrored compressed all-gathers back. Gradient bytes on the wire
  shrink ~2.1x vs bf16 (e4m3 + QLC at the planner's capacity).

  The wire is lossless relative to the e4m3-quantized values; if the
  escape pool ever overflows (``ok=False`` in metrics) the trainer
  retries the step through the baseline path — numerics never silently
  corrupt.

Parameters in compressed mode are dp-replicated (TP-sharded only);
archs too large for that (nemotron-340b, jamba-398b at full size) train
via the baseline FSDP path (see DESIGN.md §8).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.comm import CommConfig
from repro.comm.channel import Channel, ChannelSpec
from repro.configs.base import ModelConfig
from repro.core.registry import CodecRegistry
from repro.models import init_params, next_token_loss, param_specs
from repro.parallel import sharding as shd
from repro.training import optimizer as opt

GRAD_TYPE = "grads"      # registry key for the gradient reduce-scatter
PARAM_TYPE = "params"    # registry key for the parameter all-gather


def step_channels(codec, comm_cfg: CommConfig = None, *,
                  dp_sizes, rs_order, transport=None, transport_model=None,
                  pod_axis=None,
                  grad_key: str = GRAD_TYPE, param_key: str = PARAM_TYPE):
    """Open the compressed step's wire channels: one per (collective,
    dp axis) — the single point where codec x transport x axis is bound
    (this replaced the old ``resolve_step_codecs`` /
    ``resolve_step_transports`` / ``_auto_axis_transports`` trio).

    ``codec`` is either a bare ``CodecTables`` (legacy: one LUT + one
    ``comm_cfg`` for both collectives) or a ``CodecRegistry`` holding a
    ``grad_key`` entry (gradient reduce-scatter wire) and optionally a
    ``param_key`` entry (updated-parameter all-gather wire; falls back
    to the grad entry). With a registry, ``comm_cfg`` acts as an
    override source for the non-plan knobs (``enabled``,
    ``use_kernels``, ``scale_dtype``) on top of each entry's calibrated
    plan.

    ``transport`` is ``None`` (one-shot everywhere, legacy), a
    ``TransportConfig``/str applied to both collectives, ``"auto"``
    (each channel resolves one-shot vs ring + hop chunking per call
    from the static payload geometry — registry-cached autotunings
    first, then the planner's alpha-beta model, with the one-shot RS
    charged its per-rank accumulate dispatches), or a dict with
    ``grad_key``/``param_key`` entries — per-collective transport
    policies next to the per-collective codec keys.

    ``pod_axis`` (with its size present in ``dp_sizes``) binds every
    opened channel to that slow second axis: each collective then runs
    once over the combined pod x local group (``rs_order`` should name
    only the local axis), and ``"hierarchical"``/``"auto"`` transports
    ring within the pod while bridging pods with one compressed
    exchange per hop group — the multi-host wire.

    Returns ``(rs_channels, ag_channels, rs_cfg)``: ``{axis: Channel}``
    maps over ``rs_order``, plus the gradient wire's resolved
    ``CommConfig`` (the step's flat-vector geometry is derived from
    it).
    """
    if isinstance(transport, dict):
        rs_t = transport.get(grad_key)
        ag_t = transport.get(param_key)
    else:
        rs_t = ag_t = transport

    registry = codec if isinstance(codec, CodecRegistry) else None
    if registry is not None:
        g = registry.get(grad_key)
        if g is None:
            raise KeyError(
                f"registry has no {grad_key!r} entry; have "
                f"{registry.names()}")
        p = registry.get(param_key, default=g)
        overrides = {}
        if comm_cfg is not None:
            overrides = dict(enabled=comm_cfg.enabled,
                             use_kernels=comm_cfg.use_kernels,
                             scale_dtype=comm_cfg.scale_dtype)
        rs_codec, ag_codec = g, p
        rs_cfg, ag_cfg = g.config(**overrides), p.config(**overrides)
    else:
        if comm_cfg is None:
            raise TypeError("bare CodecTables needs an explicit CommConfig")
        rs_codec = ag_codec = codec
        rs_cfg = ag_cfg = comm_cfg
    if rs_cfg.chunk_symbols != ag_cfg.chunk_symbols:
        raise ValueError(
            "grad and param codecs must share chunk_symbols, got "
            f"{rs_cfg.chunk_symbols} vs {ag_cfg.chunk_symbols}")

    def open_axis(codec_, cfg_, t, ax):
        pod_kw = {}
        if pod_axis is not None and ax != pod_axis:
            pod_kw = dict(pod_axis=pod_axis,
                          pod_axis_size=int(dp_sizes[pod_axis]))
        return Channel(
            ChannelSpec(codec=codec_, cfg=cfg_, transport=t, axis=ax,
                        axis_size=int(dp_sizes[ax]), **pod_kw),
            registry=registry, model=transport_model)

    rs_ch = {ax: open_axis(rs_codec, rs_cfg, rs_t, ax) for ax in rs_order}
    ag_ch = {ax: open_axis(ag_codec, ag_cfg, ag_t, ax) for ax in rs_order}
    return rs_ch, ag_ch, rs_cfg


# Version-compat shard_map now lives with the other mesh helpers.
_shard_map = shd.shard_map_compat


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    comm_mode: str = "baseline"      # baseline | compressed
    batch_axes: Tuple[str, ...] = ("pod", "data")


def dp_axes_in(mesh: Mesh, cfg: TrainConfig) -> Tuple[str, ...]:
    return tuple(a for a in cfg.batch_axes if a in mesh.axis_names)


def dp_size_of(mesh: Mesh, cfg: TrainConfig) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes_in(mesh, cfg)],
                       initial=1))


def batch_pspec(mesh: Mesh, cfg: TrainConfig) -> P:
    axes = dp_axes_in(mesh, cfg)
    return P(axes if axes else None)


def _loss_fn(model_cfg: ModelConfig, moe_channels=None):
    """Loss closure; ``moe_channels`` (a ``{name: Channel}`` map over
    ``moe.MOE_DISPATCH``/``moe.MOE_COMBINE``) puts the expert-parallel
    ``shardmap_a2a`` dispatch on the compressed wire — the binding is
    consulted when the loss is TRACED, so it wraps the call here."""
    from repro.models import moe as moe_mod

    def f(params, batch):
        ctx = (moe_mod.bind_moe_channels(moe_channels)
               if moe_channels else contextlib.nullcontext())
        with ctx:
            return next_token_loss(
                params, model_cfg, batch["tokens"], batch["labels"],
                batch.get("prefix_emb"))
    return f


def _microbatched_grads(loss_fn, params, batch, n_micro: int):
    """Gradient accumulation over n_micro microbatches (scan)."""
    if n_micro == 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    split = jax.tree.map(
        lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
        batch)

    def body(carry, mb):
        acc, loss_acc = carry
        l, g = jax.value_and_grad(loss_fn)(params, mb)
        acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
        return (acc, loss_acc + l), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gacc, lacc), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), split)
    inv = 1.0 / n_micro
    return lacc * inv, jax.tree.map(lambda g: g * inv, gacc)


# --------------------------------------------------------------------------
# Baseline (GSPMD) step
# --------------------------------------------------------------------------

def make_baseline_step(model_cfg: ModelConfig, opt_cfg: opt.OptConfig,
                       train_cfg: TrainConfig, *,
                       moe_channels=None) -> Callable:
    """``moe_channels`` compresses the MoE expert all_to_all (forward
    activations) even in baseline comm mode — the gradient wire stays
    dense while ``moe.impl="shardmap_a2a"`` moves QLC containers."""
    loss_fn = _loss_fn(model_cfg, moe_channels=moe_channels)

    def train_step(params, opt_state, batch):
        loss, grads = _microbatched_grads(
            loss_fn, params, batch, train_cfg.microbatches)
        new_params, new_state, info = opt.apply_update(
            params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "ok": jnp.bool_(True), **info}
        return new_params, new_state, metrics

    return train_step


# --------------------------------------------------------------------------
# Compressed-communication step
# --------------------------------------------------------------------------

def _manual_param_specs(model_cfg: ModelConfig, mesh: Mesh):
    """PartitionSpecs for params under manual model sharding
    (dp-replicated), with shape-aware divisibility fallback."""
    shapes = jax.eval_shape(
        lambda k: init_params(model_cfg, k), jax.random.PRNGKey(0))
    specs = param_specs(model_cfg)
    with shd.use_mesh(mesh):
        rules = shd.get_rules()
        pspecs = jax.tree.map(
            lambda spec, leaf: rules.spec(spec, shape=leaf.shape),
            specs, shapes, is_leaf=shd.is_spec_leaf)
    return pspecs, shapes


def _local_numel(pspec: P, shape, mesh: Mesh) -> int:
    n = 1
    entries = tuple(pspec) + (None,) * (len(shape) - len(pspec))
    for dim, entry in zip(shape, entries):
        if entry is None:
            n *= dim
        else:
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            n *= dim // int(np.prod([mesh.shape[a] for a in axes]))
    return n


def _replication_factor(pspec: P, mesh: Mesh,
                        model_axes=("model",)) -> float:
    used = set()
    for entry in tuple(pspec):
        if entry is None:
            continue
        for a in ((entry,) if isinstance(entry, str) else entry):
            used.add(a)
    rep = 1
    for a in model_axes:
        if a in mesh.axis_names and a not in used:
            rep *= mesh.shape[a]
    return float(rep)


def flat_geometry(model_cfg: ModelConfig, mesh: Mesh,
                  train_cfg: TrainConfig, comm_cfg: CommConfig):
    """(n_local, n_padded, seg, weight_vec) of the per-model-rank flat
    parameter vector. ``weight_vec`` downweights model-replicated leaves
    so the psum'd grad norm is exact."""
    pspecs, shapes = _manual_param_specs(model_cfg, mesh)
    dp_total = dp_size_of(mesh, train_cfg)
    k = comm_cfg.chunk_symbols

    leaves_spec = jax.tree.leaves(pspecs,
                                  is_leaf=lambda s: isinstance(s, P))
    leaves_shape = jax.tree.leaves(shapes)
    sizes = [_local_numel(s, l.shape, mesh)
             for s, l in zip(leaves_spec, leaves_shape)]
    reps = [_replication_factor(s, mesh)
            for s, l in zip(leaves_spec, leaves_shape)]
    n_local = int(sum(sizes))
    n_padded = -(-n_local // (dp_total * k)) * (dp_total * k)
    seg = n_padded // dp_total
    w = np.concatenate(
        [np.full(n, 1.0 / r, np.float32) for n, r in zip(sizes, reps)]
        + [np.zeros(n_padded - n_local, np.float32)])
    return n_local, n_padded, seg, w


def _flatten_local(tree) -> Tuple[jnp.ndarray, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    meta = (treedef, [(l.shape, l.dtype) for l in leaves])
    return flat, meta


def _unflatten_local(flat: jnp.ndarray, meta) -> Any:
    treedef, shapes = meta
    out, off = [], 0
    for shape, dtype in shapes:
        n = int(np.prod(shape, initial=1))
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def make_compressed_step(model_cfg: ModelConfig, opt_cfg: opt.OptConfig,
                         train_cfg: TrainConfig, mesh: Mesh,
                         tables, comm_cfg: CommConfig = None, *,
                         grad_key: str = GRAD_TYPE,
                         param_key: str = PARAM_TYPE,
                         transport=None,
                         transport_model=None,
                         hierarchical_wire: bool = False,
                         moe_channels=None,
                         telemetry: bool = False) -> Callable:
    """train_step(params, flat_opt_state, batch) for compressed mode.

    ``telemetry=True`` additionally returns the encode-side symbol
    histograms of the gradient and parameter wires in the metrics
    (``"adapt/grads_hist"`` / ``"adapt/params_hist"``, i32[256],
    psum'd over every rank — global traffic). The histogram rides the
    fused encode kernel (``emit_hist``), so the payload math is
    untouched: a telemetry step is bit-identical to a plain one. These
    are the ``repro.adaptive.TrainingAdapter`` inputs.

    ``tables`` is a legacy ``CodecTables`` (with ``comm_cfg``) or a
    ``CodecRegistry``: the gradient reduce-scatter then uses the
    ``grad_key`` codec and the parameter all-gather the ``param_key``
    codec — per-collective tensor-type selection (paper §7).

    ``transport`` selects the collective transport the same way:
    ``None`` (one-shot), a ``TransportConfig``/"ring" for both, a dict
    with ``grad_key``/``param_key`` entries (per-collective transport
    keys), or ``"auto"`` — each channel picks one-shot vs ring (and
    the ring's hop chunking) per dp axis from the static payload
    geometry, preferring transports autotuned into the registry
    (``Channel.autotune``). ``transport_model`` (an
    ``AlphaBetaModel``) supplies measured constants for the ``"auto"``
    choice — e.g. the decode throughput
    ``benchmarks/transport_overlap.py`` measures; default constants
    are the v5e first-order guesses.

    ``hierarchical_wire=True`` (the ``launch/train.py --pods`` path)
    replaces the per-axis sequential collectives on a pod x data mesh
    with ONE pod-bound channel per collective: the reduce-scatter and
    all-gather each run once over the combined group in pod-major rank
    order, and a ``"hierarchical"`` (or ``"auto"``-chosen) transport
    rings within the pod while bridging pods with one compressed
    exchange per hop group. Bit-identical gradients to the one-shot
    combined-group wire; on a mesh without a ``"pod"`` axis the flag
    is a no-op.

    All wire decisions are bound ONCE at step build time as
    :class:`~repro.comm.channel.Channel` objects — one per
    (collective, dp axis) — via :func:`step_channels`.
    """
    if (model_cfg.moe is not None
            and model_cfg.moe.impl == "shardmap_a2a"
            and not hasattr(jax, "shard_map")):
        raise NotImplementedError(
            "moe.impl='shardmap_a2a' cannot run inside the compressed "
            "step on this jax: stage 1 falls back to "
            "vmap(spmd_axis_name=...), which cannot nest the expert "
            "shard_map. Use make_baseline_step(..., moe_channels=...) — "
            "the expert all_to_all still moves QLC containers there — "
            "or moe.impl='gspmd'/'grouped_local' for compressed "
            "gradients.")
    loss_fn = _loss_fn(model_cfg, moe_channels=moe_channels)
    dp_axes = dp_axes_in(mesh, train_cfg)
    dp_sizes = {a: mesh.shape[a] for a in dp_axes}
    dp_total = dp_size_of(mesh, train_cfg)
    pod_axis = ("pod" if hierarchical_wire and "pod" in dp_axes
                and "data" in dp_axes else None)
    if pod_axis is not None:
        rs_order = ("data",)            # one pod-bound combined group
    else:
        rs_order = tuple(a for a in ("data", "pod") if a in dp_axes)
    rs_ch, ag_ch, comm_cfg = step_channels(
        tables, comm_cfg, dp_sizes=dp_sizes, rs_order=rs_order,
        transport=transport, transport_model=transport_model,
        pod_axis=pod_axis, grad_key=grad_key, param_key=param_key)

    p_specs, _ = _manual_param_specs(model_cfg, mesh)
    # Stacked-grad specs: stage 1 (model under auto) may only reference
    # the manual dp axes; stage 2 (fully manual) names the model dims.
    g_specs = jax.tree.map(
        lambda s: P(*((dp_axes,) + tuple(s))), p_specs,
        is_leaf=lambda s: isinstance(s, P))
    g_specs_s1 = jax.tree.map(
        lambda s: P(*((dp_axes,) + (None,) * len(tuple(s)))), p_specs,
        is_leaf=lambda s: isinstance(s, P))
    b_spec = batch_pspec(mesh, train_cfg)
    n_local, n_padded, seg_len, weight_vec = flat_geometry(
        model_cfg, mesh, train_cfg, comm_cfg)

    # ---- stage 1: per-dp-shard gradients (model axis under GSPMD) -------
    if hasattr(jax, "shard_map"):
        # New jax: dp axes manual, model axis auto.
        def grad_body(params, batch):
            loss, grads = _microbatched_grads(
                loss_fn, params, batch, train_cfg.microbatches)
            return loss[None], jax.tree.map(lambda g: g[None], grads)

        stage1 = _shard_map(
            grad_body, mesh=mesh,
            in_specs=(jax.tree.map(lambda s: P(), p_specs,
                                   is_leaf=lambda s: isinstance(s, P)),
                      b_spec),
            out_specs=(P(dp_axes), g_specs_s1),
            manual_axes=dp_axes)
    else:
        # Older jax: partially-auto shard_map trips the XLA SPMD
        # partitioner; the equivalent classic formulation is a
        # spmd_axis_name'd vmap over the dp-stacked batch under plain
        # GSPMD — same per-shard gradients, stacked on the leading dim.
        def stage1(params, batch):
            split = jax.tree.map(
                lambda x: x.reshape(
                    (dp_total, x.shape[0] // dp_total) + x.shape[1:]),
                batch)

            def per_shard(mb):
                with shd.block_axes(dp_axes):
                    return _microbatched_grads(
                        loss_fn, params, mb, train_cfg.microbatches)

            return jax.vmap(per_shard, spmd_axis_name=dp_axes)(split)

    # ---- stage 2: hierarchical compressed RS + ZeRO-1 Adam + AG ---------
    def sync_body(params, grads_stacked, flat_opt):
        grads_local = jax.tree.map(lambda g: g[0], grads_stacked)
        g_flat, meta = _flatten_local(grads_local)
        p_flat, _ = _flatten_local(params)
        pad = n_padded - n_local
        g_flat = jnp.pad(g_flat, (0, pad))
        p_flat = jnp.pad(p_flat, (0, pad))

        seg = g_flat
        ok = jnp.bool_(True)
        ghist = phist = jnp.zeros((256,), jnp.int32)
        for ax in rs_order:     # intra-pod then cross-pod (flat mode),
                                # or ONE pod-bound combined group
            if telemetry:
                (seg, _valid, ok_i), h = rs_ch[ax].reduce_scatter(
                    seg, with_hist=True)
                ghist = ghist + h
            else:
                seg, _valid, ok_i = rs_ch[ax].reduce_scatter(seg)
            ok &= ok_i
        seg = seg / dp_total                    # mean over dp

        # exact global grad norm: weight out model-replication. With a
        # pod-bound wire the segment owner is the pod-major combined
        # rank (the channel's rank convention); flat mode keeps the
        # historic rs_order fold.
        idx = (jax.lax.axis_index(pod_axis).astype(jnp.int32)
               if pod_axis is not None else jnp.int32(0))
        for ax in rs_order:
            idx = idx * dp_sizes[ax] + jax.lax.axis_index(ax)
        w_seg = jax.lax.dynamic_slice(
            jnp.asarray(weight_vec), (idx * seg_len,), (seg_len,))
        local_sq = jnp.sum(w_seg * jnp.square(seg))
        gnorm = jnp.sqrt(jax.lax.psum(
            local_sq, tuple(dp_axes) + ("model",)))

        p_seg = jax.lax.dynamic_slice(p_flat, (idx * seg_len,), (seg_len,))
        opt_local = {kk: (vv.reshape(vv.shape[-1:]) if vv.ndim else vv)
                     for kk, vv in flat_opt.items()}
        new_seg, new_opt, lr = opt.apply_flat_update(
            p_seg, seg, opt_local, opt_cfg, gnorm)

        full = new_seg
        for ax in reversed(rs_order):   # mirrored: cross-pod first
            if telemetry:
                full, ok_i, h = ag_ch[ax].all_gather(full, with_hist=True)
                phist = phist + h
            else:
                full, ok_i = ag_ch[ax].all_gather(full)
            ok &= ok_i
        # ok is per-rank (each rank decodes different payloads, and the
        # model axis shards the flat vector); the step's retry signal
        # must trip when ANY rank's escape pool overflowed. Reduce it
        # globally — the P() out-spec would otherwise silently report
        # rank 0's flag.
        ok = jnp.equal(jax.lax.psum(
            jnp.where(ok, jnp.int32(0), jnp.int32(1)),
            tuple(dp_axes) + ("model",)), 0)
        new_params = _unflatten_local(full[:n_local], meta)
        new_params = jax.tree.map(lambda a, old: a.astype(old.dtype),
                                  new_params, params)
        new_opt_out = {kk: new_opt[kk].reshape(flat_opt[kk].shape)
                       for kk in flat_opt}
        if telemetry:
            # Global traffic view: every rank encodes a different shard
            # (and the model axis splits the flat vector), so the
            # channel histograms are per-rank. Sum them.
            axes = tuple(dp_axes) + ("model",)
            ghist = jax.lax.psum(ghist, axes)
            phist = jax.lax.psum(phist, axes)
            return (new_params, new_opt_out, ok, gnorm, lr,
                    ghist, phist)
        return new_params, new_opt_out, ok, gnorm, lr

    opt_state_spec = {
        "m": P(*(dp_axes + ("model", None))),
        "v": P(*(dp_axes + ("model", None))),
        "step": P(),
    }

    out_specs = (p_specs, opt_state_spec, P(), P(), P())
    if telemetry:
        out_specs += (P(), P())
    stage2 = _shard_map(
        sync_body, mesh=mesh,
        in_specs=(p_specs, g_specs, opt_state_spec),
        out_specs=out_specs)

    def train_step(params, flat_opt_state, batch):
        loss_per_dp, grads_stacked = stage1(params, batch)
        outs = stage2(params, grads_stacked, flat_opt_state)
        new_params, new_opt, ok, gnorm, lr = outs[:5]
        metrics = {"loss": jnp.mean(loss_per_dp), "ok": ok,
                   "grad_norm": gnorm, "lr": lr}
        if telemetry:
            metrics["adapt/grads_hist"] = outs[5]
            metrics["adapt/params_hist"] = outs[6]
        return new_params, new_opt, metrics

    return train_step


def init_compressed_opt_state(model_cfg: ModelConfig, mesh: Mesh,
                              train_cfg: TrainConfig, comm_cfg,
                              opt_cfg: opt.OptConfig):
    """Global ZeRO-1 state arrays [*dp_dims, model, seg].

    ``comm_cfg``: a ``CommConfig``, or the ``CodecRegistry`` passed to
    ``make_compressed_step`` (geometry comes from its grad entry)."""
    if isinstance(comm_cfg, CodecRegistry):
        comm_cfg = Channel(ChannelSpec(codec=GRAD_TYPE),
                           registry=comm_cfg).cfg
    _, _, seg, _ = flat_geometry(model_cfg, mesh, train_cfg, comm_cfg)
    dp_axes = dp_axes_in(mesh, train_cfg)
    lead = tuple(mesh.shape[a] for a in dp_axes) + (mesh.shape["model"],)
    dt = jnp.dtype(opt_cfg.moment_dtype)
    return {
        "m": jnp.zeros(lead + (seg,), dt),
        "v": jnp.zeros(lead + (seg,), dt),
        "step": jnp.zeros((), jnp.int32),
    }
