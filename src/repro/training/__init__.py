from repro.training.optimizer import OptConfig  # noqa: F401
from repro.training.train_step import (  # noqa: F401
    TrainConfig,
    init_compressed_opt_state,
    make_baseline_step,
    make_compressed_step,
    step_channels,
)
from repro.training.trainer import Trainer, TrainerConfig  # noqa: F401
