"""Training loop: checkpoint/resume, straggler watchdog, comm-failure
retry (compressed step -> baseline step), metrics."""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.fault import StragglerWatchdog

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 100
    log_every: int = 10
    keep_checkpoints: int = 3


class Trainer:
    """Drives a jitted train step over a dataset with fault handling.

    ``step_fn(params, opt_state, batch) -> (params, opt_state, metrics)``.
    If ``metrics["ok"]`` is False (compressed-wire escape-pool overflow),
    the step is redone with ``fallback_step_fn`` — the paper's lossless
    guarantee is preserved by retrying on the uncompressed path rather
    than accepting corrupt gradients.

    ``on_step(step, metrics) -> Optional[new_step_fn]`` runs after each
    completed step (post-fallback). Returning a callable replaces
    ``step_fn`` from the next step on — the online codec adaptation
    seam (``repro.adaptive.TrainingAdapter`` observes the step's
    telemetry histograms and, after a hot-swap, returns a step rebuilt
    against the updated registry).
    """

    def __init__(self, cfg: TrainerConfig, step_fn: Callable,
                 fallback_step_fn: Optional[Callable] = None,
                 on_step: Optional[Callable] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.fallback_step_fn = fallback_step_fn
        self.on_step = on_step
        self.watchdog = StragglerWatchdog()
        self.ckpt = (CheckpointManager(cfg.checkpoint_dir,
                                       keep=cfg.keep_checkpoints)
                     if cfg.checkpoint_dir else None)
        self.history: list = []
        self.comm_fallbacks = 0

    def restore_or(self, params, opt_state, start_step: int = 0):
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            (params, opt_state), extra = self.ckpt.restore(
                (params, opt_state))
            start_step = int(extra.get("step", self.ckpt.latest_step()))
            log.info("resumed from step %d", start_step)
        return params, opt_state, start_step

    def run(self, params, opt_state, dataset, start_step: int = 0):
        step = start_step
        while step < self.cfg.total_steps:
            batch = dataset.batch_at(step)
            t0 = time.time()
            params2, opt2, metrics = self.step_fn(params, opt_state, batch)
            ok = bool(np.asarray(metrics.get("ok", True)))
            if not ok and self.fallback_step_fn is not None:
                # escape-pool overflow: redo this step uncompressed
                self.comm_fallbacks += 1
                log.warning("comm escape overflow at step %d; retrying "
                            "uncompressed", step)
                params2, opt2, metrics = self.fallback_step_fn(
                    params, opt_state, batch)
            params, opt_state = params2, opt2
            dt = time.time() - t0
            self.watchdog.observe(step, dt)
            if self.on_step is not None:
                new_step_fn = self.on_step(step, metrics)
                if new_step_fn is not None:
                    log.info("step fn replaced at step %d (codec "
                             "hot-swap)", step)
                    self.step_fn = new_step_fn
            step += 1

            loss = float(np.asarray(metrics["loss"]))
            self.history.append({"step": step, "loss": loss, "dt": dt})
            if step % self.cfg.log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
            if self.ckpt is not None and (
                    step % self.cfg.checkpoint_every == 0
                    or step == self.cfg.total_steps):
                self.ckpt.save(step, (params, opt_state),
                               extra={"step": step})
        return params, opt_state
