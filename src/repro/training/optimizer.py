"""AdamW + LR schedules, built in-repo (no external optimizer dep).

Two state layouts:
  * pytree state (mirrors params) — baseline GSPMD path.
  * flat sliced state [seg] — ZeRO-1 sharded optimizer used by the
    compressed-communication train step (each (dp, model) rank updates
    its slice of the flat parameter vector).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"   # bfloat16 halves optimizer memory


def lr_at(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


# ---- pytree-state AdamW ---------------------------------------------------

def init_state(params, cfg: OptConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def apply_update(params, grads, state, cfg: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


# ---- flat-slice AdamW (ZeRO-1, used by the compressed train step) --------

def init_flat_state(seg_len: int, cfg: OptConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    return {
        "m": jnp.zeros((seg_len,), dt),
        "v": jnp.zeros((seg_len,), dt),
        "step": jnp.zeros((), jnp.int32),
    }


def apply_flat_update(p_seg, g_seg, state, cfg: OptConfig, gnorm
                      ) -> Tuple[jnp.ndarray, Dict[str, Any], jnp.ndarray]:
    """AdamW on a flat slice (clip uses the provided global grad norm)."""
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    g = g_seg.astype(jnp.float32) * scale
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    m32 = b1 * state["m"].astype(jnp.float32) + (1 - b1) * g
    v32 = b2 * state["v"].astype(jnp.float32) + (1 - b2) * jnp.square(g)
    delta = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
    if cfg.weight_decay:
        delta = delta + cfg.weight_decay * p_seg.astype(jnp.float32)
    new_p = p_seg.astype(jnp.float32) - lr * delta
    new_state = {"m": m32.astype(state["m"].dtype),
                 "v": v32.astype(state["v"].dtype), "step": step}
    return new_p.astype(p_seg.dtype), new_state, lr
