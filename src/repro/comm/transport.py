"""Transport layer for the compressed collectives: one-shot, ring, and
hierarchical (the kinds in ``planner.TRANSPORT_KINDS``).

The paper's value proposition is that QLC decode is cheap enough to sit
on the critical path of bandwidth-bound collectives — but only if it
actually overlaps the wire. This module owns HOW the compressed payload
moves:

* **one-shot** (legacy): a single ``lax.all_gather`` / ``lax.all_to_all``
  of the full payload; every decode runs strictly after the last byte
  lands. Decode latency adds serially to wire latency. On a channel
  bound to a pod axis the collective runs over the combined
  ``(pod_axis, axis)`` tuple group.

* **ring**: the payload moves in ``axis_size - 1`` ``lax.ppermute``
  hops. The graph is structured so hop *k*'s decode (+ dequantize, and
  for reduce-scatter + accumulate — one fused Pallas dispatch with
  ``cfg.use_kernels``) has NO data dependency on hop *k+1*'s transfer,
  so the compiler's latency-hiding scheduler runs them concurrently:
  decode hides behind the wire instead of following it.
  ``TransportConfig.hop_chunks`` splits each hop payload into
  independently-compressed pieces for finer-grained overlap (the
  planner's alpha-beta model picks it).

* **hierarchical** (multi-host): for a ``pod_size x local_size`` group
  (``pod_axis`` crossing the slow DCN tier, the local axis on ICI),
  an intra-pod ring over the local axis where each hop group's unit is
  bridged across pods by ONE compressed pod-axis exchange — the
  original compressed bytes, never partial sums, cross the DCN — and
  decode of hop group *t* overlaps both the next local hop and bridge
  *t+1*. See the per-collective schedules below.

Schedules (d = axis size, i = this device):

* all-gather — classic neighbor ring: forward what arrived last hop on
  the fixed perm ``i -> i+1``; hop *s* delivers peer ``i-s``'s original
  payload, which is decoded into its output row while hop *s+1* is in
  flight. Hierarchical: the arrived payload is additionally
  ``all_gather``'d over the pod axis (the bridge), and all ``pod_size``
  copies decode into their pod-major output rows.
* reduce-scatter / all-to-all — rotated pairwise exchange: hop *s* uses
  perm ``j -> j+s``, every device sends its ORIGINAL compressed segment
  destined for peer ``j+s`` and receives peer ``i-s``'s segment for
  itself. No partial sums ever cross the wire, so nothing is
  re-quantized or re-encoded mid-flight — hop count trades for exact
  transport equivalence. Hierarchical: hop group *t* first bridges the
  ``local_size`` segments destined for pod ``q+t`` with one distance-t
  pod ppermute, then the intra-pod rotated exchange delivers them.

**Bit-identity contract**: all transports move the same compressed
bytes and decode them with the same code, and the reduce-scatter runs
the identical per-row-piece accumulate op sequence in a fixed arrival
order — source ``((q-t) mod P, (l-s) mod L)`` for pod distance ``t``
major, local ring distance ``s`` minor, which for one pod (``P == 1``)
is exactly the classic ring order (own segment, then peers ``i-1,
i-2, ...``) — ``_accumulate_row_pieces``. One-shot, ring, and
hierarchical therefore produce bit-identical
outputs and identical ``ok`` flags — transports are interchangeable
per collective, selected by the planner's cost model. This holds for
``hop_chunks > 1`` too: each independently-compressed piece carries an
escape pool sized for the WHOLE row (``_compress_pieces``), and the
``ok`` flag is evaluated per ROW as the summed piece escape count
against that row-sized pool (``_row_pool_ok``) — exactly the predicate
the one-shot transport evaluates on its single row payload, so an
escape burst concentrated in one piece flips ``ok`` on both transports
or neither. The pools cost ``hop_chunks - 1`` extra row-pool copies of
wire per row (``planner.payload_wire_bytes(hop_chunks=...)``), a
second-order overhead the planner's hop-count search absorbs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.planner import TransportConfig
from repro.comm import compressed as comp


def _require_axis_size(t: TransportConfig, axis_size: Optional[int]) -> int:
    if axis_size is None:
        raise ValueError(
            "the ring transport needs the static axis_size (the hop loop "
            "is unrolled at trace time); pass axis_size=mesh.shape[axis]")
    return int(axis_size)


def _tree_permute(tree, axis_name, perm):
    return jax.tree.map(
        lambda a: jax.lax.ppermute(a, axis_name, perm), tree)


def _tree_row(tree, idx):
    """Dynamic leading-axis row select on a pytree (traced index)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, idx, axis=0,
                                               keepdims=False), tree)


def _neighbor_perm(d: int):
    return [(j, (j + 1) % d) for j in range(d)]


def _shift_perm(d: int, s: int):
    return [(j, (j + s) % d) for j in range(d)]


def _resolve_pod(t: TransportConfig, pod_axis, pod_size):
    """Normalize ``(transport, pod binding)`` for one exchange.

    Without a pod axis ``hierarchical`` degrades to ``ring`` (its
    intra-pod tier) so flat channels can carry a hierarchical config
    unchanged. With one, ``ring`` is rejected: a flat neighbor ring
    over a two-axis group is not expressible (``ppermute`` takes a
    single axis name) — it exists only as the planner's modeled
    baseline (``modeled_flat_ring_time``).
    """
    P = int(pod_size) if pod_axis is not None else 1
    if pod_axis is None or P <= 1:
        if t.kind == "hierarchical":
            t = dataclasses.replace(t, kind="ring")
        return t, None, 1
    if t.kind == "ring":
        raise ValueError(
            "kind='ring' is a single-axis neighbor ring and cannot run "
            "over a pod-bound channel (lax.ppermute takes one axis "
            "name); use 'oneshot' or 'hierarchical'")
    return t, pod_axis, P


def _compress_pieces(flat: jnp.ndarray, hop_chunks: int, tables, cfg,
                     emit_hist: bool = False):
    """[..., seg] -> ``(pieces, hist)``: a list of ``hop_chunks``
    independently-compressed piece trees ``(WirePayload, scales)``
    (each with ``flat``'s lead dims), plus the summed i32[256] symbol
    histogram over everything compressed when ``emit_hist`` else None.

    Each piece is a SEPARATE pytree — the ring issues one transfer and
    one decode(+accumulate) dispatch per piece, so piece *p*'s decode
    has no data dependency on piece *p'*'s transfer and the intra-hop
    interleave the planner's cost model prices actually exists in the
    graph (stacking the pieces into one array would fuse them back into
    a single transfer + a single decode).

    Escape-pool parity: with ``hop_chunks > 1`` every piece's pool is
    sized for the WHOLE row (``pool_slots_per_1k`` scaled by the piece
    count — ``ceil((n/h) * p*h / 1024) == ceil(n * p / 1024)``), so the
    row-level ok predicate (:func:`_row_pool_ok`) is exactly the
    one-shot transport's ``total_escapes <= row_pool_slots``.
    """
    pieces = flat.reshape(flat.shape[:-1] + (hop_chunks, -1))
    if hop_chunks > 1 and cfg.enabled:
        cfg = dataclasses.replace(
            cfg, pool_slots_per_1k=cfg.pool_slots_per_1k * hop_chunks)
    if not emit_hist:
        return [comp._compress_values(pieces[..., p, :], tables, cfg)
                for p in range(hop_chunks)], None
    outs = [comp._compress_values(pieces[..., p, :], tables, cfg,
                                  emit_hist=True)
            for p in range(hop_chunks)]
    hist = sum(h for _, _, h in outs)
    return [(pp, ps) for pp, ps, _ in outs], hist


def _row_pool_ok(pieces) -> jnp.ndarray:
    """Row-level escape-pool ok of one row's piece list.

    Every piece carries a row-sized pool (:func:`_compress_pieces`), so
    the row is lossless exactly when the escape count summed across its
    pieces fits that pool — the one-shot predicate. A piece-local
    overflow implies the sum overflows too, so ``ok=True`` still
    guarantees every individual piece decoded losslessly.
    """
    pool_slots = pieces[0][0].pool.shape[-2]
    total = sum(jnp.sum(pp.pool_count) for pp, _ in pieces)
    return total <= pool_slots


def _accumulate_row_pieces(accs, pieces, tables, cfg, ok):
    """Fold one peer row's piece list into the per-piece accumulators.

    This is the transport contract's ONLY reduce step — the one-shot
    transport (rows landed via ``all_to_all``) and the ring transport
    (rows arriving hop by hop) run the identical per-piece
    ``decompress``/``accumulate_values`` sequence. Fixing the op
    sequence, not just the summation order, is what makes the
    transports bit-identical: f32 addition is non-associative AND the
    compiler may keep excess precision (FMA-contract a dequantize
    multiply into an adjacent add), so the same values reduced through
    a different graph shape could round differently.
    """
    for p, (pp, ps) in enumerate(pieces):
        if accs[p] is None:
            accs[p], _ = comp._decompress_values(pp, ps, tables, cfg)
        else:
            accs[p], _ = comp._accumulate_values(
                accs[p], comp.WirePayload(*pp), ps, tables, cfg)
    ok &= _row_pool_ok(pieces)
    return accs, ok


def ring_stream(local, axis_name, axis_size: int, consume, init):
    """Generic neighbor-forwarding ring drive (the transport contract's
    ONE implementation of the classic ring schedule — the compressed
    all-gather and the sharded weight open both run on it).

    ``local`` is this device's payload (any pytree). At hop *s* the
    buffer holding peer ``i-s``'s original payload is consumed while
    the ppermute forwarding it to the next neighbor is already issued —
    ``consume(carry, buf, src, hop) -> carry`` must not depend on that
    transfer, which is exactly what lets decode overlap the wire.
    Returns the final carry.
    """
    d = axis_size
    my = jax.lax.axis_index(axis_name)
    perm = _neighbor_perm(d)
    buf, carry = local, init
    for s in range(d):
        nxt = _tree_permute(buf, axis_name, perm) if s < d - 1 else None
        carry = consume(carry, buf, jnp.mod(my - s, d), s)
        buf = nxt
    return carry


# --------------------------------------------------------------------------
# All-gather
# --------------------------------------------------------------------------

def exchange_all_gather(flat: jnp.ndarray, axis_name, tables, cfg,
                        t: TransportConfig,
                        axis_size: Optional[int] = None,
                        emit_hist: bool = False,
                        pod_axis=None, pod_size: int = 1):
    """Gather every peer's padded shard ``flat [seg]`` -> ``[d, seg]``.

    Returns ``(vals f32 [d, seg], ok bool [])``; with ``emit_hist``
    additionally the i32[256] histogram of the LOCAL shard's encoded
    symbols (telemetry tap — per-device; psum it for a global view).

    With ``pod_axis`` bound the group is the combined
    ``pod_size x axis_size`` mesh slab and the output has
    ``pod_size * axis_size`` rows in pod-major global-rank order
    (``g = q * axis_size + l``); ``axis_size`` stays the LOCAL size.
    """
    t, pod_axis, P = _resolve_pod(t, pod_axis, pod_size)
    if t.kind == "oneshot":
        c = comp._compress_values(flat, tables, cfg, emit_hist=emit_hist)
        payload, scales = c[0], c[1]
        axes = (pod_axis, axis_name) if pod_axis is not None else axis_name
        g_payload = comp.WirePayload(*jax.tree.map(
            lambda a: jax.lax.all_gather(a, axes), payload))
        g_scales = jax.lax.all_gather(scales, axes)
        vals, ok = comp._decompress_values(g_payload, g_scales, tables, cfg)
        if emit_hist:
            return vals, jnp.all(ok), c[2]
        return vals, jnp.all(ok)

    d = _require_axis_size(t, axis_size)
    h = t.hop_chunks
    pieces, hist = _compress_pieces(flat, h, tables, cfg, emit_hist)

    if t.kind == "hierarchical":
        # Intra-pod neighbor ring; each arriving local hop buffer is
        # bridged by ONE pod-axis all_gather of the original compressed
        # bytes, and all P pod copies decode into their pod-major
        # output rows while the next local hop is in flight.
        def consume(carry, buf, src, _hop):
            out, ok = carry
            bridged = [jax.tree.map(
                lambda a: jax.lax.all_gather(a, pod_axis), pc)
                for pc in buf]
            for qq in range(P):
                row = [jax.tree.map(lambda a: a[qq], br) for br in bridged]
                for p, (pp, ps) in enumerate(row):
                    vals, _ = comp._decompress_values(pp, ps, tables, cfg)
                    out = jax.lax.dynamic_update_slice(
                        out, vals.reshape(1, 1, -1),
                        (jnp.int32(qq) * d + src, jnp.int32(p), 0))
                ok &= _row_pool_ok(row)
            return out, ok

        out0 = jnp.zeros((P * d, h, flat.shape[0] // h), jnp.float32)
        out, ok = ring_stream(pieces, axis_name, d, consume,
                              (out0, jnp.bool_(True)))
        if emit_hist:
            return out.reshape(P * d, -1), ok, hist
        return out.reshape(P * d, -1), ok

    def consume(carry, buf, src, _hop):
        out, ok = carry
        for p, (pp, ps) in enumerate(buf):
            vals, _ = comp._decompress_values(pp, ps, tables, cfg)
            out = jax.lax.dynamic_update_slice(
                out, vals.reshape(1, 1, -1), (src, jnp.int32(p), 0))
        ok &= _row_pool_ok(buf)
        return out, ok

    out0 = jnp.zeros((d, h, flat.shape[0] // h), jnp.float32)
    out, ok = ring_stream(pieces, axis_name, d, consume,
                          (out0, jnp.bool_(True)))
    if emit_hist:
        return out.reshape(d, -1), ok, hist
    return out.reshape(d, -1), ok


# --------------------------------------------------------------------------
# Reduce-scatter
# --------------------------------------------------------------------------

def exchange_reduce_scatter(xs: jnp.ndarray, axis_name, axis_size: int,
                            tables, cfg, t: TransportConfig,
                            emit_hist: bool = False,
                            pod_axis=None, pod_size: int = 1):
    """Reduce-scatter of ``xs [d, seg]`` (row j = this device's summand
    of peer j's output segment). Returns ``(acc f32 [seg], ok)``; with
    ``emit_hist`` additionally the i32[256] histogram of ALL symbols
    this device encoded (every row it contributed).

    Every transport quantizes+encodes each segment exactly once and
    sums dequantized f32 at the destination in the canonical
    ``(pod distance, local ring distance)`` arrival order —
    bit-identical across transports.

    With ``pod_axis`` bound, ``axis_size`` is the LOCAL size, ``xs``
    has ``pod_size * axis_size`` rows in pod-major global-rank order,
    and row ``g`` is the summand for combined rank ``g``.
    """
    t, pod_axis, P = _resolve_pod(t, pod_axis, pod_size)
    d = axis_size
    h = t.hop_chunks
    pieces, hist = _compress_pieces(xs, h, tables, cfg,
                                    emit_hist)    # h trees, lead [P*d]
    my = jax.lax.axis_index(axis_name)
    q = (jax.lax.axis_index(pod_axis) if pod_axis is not None
         else jnp.int32(0))

    def row_pieces(idx):
        return [_tree_row(pc, idx) for pc in pieces]

    accs = [None] * h
    ok = jnp.bool_(True)

    if t.kind == "oneshot":
        axes = (pod_axis, axis_name) if pod_axis is not None else axis_name
        a2a = lambda a: jax.lax.all_to_all(                 # noqa: E731
            a, axes, split_axis=0, concat_axis=0, tiled=True)
        r_pieces = [(comp.WirePayload(*jax.tree.map(a2a, pp)), a2a(ps))
                    for pp, ps in pieces]
        # Decode strictly AFTER the full exchange (that is what makes
        # it one-shot), but through the shared per-row-piece accumulate
        # primitive so the reduction is op-for-op the ring's. This
        # costs d accumulate dispatches where a single batched decode
        # + add chain would do — a deliberate trade: the batched form's
        # external adds are subject to graph-dependent FMA contraction
        # against the ring's in-kernel accumulate, and no graph-level
        # fence reliably pins that down (_accumulate_row_pieces); the
        # planner charges one-shot RS for the d dispatches.
        # Arrival order is the canonical (tp, s) nesting; at P == 1 it
        # is exactly the classic flat order (my - s) mod d.
        for tp in range(P):
            for s in range(d):
                idx = jnp.mod(q - tp, P) * d + jnp.mod(my - s, d)
                accs, ok = _accumulate_row_pieces(
                    accs, [_tree_row(pc, idx) for pc in r_pieces],
                    tables, cfg, ok)
        if emit_hist:
            return jnp.concatenate(accs), ok, hist
        return jnp.concatenate(accs), ok

    if t.kind == "hierarchical":
        # Hop group tp: slice the d ORIGINAL compressed segments
        # destined for pod q+tp, bridge them with one distance-tp pod
        # ppermute (after which this device holds source (q-tp, my)'s
        # segments for its own pod), then the intra-pod rotated
        # exchange delivers source ((q-tp) mod P, (my-s) mod d)'s
        # segment at local step s — the canonical accumulate order.
        for tp in range(P):
            start = jnp.mod(q + tp, P) * d
            grp = [jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, start, d,
                                                       axis=0), pc)
                for pc in pieces]
            if tp > 0:
                grp = _tree_permute(grp, pod_axis, _shift_perm(P, tp))
            for s in range(d):
                unit = [_tree_row(g, jnp.mod(my + s, d)) for g in grp]
                if s > 0:
                    unit = _tree_permute(unit, axis_name,
                                         _shift_perm(d, s))
                accs, ok = _accumulate_row_pieces(accs, unit, tables,
                                                  cfg, ok)
        if emit_hist:
            return jnp.concatenate(accs), ok, hist
        return jnp.concatenate(accs), ok

    # Rotated pairwise exchange: hop s sends the ORIGINAL compressed
    # segment destined for peer i+s and receives peer i-s's segment for
    # this device; the per-piece fused decode→dequantize→accumulate of
    # hop s runs while hop s+1 (and this hop's other pieces) are in
    # flight. Own contribution first — same decode as if it crossed the
    # wire (segment j is encoded once, decoded once, everywhere).
    for s in range(d):
        unit = row_pieces(jnp.mod(my + s, d))
        if s > 0:
            unit = _tree_permute(unit, axis_name, _shift_perm(d, s))
        accs, ok = _accumulate_row_pieces(accs, unit, tables, cfg, ok)
    if emit_hist:
        return jnp.concatenate(accs), ok, hist
    return jnp.concatenate(accs), ok


# --------------------------------------------------------------------------
# All-to-all
# --------------------------------------------------------------------------

def exchange_all_to_all(rows: jnp.ndarray, axis_name, tables, cfg,
                        t: TransportConfig,
                        axis_size: Optional[int] = None,
                        emit_hist: bool = False,
                        pod_axis=None, pod_size: int = 1):
    """All-to-all of ``rows [d, n]`` (row j -> peer j); returns
    ``(vals f32 [d, n], ok)`` — with ``emit_hist`` additionally the
    i32[256] histogram of all symbols this device encoded — where
    output row j holds peer j's dequantized row for this device.

    This is the MoE expert-dispatch wire (``moe.impl="shardmap_a2a"``
    routes its dispatch/combine buffers through ``Channel.all_to_all``
    → here). The ring schedule's hop *s* is a distance-``s`` ppermute
    whose decode overlaps hop *s+1*'s transfer; it is bit-identical to
    one-shot (the own row stays quantized either way), and its modeled
    cost — including the ``s`` link traversals a distance-``s``
    ppermute serializes through — is ``planner.modeled_a2a_ring_time``,
    which drives the ``"auto"`` selection.

    With ``pod_axis`` bound, ``rows`` has ``pod_size * axis_size``
    rows keyed by pod-major combined rank (``axis_size`` = LOCAL size)
    and the hierarchical schedule moves each destination-pod group of
    ``axis_size`` original compressed rows over ONE distance-``tp``
    pod ppermute before the intra-pod rotated exchange delivers them.
    """
    t, pod_axis, P = _resolve_pod(t, pod_axis, pod_size)
    dt = rows.shape[0]                    # combined group size P * L
    if t.kind == "oneshot":
        c = comp._compress_values(rows, tables, cfg, emit_hist=emit_hist)
        payload, scales = c[0], c[1]
        axes = (pod_axis, axis_name) if pod_axis is not None else axis_name
        a2a = lambda a: jax.lax.all_to_all(                 # noqa: E731
            a, axes, split_axis=0, concat_axis=0, tiled=True)
        r_payload = comp.WirePayload(*jax.tree.map(a2a, payload))
        r_scales = a2a(scales)
        vals, ok = comp._decompress_values(r_payload, r_scales, tables, cfg)
        if emit_hist:
            return vals, jnp.all(ok), c[2]
        return vals, jnp.all(ok)

    # The LOCAL size is static from rows.shape; an explicit axis_size
    # must agree.
    d = dt // P
    assert d * P == dt, (dt, P)
    assert axis_size is None or int(axis_size) == d, (axis_size, d, P)
    h = t.hop_chunks
    pieces, hist = _compress_pieces(rows, h, tables, cfg,
                                    emit_hist)      # h trees, lead [dt]
    my = jax.lax.axis_index(axis_name)
    out = jnp.zeros((dt, h, rows.shape[-1] // h), jnp.float32)
    ok = jnp.bool_(True)

    if t.kind == "hierarchical":
        # Same movement as the hierarchical reduce-scatter — hop group
        # tp bridges the d original rows destined for pod q+tp over one
        # distance-tp pod ppermute, then the intra-pod rotated exchange
        # delivers them — but the delivered unit is scattered into the
        # source's pod-major output row instead of accumulated.
        q = jax.lax.axis_index(pod_axis)
        for tp in range(P):
            start = jnp.mod(q + tp, P) * d
            grp = [jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, start, d,
                                                       axis=0), pc)
                for pc in pieces]
            if tp > 0:
                grp = _tree_permute(grp, pod_axis, _shift_perm(P, tp))
            for s in range(d):
                src = jnp.mod(q - tp, P) * d + jnp.mod(my - s, d)
                unit = [_tree_row(g, jnp.mod(my + s, d)) for g in grp]
                if s > 0:
                    unit = _tree_permute(unit, axis_name,
                                         _shift_perm(d, s))
                for p, (pp, ps) in enumerate(unit):
                    vals, _ = comp._decompress_values(pp, ps, tables, cfg)
                    out = jax.lax.dynamic_update_slice(
                        out, vals.reshape(1, 1, -1),
                        (src, jnp.int32(p), 0))
                ok &= _row_pool_ok(unit)
        return out.reshape(dt, -1), ok

    # Own row needs no wire but the same decode (a2a keeps the local
    # row quantized, matching the one-shot path bit for bit).
    for s in range(d):
        src = jnp.mod(my - s, d)
        unit = [_tree_row(pc, jnp.mod(my + s, d)) for pc in pieces]
        if s > 0:
            unit = _tree_permute(unit, axis_name, _shift_perm(d, s))
        for p, (pp, ps) in enumerate(unit):
            vals, _ = comp._decompress_values(pp, ps, tables, cfg)
            out = jax.lax.dynamic_update_slice(
                out, vals.reshape(1, 1, -1), (src, jnp.int32(p), 0))
        ok &= _row_pool_ok(unit)
    return out.reshape(dt, -1), ok
