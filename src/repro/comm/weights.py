"""QLC-compressed weight wire for serving (paper §7: per-tensor-type
LUTs; FFN1/FFN2 *weights* are among the tensor types the paper's traces
cover).

Decode steps at production scale are collective-bound: with FSDP'd
parameters every token gathers the sharded weights in bf16. Storing the
layer-stack parameters as block-32 e4m3 symbols (+ QLC words) makes
those gathers move ~0.46x (QLC) / ~0.53x (raw e4m3) of the bytes; the
codec runs in-graph right after the gather, inside the layer scan — a
compute-for-bandwidth trade that wins exactly when the roofline says
the cell is collective-bound.

Per-leaf codecs: the wire codec carries a
:class:`~repro.core.registry.CodecRegistry` and every compressed leaf
records its **scheme-id** in its :class:`LeafMeta` — different leaves
(FFN1 vs FFN2 vs attention stacks) decode under different LUTs, and the
whole recipe serializes to a JSON manifest
(:meth:`GroupWireCodec.manifest`) that a serving host can reload with
:meth:`GroupWireCodec.from_manifest` — no out-of-band table agreement.
Legacy call sites passing a bare ``CodecTables`` keep working (wrapped
into a one-entry registry).

Weights are static: for real parameters the slot capacity is the exact
measured max chunk size — zero escapes, no pool, unconditionally
lossless (relative to the e4m3 values). Embeddings / LM head stay in
bf16 (token gathers touch single rows; whole-table decode would be
absurd).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.registry import CodecRegistry, registry_of
from repro.quant import e4m3

CHUNK = 1024
MIN_COMPRESS_SIZE = 1 << 16      # per-group; leave norms etc. alone

#: registry name used when no per-leaf type key resolves.
DEFAULT_TYPE = "default"


@dataclasses.dataclass(frozen=True)
class LeafMeta:
    group_shape: Tuple[int, ...]   # shape of ONE group's slice
    dtype: Any
    n_symbols: int                 # per group
    n_chunks: int                  # per group
    capacity_words: int
    mode: str                      # qlc | e4m3
    scheme_id: int = 0             # registry id of the leaf's codec


@dataclasses.dataclass
class GroupWireCodec:
    """Static recipe + per-leaf codecs to open wired group params
    in-graph.

    Works on a whole wired tree (leaves keep their leading group dim)
    or on a single group's slice inside the layer scan (group dim
    already indexed away) — leading dims are preserved either way.

    Each leaf's :class:`LeafMeta` carries a scheme-id into
    ``registry``, so one wired tree mixes codecs freely (per-tensor-
    type LUTs). ``manifest()``/``from_manifest()`` round-trip the whole
    recipe — registry AND channel placement (transport/axis/kernel
    toggle) included — through JSON.

    ``use_kernels=True`` opens QLC leaves with the fused
    decode→dequantize Pallas kernel (``repro.kernels.ops``): one
    dispatch from packed words to float values, decoded symbols never
    touch HBM. Numerics are bit-identical to the pure-JAX path.

    :meth:`channel` binds the wire codec's placement as a
    :class:`~repro.comm.channel.Channel`; ``open_group_sharded`` (and
    ``serving.open_params``) accept one in place of loose
    axis/transport kwargs.
    """
    meta: Dict[str, LeafMeta]
    registry: CodecRegistry
    use_kernels: bool = False
    # Default transport for `open_group_sharded` (None => ring): how a
    # chunk-sharded wire moves to this device — "oneshot" all_gather
    # then decode, or ppermute ring hops with per-hop decode overlap.
    transport: Optional[Any] = None
    # Mesh axis the chunk-sharded open runs over (manifest metadata;
    # axis_size stays deployment-local).
    axis: Optional[str] = None

    @property
    def tables(self):
        """Back-compat: the registry's sole/first entry's tables."""
        entries = self.registry.entries()
        return entries[0].tables if entries else None

    def channel(self, axis_name: Optional[str] = None,
                axis_size: Optional[int] = None, *, transport=None,
                use_kernels: Optional[bool] = None):
        """This wire's placement as a bound
        :class:`~repro.comm.channel.Channel`.

        The channel carries transport policy + mesh axis + kernel
        toggle (per-leaf codecs still resolve by scheme-id from the
        registry); pass it to :func:`repro.serving.open_params` /
        :meth:`open_group_sharded`. Arguments default to the codec's
        recorded placement (``self.transport`` / ``self.axis`` /
        ``self.use_kernels``); an axis-bound channel with no recorded
        transport defaults to ``"ring"``, matching the sharded open's
        loose-kwarg default — both spellings stream the wire the same
        way.
        """
        from repro.comm.channel import Channel, ChannelSpec
        axis = axis_name if axis_name is not None else self.axis
        t = transport if transport is not None else self.transport
        if t is None and axis is not None:
            t = "ring"          # the sharded open's default transport
        return Channel(
            ChannelSpec(
                codec=None,
                transport=t,
                axis=axis,
                axis_size=axis_size,
                use_kernels=(self.use_kernels if use_kernels is None
                             else use_kernels)),
            registry=self.registry)

    def open_group(self, pg):
        def walk(node, prefix):
            if isinstance(node, dict) and (
                    set(node) == {"codes", "scales"}
                    or set(node) == {"words", "scales"}):
                return self._decode(node, self.meta[prefix])
            if isinstance(node, dict):
                return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                        for k, v in node.items()}
            return node
        return walk(pg, "")

    def open_group_sharded(self, pg, axis_name=None,
                           axis_size: Optional[int] = None,
                           transport=None, *, channel=None):
        """Open a wired tree whose compressed leaves are SHARDED along
        the chunk dim across ``axis_name`` (call inside ``shard_map``).

        This is the FSDP serving gather: instead of all-gathering bf16
        weights, each device streams the QLC wire of every peer's chunk
        shard and decodes it in-graph. With the ring transport
        (default) hop *k*'s shard decodes — one fused
        decode→dequantize dispatch per hop with ``use_kernels`` —
        while hop *k+1*'s compressed bytes are in flight; the one-shot
        transport all-gathers the whole wire first and decodes after.
        Both produce values bit-identical to :meth:`open_group` on the
        unsharded tree (per-chunk decode is independent of batching).

        ``channel`` (a :class:`~repro.comm.channel.Channel`) supplies
        axis/axis_size/transport in one bound object; its ``"auto"``
        policy resolves per leaf from the shard's static geometry.
        """
        if channel is not None:
            axis_name = axis_name or channel.axis
            axis_size = axis_size or channel.axis_size
        if axis_name is None or axis_size is None:
            raise ValueError(
                "the sharded open needs a mesh axis + static axis_size "
                "(pass axis_name/axis_size or a bound Channel)")
        t = None
        if channel is None or transport is not None:
            from repro.comm.planner import resolve_transport
            t = resolve_transport(
                transport if transport is not None
                else (self.transport or "ring"))

        def walk(node, prefix):
            if isinstance(node, dict) and (
                    set(node) == {"codes", "scales"}
                    or set(node) == {"words", "scales"}):
                return self._decode_sharded(
                    node, self.meta[prefix], axis_name, axis_size, t,
                    channel=channel)
            if isinstance(node, dict):
                return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                        for k, v in node.items()}
            return node
        return walk(pg, "")

    def _decode_sharded(self, wire, m: LeafMeta, axis_name,
                        axis_size: int, t, channel=None) -> jnp.ndarray:
        d = axis_size
        main_key = "codes" if m.mode == "e4m3" else "words"
        ncl = wire[main_key].shape[-2]           # local chunk shard
        assert ncl * d == m.n_chunks, (
            "leaf must be evenly chunk-sharded", ncl, d, m.n_chunks)
        if t is None:                # channel-bound transport, per leaf
            t = channel.resolved_transport(ncl * CHUNK, axis_size=d)

        if t.kind == "oneshot":
            g_wire = {k: jnp.moveaxis(
                jax.lax.all_gather(v, axis_name), 0, -3 if k == main_key
                else -2) for k, v in wire.items()}
            # [..., d, ncl, W] -> [..., d*ncl, W] (chunk-major order)
            g_wire = {
                main_key: g_wire[main_key].reshape(
                    wire[main_key].shape[:-2]
                    + (m.n_chunks, wire[main_key].shape[-1])),
                "scales": g_wire["scales"].reshape(
                    wire["scales"].shape[:-1] + (-1,)),
            }
            vals = self._decode_flat(g_wire, m, m.n_chunks)
        else:
            from repro.comm.planner import clamp_hop_chunks
            from repro.comm.transport import ring_stream
            lead = wire[main_key].shape[:-2]
            # hop_chunks pieces per shard (clamped to tile the local
            # chunk count) — finer decode/transfer interleave, same as
            # the collectives' hop chunking.
            hp = clamp_hop_chunks(t.hop_chunks, ncl)
            npc = ncl // hp                       # chunks per piece
            piece = npc * CHUNK
            sb = piece // e4m3.BLOCK
            pieces = [{main_key: wire[main_key][..., p * npc:(p + 1) * npc,
                                                :],
                       "scales": wire["scales"][..., p * sb:(p + 1) * sb]}
                      for p in range(hp)]

            # Shared neighbor-forwarding ring (transport.ring_stream):
            # decode the pieces already here while the next hop's
            # compressed bytes are in flight.
            def consume(out, buf, src, _hop):
                for p, pc in enumerate(buf):
                    vals = self._decode_flat(pc, m, npc)  # [*lead, piece]
                    out = jax.lax.dynamic_update_slice(
                        out, vals.reshape(lead + (1, 1, piece)),
                        (0,) * len(lead) + (src, jnp.int32(p),
                                            jnp.int32(0)))
                return out

            out0 = jnp.zeros(lead + (d, hp, piece), self._decode_dtype(m))
            out = ring_stream(pieces, axis_name, d, consume, out0)
            vals = out.reshape(lead + (d * ncl * CHUNK,))

        out = vals[..., :m.n_symbols].reshape(
            vals.shape[:-1] + m.group_shape)
        return out.astype(m.dtype)

    def _decode_dtype(self, m: LeafMeta):
        """dtype `_decode_flat` emits for this leaf (pre-epilogue)."""
        if m.mode == "qlc" and self.use_kernels:
            if jnp.dtype(m.dtype) in (jnp.dtype(jnp.bfloat16),
                                      jnp.dtype(jnp.float32)):
                return jnp.dtype(m.dtype)
        return jnp.dtype(jnp.float32)

    def _decode_flat(self, wire, m: LeafMeta, n_chunks: int
                     ) -> jnp.ndarray:
        """Decode a (possibly chunk-sharded) wire dict to flat values
        ``[*lead, n_chunks*CHUNK]`` — pre-slice, in the decode dtype.

        ``n_chunks`` is the chunk count of THIS wire dict: ``m.n_chunks``
        for a whole leaf, or the local shard's count on the sharded ring
        path (per-chunk decode is independent, so shard decodes are
        bit-identical to the corresponding slice of a whole-leaf decode).
        """
        tables = self.registry.by_id(m.scheme_id).tables
        padded = n_chunks * CHUNK
        main = wire["codes"] if m.mode == "e4m3" else wire["words"]
        lead = main.shape[:-2]
        g = int(np.prod(lead, initial=1))
        scales = wire["scales"].reshape(lead + (-1,))[..., :padded // e4m3.BLOCK]
        if m.mode == "qlc" and self.use_kernels:
            from repro.kernels import ops as kops
            # Emit the leaf's dtype straight from the kernel when it is
            # a float type the store supports (bf16 weights: no second
            # pass over the tensor).
            out_dt = (jnp.dtype(m.dtype)
                      if jnp.dtype(m.dtype) in (jnp.dtype(jnp.bfloat16),
                                                jnp.dtype(jnp.float32))
                      else jnp.float32)
            return kops.decode_dequantize(
                main.reshape(g * n_chunks, m.capacity_words),
                scales.astype(jnp.float32).reshape(
                    g * n_chunks, CHUNK // e4m3.BLOCK),
                tables, CHUNK,
                out_dtype=out_dt).reshape(lead + (padded,))
        if m.mode == "e4m3":
            codes_flat = main.reshape(lead + (padded,))
        else:
            codes_flat = codec.decode_chunks(
                main, tables, CHUNK).reshape(lead + (padded,))
        return e4m3.dequantize_block32(
            codes_flat, scales.astype(jnp.float32))

    def _decode(self, wire, m: LeafMeta) -> jnp.ndarray:
        # One explicit gather of the wire (replicate), THEN decode: the
        # codec loop must consume local data or GSPMD re-gathers every
        # iteration.
        import jax as _jax
        from jax.sharding import PartitionSpec as _P
        try:
            wire = {k: _jax.lax.with_sharding_constraint(v, _P())
                    for k, v in wire.items()}
        except Exception:
            pass
        # Wire leaves are [*lead_g, n_chunks, …] — lead_g is the group
        # dim for a whole wired tree, or () inside the per-layer scan
        # where the group dim was indexed away. Every group decodes;
        # lead dims are preserved in the output.
        vals = self._decode_flat(wire, m, m.n_chunks)
        lead = vals.shape[:-1]
        out = vals[..., :m.n_symbols].reshape(lead + m.group_shape)
        return out.astype(m.dtype)

    # ---- manifest (serving handoff) -------------------------------------

    def manifest(self) -> Dict:
        """JSON-able recipe: per-leaf geometry + scheme-ids, the
        registry itself, and the channel placement (transport / axis /
        kernel toggle) — the whole binding round-trips."""
        from repro.comm.channel import transport_to_json
        leaves = {}
        for key, m in self.meta.items():
            leaves[key] = {
                "group_shape": list(m.group_shape),
                "dtype": str(jnp.dtype(m.dtype)),
                "n_symbols": m.n_symbols,
                "n_chunks": m.n_chunks,
                "capacity_words": m.capacity_words,
                "mode": m.mode,
                "scheme_id": m.scheme_id,
            }
        return {"version": 1, "leaves": leaves,
                "registry": self.registry.to_json_dict(),
                "channel": {
                    "transport": transport_to_json(self.transport),
                    "axis": self.axis,
                    "use_kernels": self.use_kernels,
                }}

    @classmethod
    def from_manifest(cls, d: Dict,
                      use_kernels: Optional[bool] = None
                      ) -> "GroupWireCodec":
        from repro.comm.channel import transport_from_json
        registry = CodecRegistry.from_json_dict(d["registry"])
        meta = {}
        for key, lm in d["leaves"].items():
            meta[key] = LeafMeta(
                group_shape=tuple(lm["group_shape"]),
                dtype=jnp.dtype(lm["dtype"]),
                n_symbols=int(lm["n_symbols"]),
                n_chunks=int(lm["n_chunks"]),
                capacity_words=int(lm["capacity_words"]),
                mode=lm["mode"],
                scheme_id=int(lm["scheme_id"]),
            )
        ch = d.get("channel", {})
        if use_kernels is None:        # explicit arg beats the manifest
            use_kernels = bool(ch.get("use_kernels", False))
        return cls(meta=meta, registry=registry, use_kernels=use_kernels,
                   transport=transport_from_json(ch.get("transport")),
                   axis=ch.get("axis"))


def _eligible(leaf_shape) -> bool:
    if len(leaf_shape) < 2:
        return False
    per_group = int(np.prod(leaf_shape[1:]))
    return per_group >= MIN_COMPRESS_SIZE


def _geometry(leaf_shape, mode: str, capacity_words: int):
    g = leaf_shape[0]
    n = int(np.prod(leaf_shape[1:]))
    padded = -(-n // CHUNK) * CHUNK           # CHUNK % BLOCK == 0
    n_chunks = padded // CHUNK
    return g, n, padded, n_chunks


def _entry_for(registry: CodecRegistry, prefix: str,
               type_key_fn: Optional[Callable[[str], str]]):
    """Resolve a leaf path to its registry entry (per-tensor-type)."""
    if type_key_fn is not None:
        name = type_key_fn(prefix)
        if name is not None and name in registry:
            return registry[name]
    entry = registry.get(prefix, default=DEFAULT_TYPE)
    if entry is None:
        entries = registry.entries()
        if not entries:
            raise KeyError("empty codec registry")
        entry = entries[0]
    return entry


def compress_groups(groups, tables, mode: str = "qlc",
                    use_kernels: bool = False,
                    type_key_fn: Optional[Callable[[str], str]] = None,
                    ) -> Tuple[Any, GroupWireCodec]:
    """Real-parameter transform (serving launcher path).

    ``tables`` is a ``CodecTables`` (single global LUT, legacy) or a
    :class:`~repro.core.registry.CodecRegistry`; with a registry, each
    leaf's codec resolves per tensor type: ``type_key_fn(leaf_path) ->
    registry name`` if given, else an entry named exactly like the leaf
    path, else the ``"default"`` entry, else the first entry. The
    chosen scheme-id is recorded per leaf in the wire manifest.
    """
    registry = registry_of(tables)
    meta: Dict[str, LeafMeta] = {}

    def walk(node, prefix):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        leaf = node
        if not _eligible(leaf.shape):
            return leaf
        entry = _entry_for(registry, prefix, type_key_fn)
        g, n, padded, n_chunks = _geometry(leaf.shape, mode, 0)
        flat = leaf.reshape(g, -1).astype(jnp.float32)
        flat = jnp.pad(flat, ((0, 0), (0, padded - n)))
        codes, scales = e4m3.quantize_block32(flat)
        scales = scales.astype(jnp.bfloat16)
        if mode == "e4m3":
            meta[prefix] = LeafMeta(leaf.shape[1:], leaf.dtype, n,
                                    n_chunks, 0, "e4m3", entry.scheme_id)
            return {"codes": codes.reshape(g, n_chunks, CHUNK),
                    "scales": scales}
        chunks = codes.reshape(g * n_chunks, CHUNK)
        nbits = codec.encode_chunk_bits(
            chunks, jnp.asarray(entry.tables.enc_len, jnp.uint32))
        cap = int(np.ceil(float(jnp.max(nbits)) / 32))   # exact: 0 escapes
        words, _ = codec.encode_chunks(chunks, entry.tables, cap)
        meta[prefix] = LeafMeta(leaf.shape[1:], leaf.dtype, n, n_chunks,
                                cap, "qlc", entry.scheme_id)
        return {"words": words.reshape(g, n_chunks, cap),
                "scales": scales}

    wired = walk(groups, "")
    return wired, GroupWireCodec(meta=meta, registry=registry,
                                 use_kernels=use_kernels)


def wire_shape_structs(group_shapes, tables, capacity_words: int,
                       mode: str = "qlc", mesh=None,
                       wire_axes=("pod", "data"),
                       type_key_fn: Optional[Callable[[str], str]] = None):
    """Dry-run path: ShapeDtypeStructs of the wired groups (no data).

    ``capacity_words`` comes from the planner (real serving measures the
    exact max; the static wire size is what the roofline sees either
    way). ``tables`` accepts a registry exactly like
    :func:`compress_groups`.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    registry = registry_of(tables)
    meta: Dict[str, LeafMeta] = {}

    axes = tuple(a for a in wire_axes
                 if mesh is None or a in mesh.axis_names)

    def shard(shape, dim):
        if mesh is None:
            return None
        total = int(np.prod([mesh.shape[a] for a in axes]))
        spec = [None] * len(shape)
        if shape[dim] % total == 0:
            spec[dim] = axes
        return NamedSharding(mesh, P(*spec))

    def sds(shape, dtype, dim):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=shard(shape, dim))

    def walk(node, prefix):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        leaf = node
        if not _eligible(leaf.shape):
            return leaf
        entry = _entry_for(registry, prefix, type_key_fn)
        g, n, padded, n_chunks = _geometry(leaf.shape, mode, capacity_words)
        scales_sds = sds((g, padded // e4m3.BLOCK), jnp.bfloat16, 1)
        if mode == "e4m3":
            meta[prefix] = LeafMeta(tuple(leaf.shape[1:]), leaf.dtype, n,
                                    n_chunks, 0, "e4m3", entry.scheme_id)
            return {"codes": sds((g, n_chunks, CHUNK), jnp.uint8, 1),
                    "scales": scales_sds}
        meta[prefix] = LeafMeta(tuple(leaf.shape[1:]), leaf.dtype, n,
                                n_chunks, capacity_words, "qlc",
                                entry.scheme_id)
        return {"words": sds((g, n_chunks, capacity_words), jnp.uint32, 1),
                "scales": scales_sds}

    wired = walk(group_shapes, "")
    return wired, GroupWireCodec(meta=meta, registry=registry)
