"""Capacity / escape / transport planning for compressed collectives.

Wire format: chooses the static wire slot size per chunk from the
calibration histogram: slot = mean code length plus a Hoeffding-bounded
margin so the per-chunk escape probability is below
``target_escape_prob``, and an overflow pool sized so whole-payload
fallback is ~never needed.

Transport: an alpha-beta cost model (:class:`AlphaBetaModel`) selects
between the transports in :data:`TRANSPORT_KINDS` — one-shot (single
``all_gather``/``all_to_all`` of the full payload, decode strictly
after the wire), ring (``ppermute`` hops with hop *k*'s decode
overlapping hop *k+1*'s transfer — ``repro.comm.transport``), and
hierarchical (two-tier pod x local groups: intra-pod ring over the ICI
link class with one compressed inter-pod bridge exchange per hop
group over the DCN link class) — and sizes the ring's hop chunking.
The model carries per-link-class constants (:data:`LINK_CLASSES`):
per-message latency alpha and wire bandwidth beta for the ICI tier and
for the DCN tier separately, plus decode throughput beta_decode and a
per-dispatch kernel overhead; ``choose_transport`` minimizes the
modeled time, and ``Channel.autotune`` replaces the first-order
defaults with per-axis measured constants cached in the registry.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core import entropy
from repro.core.lut import CodecTables
from repro.roofline import hw

MIN_CODE_BITS = 4
MAX_CODE_BITS = 11


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """Static wire-format parameters for one tensor type."""
    chunk_symbols: int
    capacity_words: int          # QLC slot per chunk, 32-bit words
    pool_slots_per_1k: int       # escape-pool slots per 1024 chunks (min 1)
    expected_bits_per_symbol: float
    escape_prob_bound: float
    #: Per-symbol slack the calibration intended between the expected
    #: code length and the sized slot — the ONE place a stream's drift
    #: headroom is recorded. ``empirical_plan`` adds it above the
    #: measured p99.9 chunk sum (0.5 suits heavy-tailed gradient
    #: streams; plateaued streams like MoE dispatch pass 0.25), and the
    #: adaptive drift policy (``repro.adaptive``) reads the same field
    #: as its recalibration threshold: measured bits/symbol exceeding
    #: ``expected_bits_per_symbol + drift_margin_bits`` means the
    #: stream has left the envelope this plan was sized for.
    drift_margin_bits: float = 0.5

    @property
    def capacity_bits(self) -> int:
        return self.capacity_words * 32

    @property
    def wire_bytes_per_symbol(self) -> float:
        """Main-slot wire bytes per symbol (excl. scales/flags/pool)."""
        return self.capacity_words * 4 / self.chunk_symbols

    def pool_slots(self, n_chunks: int) -> int:
        return max(1, math.ceil(n_chunks * self.pool_slots_per_1k / 1024))


def hoeffding_margin_bits(chunk_symbols: int, target_prob: float,
                          lo: float = MIN_CODE_BITS,
                          hi: float = MAX_CODE_BITS) -> float:
    """Per-symbol margin t with P(mean_len > mu + t) <= target_prob."""
    return (hi - lo) * math.sqrt(math.log(1.0 / target_prob)
                                 / (2.0 * chunk_symbols))


def plan_for_tables(tables: CodecTables, counts: np.ndarray,
                    chunk_symbols: int = 1024,
                    target_escape_prob: float = 1e-6,
                    capacity_factor: Optional[float] = None,
                    pool_slots_per_1k: int = 8,
                    drift_margin_bits: float = 0.5) -> CommPlan:
    """Build a plan from calibrated tables + the calibration histogram.

    ``capacity_factor`` (bytes-per-symbol / 1.0) overrides the Hoeffding
    sizing when given — used by the perf loop to trade escape risk for
    bandwidth. ``drift_margin_bits`` records the stream's intended
    drift headroom on the plan (see :class:`CommPlan`); the iid sizing
    here does not consume it, but ``empirical_plan`` and the adaptive
    drift policy both read it from the plan.
    """
    pmf = entropy.normalize_counts(counts)
    mu = float(np.dot(tables.enc_len.astype(np.float64), pmf))
    if capacity_factor is None:
        t = hoeffding_margin_bits(chunk_symbols, target_escape_prob)
        bits_per_sym = min(8.0, mu + t)
    else:
        bits_per_sym = 8.0 * capacity_factor
    cap_words = max(1, math.ceil(bits_per_sym * chunk_symbols / 32))
    return CommPlan(
        chunk_symbols=chunk_symbols,
        capacity_words=cap_words,
        pool_slots_per_1k=pool_slots_per_1k,
        expected_bits_per_symbol=mu,
        escape_prob_bound=target_escape_prob,
        drift_margin_bits=drift_margin_bits,
    )


def effective_compression_ratio(plan: CommPlan,
                                scale_bytes_per_symbol: float = 2.0 / 32,
                                baseline_bytes: float = 2.0) -> float:
    """baseline (bf16) bytes / compressed wire bytes, incl. scale overhead."""
    wire = plan.wire_bytes_per_symbol + scale_bytes_per_symbol \
        + 1.0 / plan.chunk_symbols  # 1 flag byte per chunk
    return baseline_bytes / wire


# --------------------------------------------------------------------------
# Transport selection (one-shot vs ring, hop chunking)
# --------------------------------------------------------------------------

#: The valid ``TransportConfig.kind`` values, in one place: validation
#: error messages, ``resolve_transport``, launcher ``--transport``
#: choices, and the docs all enumerate THIS tuple, so a new kind (like
#: ``"hierarchical"``, added with the multi-host tier) cannot drift out
#: of any of them.
TRANSPORT_KINDS = ("oneshot", "ring", "hierarchical")


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """Static transport selection for one compressed collective.

    ``kind`` (one of :data:`TRANSPORT_KINDS`):
      * ``"oneshot"`` — legacy path: one ``lax.all_gather`` /
        ``lax.all_to_all`` of the full compressed payload, decode runs
        strictly after the wire.
      * ``"ring"`` — ``ppermute``-based schedule: the payload moves in
        ``axis_size - 1`` hops and hop *k* is decoded (+ dequantized,
        and for reduce-scatter + accumulated) while hop *k+1* is in
        flight.
      * ``"hierarchical"`` — two-tier schedule for a channel bound to a
        pod axis AND a local axis: an intra-pod ring over the local
        (ICI) axis with ONE compressed inter-pod bridge exchange per
        hop group over the pod (DCN) axis, bridge *t+1* overlapping
        hop group *t*'s decode. On a channel with no pod axis it
        degrades to ``"ring"``.

    ``hop_chunks`` (ring/hierarchical) splits each hop's payload into
    that many independently-compressed pieces so decode and transfer
    also overlap *within* a hop — the cost model trades per-message
    latency (more messages) against pipeline fill (smaller units).
    """
    kind: str = "oneshot"            # see TRANSPORT_KINDS
    hop_chunks: int = 1

    def __post_init__(self):
        if self.kind not in TRANSPORT_KINDS:
            raise ValueError(
                f"unknown transport kind {self.kind!r}; valid kinds: "
                + ", ".join(repr(k) for k in TRANSPORT_KINDS))
        if self.hop_chunks < 1:
            raise ValueError("hop_chunks must be >= 1")


ONESHOT = TransportConfig("oneshot")
RING = TransportConfig("ring")
HIERARCHICAL = TransportConfig("hierarchical")


def resolve_transport(transport) -> TransportConfig:
    """Normalize ``None`` (legacy one-shot) / str / TransportConfig.

    Strings must name a kind in :data:`TRANSPORT_KINDS` (validated by
    ``TransportConfig.__post_init__``, which enumerates the valid kinds
    in its error)."""
    if transport is None:
        return ONESHOT
    if isinstance(transport, TransportConfig):
        return transport
    if isinstance(transport, str):
        return TransportConfig(kind=transport)
    raise TypeError(
        f"bad transport spec: {transport!r} (expected None, a "
        f"TransportConfig, or one of {TRANSPORT_KINDS})")


#: Ring hop-chunk candidates the planner compares. Shared by
#: choose_transport, transport_crossover_bytes, and the benchmark
#: columns so they can never desynchronize.
HOP_CHUNK_CANDIDATES = (1, 2, 4, 8)


def clamp_hop_chunks(hop_chunks: int, n_chunks: int) -> int:
    """Largest h <= hop_chunks that tiles ``n_chunks`` (>= 1).

    Ring hop pieces must tile the payload's chunk count — otherwise the
    per-piece padding changes the static payload geometry (e.g. the
    ZeRO-1 segment length ``flat_geometry`` was computed from).
    """
    h = max(1, min(hop_chunks, n_chunks))
    while n_chunks % h:
        h -= 1
    return h


#: Link classes the cost model distinguishes: ``"ici"`` — the intra-pod
#: inter-chip interconnect a local mesh axis runs over — and ``"dcn"``
#: — the cross-pod data-center network a pod axis crosses. Per-axis
#: autotune probes cache constants for one of these classes in the
#: registry (``CodecRegistry.cache_link_constants``).
LINK_CLASSES = ("ici", "dcn")


@dataclasses.dataclass(frozen=True)
class AlphaBetaModel:
    """alpha-beta cost model of one compressed-collective exchange,
    with per-link-class wire constants (:data:`LINK_CLASSES`).

    * ``alpha_s`` / ``wire_Bps`` — ICI tier: per-message latency
      (collective launch + first-byte) and link bandwidth for a LOCAL
      mesh axis (defaults: 1us, one v5e ICI link
      ``roofline.hw.ICI_LINK_BW``).
    * ``dcn_alpha_s`` / ``dcn_wire_Bps`` — DCN tier: the same two
      constants for a cross-pod axis (defaults
      ``roofline.hw.DCN_LATENCY_S`` / ``hw.DCN_LINK_BW`` — an order of
      magnitude slower on both axes, which is the whole reason the
      hierarchical transport exists).
    * ``decode_Bps`` — fused decode→dequantize throughput in *decoded
      value bytes* per second (calibrate with a measured number, e.g.
      from ``benchmarks/transport_overlap.py``).
    * ``dispatch_s`` — per-kernel-dispatch overhead (one decode dispatch
      per ring hop piece).

    ``wire_time(bytes, link=...)`` charges a transfer to one link
    class; ``with_link(link, ...)`` folds measured per-axis constants
    in (``Channel.autotune``'s wire probe → registry link cache →
    here), replacing the shared first-order guesses.

    Topology note: every hop is charged one ``alpha`` + payload/``wire
    bandwidth``, which models the all-gather's neighbor-forwarding ring
    exactly. The reduce-scatter/all-to-all schedules use distance-s
    ppermutes; on a mesh axis that maps to one physical 1-D ring those
    cost up to ``s`` link traversals — :func:`modeled_a2a_ring_time`
    charges them (the a2a transport choice goes through
    :func:`choose_a2a_transport`). A flat ring spanning pods is gated
    by its DCN-crossing neighbor every step
    (:func:`modeled_flat_ring_time`); the hierarchical schedule
    (:func:`modeled_hierarchical_time`) keeps the per-hop ring on ICI
    and batches the DCN crossings into per-hop-group bridges.
    """
    alpha_s: float = 1e-6
    wire_Bps: float = hw.ICI_LINK_BW
    decode_Bps: float = 200e9
    dispatch_s: float = 2e-6
    dcn_alpha_s: float = hw.DCN_LATENCY_S
    dcn_wire_Bps: float = hw.DCN_LINK_BW

    def _check_link(self, link: str):
        if link not in LINK_CLASSES:
            raise ValueError(f"unknown link class {link!r}; valid "
                             f"classes: {LINK_CLASSES}")

    def link_alpha(self, link: str = "ici") -> float:
        self._check_link(link)
        return self.dcn_alpha_s if link == "dcn" else self.alpha_s

    def link_Bps(self, link: str = "ici") -> float:
        self._check_link(link)
        return self.dcn_wire_Bps if link == "dcn" else self.wire_Bps

    def with_link(self, link: str, *, alpha_s: Optional[float] = None,
                  wire_Bps: Optional[float] = None) -> "AlphaBetaModel":
        """Copy with ``link``'s measured constants substituted."""
        self._check_link(link)
        kw = {}
        pre = "dcn_" if link == "dcn" else ""
        if alpha_s is not None:
            kw[pre + "alpha_s"] = float(alpha_s)
        if wire_Bps is not None:
            kw[pre + "wire_Bps"] = float(wire_Bps)
        return dataclasses.replace(self, **kw) if kw else self

    def wire_time(self, wire_bytes: float, link: str = "ici") -> float:
        return self.link_alpha(link) + wire_bytes / self.link_Bps(link)

    def decode_time(self, value_bytes: float) -> float:
        return self.dispatch_s + value_bytes / self.decode_Bps


def payload_wire_bytes(n_symbols: int, chunk_symbols: int,
                       capacity_words: int, pool_slots_per_1k: int = 8,
                       scale_bytes: int = 2, hop_chunks: int = 1) -> int:
    """Static wire bytes of one shard's compressed payload (slots +
    flags + pool + pool count + block-32 scales) — mirrors
    ``compressed.wire_bytes`` without building arrays.

    ``hop_chunks > 1`` (ring piece split) charges one row-sized escape
    pool and pool count PER PIECE — the ok-parity wire shape
    (``transport._compress_pieces``): every piece's pool is sized for
    the whole row so the row-level ok predicate matches one-shot's.
    """
    n_chunks = max(1, math.ceil(n_symbols / chunk_symbols))
    pool_slots = max(1, math.ceil(n_chunks * pool_slots_per_1k / 1024))
    pieces = max(1, int(hop_chunks))
    return (n_chunks * capacity_words * 4          # slots
            + n_chunks                              # escape flags
            + pieces * pool_slots * chunk_symbols   # pool(s) (K/4 u32 rows)
            + pieces * 4                            # pool count(s)
            + scale_bytes * math.ceil(n_symbols / 32))


def modeled_oneshot_time(model: AlphaBetaModel, shard_wire_bytes: float,
                         shard_value_bytes: float, axis_size: int,
                         n_decode_dispatches: int = 1) -> float:
    """One-shot: every peer's payload crosses the wire, then decode
    runs strictly after it.

    ``n_decode_dispatches`` is 1 for the batched all-gather decode;
    the one-shot reduce-scatter pays ``axis_size`` sequential
    accumulate dispatches (the ring-parity op sequence — see
    ``transport.exchange_reduce_scatter``), so its auto-selection
    passes ``axis_size``.
    """
    d = axis_size
    wire = model.wire_time(shard_wire_bytes * (d - 1))
    return (wire + shard_value_bytes * d / model.decode_Bps
            + max(1, n_decode_dispatches) * model.dispatch_s)


def modeled_ring_time(model: AlphaBetaModel, shard_wire_bytes: float,
                      shard_value_bytes: float, axis_size: int,
                      hop_chunks: int = 1) -> float:
    """Ring: ``(d-1) * hop_chunks`` messages; decode of unit *k*
    overlaps the transfer of unit *k+1*, so steady state pays
    ``max(transfer, decode)`` per unit plus pipeline fill/drain."""
    d = axis_size
    if d <= 1:
        return model.decode_time(shard_value_bytes)
    h = hop_chunks
    unit_wire = model.wire_time(shard_wire_bytes / h)
    unit_dec = model.decode_time(shard_value_bytes / h)
    n_units = (d - 1) * h
    # fill (first transfer) + overlapped steady state + drain (last
    # decode) + the local shard's own decode (overlaps the first hop).
    return (unit_wire + (n_units - 1) * max(unit_wire, unit_dec)
            + unit_dec)


def modeled_hierarchical_time(model: AlphaBetaModel,
                              shard_wire_bytes: float,
                              shard_value_bytes: float, local_size: int,
                              pod_size: int,
                              hop_chunks: int = 1) -> float:
    """Hierarchical (ring-of-rings) over a ``pod_size x local_size``
    group: the intra-pod neighbor ring runs over the ICI link class and
    every hop group's unit is also bridged across pods by ONE
    compressed DCN exchange, so per pipeline unit the cost is
    ``max(ICI hop, DCN bridge of pod_size-1 payload copies, pod_size
    decodes)`` — the DCN transfers land spread across the ring instead
    of gating every neighbor step (contrast
    :func:`modeled_flat_ring_time`). Degenerates to the flat ring model
    for ``pod_size == 1``."""
    L, P = local_size, pod_size
    if P <= 1:
        return modeled_ring_time(model, shard_wire_bytes,
                                 shard_value_bytes, L, hop_chunks)
    h = hop_chunks
    ici = model.wire_time(shard_wire_bytes / h, link="ici")
    bridge = model.wire_time((P - 1) * shard_wire_bytes / h, link="dcn")
    # Each pipeline unit lands P pod copies of one hop-group chunk; of
    # the resulting L*P row decodes the device's own row overlaps the
    # pipeline fill (same convention as :func:`modeled_ring_time`), so
    # the steady state carries L*P - 1 row decodes spread over the
    # L * h units.
    dec = (P - 1.0 / L) * model.decode_time(shard_value_bytes / h)
    n_units = L * h
    # fill (hop group 0 needs no ICI hop — its bridge starts
    # immediately, and group 1's ICI hop overlaps it) + overlapped
    # steady state + drain (the last unit's pod decodes).
    return bridge + (n_units - 1) * max(ici, bridge, dec) + dec


def modeled_flat_ring_time(model: AlphaBetaModel, shard_wire_bytes: float,
                           shard_value_bytes: float, local_size: int,
                           pod_size: int, hop_chunks: int = 1) -> float:
    """A single flat neighbor ring laid across the combined
    ``pod_size x local_size`` group (pod-major rank order): every one of
    the ``d - 1`` hop steps includes a pod-boundary crossing, so the
    DCN laggard gates the WHOLE step — the wire term is charged at the
    DCN link class. This is the topology-blind baseline the
    hierarchical schedule exists to beat
    (``hierarchical_vs_flat_ring_modeled_ratio`` in
    ``benchmarks/transport_overlap.py``)."""
    d = local_size * pod_size
    if pod_size <= 1:
        return modeled_ring_time(model, shard_wire_bytes,
                                 shard_value_bytes, local_size, hop_chunks)
    if d <= 1:
        return model.decode_time(shard_value_bytes)
    h = hop_chunks
    unit_wire = model.wire_time(shard_wire_bytes / h, link="dcn")
    unit_dec = model.decode_time(shard_value_bytes / h)
    n_units = (d - 1) * h
    return (unit_wire + (n_units - 1) * max(unit_wire, unit_dec)
            + unit_dec)


def modeled_hierarchical_oneshot_time(model: AlphaBetaModel,
                                      shard_wire_bytes: float,
                                      shard_value_bytes: float,
                                      local_size: int, pod_size: int,
                                      n_decode_dispatches: int = 1
                                      ) -> float:
    """One-shot over the combined ``pod_size x local_size`` group: the
    single collective's ICI and DCN transfers proceed concurrently
    (different links), decode of all ``d`` shards runs strictly after
    the slower of the two."""
    L, P = local_size, pod_size
    d = L * P
    ici = model.wire_time((L - 1) * shard_wire_bytes, link="ici")
    dcn = (model.wire_time((P - 1) * L * shard_wire_bytes, link="dcn")
           if P > 1 else 0.0)
    return (max(ici, dcn) + shard_value_bytes * d / model.decode_Bps
            + max(1, n_decode_dispatches) * model.dispatch_s)


def choose_transport(shard_wire_bytes: float, shard_value_bytes: float,
                     axis_size: int,
                     model: Optional[AlphaBetaModel] = None,
                     hop_chunk_candidates: Sequence[int]
                     = HOP_CHUNK_CANDIDATES,
                     n_oneshot_decode_dispatches: int = 1,
                     pod_size: int = 1,
                     ) -> TransportConfig:
    """Pick the transport (and ring hop chunking) minimizing modeled time.

    ``shard_wire_bytes`` / ``shard_value_bytes`` describe ONE device's
    compressed shard; ``axis_size`` is the collective's LOCAL axis size.
    Small payloads stay one-shot (per-message alpha dominates); above
    the crossover the ring's decode/transfer overlap wins.
    ``n_oneshot_decode_dispatches``: see ``modeled_oneshot_time``.

    ``pod_size > 1`` prices the two-tier ``pod_size x axis_size`` group
    instead: one-shot over the combined group
    (:func:`modeled_hierarchical_oneshot_time`) vs the hierarchical
    ring-of-rings (:func:`modeled_hierarchical_time`). The
    topology-blind flat ring (:func:`modeled_flat_ring_time`) is NOT a
    candidate there — a neighbor ring over a two-axis group has no
    single-axis ``ppermute`` schedule to execute — it exists as the
    modeled baseline the hierarchical schedule is gated against.
    """
    model = model or AlphaBetaModel()
    P = max(1, int(pod_size))
    if axis_size * P <= 1:
        return ONESHOT
    if P > 1:
        best = ("oneshot", 1,
                modeled_hierarchical_oneshot_time(
                    model, shard_wire_bytes, shard_value_bytes,
                    axis_size, P, n_oneshot_decode_dispatches))
        for h in hop_chunk_candidates:
            t = modeled_hierarchical_time(model, shard_wire_bytes,
                                          shard_value_bytes, axis_size,
                                          P, h)
            if t < best[2]:
                best = ("hierarchical", h, t)
        return TransportConfig(kind=best[0], hop_chunks=best[1])
    best = ("oneshot", 1,
            modeled_oneshot_time(model, shard_wire_bytes,
                                 shard_value_bytes, axis_size,
                                 n_oneshot_decode_dispatches))
    for h in hop_chunk_candidates:
        t = modeled_ring_time(model, shard_wire_bytes, shard_value_bytes,
                              axis_size, h)
        if t < best[2]:
            best = ("ring", h, t)
    return TransportConfig(kind=best[0], hop_chunks=best[1])


def modeled_a2a_ring_time(model: AlphaBetaModel, row_wire_bytes: float,
                          row_value_bytes: float, axis_size: int,
                          hop_chunks: int = 1) -> float:
    """Ring all_to_all: hop *s* moves row ``(me+s) % d`` with a
    distance-``s`` ppermute while decode of the previous unit overlaps.

    Unlike the all-gather ring (neighbor forwarding, one link per hop),
    the a2a's distance-``s`` ppermute serializes through up to ``s``
    link traversals on a 1-D ring — charged here as ``s *
    row_wire_bytes / wire_Bps`` per hop. That makes the a2a ring move
    ~``d/2``x more total link traffic than one-shot, so it only wins in
    decode-bound regimes (slow ``decode_Bps`` relative to the wire) —
    exactly what the measured-constant auto-selection decides.

    ``row_*_bytes`` describe ONE destination row of this rank's send
    buffer (payload / ``axis_size``); the own-row decode (hop 0, no
    wire) overlaps the first transfer.
    """
    d = axis_size
    if d <= 1:
        return model.decode_time(row_value_bytes)
    h = hop_chunks
    unit_dec = model.decode_time(row_value_bytes / h)

    def unit_wire(s: int) -> float:
        return model.alpha_s + s * (row_wire_bytes / h) / model.wire_Bps

    units = [s for s in range(1, d) for _ in range(h)]
    t = unit_wire(units[0])
    for s in units[1:]:
        t += max(unit_wire(s), unit_dec)
    return t + unit_dec


def choose_a2a_transport(row_wire_bytes: float, row_value_bytes: float,
                         axis_size: int,
                         model: Optional[AlphaBetaModel] = None,
                         hop_chunk_candidates: Sequence[int]
                         = HOP_CHUNK_CANDIDATES) -> TransportConfig:
    """Transport choice for ``Channel.all_to_all`` (expert dispatch):
    one-shot ``lax.all_to_all`` vs the distance-charged ppermute ring of
    :func:`modeled_a2a_ring_time`. ``row_*_bytes`` describe one
    destination row; one-shot moves ``d-1`` remote rows over the wire
    then decodes all ``d``, which :func:`modeled_oneshot_time` already
    prices when fed per-row sizes.
    """
    model = model or AlphaBetaModel()
    if axis_size <= 1:
        return ONESHOT
    best = ("oneshot", 1,
            modeled_oneshot_time(model, row_wire_bytes, row_value_bytes,
                                 axis_size))
    for h in hop_chunk_candidates:
        t = modeled_a2a_ring_time(model, row_wire_bytes, row_value_bytes,
                                  axis_size, h)
        if t < best[2]:
            best = ("ring", h, t)
    return TransportConfig(kind=best[0], hop_chunks=best[1])


def transport_crossover_bytes(axis_size: int,
                              model: Optional[AlphaBetaModel] = None,
                              compression_ratio: float = 2.1,
                              lo: float = 1024.0,
                              hi: float = float(1 << 40)) -> float:
    """Smallest shard VALUE size (bytes) where the ring transport's
    modeled time beats one-shot (bisection; ``compression_ratio`` maps
    value bytes to wire bytes)."""
    model = model or AlphaBetaModel()

    def ring_wins(value_bytes: float) -> bool:
        wire = value_bytes / compression_ratio
        one = modeled_oneshot_time(model, wire, value_bytes, axis_size)
        ring = min(modeled_ring_time(model, wire, value_bytes, axis_size,
                                     h) for h in HOP_CHUNK_CANDIDATES)
        return ring < one

    if ring_wins(lo):
        return lo
    if not ring_wins(hi):
        return hi
    for _ in range(60):
        mid = math.sqrt(lo * hi)
        if ring_wins(mid):
            hi = mid
        else:
            lo = mid
    return hi
