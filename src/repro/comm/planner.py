"""Capacity / escape planning for compressed collectives.

Chooses the static wire slot size per chunk from the calibration
histogram: slot = mean code length plus a Hoeffding-bounded margin so
the per-chunk escape probability is below ``target_escape_prob``, and an
overflow pool sized so whole-payload fallback is ~never needed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core import entropy
from repro.core.lut import CodecTables

MIN_CODE_BITS = 4
MAX_CODE_BITS = 11


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """Static wire-format parameters for one tensor type."""
    chunk_symbols: int
    capacity_words: int          # QLC slot per chunk, 32-bit words
    pool_slots_per_1k: int       # escape-pool slots per 1024 chunks (min 1)
    expected_bits_per_symbol: float
    escape_prob_bound: float

    @property
    def capacity_bits(self) -> int:
        return self.capacity_words * 32

    @property
    def wire_bytes_per_symbol(self) -> float:
        """Main-slot wire bytes per symbol (excl. scales/flags/pool)."""
        return self.capacity_words * 4 / self.chunk_symbols

    def pool_slots(self, n_chunks: int) -> int:
        return max(1, math.ceil(n_chunks * self.pool_slots_per_1k / 1024))


def hoeffding_margin_bits(chunk_symbols: int, target_prob: float,
                          lo: float = MIN_CODE_BITS,
                          hi: float = MAX_CODE_BITS) -> float:
    """Per-symbol margin t with P(mean_len > mu + t) <= target_prob."""
    return (hi - lo) * math.sqrt(math.log(1.0 / target_prob)
                                 / (2.0 * chunk_symbols))


def plan_for_tables(tables: CodecTables, counts: np.ndarray,
                    chunk_symbols: int = 1024,
                    target_escape_prob: float = 1e-6,
                    capacity_factor: Optional[float] = None,
                    pool_slots_per_1k: int = 8) -> CommPlan:
    """Build a plan from calibrated tables + the calibration histogram.

    ``capacity_factor`` (bytes-per-symbol / 1.0) overrides the Hoeffding
    sizing when given — used by the perf loop to trade escape risk for
    bandwidth.
    """
    pmf = entropy.normalize_counts(counts)
    mu = float(np.dot(tables.enc_len.astype(np.float64), pmf))
    if capacity_factor is None:
        t = hoeffding_margin_bits(chunk_symbols, target_escape_prob)
        bits_per_sym = min(8.0, mu + t)
    else:
        bits_per_sym = 8.0 * capacity_factor
    cap_words = max(1, math.ceil(bits_per_sym * chunk_symbols / 32))
    return CommPlan(
        chunk_symbols=chunk_symbols,
        capacity_words=cap_words,
        pool_slots_per_1k=pool_slots_per_1k,
        expected_bits_per_symbol=mu,
        escape_prob_bound=target_escape_prob,
    )


def effective_compression_ratio(plan: CommPlan,
                                scale_bytes_per_symbol: float = 2.0 / 32,
                                baseline_bytes: float = 2.0) -> float:
    """baseline (bf16) bytes / compressed wire bytes, incl. scale overhead."""
    wire = plan.wire_bytes_per_symbol + scale_bytes_per_symbol \
        + 1.0 / plan.chunk_symbols  # 1 flag byte per chunk
    return baseline_bytes / wire
