"""Compressed collectives: QLC-coded e4m3 communication (paper §1)."""
from repro.comm.compressed import (  # noqa: F401
    CommConfig,
    ReduceScatterResult,
    WirePayload,
    accumulate_values,
    compress_codes,
    compress_values,
    decompress_codes,
    decompress_values,
    pad_to_multiple,
    qlc_all_gather,
    qlc_all_to_all,
    qlc_psum,
    qlc_reduce_scatter,
    ref_all_gather,
    ref_psum,
    ref_reduce_scatter,
    resolve_codec,
    wire_bytes,
)
from repro.comm import transport  # noqa: F401
from repro.comm import channel  # noqa: F401
from repro.comm.channel import (  # noqa: F401
    Channel,
    ChannelSpec,
    measure_decode_Bps,
    measure_wire_Bps,
    open_channels,
)
from repro.comm.planner import (  # noqa: F401
    HIERARCHICAL,
    LINK_CLASSES,
    ONESHOT,
    RING,
    TRANSPORT_KINDS,
    AlphaBetaModel,
    TransportConfig,
    choose_a2a_transport,
    choose_transport,
    modeled_a2a_ring_time,
    modeled_flat_ring_time,
    modeled_hierarchical_oneshot_time,
    modeled_hierarchical_time,
    modeled_oneshot_time,
    modeled_ring_time,
    resolve_transport,
    transport_crossover_bytes,
)
from repro.comm import container  # noqa: F401
from repro.comm.container import (  # noqa: F401
    ContainerHeader,
    decode_codes_stream,
    decode_values_stream,
    pack_stream,
    parse_header,
    stream_headers,
)
from repro.comm.container import (  # noqa: F401
    encode_values as container_encode_values,
    decode_values as container_decode_values,
    encode_codes as container_encode_codes,
    decode_codes as container_decode_codes,
)
from repro.comm.planner import CommPlan, plan_for_tables  # noqa: F401
from repro.comm.calibrate import (  # noqa: F401
    calibrate_for_gradients,
    calibrate_for_tensor,
    calibrate_kv_entries,
    calibrate_moe_entries,
    empirical_plan,
    histogram_of_quantized,
    histogram_of_tree,
    kv_symbol_stream,
)
from repro.comm.weights import (  # noqa: F401
    GroupWireCodec,
    compress_groups,
    wire_shape_structs,
)
from repro.comm.blockpool import (  # noqa: F401
    ArenaExhausted,
    ArenaStale,
    BlockArena,
    BlockPool,
    PoolExhausted,
    container_digest,
)
