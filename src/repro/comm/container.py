"""Self-describing QLC container format.

A **container** frames one compressed payload with a fixed 16-word
packed header so the payload is decodable from the bytes plus a
:class:`~repro.core.registry.CodecRegistry` alone — no out-of-band
``CommConfig`` agreement between producer and consumer (the property
Huff-LLM / ZipServ-style serving stacks need to mix streams encoded
under different schemes). Checkpoint leaves, serving weight wires, and
offline payload exchange all ride this format; a byte stream may
concatenate many containers ("sections"), each carrying its own
scheme-id, so one stream mixes tensor types freely.

Header layout (16 little-endian uint32 words)::

    word  0  magic            0x514C4331 ("QLC1")
    word  1  version          1
    word  2  scheme_id        registry id of the coding scheme
    word  3  flags            bit 0: QLC-coded (0 = raw e4m3 words)
    word  4  chunk_symbols    K, symbols per chunk
    word  5  capacity_words   32-bit words per chunk slot
    word  6  n_chunks         chunks in the payload
    word  7  pool_slots       escape-pool rows
    word  8  n_valid (lo32)   valid symbols (trailing pad dropped)
    word  9  n_valid (hi32)
    word 10  scale_dtype      0 none | 1 bfloat16 | 2 float32
    word 11  n_scales         block-32 scale count
    word 12  prefix_bits      area-code bits of the scheme (sanity)
    word 13  reserved         0
    word 14  reserved         0
    word 15  crc32            of words 0..14 (little-endian bytes)

Sections follow the header back to back, all as uint32 words:
``words [n_chunks * capacity_words]``, ``flags [ceil(n_chunks/4)]``
(packed uint8), ``pool [pool_slots * chunk_symbols/4]``, ``pool_count
[1]``, ``scales`` (bf16 packed 2-per-word, or f32 1-per-word).

Framing (header parse, section slicing) is host-side numpy — payload
lengths are data-dependent — while the decode itself runs through the
jit codec or the Pallas kernels (``use_kernels``), including the
**multi-LUT batched decode**: :func:`decode_codes_stream` decodes a
mixed-scheme stream's chunks in ONE kernel dispatch with per-chunk
scheme slots.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as _codec
from repro.core.registry import CodecEntry, CodecRegistry
from repro.comm.compressed import (CommConfig, WirePayload,
                                   _compress_codes, _compress_values,
                                   _decompress_codes, _decompress_values,
                                   _gather_pool_raw, pad_to_multiple)

MAGIC = 0x514C4331           # "QLC1"
CONTAINER_VERSION = 1
HEADER_WORDS = 16

_SCALE_DTYPES = {0: None, 1: "bfloat16", 2: "float32"}
_SCALE_CODES = {v: k for k, v in _SCALE_DTYPES.items()}
FLAG_CODED = 1


@dataclasses.dataclass(frozen=True)
class ContainerHeader:
    """Parsed container header — everything needed to slice the
    sections and rebuild the wire config."""
    scheme_id: int
    coded: bool                  # False => raw e4m3 words on the wire
    chunk_symbols: int
    capacity_words: int
    n_chunks: int
    pool_slots: int
    n_valid: int
    scale_dtype: Optional[str]   # None | "bfloat16" | "float32"
    n_scales: int
    prefix_bits: int

    # ---- section geometry (in u32 words) --------------------------------

    @property
    def words_len(self) -> int:
        return self.n_chunks * self.capacity_words

    @property
    def flags_len(self) -> int:
        return -(-self.n_chunks // 4)

    @property
    def pool_len(self) -> int:
        return self.pool_slots * (self.chunk_symbols // 4)

    @property
    def scales_len(self) -> int:
        if self.scale_dtype is None:
            return 0
        per_word = 2 if self.scale_dtype == "bfloat16" else 1
        return -(-self.n_scales // per_word)

    @property
    def body_words(self) -> int:
        return (self.words_len + self.flags_len + self.pool_len + 1
                + self.scales_len)

    @property
    def total_words(self) -> int:
        return HEADER_WORDS + self.body_words

    def comm_config(self, **overrides) -> CommConfig:
        """Reconstruct a wire config sufficient to DECODE this payload
        (the point of the container: no out-of-band agreement).

        Note the pool geometry: decode reads the actual pool size from
        the payload sections (word 7), while ``pool_slots_per_1k`` here
        is only a ceil-rounded back-derivation — re-encoding under this
        config may size the pool differently. To produce new payloads,
        use the registry entry's calibrated plan, not this config.
        """
        pool_per_1k = max(1, math.ceil(
            self.pool_slots * 1024 / max(self.n_chunks, 1)))
        kw = dict(enabled=self.coded,
                  chunk_symbols=self.chunk_symbols,
                  capacity_words=self.capacity_words,
                  pool_slots_per_1k=pool_per_1k,
                  scale_dtype=self.scale_dtype or "bfloat16")
        kw.update(overrides)
        return CommConfig(**kw)


def pack_header(h: ContainerHeader) -> np.ndarray:
    w = np.zeros(HEADER_WORDS, dtype=np.uint32)
    w[0] = MAGIC
    w[1] = CONTAINER_VERSION
    w[2] = h.scheme_id
    w[3] = FLAG_CODED if h.coded else 0
    w[4] = h.chunk_symbols
    w[5] = h.capacity_words
    w[6] = h.n_chunks
    w[7] = h.pool_slots
    w[8] = h.n_valid & 0xFFFFFFFF
    w[9] = (h.n_valid >> 32) & 0xFFFFFFFF
    w[10] = _SCALE_CODES[h.scale_dtype]
    w[11] = h.n_scales
    w[12] = h.prefix_bits
    w[15] = zlib.crc32(w[:15].tobytes())
    return w


def parse_header(buf: np.ndarray, offset: int = 0) -> ContainerHeader:
    """Parse and validate one header at ``offset`` (in u32 words)."""
    buf = np.asarray(buf, dtype=np.uint32).reshape(-1)
    if buf.size - offset < HEADER_WORDS:
        raise ValueError(
            f"truncated container: {buf.size - offset} words < header")
    w = buf[offset:offset + HEADER_WORDS]
    if int(w[0]) != MAGIC:
        raise ValueError(f"bad container magic 0x{int(w[0]):08x}")
    if int(w[1]) != CONTAINER_VERSION:
        raise ValueError(f"unsupported container version {int(w[1])}")
    if int(w[15]) != zlib.crc32(w[:15].tobytes()):
        raise ValueError("container header CRC mismatch")
    code = int(w[10])
    if code not in _SCALE_DTYPES:
        raise ValueError(f"unknown scale dtype code {code}")
    h = ContainerHeader(
        scheme_id=int(w[2]),
        coded=bool(int(w[3]) & FLAG_CODED),
        chunk_symbols=int(w[4]),
        capacity_words=int(w[5]),
        n_chunks=int(w[6]),
        pool_slots=int(w[7]),
        n_valid=int(w[8]) | (int(w[9]) << 32),
        scale_dtype=_SCALE_DTYPES[code],
        n_scales=int(w[11]),
        prefix_bits=int(w[12]),
    )
    if h.chunk_symbols <= 0 or h.chunk_symbols % 4:
        raise ValueError(f"bad chunk_symbols {h.chunk_symbols}")
    if h.n_valid > h.n_chunks * h.chunk_symbols:
        raise ValueError("n_valid exceeds payload capacity")
    if buf.size - offset < h.total_words:
        raise ValueError(
            f"truncated container: {buf.size - offset} words < "
            f"{h.total_words}")
    return h


# --------------------------------------------------------------------------
# Payload <-> words
# --------------------------------------------------------------------------

def _u8_words(a: np.ndarray) -> np.ndarray:
    a = np.ascontiguousarray(np.asarray(a, np.uint8).reshape(-1))
    pad = (-a.size) % 4
    if pad:
        a = np.concatenate([a, np.zeros(pad, np.uint8)])
    return a.view(np.uint32)


def _scales_words(scales, dtype: Optional[str]) -> np.ndarray:
    if dtype is None:
        return np.zeros(0, np.uint32)
    s = np.asarray(scales).reshape(-1)
    if dtype == "bfloat16":
        u16 = np.ascontiguousarray(s).view(np.uint16)
        if u16.size % 2:
            u16 = np.concatenate([u16, np.zeros(1, np.uint16)])
        return u16.view(np.uint32)
    return np.ascontiguousarray(s.astype(np.float32)).view(np.uint32)


def pack_payload(payload: WirePayload, scales, *, scheme_id: int,
                 cfg: CommConfig, n_valid: int,
                 prefix_bits: int = 3) -> np.ndarray:
    """Frame one (payload, scales) pair as a container word array."""
    words = np.asarray(payload.words, np.uint32)
    n_chunks, capacity_words = words.shape[-2], words.shape[-1]
    pool = np.asarray(payload.pool, np.uint32)
    scale_dtype = None if scales is None else cfg.scale_dtype
    n_scales = 0 if scales is None else int(np.asarray(scales).size)
    h = ContainerHeader(
        scheme_id=scheme_id,
        coded=cfg.enabled,
        chunk_symbols=cfg.chunk_symbols,
        capacity_words=capacity_words,
        n_chunks=n_chunks,
        pool_slots=pool.shape[-2],
        n_valid=int(n_valid),
        scale_dtype=scale_dtype,
        n_scales=n_scales,
        prefix_bits=prefix_bits,
    )
    parts = [
        pack_header(h),
        words.reshape(-1),
        _u8_words(payload.flags),
        pool.reshape(-1),
        np.asarray(payload.pool_count, np.uint32).reshape(-1)[:1],
        _scales_words(scales, scale_dtype),
    ]
    return np.concatenate(parts)


def _u8_words_device(a: jnp.ndarray) -> jnp.ndarray:
    """Device-side twin of :func:`_u8_words`: u8 flags -> packed u32
    words via ``bitcast_convert_type`` (little-endian, matching the
    host numpy view)."""
    a = jnp.asarray(a, jnp.uint8).reshape(-1)
    pad = (-a.shape[0]) % 4
    if pad:
        a = jnp.concatenate([a, jnp.zeros(pad, jnp.uint8)])
    return jax.lax.bitcast_convert_type(a.reshape(-1, 4), jnp.uint32)


def _scales_words_device(scales, dtype: Optional[str]) -> jnp.ndarray:
    """Device-side twin of :func:`_scales_words`."""
    if dtype is None:
        return jnp.zeros(0, jnp.uint32)
    s = jnp.asarray(scales).reshape(-1)
    if dtype == "bfloat16":
        u16 = jax.lax.bitcast_convert_type(
            s.astype(jnp.bfloat16), jnp.uint16)
        if u16.shape[0] % 2:
            u16 = jnp.concatenate([u16, jnp.zeros(1, jnp.uint16)])
        return jax.lax.bitcast_convert_type(
            u16.reshape(-1, 2), jnp.uint32)
    return jax.lax.bitcast_convert_type(s.astype(jnp.float32), jnp.uint32)


def frame_block_device(payload: WirePayload, scales, *, scheme_id: int,
                       cfg: CommConfig, n_valid: int,
                       prefix_bits: int = 3) -> jnp.ndarray:
    """Device-resident twin of :func:`pack_payload`: frame one
    (payload, scales) pair as container words WITHOUT a host round
    trip. The header is a compile-time constant (all geometry is static
    once the wire config is fixed — the async KV paging path requires
    ``KVCacheSpec(exact_capacity=False)`` for exactly this reason), so
    only the payload sections are device ops. Bit-identical to the host
    framing (asserted in tests), which makes container digests — and
    therefore pool dedup — agree between the sync and async paging
    paths."""
    words = jnp.asarray(payload.words, jnp.uint32)
    n_chunks, capacity_words = words.shape[-2], words.shape[-1]
    pool = jnp.asarray(payload.pool, jnp.uint32)
    scale_dtype = None if scales is None else cfg.scale_dtype
    n_scales = 0 if scales is None else int(np.prod(scales.shape))
    h = ContainerHeader(
        scheme_id=scheme_id,
        coded=cfg.enabled,
        chunk_symbols=cfg.chunk_symbols,
        capacity_words=capacity_words,
        n_chunks=n_chunks,
        pool_slots=pool.shape[-2],
        n_valid=int(n_valid),
        scale_dtype=scale_dtype,
        n_scales=n_scales,
        prefix_bits=prefix_bits,
    )
    parts = [
        jnp.asarray(pack_header(h)),
        words.reshape(-1),
        _u8_words_device(payload.flags),
        pool.reshape(-1),
        jnp.asarray(payload.pool_count, jnp.uint32).reshape(-1)[:1],
        _scales_words_device(scales, scale_dtype),
    ]
    return jnp.concatenate(parts)


def unpack_payload(buf: np.ndarray, offset: int = 0
                   ) -> Tuple[ContainerHeader, WirePayload,
                              Optional[jnp.ndarray], int]:
    """Slice one container back into (header, WirePayload, scales,
    next_offset)."""
    buf = np.asarray(buf, dtype=np.uint32).reshape(-1)
    h = parse_header(buf, offset)
    pos = offset + HEADER_WORDS

    def take(n):
        nonlocal pos
        out = buf[pos:pos + n]
        pos += n
        return out

    words = take(h.words_len).reshape(h.n_chunks, h.capacity_words)
    flags = take(h.flags_len).view(np.uint8)[:h.n_chunks]
    pool = take(h.pool_len).reshape(h.pool_slots, h.chunk_symbols // 4)
    pool_count = take(1).astype(np.int32)
    scales = None
    sw = take(h.scales_len)
    if h.scale_dtype == "bfloat16":
        scales = jnp.asarray(
            sw.view(np.uint16)[:h.n_scales]).view(jnp.bfloat16)
    elif h.scale_dtype == "float32":
        scales = jnp.asarray(sw.view(np.float32)[:h.n_scales])
    payload = WirePayload(
        words=jnp.asarray(words),
        flags=jnp.asarray(flags),
        pool=jnp.asarray(pool),
        pool_count=jnp.asarray(pool_count),
    )
    return h, payload, scales, pos


def _tables_for(h: ContainerHeader, registry: CodecRegistry):
    """Registry lookup + the header's sanity check: the scheme behind
    the wire scheme-id must have the geometry the payload was coded
    with, or decode would silently corrupt (wrong registry loaded,
    scheme-id collision across registries)."""
    tables = registry.by_id(h.scheme_id).tables
    if h.coded and tables.prefix_bits != h.prefix_bits:
        raise ValueError(
            f"scheme-id {h.scheme_id}: registry tables have "
            f"prefix_bits={tables.prefix_bits} but the container was "
            f"coded with {h.prefix_bits} — wrong registry?")
    return tables


# --------------------------------------------------------------------------
# Value / code round trips (the container's public API)
# --------------------------------------------------------------------------

def encode_values(x, entry: CodecEntry, cfg: Optional[CommConfig] = None,
                  **cfg_overrides) -> np.ndarray:
    """float array -> self-describing container (quantize + QLC-code)."""
    if cfg is None:
        cfg = entry.config(**cfg_overrides)
    flat, n = pad_to_multiple(jnp.asarray(x, jnp.float32).reshape(-1),
                              cfg.chunk_symbols)
    payload, scales = _compress_values(flat, entry.tables, cfg)
    return pack_payload(payload, scales, scheme_id=entry.scheme_id,
                        cfg=cfg, n_valid=n,
                        prefix_bits=entry.tables.prefix_bits)


def _prefetch_decode_fn():
    """Slot-decode override routing through the DMA double-buffered
    prefetch kernel (``kernels.ops.decode_block_async``) — the async KV
    paging path's word movement, bit-identical to the plain decode."""
    from repro.kernels import ops as kops

    def fn(words, tables, cfg):
        flat = words.reshape(-1, words.shape[-1])
        out = kops.decode_block_async(flat, tables, cfg.chunk_symbols)
        return out.reshape(words.shape[:-1] + (cfg.chunk_symbols,))
    return fn


def decode_values(buf, registry: CodecRegistry, offset: int = 0, *,
                  use_kernels: Optional[bool] = None,
                  prefetch: bool = False
                  ) -> Tuple[jnp.ndarray, bool, int]:
    """Container -> (float32 values [n_valid], ok, next_offset).

    Needs only the buffer and the registry: the header supplies the
    wire geometry, the scheme-id supplies the tables. ``prefetch``
    routes the slot decode through the DMA prefetch kernel.
    """
    h, payload, scales, pos = unpack_payload(buf, offset)
    tables = _tables_for(h, registry)
    cfg = h.comm_config(
        **({} if use_kernels is None else {"use_kernels": use_kernels}))
    if scales is None:
        raise ValueError("container carries no scales; use decode_codes")
    if prefetch:
        from repro.comm.compressed import (_decompress_codes as _dc,
                                           _dequantize)
        codes, ok = _dc(payload, tables, cfg,
                        decode_fn=_prefetch_decode_fn())
        vals = _dequantize(codes, scales)
    else:
        vals, ok = _decompress_values(payload, scales, tables, cfg)
    return vals.reshape(-1)[:h.n_valid], ok, pos


def encode_codes(codes, entry: CodecEntry,
                 cfg: Optional[CommConfig] = None,
                 **cfg_overrides) -> np.ndarray:
    """uint8 symbol array -> container (no scales section)."""
    if cfg is None:
        cfg = entry.config(**cfg_overrides)
    flat, n = pad_to_multiple(jnp.asarray(codes, jnp.uint8).reshape(-1),
                              cfg.chunk_symbols)
    payload = _compress_codes(flat, entry.tables, cfg)
    return pack_payload(payload, None, scheme_id=entry.scheme_id,
                        cfg=cfg, n_valid=n,
                        prefix_bits=entry.tables.prefix_bits)


def decode_codes(buf, registry: CodecRegistry, offset: int = 0, *,
                 use_kernels: Optional[bool] = None,
                 prefetch: bool = False
                 ) -> Tuple[jnp.ndarray, bool, int]:
    """Container -> (uint8 codes [n_valid], ok, next_offset)."""
    h, payload, _, pos = unpack_payload(buf, offset)
    tables = _tables_for(h, registry)
    cfg = h.comm_config(
        **({} if use_kernels is None else {"use_kernels": use_kernels}))
    out, ok = _decompress_codes(
        payload, tables, cfg,
        decode_fn=_prefetch_decode_fn() if prefetch else None)
    return out.reshape(-1)[:h.n_valid], ok, pos


# --------------------------------------------------------------------------
# Mixed-scheme streams
# --------------------------------------------------------------------------

def pack_stream(sections: Sequence[np.ndarray]) -> np.ndarray:
    """Concatenate containers into one stream."""
    return (np.concatenate([np.asarray(s, np.uint32) for s in sections])
            if sections else np.zeros(0, np.uint32))


def stream_headers(buf) -> List[Tuple[int, ContainerHeader]]:
    """Walk a stream: [(offset, header), ...] for every section."""
    buf = np.asarray(buf, dtype=np.uint32).reshape(-1)
    out, offset = [], 0
    while offset < buf.size:
        h = parse_header(buf, offset)
        out.append((offset, h))
        offset += h.total_words
    return out


def decode_values_stream(buf, registry: CodecRegistry, *,
                         use_kernels: Optional[bool] = None
                         ) -> List[Tuple[jnp.ndarray, bool]]:
    """Decode every section of a (possibly mixed-scheme) stream."""
    out, offset = [], 0
    buf = np.asarray(buf, dtype=np.uint32).reshape(-1)
    while offset < buf.size:
        vals, ok, offset = decode_values(buf, registry, offset,
                                         use_kernels=use_kernels)
        out.append((vals, ok))
    return out


def decode_codes_stream(buf, registry: CodecRegistry, *,
                        use_kernels: bool = False,
                        prefetch: bool = False
                        ) -> List[Tuple[jnp.ndarray, bool]]:
    """Decode a mixed-scheme stream's QLC chunks in ONE batched pass.

    All coded sections' chunks are concatenated (slots padded to the
    widest capacity) and decoded by a single multi-LUT dispatch — the
    per-chunk scheme slot rides next to the data, exactly the paper's
    §7 "one LUT per tensor type" deployment. Raw (uncoded) sections
    fall back to the per-section path. Escape-pool merging stays
    per-section (pool rows are section-local).
    """
    buf = np.asarray(buf, dtype=np.uint32).reshape(-1)
    parsed, offset = [], 0
    while offset < buf.size:
        h, payload, scales, offset = unpack_payload(buf, offset)
        parsed.append((h, payload, scales))
    if not parsed:
        return []

    coded = [i for i, (h, _, _) in enumerate(parsed) if h.coded]
    results: List[Optional[Tuple[jnp.ndarray, bool]]] = [None] * len(parsed)

    if coded:
        ks = {parsed[i][0].chunk_symbols for i in coded}
        if len(ks) != 1:
            raise ValueError(
                f"batched stream decode needs one chunk size, got {ks}")
        k = ks.pop()
        cap = max(parsed[i][0].capacity_words for i in coded)
        tables_list, id_map = registry.stacked_decode_tables(
            [parsed[i][0].scheme_id for i in coded])
        blocks, sids = [], []
        for i in coded:
            h, payload, _ = parsed[i]
            _tables_for(h, registry)     # prefix_bits sanity per section
            w = np.asarray(payload.words, np.uint32)
            if h.capacity_words < cap:   # pad slots to the widest scheme
                w = np.pad(w, ((0, 0), (0, cap - h.capacity_words)))
            blocks.append(w)
            sids.append(np.full(h.n_chunks, id_map[h.scheme_id],
                                np.int32))
        all_words = jnp.asarray(np.concatenate(blocks))
        all_sids = jnp.asarray(np.concatenate(sids))
        if prefetch:
            from repro.kernels import ops as kops
            dec = kops.decode_block_async(all_words, tables_list, k,
                                          scheme_ids=all_sids)
        elif use_kernels:
            from repro.kernels import ops as kops
            dec = kops.decode(all_words, tables_list, k,
                              scheme_ids=all_sids)
        else:
            dec = _codec.decode_chunks_multi(all_words, tables_list,
                                             all_sids, k)
        row = 0
        for i in coded:
            h, payload, _ = parsed[i]
            sec = dec[row:row + h.n_chunks]
            row += h.n_chunks
            # Merge section-local escapes, as _decompress_codes does.
            cfg = h.comm_config()
            escape = payload.flags.astype(bool)
            raw = _gather_pool_raw(payload, cfg)
            merged = jnp.where(escape[:, None], raw, sec)
            ok = bool(payload.pool_count[0] <= h.pool_slots)
            results[i] = (merged.reshape(-1)[:h.n_valid], ok)

    for i, (h, payload, _) in enumerate(parsed):
        if results[i] is None:          # raw e4m3 section
            out, ok = _decompress_codes(payload, None, h.comm_config())
            results[i] = (out.reshape(-1)[:h.n_valid], bool(ok))
    return results


def container_bytes(buf) -> int:
    """Wire footprint of a container/stream in bytes."""
    return int(np.asarray(buf).size) * 4
