"""Digest-addressed pool of compressed cache blocks (serving tentpole).

The continuous-batching engine (``repro.serving.scheduler``) keeps every
resident sequence's cold KV blocks in ONE global pool whose capacity is
measured in **compressed bytes** — blocks are QLC containers
(``repro.comm.container``), so the capacity lever is exactly the codec's
compression ratio (ZipServ's thesis: lossless compression as serving
memory capacity).

Content addressing reuses the registry's digest trick
(``repro.core.registry._tables_digest``): a block's address is the
sha256 of its container words plus its geometry salt. Two sequences
whose prompts share a prefix produce **bit-identical** containers for
every block fully inside the shared prefix (the cache content at token
*t* depends only on tokens ``<= t``), so ``put`` dedups them onto one
refcounted entry — prefix sharing with zero coordination. Blocks are
immutable; a sequence diverging past the shared prefix simply writes
NEW blocks under new digests while the shared entry's refcount keeps it
alive for the other sequences — copy-on-write without ever copying.

Pressure handling (graceful degradation, never OOM):

* zero-ref entries (finished sequences' blocks, kept as a reclaimable
  prefix cache) are dropped first, in LRU order;
* referenced entries spill to an unbounded host tier (``spill_host``,
  default) and are promoted back on access (``get`` counts the fetch);
* when a block can never fit — spill disabled, or the block alone
  exceeds capacity — :class:`PoolExhausted` is raised and the engine
  rejects that request with a typed error instead of corrupting its
  neighbours.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

import numpy as np


class PoolExhausted(RuntimeError):
    """The block pool cannot hold a block: device capacity is exhausted
    and host spill is disabled (or one block alone exceeds capacity).
    The serving engine turns this into a typed request rejection."""


class ArenaExhausted(RuntimeError):
    """The device block arena has no free slot. Callers fall back to
    the host-framed sync paging path (never a crash)."""


class ArenaStale(RuntimeError):
    """An arena slot's generation moved between a read being scheduled
    and its result being consumed — the slot was freed (and possibly
    rewritten) in between. Consuming the result would hand out stale
    container words, so the arena refuses with this typed error."""


class BlockArena:
    """Device-resident container arena: one fixed-geometry ``uint32``
    buffer of ``n_slots`` x ``slot_words``, indexed by slot id.

    This is the HBM home of cold KV blocks under async paging
    (``repro.serving``): container words are written once at eviction
    (``write`` — a device-side scatter, no host round trip) and read
    back as device slices for the Pallas prefetch-decode kernel
    (``repro.kernels.qlc_prefetch``). The host side keeps only a free
    list and a per-slot **generation counter**: every ``free`` bumps the
    slot's generation, so a decode scheduled against ``(slot, gen)``
    and consumed after the slot was reclaimed surfaces a typed
    :class:`ArenaStale` instead of silently decoding whatever block
    reused the slot.

    The arena does NOT know about digests or refcounts — the
    :class:`BlockPool` owns those and holds the arena view (slot + gen
    per entry), releasing slots when entries are reclaimed.
    """

    def __init__(self, n_slots: int, slot_words: int):
        if n_slots < 1 or slot_words < 1:
            raise ValueError(f"bad arena geometry ({n_slots} slots x "
                             f"{slot_words} words)")
        import jax.numpy as jnp
        self.n_slots = int(n_slots)
        self.slot_words = int(slot_words)
        self._buf = jnp.zeros((self.n_slots, self.slot_words), jnp.uint32)
        self._free = list(range(self.n_slots - 1, -1, -1))
        self._gen = [0] * self.n_slots
        self._used_words = [0] * self.n_slots
        self.writes = 0
        self.reads = 0
        self.frees = 0
        self.stale_reads = 0

    @property
    def buffer(self):
        """The arena's device buffer ``u32 [n_slots, slot_words]`` —
        the prefetch kernel's DMA source."""
        return self._buf

    def alloc(self) -> Tuple[int, int]:
        """Claim a free slot; returns ``(slot, generation)``."""
        if not self._free:
            raise ArenaExhausted(
                f"all {self.n_slots} arena slots are live")
        slot = self._free.pop()
        return slot, self._gen[slot]

    def write(self, slot: int, words) -> int:
        """Store one container's words into ``slot`` (device scatter;
        ``words`` stays on device). Returns the slot's generation."""
        n = int(words.shape[0])
        if n > self.slot_words:
            raise ValueError(f"container of {n} words exceeds the "
                             f"{self.slot_words}-word arena slot")
        self._buf = self._buf.at[slot, :n].set(words)
        self._used_words[slot] = n
        self.writes += 1
        return self._gen[slot]

    def read(self, slot: int, gen: int, n_words: Optional[int] = None):
        """Device slice of a slot's words, validated against the
        generation the caller allocated under."""
        self.check(slot, gen)
        self.reads += 1
        n = self._used_words[slot] if n_words is None else int(n_words)
        return self._buf[slot, :n]

    def check(self, slot: int, gen: int):
        """Raise :class:`ArenaStale` when ``slot`` was freed (and
        possibly reused) since generation ``gen``."""
        if self._gen[slot] != gen:
            self.stale_reads += 1
            raise ArenaStale(
                f"arena slot {slot} is at generation {self._gen[slot]}, "
                f"but the access was scheduled at generation {gen} — "
                "the block was evicted in between")

    def free(self, slot: int):
        """Return a slot to the free list and invalidate outstanding
        ``(slot, gen)`` references by bumping the generation."""
        if slot in self._free:
            raise ValueError(f"double free of arena slot {slot}")
        self._gen[slot] += 1
        self._used_words[slot] = 0
        self._free.append(slot)
        self.frees += 1

    def generation(self, slot: int) -> int:
        return self._gen[slot]

    def stats(self) -> Dict[str, int]:
        return {
            "n_slots": self.n_slots,
            "slot_words": self.slot_words,
            "live_slots": self.n_slots - len(self._free),
            "writes": self.writes,
            "reads": self.reads,
            "frees": self.frees,
            "stale_reads": self.stale_reads,
        }


def container_digest(container, *salt) -> str:
    """Content address of a container: sha256 over its words plus any
    geometry salt (layer key, block start, shapes, ...). Bit-identical
    containers — e.g. the same prompt-prefix block encoded by two
    different sequences — collide on purpose; that collision IS the
    prefix-sharing dedup."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(
        np.asarray(container, np.uint32)).tobytes())
    for s in salt:
        h.update(repr(s).encode())
    return h.hexdigest()[:32]


@dataclasses.dataclass
class _Entry:
    block: object            # duck-typed: .container u32 words, .wire_bytes
    wire_bytes: int
    refs: int
    tier: str                # "device" | "host"
    stamp: int               # LRU clock at last touch
    arena_slot: Optional[int] = None   # device-arena residency (async)
    arena_gen: int = 0


class BlockPool:
    """Refcounted, digest-addressed store of compressed blocks with a
    byte-measured device tier and an unbounded host spill tier.

    Blocks are duck-typed (anything with ``.container`` u32 words and
    an integer ``.wire_bytes`` — e.g.
    :class:`repro.serving.kv_cache.KVBlock`) so the pool lives in
    ``comm`` without importing serving.
    """

    def __init__(self, capacity_bytes: int, *, spill_host: bool = True,
                 arena: Optional[BlockArena] = None):
        if capacity_bytes < 1:
            raise ValueError(f"capacity_bytes must be >= 1, got "
                             f"{capacity_bytes}")
        self.capacity_bytes = int(capacity_bytes)
        self.spill_host = bool(spill_host)
        self.arena = arena
        self._entries: Dict[str, _Entry] = {}
        self._clock = 0
        # accounting
        self.resident_bytes = 0        # device tier
        self.host_bytes = 0
        self.logical_bytes = 0         # sum(refs * wire): the no-dedup cost
        self.referenced_bytes = 0      # unique bytes pinned by refs > 0
        self.peak_resident_bytes = 0
        self.peak_logical_bytes = 0
        self.peak_referenced_bytes = 0
        self.dedup_hits = 0
        self.spills = 0
        self.reclaims = 0
        self.host_fetches = 0
        self._unique_puts = 0
        self._unique_put_bytes = 0

    # ---- core ------------------------------------------------------------

    def digest_of(self, block) -> str:
        return container_digest(
            block.container, getattr(block, "layer", None),
            getattr(block, "start", None), getattr(block, "tokens", None),
            getattr(block, "shapes", None), getattr(block, "dtypes", None))

    def put(self, block) -> str:
        """Admit a block (or take another reference on an identical
        one). Returns its digest. Raises :class:`PoolExhausted` when it
        cannot be made resident."""
        digest = self.digest_of(block)
        e = self._entries.get(digest)
        if e is not None:
            # live entry OR zero-ref cache revival (a finished
            # sequence's block re-referenced by a shared-prefix request)
            self.dedup_hits += 1
            e.refs += 1
            if e.refs == 1:
                self._bump_referenced(e.wire_bytes)
            self._bump_logical(e.wire_bytes)
            self._touch(e)
            return digest
        wire = int(block.wire_bytes)
        if wire > self.capacity_bytes:
            raise PoolExhausted(
                f"block of {wire} compressed bytes exceeds the pool's "
                f"{self.capacity_bytes}-byte device capacity")
        self._make_room(wire)
        self._clock += 1
        self._entries[digest] = _Entry(block=block, wire_bytes=wire,
                                       refs=1, tier="device",
                                       stamp=self._clock)
        self.resident_bytes += wire
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self.resident_bytes)
        self._bump_logical(wire)
        self._bump_referenced(wire)
        self._unique_puts += 1
        self._unique_put_bytes += wire
        return digest

    def get(self, digest: str):
        """The canonical block for a digest — promoted back to the
        device tier first if pressure spilled it to host (counted in
        ``host_fetches``)."""
        e = self._entries[digest]
        if e.tier == "host":
            self._make_room(e.wire_bytes)
            e.tier = "device"
            self.host_bytes -= e.wire_bytes
            self.resident_bytes += e.wire_bytes
            self.peak_resident_bytes = max(self.peak_resident_bytes,
                                           self.resident_bytes)
            self.host_fetches += 1
        self._touch(e)
        return e.block

    def release(self, digest: str):
        """Drop one reference. Zero-ref entries STAY cached (dropped
        lazily under pressure) so a later identical prompt prefix still
        dedups against them."""
        e = self._entries[digest]
        if e.refs <= 0:
            raise ValueError(f"release of unreferenced block {digest}")
        e.refs -= 1
        self.logical_bytes -= e.wire_bytes
        if e.refs == 0:
            self.referenced_bytes -= e.wire_bytes

    def refs(self, digest: str) -> int:
        return self._entries[digest].refs

    # ---- device-arena view (async paging) -------------------------------

    def attach_arena_slot(self, digest: str, slot: int, gen: int) -> bool:
        """Record that ``digest``'s container words live in the bound
        arena at ``(slot, gen)``. Returns False (caller should free its
        slot) when the entry already has one — the dedup twin of
        ``put``: two sequences framing the same prefix block keep ONE
        arena copy."""
        e = self._entries[digest]
        if e.arena_slot is not None:
            return False
        e.arena_slot, e.arena_gen = int(slot), int(gen)
        return True

    def arena_slot_of(self, digest: str) -> Optional[Tuple[int, int]]:
        e = self._entries.get(digest)
        if e is None or e.arena_slot is None:
            return None
        return e.arena_slot, e.arena_gen

    def _drop_arena_slot(self, e: _Entry):
        if e.arena_slot is not None and self.arena is not None:
            self.arena.free(e.arena_slot)
        e.arena_slot = None

    def __contains__(self, digest: str) -> bool:
        return digest in self._entries

    # ---- admission / pressure -------------------------------------------

    def check_admission(self, projected_bytes: int):
        """Raise :class:`PoolExhausted` when a request projected to pool
        ``projected_bytes`` of compressed blocks could never run to
        completion: with host spill the device tier degrades instead of
        filling, so admission always passes; without it the projection
        must fit next to the bytes pinned by running sequences."""
        if self.spill_host:
            return
        pinned = sum(e.wire_bytes for e in self._entries.values()
                     if e.refs > 0 and e.tier == "device")
        if int(projected_bytes) + pinned > self.capacity_bytes:
            raise PoolExhausted(
                f"projected {int(projected_bytes)} compressed bytes do "
                f"not fit: {pinned} already pinned of "
                f"{self.capacity_bytes} (spill_host=False)")

    def mean_block_bytes(self) -> float:
        """Measured mean compressed bytes per unique block (0.0 before
        the first put) — the engine's admission-projection unit."""
        if not self._unique_puts:
            return 0.0
        return self._unique_put_bytes / self._unique_puts

    def _touch(self, e: _Entry):
        self._clock += 1
        e.stamp = self._clock

    def _make_room(self, need: int):
        """Evict until ``need`` device bytes fit: zero-ref cache entries
        drop first (LRU), then referenced entries spill to host (LRU);
        raises :class:`PoolExhausted` when spill is disabled and only
        referenced entries remain."""
        while self.resident_bytes + need > self.capacity_bytes:
            victims = [(e.stamp, d) for d, e in self._entries.items()
                       if e.tier == "device"
                       and (e.refs == 0 or self.spill_host)]
            # zero-ref entries strictly before referenced spills
            free = [v for v in victims
                    if self._entries[v[1]].refs == 0]
            pick = min(free) if free else (min(victims) if victims
                                           else None)
            if pick is None:
                raise PoolExhausted(
                    f"need {need} compressed bytes but "
                    f"{self.resident_bytes} of {self.capacity_bytes} "
                    "are pinned by running sequences "
                    "(spill_host=False)")
            e = self._entries[pick[1]]
            if e.refs == 0:
                del self._entries[pick[1]]
                self._drop_arena_slot(e)
                self.resident_bytes -= e.wire_bytes
                self.reclaims += 1
            else:
                e.tier = "host"
                self.resident_bytes -= e.wire_bytes
                self.host_bytes += e.wire_bytes
                self.spills += 1

    def _bump_logical(self, wire: int):
        self.logical_bytes += wire
        self.peak_logical_bytes = max(self.peak_logical_bytes,
                                      self.logical_bytes)

    def _bump_referenced(self, wire: int):
        self.referenced_bytes += wire
        self.peak_referenced_bytes = max(self.peak_referenced_bytes,
                                         self.referenced_bytes)

    # ---- accounting ------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Byte-level accounting. ``peak_logical_bytes`` is what a pool
        WITHOUT digest dedup would have held at its high-water mark —
        ``peak_logical / peak_resident`` is the prefix-sharing win on
        top of the codec's compression ratio."""
        dev = [e for e in self._entries.values() if e.tier == "device"]
        host = [e for e in self._entries.values() if e.tier == "host"]
        return {
            "capacity_bytes": self.capacity_bytes,
            "resident_bytes": self.resident_bytes,
            "host_bytes": self.host_bytes,
            "resident_blocks": len(dev),
            "host_blocks": len(host),
            "logical_bytes": self.logical_bytes,
            "referenced_bytes": self.referenced_bytes,
            "peak_resident_bytes": self.peak_resident_bytes,
            "peak_logical_bytes": self.peak_logical_bytes,
            "peak_referenced_bytes": self.peak_referenced_bytes,
            "dedup_hits": self.dedup_hits,
            "spills": self.spills,
            "reclaims": self.reclaims,
            "host_fetches": self.host_fetches,
            "unique_blocks": self._unique_puts,
            "mean_block_bytes": self.mean_block_bytes(),
        }
