"""Per-tensor-type codec calibration (paper §7: one LUT per tensor type,
derived apriori from a histogram of the quantized data).

Typical flow: run one (uncompressed) step, histogram the e4m3 symbols of
the tensors you intend to compress, build tables + wire plan. The
histogram kernel (``repro.kernels.ops.histogram``) does this on-device
for production; here numpy suffices.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.planner import CommPlan, plan_for_tables
from repro.core import adapt
from repro.core.lut import CodecTables
from repro.core.schemes import QLCScheme
from repro.quant import e4m3


def histogram_of_quantized(x: jnp.ndarray) -> np.ndarray:
    """float tensor -> counts[256] of its block-32 e4m3 symbols."""
    flat = jnp.asarray(x, jnp.float32).reshape(-1)
    n = (flat.shape[0] // e4m3.BLOCK) * e4m3.BLOCK
    codes, _ = e4m3.quantize_block32(flat[:n])
    return np.bincount(np.asarray(codes).reshape(-1),
                       minlength=256).astype(np.float64)


def histogram_of_tree(tree) -> np.ndarray:
    """Pytree of float tensors -> summed counts[256] of their e4m3
    symbols, accumulated leaf by leaf (no concatenated f32 copy of the
    whole tree). The parameter-type calibration input for
    ``CodecRegistry.register("params", ...)``."""
    counts = np.zeros(256, dtype=np.float64)
    for leaf in jax.tree.leaves(tree):
        counts += histogram_of_quantized(leaf)
    return counts


def calibrate_for_tensor(x: jnp.ndarray, scheme: Optional[QLCScheme] = None,
                         chunk_symbols: int = 1024,
                         target_escape_prob: float = 1e-6,
                         allow_search: bool = False,
                         empirical: bool = True,
                         ) -> Tuple[CodecTables, CommPlan]:
    """Histogram a representative tensor and derive tables + wire plan.

    ``empirical=True`` sizes the chunk slot from the *measured* per-chunk
    bit-count distribution rather than an iid Hoeffding bound. Real
    payloads (e.g. a whole gradient vector) are mixtures of tensor types
    with very different local statistics, so chunk sums are far more
    dispersed than iid sampling of the global PMF predicts; the quantile
    + margin sizing keeps the escape rate at the target without giving
    up the compressible bulk. (The paper's per-tensor-type LUTs, §7, are
    the other half of the answer — the planner supports one plan per
    tensor type.)
    """
    flat = jnp.asarray(x, jnp.float32).reshape(-1)
    n = (flat.shape[0] // e4m3.BLOCK) * e4m3.BLOCK
    codes, _ = e4m3.quantize_block32(flat[:n])
    codes_np = np.asarray(codes).reshape(-1)
    counts = np.maximum(
        np.bincount(codes_np, minlength=256).astype(np.float64), 1e-6)
    tables = adapt.calibrate_tables(counts, scheme=scheme,
                                    allow_search=allow_search)
    plan = plan_for_tables(tables, counts, chunk_symbols=chunk_symbols,
                           target_escape_prob=target_escape_prob)
    if empirical:
        lens = tables.enc_len[codes_np].astype(np.int64)
        n_chunks = len(lens) // chunk_symbols
        if n_chunks >= 8:
            sums = lens[:n_chunks * chunk_symbols].reshape(
                n_chunks, chunk_symbols).sum(axis=1)
            # 99.9th percentile + half-bit/symbol drift margin
            q = float(np.quantile(sums, 0.999))
            bits = min(8.0 * chunk_symbols,
                       q + 0.5 * chunk_symbols)
            cap_words = max(1, int(np.ceil(bits / 32)))
            emp_escape = float((sums > cap_words * 32).mean())
            plan = CommPlan(
                chunk_symbols=chunk_symbols,
                capacity_words=cap_words,
                pool_slots_per_1k=max(
                    8, int(np.ceil(emp_escape * 1024 * 8)) + 8),
                expected_bits_per_symbol=plan.expected_bits_per_symbol,
                escape_prob_bound=max(emp_escape, target_escape_prob),
            )
    return tables, plan


def calibrate_for_gradients(model_cfg, params, batch,
                            chunk_symbols: int = 1024,
                            allow_search: bool = False,
                            ) -> Tuple[CodecTables, CommPlan]:
    """One backward pass -> gradient histogram -> tables + plan."""
    from repro.models import next_token_loss  # local import (cycle)

    def loss(p):
        return next_token_loss(p, model_cfg, batch["tokens"],
                               batch["labels"], batch.get("prefix_emb"))

    grads = jax.grad(loss)(params)
    flat = jnp.concatenate([g.reshape(-1).astype(jnp.float32)
                            for g in jax.tree.leaves(grads)])
    return calibrate_for_tensor(flat, chunk_symbols=chunk_symbols,
                                allow_search=allow_search)
