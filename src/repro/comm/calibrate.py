"""Per-tensor-type codec calibration (paper §7: one LUT per tensor type,
derived apriori from a histogram of the quantized data).

Typical flow: run one (uncompressed) step, histogram the e4m3 symbols of
the tensors you intend to compress, build tables + wire plan. The
histogram kernel (``repro.kernels.ops.histogram``) does this on-device
for production; here numpy suffices.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.planner import CommPlan, plan_for_tables
from repro.core import adapt
from repro.core.lut import CodecTables
from repro.core.schemes import QLCScheme
from repro.quant import e4m3


def histogram_of_quantized(x: jnp.ndarray) -> np.ndarray:
    """float tensor -> counts[256] of its block-32 e4m3 symbols."""
    flat = jnp.asarray(x, jnp.float32).reshape(-1)
    n = (flat.shape[0] // e4m3.BLOCK) * e4m3.BLOCK
    codes, _ = e4m3.quantize_block32(flat[:n])
    return np.bincount(np.asarray(codes).reshape(-1),
                       minlength=256).astype(np.float64)


def histogram_of_tree(tree) -> np.ndarray:
    """Pytree of float tensors -> summed counts[256] of their e4m3
    symbols, accumulated leaf by leaf (no concatenated f32 copy of the
    whole tree). The parameter-type calibration input for
    ``CodecRegistry.register("params", ...)``."""
    counts = np.zeros(256, dtype=np.float64)
    for leaf in jax.tree.leaves(tree):
        counts += histogram_of_quantized(leaf)
    return counts


def calibrate_for_tensor(x: jnp.ndarray, scheme: Optional[QLCScheme] = None,
                         chunk_symbols: int = 1024,
                         target_escape_prob: float = 1e-6,
                         allow_search: bool = False,
                         empirical: bool = True,
                         ) -> Tuple[CodecTables, CommPlan]:
    """Histogram a representative tensor and derive tables + wire plan.

    ``empirical=True`` sizes the chunk slot from the *measured* per-chunk
    bit-count distribution rather than an iid Hoeffding bound. Real
    payloads (e.g. a whole gradient vector) are mixtures of tensor types
    with very different local statistics, so chunk sums are far more
    dispersed than iid sampling of the global PMF predicts; the quantile
    + margin sizing keeps the escape rate at the target without giving
    up the compressible bulk. (The paper's per-tensor-type LUTs, §7, are
    the other half of the answer — the planner supports one plan per
    tensor type.)
    """
    flat = jnp.asarray(x, jnp.float32).reshape(-1)
    n = (flat.shape[0] // e4m3.BLOCK) * e4m3.BLOCK
    codes, _ = e4m3.quantize_block32(flat[:n])
    codes_np = np.asarray(codes).reshape(-1)
    counts = np.maximum(
        np.bincount(codes_np, minlength=256).astype(np.float64), 1e-6)
    tables = adapt.calibrate_tables(counts, scheme=scheme,
                                    allow_search=allow_search)
    plan = plan_for_tables(tables, counts, chunk_symbols=chunk_symbols,
                           target_escape_prob=target_escape_prob)
    if empirical:
        plan = empirical_plan(tables, codes_np, plan,
                              chunk_symbols=chunk_symbols,
                              target_escape_prob=target_escape_prob)
    return tables, plan


def empirical_plan(tables: CodecTables, syms: np.ndarray, plan: CommPlan,
                   *, chunk_symbols: int = 1024,
                   target_escape_prob: float = 1e-6,
                   max_pool_slots_per_1k: Optional[int] = None,
                   drift_margin_bits: Optional[float] = None) -> CommPlan:
    """Re-size a plan's chunk slot from the *measured* per-chunk
    bit-count distribution of a representative symbol stream.

    Real payloads are mixtures of local statistics (tensor types,
    byte planes), so chunk sums are more dispersed than iid sampling
    of the global PMF predicts; the 99.9th-percentile + drift-margin
    sizing keeps the escape rate at the target without giving up the
    compressible bulk. Streams shorter than 8 chunks keep the iid plan.

    ``max_pool_slots_per_1k`` caps the escape pool for callers that
    have a raw-wire fallback for incompressible streams (the paged KV
    cache) — an uncapped near-uniform byte stream would otherwise size
    a pool bigger than its payload. The default (no cap) keeps the
    collectives' guarantee that the pool covers the measured escape
    rate.

    The per-symbol headroom added above the measured 99.9th percentile
    is the incoming plan's ``drift_margin_bits`` (the ONE per-entry
    field recording intended drift headroom — set it via
    ``plan_for_tables(drift_margin_bits=...)``); the keyword here is an
    explicit override. The 0.5-bit default suits gradient streams,
    whose chunk sums have heavy tails that keep moving over training.
    Streams whose chunk-sum distribution *plateaus* — e.g. MoE dispatch
    buffers, where capacity padding makes the distribution bimodal and
    the all-token mode sits at the e4m3 code's bounded expected length,
    so p99.9 ~= max — carry a smaller margin and let the escape pool
    absorb residual drift. The margin is preserved on the returned
    plan (and registry-JSON round-tripped), so the adaptive drift
    policy reads the same headroom the slot was sized with.
    """
    if drift_margin_bits is None:
        drift_margin_bits = plan.drift_margin_bits
    syms = np.asarray(syms).reshape(-1)
    lens = tables.enc_len[syms].astype(np.int64)
    n_chunks = len(lens) // chunk_symbols
    if n_chunks < 8:
        return plan
    sums = lens[:n_chunks * chunk_symbols].reshape(
        n_chunks, chunk_symbols).sum(axis=1)
    # 99.9th percentile + per-symbol drift margin
    q = float(np.quantile(sums, 0.999))
    bits = min(8.0 * chunk_symbols, q + drift_margin_bits * chunk_symbols)
    cap_words = max(1, int(np.ceil(bits / 32)))
    emp_escape = float((sums > cap_words * 32).mean())
    pool = max(8, int(np.ceil(emp_escape * 1024 * 8)) + 8)
    if max_pool_slots_per_1k is not None:
        pool = min(max_pool_slots_per_1k, pool)
    return CommPlan(
        chunk_symbols=chunk_symbols,
        capacity_words=cap_words,
        pool_slots_per_1k=pool,
        expected_bits_per_symbol=plan.expected_bits_per_symbol,
        escape_prob_bound=max(emp_escape, target_escape_prob),
        drift_margin_bits=drift_margin_bits,
    )


def calibrate_for_gradients(model_cfg, params, batch,
                            chunk_symbols: int = 1024,
                            allow_search: bool = False,
                            ) -> Tuple[CodecTables, CommPlan]:
    """One backward pass -> gradient histogram -> tables + plan."""
    from repro.models import next_token_loss  # local import (cycle)

    def loss(p):
        return next_token_loss(p, model_cfg, batch["tokens"],
                               batch["labels"], batch.get("prefix_emb"))

    grads = jax.grad(loss)(params)
    flat = jnp.concatenate([g.reshape(-1).astype(jnp.float32)
                            for g in jax.tree.leaves(grads)])
    return calibrate_for_tensor(flat, chunk_symbols=chunk_symbols,
                                allow_search=allow_search)


# --------------------------------------------------------------------------
# Per-layer KV / SSM-state codecs (serving paged cache)
# --------------------------------------------------------------------------

def kv_symbol_stream(arrays, mode: str = "qlc") -> np.ndarray:
    """Decode-state arrays -> the uint8 symbol stream the KV codec sees.

    ``mode="qlc"`` (lossless): the arrays' raw bytes ARE the symbols —
    the checkpoint manager's byte-width trick extended to wider dtypes,
    so encode→decode is bit-exact and serving output is token-identical
    to a dense cache. ``mode="e4m3"``: block-32 e4m3 symbols of the
    values (the fp8-cache trade: quantization is lossy once, the QLC
    coding on top is not).
    """
    if mode == "e4m3":
        parts = []
        for a in arrays:
            flat = jnp.asarray(a, jnp.float32).reshape(-1)
            n = (flat.shape[0] // e4m3.BLOCK) * e4m3.BLOCK
            if n:
                codes, _ = e4m3.quantize_block32(flat[:n])
                parts.append(np.asarray(codes).reshape(-1))
        return (np.concatenate(parts) if parts
                else np.zeros(0, np.uint8))
    return np.concatenate(
        [np.ascontiguousarray(np.asarray(a)).view(np.uint8).reshape(-1)
         for a in arrays]) if arrays else np.zeros(0, np.uint8)


def byte_planes(arrays) -> Dict[Tuple[int, int], np.ndarray]:
    """Byte-plane decomposition of float state arrays (lossless mode's
    symbol streams).

    Little-endian byte *j* of every ``itemsize``-wide value, pooled
    across arrays in order: ``{(itemsize, j): uint8 stream}``. A
    float's planes have wildly different entropy — sign/exponent bytes
    code down to a few bits, mantissa bytes are near-uniform — so one
    interleaved stream wastes slot capacity on the worst plane, while
    per-plane containers (each with its own calibrated LUT and
    measured capacity, raw where the codec cannot win) compress the
    compressible planes without the mantissa dragging them down.
    """
    groups: Dict[int, list] = {}
    for a in arrays:
        isz = np.dtype(np.asarray(a).dtype).itemsize
        b = np.ascontiguousarray(np.asarray(a)).view(np.uint8)
        groups.setdefault(isz, []).append(b.reshape(-1, isz))
    out: Dict[Tuple[int, int], np.ndarray] = {}
    for isz in sorted(groups):
        mat = np.concatenate(groups[isz], axis=0)        # [n_values, isz]
        for j in range(isz):
            out[(isz, j)] = np.ascontiguousarray(mat[:, j])
    return out


def calibrate_moe_entries(registry, model_cfg, params, batch, *,
                          chunk_symbols: int = 1024,
                          target_escape_prob: float = 1e-4,
                          dispatch_name: str = "moe/dispatch",
                          combine_name: str = "moe/combine",
                          allow_search: bool = False) -> Dict[str, "object"]:
    """Calibrate the MoE expert-dispatch wire codecs into ``registry``.

    Runs ONE eager forward pass over ``batch`` with traffic capture on
    (``moe.capture_moe_traffic``), recomputes each captured MoE layer's
    dispatch/combine buffers via ``moe.dispatch_traffic`` — the actual
    routed-token values entering/leaving the expert ``all_to_all``,
    capacity drops and padding zeros included — and registers one codec
    per direction from the pooled e4m3-symbol histograms:

    * ``dispatch_name`` — pre-FFN token activations (a2a out),
    * ``combine_name`` — post-FFN expert outputs (a2a back).

    The two distributions differ (the FFN reshapes the value histogram),
    which is why they get separate LUTs + slot plans (paper §7's
    per-tensor-type rule applied per collective). Names already in
    ``registry`` are kept (idempotent). Returns ``{name: CodecEntry}``.

    The capture forward runs with ``use_scan=False``/``remat="none"``
    (scan traces its body even when called eagerly) and
    ``moe.impl="gspmd"`` (no mesh needed) — routing is impl-invariant,
    so the histograms apply to the ``shardmap_a2a`` wire unchanged.
    """
    from repro.models import moe, next_token_loss  # local import (cycle)

    todo = [n for n in (dispatch_name, combine_name) if n not in registry]
    if not todo:
        return {dispatch_name: registry[dispatch_name],
                combine_name: registry[combine_name]}

    eager_cfg = dataclasses.replace(
        model_cfg, use_scan=False, remat="none",
        moe=dataclasses.replace(model_cfg.moe, impl="gspmd"))
    captured: list = []
    with moe.capture_moe_traffic(captured):
        next_token_loss(params, eager_cfg, batch["tokens"],
                        batch["labels"], batch.get("prefix_emb"))
    if not captured:
        raise ValueError(
            "no MoE traffic captured — is model_cfg.moe set (and the "
            "forward eager)?")

    streams = {dispatch_name: [], combine_name: []}
    for layer_params, x in captured:
        buf, out_e = moe.dispatch_traffic(layer_params, x, eager_cfg)
        streams[dispatch_name].append(buf)
        streams[combine_name].append(out_e)

    entries = {}
    for name in (dispatch_name, combine_name):
        if name not in todo:
            entries[name] = registry[name]
            continue
        syms = kv_symbol_stream(streams[name], mode="e4m3")
        counts = np.maximum(
            np.bincount(syms, minlength=256).astype(np.float64), 1e-6)
        tables = adapt.calibrate_tables(counts, allow_search=allow_search)
        # Padding zeros make routed-token buffers bimodal; size the
        # slot from measured chunk sums. The chunk-sum distribution
        # plateaus at the all-token mode (p99.9 ~= max), so a quarter-
        # bit drift margin suffices — the capped escape pool and the
        # a2a wire's ok flag cover the residual tail. Recording the
        # margin on the plan (rather than passing it ad hoc) lets the
        # drift policy read the same headroom the slot was sized with.
        plan = plan_for_tables(tables, counts, chunk_symbols=chunk_symbols,
                               target_escape_prob=target_escape_prob,
                               drift_margin_bits=0.25)
        plan = empirical_plan(tables, syms, plan,
                              chunk_symbols=chunk_symbols,
                              target_escape_prob=target_escape_prob,
                              max_pool_slots_per_1k=64)
        entries[name] = registry.register_tables(name, tables, plan,
                                                 counts=counts)
    return entries


def _layer_index(key) -> int:
    if isinstance(key, int):
        return key
    s = str(key)
    return int(s[1:] if s.startswith("l") else s)


def calibrate_kv_entries(registry, layer_arrays, *, mode: str = "qlc",
                         chunk_symbols: int = 1024,
                         target_escape_prob: float = 1e-4,
                         prefix: str = "kv",
                         plane_split_min_symbols: Optional[int] = None,
                         merge_tol: float = 0.05,
                         allow_search: bool = False) -> Dict[str, "object"]:
    """Calibrate per-layer KV/SSM-state codecs into ``registry``.

    ``layer_arrays`` maps layer keys (``"l0"``/``0``/...) to the state
    arrays that layer's cache blocks will carry (attention K/V slices,
    SSM state leaves) — e.g. a prefill-state snapshot. In ``"e4m3"``
    mode each layer's e4m3-symbol histogram registers one codec under
    ``f"{prefix}/layer{i}"``; in the lossless ``"qlc"`` mode each
    **byte plane** (:func:`byte_planes`) registers its own codec under
    ``f"{prefix}/layer{i}/w{itemsize}b{j}"`` — planes are where the
    byte stream is stationary, so per-plane LUTs + slot capacities win
    where one interleaved codec cannot. Layers whose planes are smaller
    than ``plane_split_min_symbols`` (default ``2 * chunk_symbols``)
    register ONE interleaved codec under the base name instead —
    per-plane container framing would eat the win on tiny states. The
    chosen layout is recorded by which names exist, so the paged cache
    derives it from the registry, never re-guessing from block sizes.

    **Cross-layer LUT sharing** (``merge_tol``): the same byte plane of
    different layers (e.g. every K exponent byte) has nearly the same
    histogram, and registering per-layer tables for each would blow up
    the scheme-id space linearly in depth for no coding gain. New
    streams whose normalized histograms are within total-variation
    distance ``merge_tol`` of a group's first member share ONE set of
    tables built from the group's summed counts — the registry's table
    digest then collapses the whole group onto one scheme-id (one LUT
    on device). Slot capacity stays **per name**: each stream's plan is
    empirically sized from its own measured chunk sums
    (:func:`empirical_plan`), so sharing tables never inflates another
    layer's containers. ``merge_tol=0`` disables merging (only
    bit-identical tables dedupe, the pre-sharing behavior).

    Returns ``{name: CodecEntry}``.
    """
    if plane_split_min_symbols is None:
        plane_split_min_symbols = 2 * chunk_symbols

    # Pass 1: collect every (name, symbol stream) needing registration,
    # in deterministic layer order.
    pending = []                      # [(name, syms)]
    layout: list = []                 # names in output order
    for key in sorted(layer_arrays, key=_layer_index):
        base = f"{prefix}/layer{_layer_index(key)}"
        if mode == "e4m3":
            streams = [(base, kv_symbol_stream(layer_arrays[key], mode))]
        else:
            planes = byte_planes(layer_arrays[key])
            if min((p.size for p in planes.values()), default=0) \
                    >= plane_split_min_symbols:
                streams = [(f"{base}/w{isz}b{j}", plane)
                           for (isz, j), plane in planes.items()]
            else:
                streams = [(base,
                            kv_symbol_stream(layer_arrays[key], "qlc"))]
        for name, syms in streams:
            layout.append(name)
            if name not in registry:
                pending.append((name, np.asarray(syms)))

    # Pass 2: group pending streams by histogram similarity; one set of
    # tables per group (summed counts), one empirically-sized plan per
    # stream.
    groups = []   # [{pmf, counts, members: [(name, syms, counts)]}]
    for name, syms in pending:
        counts = np.maximum(
            np.bincount(syms, minlength=256).astype(np.float64), 1e-6)
        pmf = counts / counts.sum()
        for g in groups:
            if merge_tol > 0 and \
                    0.5 * float(np.abs(pmf - g["pmf"]).sum()) <= merge_tol:
                g["counts"] += counts
                g["members"].append((name, syms, counts))
                break
        else:
            groups.append({"pmf": pmf, "counts": counts.copy(),
                           "members": [(name, syms, counts)]})

    entries = {}
    for g in groups:
        tables = adapt.calibrate_tables(g["counts"],
                                        allow_search=allow_search)
        for name, syms, counts in g["members"]:
            plan = plan_for_tables(tables, counts,
                                   chunk_symbols=chunk_symbols,
                                   target_escape_prob=target_escape_prob)
            # Capped pool: the paged cache wires incompressible streams
            # raw (codec_wins), so the pool never needs to cover a
            # pathological escape rate here.
            plan = empirical_plan(tables, syms, plan,
                                  chunk_symbols=chunk_symbols,
                                  target_escape_prob=target_escape_prob,
                                  max_pool_slots_per_1k=64)
            entries[name] = registry.register_tables(name, tables, plan,
                                                     counts=counts)
    return {name: entries.get(name, registry[name]) for name in layout}
