"""QLC-compressed collectives (the paper's motivating application, §1).

Built on shard_map + jax.lax collectives. The wire format is shape-static
(XLA requirement): each 1024-symbol chunk gets a fixed QLC slot sized by
the planner, a 1-byte escape flag, and escaped chunks ride in a small
fixed overflow pool. If the pool itself overflows (probability bounded
below the planner's target; adversarial data only), the payload is
flagged not-ok and the caller retries the step uncompressed — the
trainer implements that retry. Lossless semantics never depend on
statistics.

Collectives:
  qlc_all_gather      — AG of e4m3-quantized, QLC-coded shards.
  qlc_reduce_scatter  — RS as quantize-encode + all_to_all + decode-sum.
  qlc_psum            — RS followed by AG (both compressed).
  qlc_all_to_all      — compressed expert/MoE dispatch.

Each has an uncompressed-e4m3 twin (cfg.enabled=False → raw codes on the
wire) and a bf16 reference; the coding step is bit-exact lossless, so
compressed and raw-e4m3 paths produce IDENTICAL numerics (tested).

Codec arguments: every entry point accepts either the legacy
``(CodecTables, CommConfig)`` pair or a
:class:`~repro.core.registry.CodecEntry` from a per-tensor-type
registry (``resolve_codec`` is the shim); the entry's calibrated plan
supplies the wire config. For payloads that must decode WITHOUT this
out-of-band config (checkpoints, serving manifests, offline exchange),
``repro.comm.container`` frames them with a self-describing header
(scheme-id + chunk geometry + capacity + pool + scale layout).

**Deprecation**: the loose-kwarg functional API here (``qlc_*``,
``compress_values``, ``decompress_values``, ...) is superseded by
:class:`repro.comm.channel.Channel`, which binds codec + transport +
mesh axis once and exposes the same surface as methods. The functions
remain as thin wrappers building a channel per call — bit-identical
outputs — and emit a ``DeprecationWarning``.

With ``cfg.use_kernels=True`` the local quantize→encode and
decode→dequantize stages each run as one fused Pallas dispatch
(``repro.kernels.ops``) instead of separate XLA ops — same numerics.
(On this path the uint8 symbols ARE still written once to HBM, because
the escape pool needs them; the fusion saves the separate quantize and
encode dispatches and their re-reads. Callers without an escape pool —
the weight wire, serving, checkpoints — get the full
symbols-stay-in-VMEM benefit.) Note: ``pallas_call`` has no shard_map
replication rule, so callers must pass ``check_rep=False`` to
``shard_map`` when enabling kernels.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.lut import CodecTables
from repro.comm.planner import CommPlan
from repro.quant import e4m3


def _warn_legacy(old: str, new: str):
    warnings.warn(
        f"{old} is deprecated; bind the codec once with "
        f"repro.comm.channel.Channel and call {new}",
        DeprecationWarning, stacklevel=3)


def _legacy_channel(tables, cfg, *, transport=None, axis_name=None,
                    axis_size=None):
    """One-shot Channel for a deprecated functional call."""
    from repro.comm.channel import Channel, ChannelSpec
    tables, cfg = resolve_codec(tables, cfg)
    return Channel(ChannelSpec(codec=tables, cfg=cfg, transport=transport,
                               axis=axis_name, axis_size=axis_size))


def resolve_codec(codec_like, cfg: Optional["CommConfig"] = None,
                  **cfg_overrides):
    """Normalize a codec argument to ``(tables, cfg)``.

    Accepts the legacy ``(CodecTables, CommConfig)`` pair or a registry
    :class:`~repro.core.registry.CodecEntry`, whose plan supplies the
    wire config when ``cfg`` is omitted (overrides, e.g.
    ``use_kernels=True``, apply on top). This is the API-migration
    shim: every collective and (de)compression entry point routes
    through it.
    """
    from repro.core.registry import CodecEntry
    if isinstance(codec_like, CodecEntry):
        tables = codec_like.tables
        if cfg is None:
            cfg = codec_like.config(**cfg_overrides)
        return tables, cfg
    if cfg is None:
        raise TypeError(
            "a bare CodecTables needs an explicit CommConfig; pass a "
            "registry CodecEntry to derive it from the calibrated plan")
    return codec_like, cfg


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Static configuration of the compressed-collective wire format."""
    enabled: bool = True          # False => raw e4m3 codes on the wire
    chunk_symbols: int = 1024
    capacity_words: int = 240     # 7.5 bits/symbol default
    pool_slots_per_1k: int = 8
    scale_dtype: str = "bfloat16"
    # Fused Pallas kernels inside the graph: quantize+encode and
    # decode+dequantize each run as one dispatch (repro.kernels.ops).
    # Bit-exact vs the pure-JAX path; compiled on TPU, interpret on CPU.
    use_kernels: bool = False

    @classmethod
    def from_plan(cls, plan: CommPlan, **kw) -> "CommConfig":
        base = dict(chunk_symbols=plan.chunk_symbols,
                    capacity_words=plan.capacity_words,
                    pool_slots_per_1k=plan.pool_slots_per_1k)
        base.update(kw)          # explicit overrides win over the plan
        return cls(**base)

    def pool_slots(self, n_chunks: int) -> int:
        return max(1, math.ceil(n_chunks * self.pool_slots_per_1k / 1024))

    def raw_words(self) -> int:
        return self.chunk_symbols // 4


class WirePayload(NamedTuple):
    """Static-shape compressed payload for one (src -> dst) transfer."""
    words: jnp.ndarray       # u32 [..., n_chunks, capacity_words]
    flags: jnp.ndarray       # u8  [..., n_chunks] 1 = escaped-to-pool
    pool: jnp.ndarray        # u32 [..., pool_slots, K/4] raw escaped chunks
    pool_count: jnp.ndarray  # i32 [..., 1] number of escapes


def wire_bytes(payload: WirePayload, scales: Optional[jnp.ndarray] = None
               ) -> int:
    """Static wire footprint in bytes (for accounting/benchmarks)."""
    total = sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in payload)
    if scales is not None:
        total += int(np.prod(scales.shape)) * scales.dtype.itemsize
    return total


# --------------------------------------------------------------------------
# Payload compress / decompress (local, shape-static, jit-friendly)
# --------------------------------------------------------------------------

def _encode(chunks: jnp.ndarray, tables: CodecTables, cfg: CommConfig):
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        flat = chunks.reshape(-1, cfg.chunk_symbols)
        words, nbits = kops.encode(flat, tables, cfg.capacity_words)
        lead = chunks.shape[:-1]
        return (words.reshape(lead + (cfg.capacity_words,)),
                nbits.reshape(lead))
    return codec.encode_chunks(chunks, tables, cfg.capacity_words)


def _decode(words: jnp.ndarray, tables: CodecTables, cfg: CommConfig):
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        flat = words.reshape(-1, cfg.capacity_words)
        out = kops.decode(flat, tables, cfg.chunk_symbols)
        return out.reshape(words.shape[:-1] + (cfg.chunk_symbols,))
    return codec.decode_chunks(words, tables, cfg.chunk_symbols)


def _raw_payload(chunks: jnp.ndarray) -> WirePayload:
    """Raw e4m3 wire: bitcast u8 -> u32, no escapes."""
    *lead, n_chunks, k = chunks.shape
    raw = jax.lax.bitcast_convert_type(
        chunks.reshape(*lead, n_chunks, k // 4, 4), jnp.uint32)
    return WirePayload(
        words=raw,
        flags=jnp.zeros((*lead, n_chunks), dtype=jnp.uint8),
        pool=jnp.zeros((*lead, 1, k // 4), dtype=jnp.uint32),
        pool_count=jnp.zeros((*lead, 1), dtype=jnp.int32),
    )


# --- escape-pool machinery (shared by wire assembly and both decode
# --- paths; the slot/gather invariants live ONLY here) --------------------

def _escape_slots(escape: jnp.ndarray, pool_slots: int):
    """Per-chunk pool slot assignment from escape flags.

    Returns ``(esc_idx, slot)``: running escape index, and the scatter
    slot (``pool_slots`` — i.e. dropped — for non-escaped and
    pool-overflowing chunks).
    """
    esc_i = escape.astype(jnp.int32)
    esc_idx = jnp.cumsum(esc_i, axis=-1) - esc_i
    slot = jnp.where(escape.astype(bool), esc_idx, pool_slots)
    return esc_idx, slot


def _scatter_pool_rows(rows: jnp.ndarray, slot: jnp.ndarray,
                       pool_slots: int) -> jnp.ndarray:
    """[..., n_chunks, W] rows -> [..., pool_slots, W] (drop slot==pool_slots)."""
    *lead, n_chunks, w = rows.shape

    def one(z, s_, v_):
        return z.at[s_].set(v_, mode="drop")

    zeros = jnp.zeros((*lead, pool_slots, w), rows.dtype)
    if lead:
        out = jax.vmap(one)(zeros.reshape(-1, pool_slots, w),
                            slot.reshape(-1, n_chunks),
                            rows.reshape(-1, n_chunks, w))
        return out.reshape(*lead, pool_slots, w)
    return one(zeros, slot, rows)


def _gather_pool_rows(pool: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """[..., pool_slots, W] pool + [..., n_chunks] idx -> [..., n_chunks, W]."""
    *lead, pool_slots, w = pool.shape
    n_chunks = idx.shape[-1]

    def one(pv, iv):
        return jnp.take(pv, iv, axis=0)

    if lead:
        out = jax.vmap(one)(pool.reshape(-1, pool_slots, w),
                            idx.reshape(-1, n_chunks))
        return out.reshape(*lead, n_chunks, w)
    return one(pool, idx)


def _assemble_payload(chunks: jnp.ndarray, words: jnp.ndarray,
                      nbits: jnp.ndarray, cfg: CommConfig) -> WirePayload:
    """Build the escape-flag/pool wire format around encoded slots."""
    *lead, n_chunks, k = chunks.shape
    escape = nbits > jnp.uint32(cfg.capacity_words * 32)
    pool_slots = cfg.pool_slots(n_chunks)

    raw = jax.lax.bitcast_convert_type(
        chunks.reshape(*lead, n_chunks, k // 4, 4), jnp.uint32)

    # Escaped chunks scatter their raw form into the pool; non-escaped
    # and pool-overflowing chunks are dropped.
    _, slot = _escape_slots(escape, pool_slots)
    pool = _scatter_pool_rows(raw, slot, pool_slots)

    pool_count = jnp.sum(escape.astype(jnp.int32), axis=-1, keepdims=True)
    return WirePayload(words=words, flags=escape.astype(jnp.uint8),
                       pool=pool, pool_count=pool_count)


def _compress_codes(codes: jnp.ndarray, tables: CodecTables,
                    cfg: CommConfig) -> WirePayload:
    """Resolved-argument impl of :func:`compress_codes` (the
    non-deprecated path — ``Channel.compress_codes`` and the transport
    layer land here)."""
    k = cfg.chunk_symbols
    *lead, m = codes.shape
    assert m % k == 0, (m, k)
    n_chunks = m // k
    chunks = codes.reshape(*lead, n_chunks, k)

    if not cfg.enabled:
        return _raw_payload(chunks)

    words, nbits = _encode(chunks, tables, cfg)
    return _assemble_payload(chunks, words, nbits, cfg)


def compress_codes(codes: jnp.ndarray, tables, cfg: CommConfig = None
                   ) -> WirePayload:
    """uint8 [..., M] (M % chunk_symbols == 0) -> WirePayload.

    ``tables`` is a ``CodecTables`` (with explicit ``cfg``) or a
    registry ``CodecEntry`` (cfg defaults to its calibrated plan).

    .. deprecated:: use ``Channel.compress_codes``.
    """
    _warn_legacy("compress_codes", "Channel.compress_codes")
    return _legacy_channel(tables, cfg).compress_codes(codes)


def _gather_pool_raw(payload: WirePayload, cfg: CommConfig) -> jnp.ndarray:
    """Gather each chunk's escape-pool raw form -> u8 [..., n_chunks, K].

    Rows whose chunk did not escape hold arbitrary pool data; callers
    select with the escape flags.
    """
    k = cfg.chunk_symbols
    *lead, n_chunks, _ = payload.words.shape
    pool_slots = payload.pool.shape[-2]
    esc_idx, _ = _escape_slots(payload.flags, pool_slots)
    raw_words = _gather_pool_rows(
        payload.pool, jnp.minimum(esc_idx, pool_slots - 1))
    raw = jax.lax.bitcast_convert_type(raw_words, jnp.uint8)  # [...,K/4,4]
    return raw.reshape(*lead, n_chunks, k)


def decompress_codes(payload: WirePayload, tables,
                     cfg: CommConfig = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """WirePayload -> (uint8 codes [..., M], ok bool[...]).

    .. deprecated:: use ``Channel.decompress_codes``.
    """
    _warn_legacy("decompress_codes", "Channel.decompress_codes")
    if tables is not None or cfg is None:
        tables, cfg = resolve_codec(tables, cfg)
    return _decompress_codes(payload, tables, cfg)


def _decompress_codes(payload: WirePayload, tables: Optional[CodecTables],
                      cfg: CommConfig, *, decode_fn=None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Resolved-argument impl of :func:`decompress_codes`. ``tables``
    may be ``None`` only for a raw (``cfg.enabled=False``) wire.
    ``decode_fn(words, tables, cfg)`` overrides the slot decode — the
    async KV paging path routes it through the DMA prefetch kernel
    (``kernels.ops.decode_block_async``) while reusing this escape
    merge unchanged."""
    k = cfg.chunk_symbols
    *lead, n_chunks, _ = payload.words.shape

    if not cfg.enabled:
        chunks = jax.lax.bitcast_convert_type(payload.words, jnp.uint8)
        codes_out = chunks.reshape(*lead, n_chunks * k)
        ok = jnp.ones(tuple(lead), dtype=bool) if lead else jnp.bool_(True)
        return codes_out, ok

    dec = (_decode if decode_fn is None else decode_fn)(
        payload.words, tables, cfg)                    # [..., n_chunks, K]

    escape = payload.flags.astype(bool)
    raw = _gather_pool_raw(payload, cfg)
    pool_slots = payload.pool.shape[-2]

    out = jnp.where(escape[..., None], raw, dec)
    ok = (payload.pool_count[..., 0] <= pool_slots)
    return out.reshape(*lead, n_chunks * k), ok


# --------------------------------------------------------------------------
# Quantization plumbing
# --------------------------------------------------------------------------

def _quantize(x: jnp.ndarray, cfg: CommConfig):
    """float [..., M] -> (codes u8 [..., M], scales scale_dtype [..., M/32])."""
    codes, scales = e4m3.quantize_block32(x.astype(jnp.float32))
    return codes, scales.astype(cfg.scale_dtype)


def _dequantize(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    return e4m3.dequantize_block32(codes, scales.astype(jnp.float32))


# --------------------------------------------------------------------------
# Fused value <-> wire transforms (the collectives' local hot path)
# --------------------------------------------------------------------------

def compress_values(x: jnp.ndarray, tables, cfg: CommConfig = None
                    ) -> Tuple[WirePayload, jnp.ndarray]:
    """float [..., M] (M % chunk_symbols == 0) -> (WirePayload, scales).

    ``tables`` may be a registry ``CodecEntry`` (cfg optional, derived
    from its plan). For a self-describing framing of the result see
    ``repro.comm.container`` — the container header carries the wire
    geometry + scheme-id so the payload decodes without this cfg.

    .. deprecated:: use ``Channel.compress``.
    """
    _warn_legacy("compress_values", "Channel.compress")
    return _legacy_channel(tables, cfg).compress(x)


def _compress_values(x: jnp.ndarray, tables: CodecTables, cfg: CommConfig,
                     *, emit_hist: bool = False):
    """Resolved-argument impl of :func:`compress_values`.

    With ``cfg.use_kernels`` the e4m3 quantization and QLC encode run as
    ONE fused Pallas dispatch (the symbols are emitted once, for the
    escape pool, instead of being written by quantize and re-read by
    encode); otherwise the pure-JAX quantize -> encode pipeline runs.
    Both paths are bit-exact identical: the fused kernel's quantizer is
    tested bit-equal to ``e4m3.quantize_block32`` and its packer to
    ``codec.encode_chunks``.

    ``emit_hist=True`` appends the 256-bin symbol histogram (i32[256],
    summed over ALL lead dims) to the return: on the kernel path it
    rides the fused encode pass for free (the symbols are already in
    registers); the pure path pays one ``bincount``. This is the
    telemetry tap for ``repro.adaptive`` — the histogram describes
    exactly the symbols that went on the wire.
    """
    k = cfg.chunk_symbols
    *lead, m = x.shape
    assert m % k == 0, (m, k)
    n_chunks = m // k

    if cfg.enabled and cfg.use_kernels:
        from repro.kernels import ops as kops
        flat = x.reshape(-1, k).astype(jnp.float32)
        # emit_codes: the escape pool stores raw symbols of overflowing
        # chunks, so the wire assembly needs them once per chunk.
        outs = kops.quantize_encode(
            flat, tables, cfg.capacity_words, emit_codes=True,
            emit_hist=emit_hist)
        words, nbits, scales, chunk_codes = outs[:4]
        words = words.reshape(*lead, n_chunks, cfg.capacity_words)
        nbits = nbits.reshape(*lead, n_chunks)
        chunks = chunk_codes.reshape(*lead, n_chunks, k)
        scales = scales.reshape(*lead, m // e4m3.BLOCK).astype(cfg.scale_dtype)
        payload = _assemble_payload(chunks, words, nbits, cfg)
        if emit_hist:
            return payload, scales, outs[4]
        return payload, scales

    codes, scales = _quantize(x, cfg)
    payload = _compress_codes(codes, tables, cfg)
    if emit_hist:
        hist = jnp.bincount(codes.reshape(-1), length=256).astype(jnp.int32)
        return payload, scales, hist
    return payload, scales


def _pool_values(payload: WirePayload, scales: jnp.ndarray,
                 cfg: CommConfig):
    """Escape epilogue shared by the fused decode paths: dequantize ONLY
    the pool rows (O(pool_slots*K), not O(M)) — scatter each escaped
    chunk's scales to its slot, decode the raw pool bytes once, gather
    rows back per chunk.

    Returns ``(escape bool [..., n_chunks], raw_vals f32 [..., n_chunks,
    K], ok bool [...])``. Rows whose chunk did not escape (and, when the
    pool itself overflowed — ok=False, caller retries — rows beyond the
    pool) hold unspecified values; callers select with ``escape``.
    """
    k = cfg.chunk_symbols
    k32 = k // e4m3.BLOCK
    *lead, n_chunks, _ = payload.words.shape
    pool_slots = payload.pool.shape[-2]
    escape = payload.flags.astype(bool)
    esc_idx, slot = _escape_slots(payload.flags, pool_slots)
    chunk_scales = scales.astype(jnp.float32).reshape(*lead, n_chunks, k32)
    pool_scales = _scatter_pool_rows(chunk_scales, slot, pool_slots)

    pool_u8 = jax.lax.bitcast_convert_type(payload.pool, jnp.uint8)
    pool_vals = e4m3.dequantize_block32(
        pool_u8.reshape(*lead, pool_slots * k),
        pool_scales.reshape(*lead, pool_slots * k32),
    ).reshape(*lead, pool_slots, k)

    raw_vals = _gather_pool_rows(
        pool_vals, jnp.minimum(esc_idx, pool_slots - 1))
    ok = (payload.pool_count[..., 0] <= pool_slots)
    return escape, raw_vals, ok


def decompress_values(payload: WirePayload, scales: jnp.ndarray,
                      tables, cfg: CommConfig = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(WirePayload, scales) -> (float32 values [..., M], ok bool[...]).

    .. deprecated:: use ``Channel.decompress``.
    """
    _warn_legacy("decompress_values", "Channel.decompress")
    return _legacy_channel(tables, cfg).decompress(payload, scales)


def _decompress_values(payload: WirePayload, scales: jnp.ndarray,
                       tables: CodecTables, cfg: CommConfig
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Resolved-argument impl of :func:`decompress_values`.

    With ``cfg.use_kernels`` the QLC decode and e4m3 dequantize run as
    one fused Pallas dispatch producing floats directly from packed
    words; escaped chunks are dequantized from their raw pool form and
    selected in, which is elementwise identical to merging at the code
    level (dequantization is a per-symbol table gather times the block
    scale either way).
    """
    k = cfg.chunk_symbols
    *lead, n_chunks, _ = payload.words.shape

    if cfg.enabled and cfg.use_kernels:
        from repro.kernels import ops as kops
        k32 = k // e4m3.BLOCK
        flat_words = payload.words.reshape(-1, payload.words.shape[-1])
        flat_scales = scales.astype(jnp.float32).reshape(-1, k32)
        vals = kops.decode_dequantize(flat_words, flat_scales, tables, k)
        vals = vals.reshape(*lead, n_chunks, k)

        escape, raw_vals, ok = _pool_values(payload, scales, cfg)
        out = jnp.where(escape[..., None], raw_vals, vals)
        return out.reshape(*lead, n_chunks * k), ok

    codes, ok = _decompress_codes(payload, tables, cfg)
    return _dequantize(codes, scales), ok


def accumulate_values(acc: jnp.ndarray, payload: WirePayload,
                      scales: jnp.ndarray, tables, cfg: CommConfig = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``acc + decompress_values(payload)`` — the ring reduce-scatter's
    per-hop step. Returns ``(new_acc f32 [..., M], ok)``.

    .. deprecated:: use a ``Channel`` (the ring transport accumulates
    through ``transport._accumulate_row_pieces`` internally).
    """
    _warn_legacy("accumulate_values", "Channel collectives")
    tables, cfg = resolve_codec(tables, cfg)
    return _accumulate_values(acc, payload, scales, tables, cfg)


def _accumulate_values(acc: jnp.ndarray, payload: WirePayload,
                       scales: jnp.ndarray, tables: CodecTables,
                       cfg: CommConfig
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Resolved-argument impl of :func:`accumulate_values`.

    With ``cfg.use_kernels`` the decode, dequantize, AND the running sum
    run as ONE fused Pallas dispatch
    (``kernels.ops.decode_dequantize_accumulate``): the hop's decoded
    values never materialize in HBM, only the updated accumulator does.
    Escaped chunks merge through the shared pool epilogue at the
    accumulator level — ``where(escape, acc + raw, acc + decoded)`` —
    which is bit-identical to ``acc + where(escape, raw, decoded)``
    (f32 addition distributes over the elementwise select exactly).
    """
    k = cfg.chunk_symbols
    *lead, n_chunks, _ = payload.words.shape

    if cfg.enabled and cfg.use_kernels:
        from repro.kernels import ops as kops
        k32 = k // e4m3.BLOCK
        acc_rows = acc.reshape(-1, k).astype(jnp.float32)
        flat_words = payload.words.reshape(-1, payload.words.shape[-1])
        flat_scales = scales.astype(jnp.float32).reshape(-1, k32)
        summed = kops.decode_dequantize_accumulate(
            acc_rows, flat_words, flat_scales, tables, k)
        summed = summed.reshape(*lead, n_chunks, k)

        escape, raw_vals, ok = _pool_values(payload, scales, cfg)
        acc_chunks = acc.reshape(*lead, n_chunks, k)
        out = jnp.where(escape[..., None], acc_chunks + raw_vals, summed)
        return out.reshape(*lead, n_chunks * k), ok

    vals, ok = _decompress_values(payload, scales, tables, cfg)
    return acc + vals, ok


def pad_to_multiple(x: jnp.ndarray, multiple: int) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % multiple
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, n


# --------------------------------------------------------------------------
# Collectives (call inside shard_map with a named axis)
#
# DEPRECATED wrappers: each builds a one-shot Channel
# (repro.comm.channel) binding codec + transport + axis, then calls the
# corresponding method — the collective orchestration (padding,
# transport dispatch, valid-length accounting) lives on Channel now.
# Outputs are bit-identical to the pre-channel implementations; both
# transports remain bit-identical to each other (tested) — the reduce
# accumulation order is part of the transport contract (see
# transport._accumulate_row_pieces).
# --------------------------------------------------------------------------

class ReduceScatterResult(NamedTuple):
    """``qlc_reduce_scatter`` output.

    ``segment`` is the shard's summed segment, padded to the static
    segment length; ``valid`` (i32 scalar, traced) is how many leading
    entries of ``segment`` map to real (pre-padding) input on THIS
    shard — callers no longer re-derive it from ``cfg.chunk_symbols``
    and the axis geometry.
    """
    segment: jnp.ndarray     # f32 [seg_padded]
    valid: jnp.ndarray       # i32 [] — # of real entries in segment
    ok: jnp.ndarray          # bool []


def qlc_all_gather(x: jnp.ndarray, axis_name, tables,
                   cfg: CommConfig = None, *, transport=None,
                   axis_size: Optional[int] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-gather with e4m3+QLC wire. Returns (tiled gather f32 [D*n], ok).

    ``x`` is this shard's (float) payload; output is the concatenation of
    every peer's dequantized payload along axis 0 (flattened).
    ``tables`` is a ``CodecTables`` (explicit ``cfg``) or a registry
    ``CodecEntry`` (cfg from its plan) — same for every collective here.

    ``transport`` is ``None``/"oneshot" (legacy), "ring", or a planner
    :class:`~repro.comm.planner.TransportConfig`; the ring transport
    additionally needs the static ``axis_size``.

    .. deprecated:: use ``Channel.all_gather``.
    """
    _warn_legacy("qlc_all_gather", "Channel.all_gather")
    ch = _legacy_channel(tables, cfg, transport=transport,
                         axis_name=axis_name, axis_size=axis_size)
    return ch.all_gather(x)


def qlc_reduce_scatter(x: jnp.ndarray, axis_name, axis_size: int,
                       tables, cfg: CommConfig = None, *, transport=None
                       ) -> ReduceScatterResult:
    """Reduce-scatter(sum) with e4m3+QLC wire.

    Implemented as quantize-encode + exchange + decode-sum (the standard
    compressed-RS decomposition: compression must happen before the
    wire, so the reduction moves after the exchange). The one-shot
    transport exchanges via ``all_to_all``; the ring transport sends one
    original compressed segment per ``ppermute`` hop and folds it into
    the accumulator on arrival (fused decode→dequantize→accumulate
    dispatch when ``cfg.use_kernels``). Accumulation order is the ring
    arrival order on both transports, so they are bit-identical.

    Returns :class:`ReduceScatterResult` ``(segment, valid, ok)``; the
    segment is padded to the static length, ``valid`` counts its real
    entries. See ``qlc_psum`` for the round trip.

    .. deprecated:: use ``Channel.reduce_scatter``.
    """
    _warn_legacy("qlc_reduce_scatter", "Channel.reduce_scatter")
    ch = _legacy_channel(tables, cfg, transport=transport,
                         axis_name=axis_name, axis_size=axis_size)
    return ch.reduce_scatter(x)


def qlc_psum(x: jnp.ndarray, axis_name, axis_size: int, tables,
             cfg: CommConfig = None, *, transport=None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce(sum) = compressed RS + compressed AG.

    Note both phases quantize (two e4m3 roundings), as in standard
    compressed all-reduce; the QLC coding itself adds zero error. The
    codec is resolved ONCE (by the channel) and threaded through both
    phases.

    .. deprecated:: use ``Channel.psum``.
    """
    _warn_legacy("qlc_psum", "Channel.psum")
    ch = _legacy_channel(tables, cfg, transport=transport,
                         axis_name=axis_name, axis_size=axis_size)
    return ch.psum(x)


def qlc_all_to_all(x: jnp.ndarray, axis_name, tables,
                   cfg: CommConfig = None, *, transport=None,
                   axis_size: Optional[int] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compressed all-to-all of x [D, ...] (row j -> peer j).

    .. deprecated:: use ``Channel.all_to_all``.
    """
    _warn_legacy("qlc_all_to_all", "Channel.all_to_all")
    # d is static from x.shape, so the legacy no-axis_size call keeps
    # working; Channel itself refuses a ring transport without it.
    ch = _legacy_channel(tables, cfg, transport=transport,
                         axis_name=axis_name,
                         axis_size=x.shape[0] if axis_size is None
                         else axis_size)
    return ch.all_to_all(x)


# --------------------------------------------------------------------------
# References (bf16 wire, no compression) for tests & baseline mode
# --------------------------------------------------------------------------

def ref_psum(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    return jax.lax.psum(x, axis_name)


def ref_all_gather(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    return jax.lax.all_gather(x.reshape(-1), axis_name).reshape(-1)


def ref_reduce_scatter(x: jnp.ndarray, axis_name, axis_size: int
                       ) -> jnp.ndarray:
    flat, _ = pad_to_multiple(x, axis_size)
    return jax.lax.psum_scatter(
        flat.reshape(axis_size, -1), axis_name, scatter_dimension=0,
        tiled=False).reshape(-1)
