"""QLC-compressed collectives (the paper's motivating application, §1).

Built on shard_map + jax.lax collectives. The wire format is shape-static
(XLA requirement): each 1024-symbol chunk gets a fixed QLC slot sized by
the planner, a 1-byte escape flag, and escaped chunks ride in a small
fixed overflow pool. If the pool itself overflows (probability bounded
below the planner's target; adversarial data only), the payload is
flagged not-ok and the caller retries the step uncompressed — the
trainer implements that retry. Lossless semantics never depend on
statistics.

Collectives:
  qlc_all_gather      — AG of e4m3-quantized, QLC-coded shards.
  qlc_reduce_scatter  — RS as quantize-encode + all_to_all + decode-sum.
  qlc_psum            — RS followed by AG (both compressed).
  qlc_all_to_all      — compressed expert/MoE dispatch.

Each has an uncompressed-e4m3 twin (cfg.enabled=False → raw codes on the
wire) and a bf16 reference; the coding step is bit-exact lossless, so
compressed and raw-e4m3 paths produce IDENTICAL numerics (tested).
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.lut import CodecTables
from repro.comm.planner import CommPlan
from repro.quant import e4m3


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Static configuration of the compressed-collective wire format."""
    enabled: bool = True          # False => raw e4m3 codes on the wire
    chunk_symbols: int = 1024
    capacity_words: int = 240     # 7.5 bits/symbol default
    pool_slots_per_1k: int = 8
    scale_dtype: str = "bfloat16"
    use_kernels: bool = False     # Pallas kernels inside the graph

    @classmethod
    def from_plan(cls, plan: CommPlan, **kw) -> "CommConfig":
        return cls(chunk_symbols=plan.chunk_symbols,
                   capacity_words=plan.capacity_words,
                   pool_slots_per_1k=plan.pool_slots_per_1k, **kw)

    def pool_slots(self, n_chunks: int) -> int:
        return max(1, math.ceil(n_chunks * self.pool_slots_per_1k / 1024))

    def raw_words(self) -> int:
        return self.chunk_symbols // 4


class WirePayload(NamedTuple):
    """Static-shape compressed payload for one (src -> dst) transfer."""
    words: jnp.ndarray       # u32 [..., n_chunks, capacity_words]
    flags: jnp.ndarray       # u8  [..., n_chunks] 1 = escaped-to-pool
    pool: jnp.ndarray        # u32 [..., pool_slots, K/4] raw escaped chunks
    pool_count: jnp.ndarray  # i32 [..., 1] number of escapes


def wire_bytes(payload: WirePayload, scales: Optional[jnp.ndarray] = None
               ) -> int:
    """Static wire footprint in bytes (for accounting/benchmarks)."""
    total = sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in payload)
    if scales is not None:
        total += int(np.prod(scales.shape)) * scales.dtype.itemsize
    return total


# --------------------------------------------------------------------------
# Payload compress / decompress (local, shape-static, jit-friendly)
# --------------------------------------------------------------------------

def _encode(chunks: jnp.ndarray, tables: CodecTables, cfg: CommConfig):
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        flat = chunks.reshape(-1, cfg.chunk_symbols)
        words, nbits = kops.encode(flat, tables, cfg.capacity_words)
        lead = chunks.shape[:-1]
        return (words.reshape(lead + (cfg.capacity_words,)),
                nbits.reshape(lead))
    return codec.encode_chunks(chunks, tables, cfg.capacity_words)


def _decode(words: jnp.ndarray, tables: CodecTables, cfg: CommConfig):
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        flat = words.reshape(-1, cfg.capacity_words)
        out = kops.decode(flat, tables, cfg.chunk_symbols)
        return out.reshape(words.shape[:-1] + (cfg.chunk_symbols,))
    return codec.decode_chunks(words, tables, cfg.chunk_symbols)


def compress_codes(codes: jnp.ndarray, tables: CodecTables, cfg: CommConfig
                   ) -> WirePayload:
    """uint8 [..., M] (M % chunk_symbols == 0) -> WirePayload."""
    k = cfg.chunk_symbols
    *lead, m = codes.shape
    assert m % k == 0, (m, k)
    n_chunks = m // k
    chunks = codes.reshape(*lead, n_chunks, k)

    if not cfg.enabled:
        # Raw e4m3 wire: bitcast u8 -> u32, no escapes.
        raw = jax.lax.bitcast_convert_type(
            chunks.reshape(*lead, n_chunks, k // 4, 4), jnp.uint32)
        return WirePayload(
            words=raw,
            flags=jnp.zeros((*lead, n_chunks), dtype=jnp.uint8),
            pool=jnp.zeros((*lead, 1, k // 4), dtype=jnp.uint32),
            pool_count=jnp.zeros((*lead, 1), dtype=jnp.int32),
        )

    words, nbits = _encode(chunks, tables, cfg)
    escape = nbits > jnp.uint32(cfg.capacity_words * 32)
    pool_slots = cfg.pool_slots(n_chunks)

    raw = jax.lax.bitcast_convert_type(
        chunks.reshape(*lead, n_chunks, k // 4, 4), jnp.uint32)

    esc_idx = jnp.cumsum(escape.astype(jnp.int32), axis=-1) - escape
    # Escaped chunks scatter their raw form into the pool; non-escaped
    # and pool-overflowing chunks are dropped (index == pool_slots).
    slot = jnp.where(escape, esc_idx, pool_slots)

    def scatter_rows(pool_z, slot_v, raw_v):
        return pool_z.at[slot_v].set(raw_v, mode="drop")

    pool_z = jnp.zeros((*lead, pool_slots, k // 4), dtype=jnp.uint32)
    if lead:
        flat_pool = pool_z.reshape(-1, pool_slots, k // 4)
        flat_slot = slot.reshape(-1, n_chunks)
        flat_raw = raw.reshape(-1, n_chunks, k // 4)
        pool = jax.vmap(scatter_rows)(flat_pool, flat_slot, flat_raw)
        pool = pool.reshape(*lead, pool_slots, k // 4)
    else:
        pool = scatter_rows(pool_z, slot, raw)

    pool_count = jnp.sum(escape.astype(jnp.int32), axis=-1, keepdims=True)
    return WirePayload(words=words, flags=escape.astype(jnp.uint8),
                       pool=pool, pool_count=pool_count)


def decompress_codes(payload: WirePayload, tables: CodecTables,
                     cfg: CommConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """WirePayload -> (uint8 codes [..., M], ok bool[...])."""
    k = cfg.chunk_symbols
    *lead, n_chunks, _ = payload.words.shape

    if not cfg.enabled:
        chunks = jax.lax.bitcast_convert_type(payload.words, jnp.uint8)
        codes_out = chunks.reshape(*lead, n_chunks * k)
        ok = jnp.ones(tuple(lead), dtype=bool) if lead else jnp.bool_(True)
        return codes_out, ok

    dec = _decode(payload.words, tables, cfg)          # [..., n_chunks, K]

    escape = payload.flags.astype(bool)
    esc_idx = (jnp.cumsum(payload.flags.astype(jnp.int32), axis=-1)
               - payload.flags.astype(jnp.int32))
    pool_slots = payload.pool.shape[-2]
    gather_idx = jnp.minimum(esc_idx, pool_slots - 1)

    def gather_rows(pool_v, idx_v):
        return jnp.take(pool_v, idx_v, axis=0)          # [n_chunks, K/4]

    if lead:
        flat_pool = payload.pool.reshape(-1, pool_slots, k // 4)
        flat_idx = gather_idx.reshape(-1, n_chunks)
        raw_words = jax.vmap(gather_rows)(flat_pool, flat_idx)
        raw_words = raw_words.reshape(*lead, n_chunks, k // 4)
    else:
        raw_words = gather_rows(payload.pool, gather_idx)

    raw = jax.lax.bitcast_convert_type(raw_words, jnp.uint8)  # [...,K/4,4]
    raw = raw.reshape(*lead, n_chunks, k)

    out = jnp.where(escape[..., None], raw, dec)
    ok = (payload.pool_count[..., 0] <= pool_slots)
    return out.reshape(*lead, n_chunks * k), ok


# --------------------------------------------------------------------------
# Quantization plumbing
# --------------------------------------------------------------------------

def _quantize(x: jnp.ndarray, cfg: CommConfig):
    """float [..., M] -> (codes u8 [..., M], scales scale_dtype [..., M/32])."""
    codes, scales = e4m3.quantize_block32(x.astype(jnp.float32))
    return codes, scales.astype(cfg.scale_dtype)


def _dequantize(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    return e4m3.dequantize_block32(codes, scales.astype(jnp.float32))


def pad_to_multiple(x: jnp.ndarray, multiple: int) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % multiple
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, n


# --------------------------------------------------------------------------
# Collectives (call inside shard_map with a named axis)
# --------------------------------------------------------------------------

def qlc_all_gather(x: jnp.ndarray, axis_name, tables: CodecTables,
                   cfg: CommConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-gather with e4m3+QLC wire. Returns (tiled gather f32 [D*n], ok).

    ``x`` is this shard's (float) payload; output is the concatenation of
    every peer's dequantized payload along axis 0 (flattened).
    """
    flat, n = pad_to_multiple(x, cfg.chunk_symbols)
    codes, scales = _quantize(flat, cfg)
    payload = compress_codes(codes, tables, cfg)

    g_payload = jax.tree.map(
        lambda a: jax.lax.all_gather(a, axis_name), payload)
    g_payload = WirePayload(*g_payload)
    g_scales = jax.lax.all_gather(scales, axis_name)

    g_codes, ok = decompress_codes(g_payload, tables, cfg)   # [D, M], [D]
    vals = _dequantize(g_codes, g_scales)                    # [D, M]
    return vals[:, :n].reshape(-1), jnp.all(ok)


def qlc_reduce_scatter(x: jnp.ndarray, axis_name, axis_size: int,
                       tables: CodecTables, cfg: CommConfig
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reduce-scatter(sum) with e4m3+QLC wire.

    Implemented as quantize-encode + all_to_all + decode-sum (the standard
    compressed-RS decomposition: compression must happen before the wire,
    so the reduction moves after the exchange).

    Returns (my summed segment f32 [ceil(n/D*K)*K... padded segment], ok).
    Callers slice/reshape; see ``qlc_psum`` for the round trip.
    """
    d = axis_size
    flat, n = pad_to_multiple(x, d * cfg.chunk_symbols)
    seg = flat.shape[0] // d
    xs = flat.reshape(d, seg)

    codes, scales = _quantize(xs, cfg)          # [D, seg], [D, seg/32]
    payload = compress_codes(codes, tables, cfg)

    a2a = lambda a: jax.lax.all_to_all(
        a, axis_name, split_axis=0, concat_axis=0, tiled=True)
    r_payload = WirePayload(*jax.tree.map(a2a, payload))
    r_scales = a2a(scales)

    r_codes, ok = decompress_codes(r_payload, tables, cfg)   # [D, seg], [D]
    vals = _dequantize(r_codes, r_scales)                    # [D, seg]
    return jnp.sum(vals, axis=0), jnp.all(ok)


def qlc_psum(x: jnp.ndarray, axis_name, axis_size: int, tables: CodecTables,
             cfg: CommConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce(sum) = compressed RS + compressed AG.

    Note both phases quantize (two e4m3 roundings), as in standard
    compressed all-reduce; the QLC coding itself adds zero error.
    """
    seg, ok_rs = qlc_reduce_scatter(x, axis_name, axis_size, tables, cfg)
    full, ok_ag = qlc_all_gather(seg, axis_name, tables, cfg)
    out = full[:x.size].reshape(x.shape)
    return out, ok_rs & ok_ag


def qlc_all_to_all(x: jnp.ndarray, axis_name, tables: CodecTables,
                   cfg: CommConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compressed all-to-all of x [D, ...] (row j -> peer j)."""
    d = x.shape[0]
    row = x.reshape(d, -1)
    n = row.shape[1]
    pad = (-n) % cfg.chunk_symbols
    if pad:
        row = jnp.pad(row, ((0, 0), (0, pad)))

    codes, scales = _quantize(row, cfg)
    payload = compress_codes(codes, tables, cfg)

    a2a = lambda a: jax.lax.all_to_all(
        a, axis_name, split_axis=0, concat_axis=0, tiled=True)
    r_payload = WirePayload(*jax.tree.map(a2a, payload))
    r_scales = a2a(scales)

    r_codes, ok = decompress_codes(r_payload, tables, cfg)
    vals = _dequantize(r_codes, r_scales)[:, :n]
    return vals.reshape(x.shape), jnp.all(ok)


# --------------------------------------------------------------------------
# References (bf16 wire, no compression) for tests & baseline mode
# --------------------------------------------------------------------------

def ref_psum(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    return jax.lax.psum(x, axis_name)


def ref_all_gather(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    return jax.lax.all_gather(x.reshape(-1), axis_name).reshape(-1)


def ref_reduce_scatter(x: jnp.ndarray, axis_name, axis_size: int
                       ) -> jnp.ndarray:
    flat, _ = pad_to_multiple(x, axis_size)
    return jax.lax.psum_scatter(
        flat.reshape(axis_size, -1), axis_name, scatter_dimension=0,
        tiled=False).reshape(-1)
