"""Unified ``Channel`` API: bind codec + transport + mesh axis ONCE.

The paper's deployment model (one LUT per tensor type, §7) and the
transport layer both imply a *binding* — codec entry x transport plan x
mesh axis — yet the pre-channel entry points re-accepted it as loose
kwargs (``tables, cfg=None, *, transport=None, axis_size=None``) with
resolution logic duplicated across the collectives, the train step, the
weight wire, and serving. A :class:`Channel` makes that decision once:

    reg = CodecRegistry(); reg.register("grads", counts)
    ch = Channel(ChannelSpec(codec="grads", transport="auto",
                             axis="data", axis_size=8), registry=reg)
    seg, valid, ok = ch.reduce_scatter(g)      # inside shard_map
    full, ok = ch.all_gather(seg)

The channel is immutable: every wire decision (tables, wire config,
transport policy, axis placement, kernel toggle) is resolved and
validated at construction — a ring transport without a static
``axis_size`` is a construction-time ``ValueError``, not a mid-trace
surprise — and the four collectives plus the local
``compress``/``decompress`` transforms are methods, so nothing is
re-resolved per call. The one *per-call* decision left is the
``"auto"`` transport policy: payload sizes are only static at trace
time, so ``resolved_transport`` picks one-shot vs ring (and clamps
ring hop chunking to tile the payload) from each call's static
geometry — this is what used to be ``train_step._auto_axis_transports``.

``Channel.autotune`` closes the ROADMAP "autotuned hop size" item: it
measures this host's decode throughput on a representative payload of
the channel's own codec (the ``benchmarks/transport_overlap`` beta_decode
measurement, packaged as :func:`measure_decode_Bps`) and — given a
``mesh`` — the per-axis WIRE bandwidth (:func:`measure_wire_Bps`, one
timed ppermute per axis), feeds both to the planner's per-link-class
alpha-beta model, and caches the tuned
:class:`~repro.comm.planner.TransportConfig` in the channel's
:class:`~repro.core.registry.CodecRegistry` keyed by
``(scheme_id, axis, payload bucket, is_reduce)`` plus the measured
link constants per axis (``cache_link_constants``). Both caches
serialize with the
registry JSON, so a reloaded registry reuses the tuning — and any
channel with ``transport="auto"`` bound to that registry picks it up
before falling back to the modeled choice.

``open_channels(registry, mesh, ...)`` builds the per-tensor-type
``{name: Channel}`` map in one call. Multi-host DCN-tier transport is
the ``ChannelSpec(pod_axis=..., pod_axis_size=...)`` binding: the
collectives then run over the combined pod x local group (pod-major
rank order) and the ``hierarchical`` transport rings within the pod
while bridging pods with one compressed exchange per hop group
(``repro.comm.transport``).

The legacy functional API (``qlc_*``, ``compress_values``, ...) remains
as thin deprecated wrappers over one-shot channels — bit-identical
outputs, ``DeprecationWarning`` on call.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import compressed as comp
from repro.comm.planner import (AlphaBetaModel, ONESHOT, TransportConfig,
                                choose_a2a_transport, choose_transport,
                                clamp_hop_chunks, payload_wire_bytes)

#: sentinel transport policy: resolve per call from static payload
#: geometry (registry cache first, then the planner's alpha-beta model).
AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """Declarative channel binding: codec x transport x mesh axis.

    ``codec``
        What compresses the wire: a registry key (``str``, resolved
        against the registry the channel is opened with), a
        :class:`~repro.core.registry.CodecEntry`, a bare
        :class:`~repro.core.lut.CodecTables` (requires ``cfg``), or
        ``None`` — the registry's ``"default"``/first entry.
    ``cfg``
        Explicit :class:`~repro.comm.compressed.CommConfig`. Optional
        with an entry (derived from its calibrated plan); required with
        bare tables.
    ``transport``
        ``None``/``"oneshot"`` (legacy single collective), ``"ring"``
        (ppermute pipeline), ``"hierarchical"`` (intra-pod ring +
        compressed inter-pod bridge; needs ``pod_axis`` to differ from
        ring), ``"auto"`` (planner/registry-cache choice per call), or
        a concrete :class:`~repro.comm.planner.TransportConfig`.
    ``axis`` / ``axis_size``
        The mesh axis the collectives run over and its static size.
        Ring, hierarchical and auto transports REQUIRE ``axis_size``
        (the hop loop is unrolled at trace time) — validated at
        construction.
    ``pod_axis`` / ``pod_axis_size``
        Optional second (slow, DCN-tier) mesh axis. When bound, the
        collectives run over the combined ``pod_axis_size x axis_size``
        group in pod-major rank order (``g = pod_index * axis_size +
        local_index``) and ``axis``/``axis_size`` keep describing the
        LOCAL (fast, ICI) axis. ``"ring"`` cannot run over a pod-bound
        channel (validated at construction); ``"hierarchical"`` without
        a pod axis degrades to ``"ring"``.
    ``use_kernels`` / ``enabled`` / ``scale_dtype``
        Non-plan wire knobs; ``None`` keeps the codec's defaults.
    """
    codec: Any = None
    cfg: Optional["comp.CommConfig"] = None
    transport: Any = None
    axis: Optional[str] = None
    axis_size: Optional[int] = None
    pod_axis: Optional[str] = None
    pod_axis_size: Optional[int] = None
    use_kernels: Optional[bool] = None
    enabled: Optional[bool] = None
    scale_dtype: Optional[str] = None

    def cfg_overrides(self) -> Dict[str, Any]:
        return {k: v for k, v in (("use_kernels", self.use_kernels),
                                  ("enabled", self.enabled),
                                  ("scale_dtype", self.scale_dtype))
                if v is not None}


def _resolve_transport_policy(transport):
    """``ChannelSpec.transport`` -> TransportConfig or the AUTO sentinel."""
    if transport is None:
        return ONESHOT
    if isinstance(transport, TransportConfig):
        return transport
    if isinstance(transport, str):
        if transport == AUTO:
            return AUTO
        return TransportConfig(kind=transport)     # validates the kind
    raise TypeError(f"bad transport spec: {transport!r}")


class Channel:
    """Immutable bound wire: codec + transport policy + mesh axis.

    Construct from a :class:`ChannelSpec` (plus the registry supplying
    named codecs and the autotune cache); all resolution and validation
    happens here, once. Collective methods (``all_gather``,
    ``reduce_scatter``, ``psum``, ``all_to_all``) must be called inside
    ``shard_map`` with ``spec.axis`` manual, exactly like the legacy
    ``qlc_*`` functions; ``compress``/``decompress``/``wire_bytes``
    are local and need no mesh.
    """

    __slots__ = ("spec", "registry", "entry", "tables", "cfg", "model",
                 "_transport")

    def __init__(self, spec: ChannelSpec, *, registry=None, model=None):
        from repro.core.lut import CodecTables
        from repro.core.registry import CodecEntry, CodecRegistry

        if registry is not None and not isinstance(registry, CodecRegistry):
            raise TypeError(f"registry must be a CodecRegistry, got "
                            f"{type(registry).__name__}")

        codec = spec.codec
        entry = None
        if isinstance(codec, str):
            if registry is None:
                raise TypeError(
                    f"codec {codec!r} is a registry key but the channel "
                    "has no registry; pass Channel(spec, registry=...)")
            entry = registry[codec]
        elif isinstance(codec, CodecEntry):
            entry = codec
        elif codec is None:
            if registry is None:
                raise TypeError(
                    "ChannelSpec.codec is None and no registry given; "
                    "name a codec or bind a registry with entries")
            entry = registry.get("default")
            if entry is None:
                entries = registry.entries()
                if not entries:
                    raise TypeError("empty codec registry")
                entry = entries[0]

        if entry is not None:
            tables = entry.tables
            cfg = spec.cfg
            if cfg is None:
                cfg = entry.config(**spec.cfg_overrides())
            elif spec.cfg_overrides():
                cfg = dataclasses.replace(cfg, **spec.cfg_overrides())
        elif isinstance(codec, CodecTables):
            if spec.cfg is None:
                raise TypeError(
                    "a bare CodecTables needs an explicit CommConfig; "
                    "pass ChannelSpec(cfg=...) or a registry CodecEntry")
            tables = codec
            cfg = dataclasses.replace(spec.cfg, **spec.cfg_overrides()) \
                if spec.cfg_overrides() else spec.cfg
        else:
            raise TypeError(f"bad codec spec: {codec!r}")

        transport = _resolve_transport_policy(spec.transport)
        kind = AUTO if transport == AUTO else transport.kind
        if kind in ("ring", "hierarchical") and spec.axis is None:
            raise ValueError(
                f"the {kind!r} transport needs a mesh axis; pass "
                "ChannelSpec(axis=..., axis_size=...)")
        if kind in ("ring", "hierarchical", AUTO) and spec.axis is not None \
                and spec.axis_size is None:
            raise ValueError(
                f"the {kind!r} transport needs the static axis_size "
                f"(the ring hop loop is unrolled at trace time); pass "
                f"ChannelSpec(axis={spec.axis!r}, "
                f"axis_size=mesh.shape[{spec.axis!r}])")
        if spec.axis_size is not None and spec.axis_size < 1:
            raise ValueError(f"axis_size must be >= 1, got "
                             f"{spec.axis_size}")
        if spec.pod_axis is not None:
            if spec.pod_axis == spec.axis:
                raise ValueError(
                    f"pod_axis {spec.pod_axis!r} must differ from the "
                    "local axis")
            if spec.pod_axis_size is None:
                raise ValueError(
                    "a pod-bound channel needs the static "
                    "pod_axis_size (the bridge loop is unrolled at "
                    f"trace time); pass ChannelSpec(pod_axis="
                    f"{spec.pod_axis!r}, "
                    f"pod_axis_size=mesh.shape[{spec.pod_axis!r}])")
            if spec.pod_axis_size < 1:
                raise ValueError(f"pod_axis_size must be >= 1, got "
                                 f"{spec.pod_axis_size}")
            if kind == "ring" and spec.pod_axis_size > 1:
                raise ValueError(
                    "kind='ring' is a single-axis neighbor ring and "
                    "cannot run over a pod-bound channel; use "
                    "'oneshot', 'hierarchical', or 'auto'")
        elif spec.pod_axis_size not in (None, 1):
            raise ValueError("pod_axis_size without pod_axis")

        object.__setattr__(self, "spec", spec)
        object.__setattr__(self, "registry", registry)
        object.__setattr__(self, "entry", entry)
        object.__setattr__(self, "tables", tables)
        object.__setattr__(self, "cfg", cfg)
        object.__setattr__(self, "model", model)
        object.__setattr__(self, "_transport", transport)

    def __setattr__(self, name, value):
        raise AttributeError(
            f"Channel is immutable; use channel.replace({name}=...)")

    def __repr__(self):
        t = self._transport
        t = t if t == AUTO else t.kind
        name = self.entry.name if self.entry is not None else "<tables>"
        return (f"Channel(codec={name!r}, transport={t!r}, "
                f"axis={self.axis!r}, axis_size={self.axis_size})")

    # ---- placement / policy ---------------------------------------------

    @property
    def axis(self) -> Optional[str]:
        return self.spec.axis

    @property
    def axis_size(self) -> Optional[int]:
        return self.spec.axis_size

    @property
    def pod_axis(self) -> Optional[str]:
        return self.spec.pod_axis

    @property
    def pod_size(self) -> int:
        """Pod-axis size (1 on a flat, single-tier channel)."""
        if self.spec.pod_axis is None:
            return 1
        return int(self.spec.pod_axis_size)

    @property
    def group_size(self) -> Optional[int]:
        """Total collective group size: ``pod_size * axis_size``."""
        if self.axis_size is None:
            return None
        return self.pod_size * int(self.axis_size)

    def _pod_kw(self) -> Dict[str, Any]:
        if self.spec.pod_axis is None or self.pod_size <= 1:
            return {}
        return {"pod_axis": self.spec.pod_axis, "pod_size": self.pod_size}

    @property
    def transport(self):
        """The bound policy: a ``TransportConfig`` or ``"auto"``."""
        return self._transport

    def replace(self, **spec_changes) -> "Channel":
        """New channel with updated spec fields (same registry/model)."""
        return Channel(dataclasses.replace(self.spec, **spec_changes),
                       registry=self.registry, model=self.model)

    def _require_axis(self) -> str:
        if self.axis is None:
            raise ValueError(
                "this channel has no mesh axis bound; collectives need "
                "ChannelSpec(axis=...)")
        return self.axis

    def resolved_transport(self, n_values: int, *, is_reduce: bool = False,
                           axis_size: Optional[int] = None,
                           is_a2a: bool = False) -> TransportConfig:
        """Concrete transport for one collective call.

        ``n_values`` is this shard's f32 value count entering the
        collective (static at trace time). The ``"auto"`` policy first
        consults the registry's autotune cache (``(scheme_id, axis,
        payload bucket, is_reduce)`` — see :meth:`autotune`), then
        falls back to the planner's alpha-beta model; one-shot
        reduce-scatter is charged its ``axis_size`` accumulate
        dispatches (ring-parity op sequence) on both paths. Ring hop
        chunking is clamped to tile the per-shard chunk count so hop
        padding can never change the payload's static segment geometry.

        ``is_a2a=True`` (``n_values`` = one destination ROW) resolves
        through the planner's distance-charged a2a model instead —
        all-gather-tuned cache entries don't transfer to the a2a's
        ppermute schedule, so the cache is skipped.

        On a pod-bound channel ``axis_size`` is the LOCAL size; the
        reduce unit divides by the combined group size, the cost model
        is the per-link-class one (axis constants from the registry's
        link cache when probed — :meth:`autotune`), and the candidates
        are one-shot vs hierarchical (a flat ring cannot run over a
        two-axis group).
        """
        d = int(axis_size if axis_size is not None
                else (self.axis_size or 1))
        P = self.pod_size
        k = self.cfg.chunk_symbols
        unit = -(-int(n_values) // (d * P)) if is_reduce else int(n_values)
        t = self._transport
        if t == AUTO:
            t = None
            if not is_a2a and self.registry is not None \
                    and self.entry is not None and self.axis is not None:
                t = self.registry.cached_transport(
                    self.entry.scheme_id, self.axis, 4 * unit,
                    is_reduce=is_reduce)
            if t is None:
                wire = payload_wire_bytes(unit, k, self.cfg.capacity_words,
                                          self.cfg.pool_slots_per_1k)
                model = self._linked_model()
                if is_a2a and P == 1:
                    t = choose_a2a_transport(wire, 4.0 * unit, d,
                                             model=model)
                else:
                    t = choose_transport(
                        wire, 4.0 * unit, d, model=model, pod_size=P,
                        n_oneshot_decode_dispatches=(d * P if is_reduce
                                                     else 1))
        if t.kind in ("ring", "hierarchical"):
            n_chunks = max(1, -(-unit // k))
            t = dataclasses.replace(
                t, hop_chunks=clamp_hop_chunks(t.hop_chunks, n_chunks))
        return t

    def _linked_model(self, base: Optional[AlphaBetaModel] = None
                      ) -> AlphaBetaModel:
        """The channel's cost model with any MEASURED per-axis link
        constants from the registry's link cache folded in
        (``CodecRegistry.cache_link_constants`` — written by
        :meth:`autotune`'s wire probe)."""
        m = base or self.model or AlphaBetaModel()
        if self.registry is None:
            return m
        for ax in (self.axis, self.spec.pod_axis):
            if ax is None:
                continue
            e = self.registry.cached_link_constants(ax)
            if e is not None:
                m = m.with_link(e["link"], wire_Bps=e["wire_Bps"],
                                alpha_s=e["alpha_s"])
        return m

    # ---- local wire transforms ------------------------------------------

    def compress(self, x: jnp.ndarray, *, with_hist: bool = False):
        """float [..., M] (M % chunk_symbols == 0) -> (payload, scales).

        ``with_hist=True`` appends the i32[256] encoded-symbol
        histogram (fused into the encode kernel — the
        ``repro.adaptive`` telemetry tap)."""
        return comp._compress_values(x, self.tables, self.cfg,
                                     emit_hist=with_hist)

    def decompress(self, payload: "comp.WirePayload", scales: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(payload, scales) -> (float32 values, ok)."""
        return comp._decompress_values(payload, scales, self.tables,
                                       self.cfg)

    def compress_codes(self, codes: jnp.ndarray) -> "comp.WirePayload":
        """uint8 symbols [..., M] -> payload (no quantization)."""
        return comp._compress_codes(codes, self.tables, self.cfg)

    def decompress_codes(self, payload: "comp.WirePayload"
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """payload -> (uint8 symbols, ok)."""
        return comp._decompress_codes(payload, self.tables, self.cfg)

    def wire_bytes(self, payload: "comp.WirePayload",
                   scales: Optional[jnp.ndarray] = None) -> int:
        """Static wire footprint of a payload (+ scales) in bytes."""
        return comp.wire_bytes(payload, scales)

    def modeled_wire_bytes(self, n_values: int,
                           hop_chunks: int = 1) -> int:
        """Static wire bytes of an ``n_values``-value payload — the
        planner-side mirror of :meth:`wire_bytes`, no arrays needed.
        ``hop_chunks > 1`` charges the ring piece split's per-piece
        row-sized escape pools (the ok-parity wire shape)."""
        return payload_wire_bytes(int(n_values), self.cfg.chunk_symbols,
                                  self.cfg.capacity_words,
                                  self.cfg.pool_slots_per_1k,
                                  hop_chunks=hop_chunks)

    # ---- collectives (call inside shard_map over spec.axis) -------------

    def all_gather(self, x: jnp.ndarray, *, with_hist: bool = False):
        """All-gather this shard's float payload. Returns
        ``(gathered f32 [group_size * x.size], ok)`` — rows in
        pod-major rank order on a pod-bound channel; ``with_hist``
        appends this shard's encoded-symbol histogram i32[256]."""
        from repro.comm import transport as tr
        axis = self._require_axis()
        t = self.resolved_transport(x.size)
        flat, n = comp.pad_to_multiple(
            x, t.hop_chunks * self.cfg.chunk_symbols)
        out = tr.exchange_all_gather(
            flat, axis, self.tables, self.cfg, t, self.axis_size,
            emit_hist=with_hist, **self._pod_kw())
        vals, ok = out[0], out[1]
        if with_hist:
            return vals[:, :n].reshape(-1), ok, out[2]
        return vals[:, :n].reshape(-1), ok

    def reduce_scatter(self, x: jnp.ndarray, *, with_hist: bool = False):
        """Reduce-scatter(sum). Returns ``ReduceScatterResult(segment,
        valid, ok)`` — segment padded to the static length, ``valid``
        counting its real entries. ``with_hist`` appends the i32[256]
        histogram of every symbol this device encoded."""
        from repro.comm import transport as tr
        axis = self._require_axis()
        if self.axis_size is None:
            raise ValueError(
                "reduce_scatter needs the static axis_size; pass "
                "ChannelSpec(axis_size=mesh.shape[axis])")
        d = int(self.axis_size)
        D = d * self.pod_size
        t = self.resolved_transport(x.size, is_reduce=True)
        flat, n = comp.pad_to_multiple(
            x, D * t.hop_chunks * self.cfg.chunk_symbols)
        seg = flat.shape[0] // D
        xs = flat.reshape(D, seg)
        out = tr.exchange_reduce_scatter(
            xs, axis, d, self.tables, self.cfg, t, emit_hist=with_hist,
            **self._pod_kw())
        acc, ok = out[0], out[1]
        idx = jax.lax.axis_index(axis).astype(jnp.int32)
        if self.spec.pod_axis is not None and self.pod_size > 1:
            idx += jax.lax.axis_index(
                self.spec.pod_axis).astype(jnp.int32) * d
        valid = jnp.clip(jnp.int32(n) - idx * seg, 0, seg)
        res = comp.ReduceScatterResult(segment=acc, valid=valid, ok=ok)
        if with_hist:
            return res, out[2]
        return res

    def psum(self, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """All-reduce(sum) = compressed RS + compressed AG (both phases
        quantize, as in standard compressed all-reduce; the QLC coding
        adds zero error). The codec is resolved ONCE — here, at channel
        construction — and threaded through both phases."""
        r = self.reduce_scatter(x)
        full, ok_ag = self.all_gather(r.segment)
        out = full[:x.size].reshape(x.shape)
        return out, r.ok & ok_ag

    def all_to_all(self, x: jnp.ndarray, *, with_hist: bool = False):
        """Compressed all-to-all of ``x [D, ...]`` (row j -> peer j).
        ``with_hist`` appends the i32[256] histogram of every symbol
        this device encoded."""
        from repro.comm import transport as tr
        axis = self._require_axis()
        d = x.shape[0]
        P = self.pod_size
        if self.axis_size is not None \
                and int(self.axis_size) * P != d:
            raise ValueError(
                f"all_to_all payload has {d} rows but the channel's "
                f"group size is {int(self.axis_size) * P} "
                f"(axis_size={self.axis_size}, pod_size={P})")
        assert d % P == 0, (d, P)
        row = x.reshape(d, -1)
        n = row.shape[1]
        t = self.resolved_transport(n, axis_size=d // P, is_a2a=True)
        pad = (-n) % (t.hop_chunks * self.cfg.chunk_symbols)
        if pad:
            row = jnp.pad(row, ((0, 0), (0, pad)))
        out = tr.exchange_all_to_all(
            row, axis, self.tables, self.cfg, t, d // P,
            emit_hist=with_hist, **self._pod_kw())
        vals, ok = out[0], out[1]
        if with_hist:
            return vals[:, :n].reshape(x.shape), ok, out[2]
        return vals[:, :n].reshape(x.shape), ok

    # ---- autotune (ROADMAP: autotuned hop size) -------------------------

    def autotune(self, payload_bytes: int, *, is_reduce: bool = False,
                 probe_symbols: int = 1 << 15, repeats: int = 3,
                 model: Optional[AlphaBetaModel] = None,
                 mesh=None, axis_link: str = "ici",
                 wire_probe_bytes: int = 1 << 22) -> "Channel":
        """Measure decode throughput (and, with a ``mesh``, per-axis
        wire bandwidth), pick the transport for a ``payload_bytes``
        per-shard unit, cache it, and return the tuned channel.

        The decode measurement is the ``benchmarks/transport_overlap``
        beta_decode probe (:func:`measure_decode_Bps`) run on a
        representative payload of THIS channel's codec (symbols sampled
        from its calibration histogram). With ``mesh`` given, each of
        the channel's axes is additionally wire-probed with one timed
        ppermute (:func:`measure_wire_Bps`) — the local axis as the
        ``axis_link`` class (``"ici"`` by default; pass ``"dcn"`` for
        a flat channel bound directly on the slow axis), the pod axis
        as ``"dcn"`` — and the
        measured constants land in the registry's link cache
        (``cache_link_constants``), where every later
        :meth:`resolved_transport` (this channel's or any sibling's)
        folds them into the planner model; without a mesh, previously
        cached link constants are still applied.

        ``is_reduce=True`` tunes the reduce-scatter use of the channel
        — the one-shot RS is charged its per-rank accumulate
        dispatches, exactly like :meth:`resolved_transport`'s modeled
        fallback. The tuned
        :class:`~repro.comm.planner.TransportConfig` is cached in the
        channel's registry under ``(scheme_id, axis, payload bucket,
        is_reduce)`` — both caches ride the registry JSON, so a
        reloaded registry reuses the tuning and every
        ``transport="auto"`` channel bound to it resolves to the
        cached config without re-measuring.
        """
        axis = self._require_axis()
        if self.axis_size is None:
            raise ValueError("autotune needs the static axis_size")
        d = int(self.axis_size)
        P = self.pod_size
        counts = None if self.entry is None else self.entry.counts
        decode_Bps, _ = measure_decode_Bps(
            self.tables, self.cfg, probe_symbols, counts=counts,
            repeats=repeats)
        if mesh is not None:
            for ax, link in ((axis, axis_link),
                             (self.spec.pod_axis, "dcn")):
                if ax is None or ax not in mesh.shape \
                        or int(mesh.shape[ax]) < 2:
                    continue
                wire_Bps, _ = measure_wire_Bps(
                    mesh, ax, wire_probe_bytes, repeats=repeats)
                if self.registry is not None:
                    self.registry.cache_link_constants(
                        ax, link, wire_Bps=wire_Bps)
        base = model or self.model or AlphaBetaModel()
        tuned_model = dataclasses.replace(
            self._linked_model(base), decode_Bps=decode_Bps)
        n_values = max(1, int(payload_bytes) // 4)
        t = choose_transport(
            self.modeled_wire_bytes(n_values), float(payload_bytes), d,
            model=tuned_model, pod_size=P,
            n_oneshot_decode_dispatches=d * P if is_reduce else 1)
        if self.registry is not None and self.entry is not None:
            self.registry.cache_transport(
                self.entry.scheme_id, axis, int(payload_bytes), t,
                is_reduce=is_reduce)
        return self.replace(transport=t)


def measure_decode_Bps(tables, cfg, n_symbols: int, *, counts=None,
                       repeats: int = 3, seed: int = 0
                       ) -> Tuple[float, float]:
    """Measure this host's fused decode→dequantize throughput.

    Times the jitted decompress of a payload whose symbols are sampled
    from ``counts`` (the codec's calibration histogram; uniform when
    omitted) — the beta_decode constant of the planner's
    :class:`~repro.comm.planner.AlphaBetaModel`, in decoded f32 value
    bytes per second. Returns ``(decode_Bps, seconds_per_call)``.
    Shared by ``Channel.autotune`` and ``benchmarks/transport_overlap``.
    """
    from repro.quant import e4m3
    k = cfg.chunk_symbols
    m = max(1, int(n_symbols) // k) * k
    rng = np.random.default_rng(seed)
    if counts is None:
        counts = np.ones(256, np.float64)
    pmf = np.maximum(np.asarray(counts, np.float64).reshape(256), 0.0)
    pmf = pmf / pmf.sum()
    syms = rng.choice(256, size=m, p=pmf).astype(np.uint8)
    x = jnp.asarray(np.asarray(e4m3.e4m3_decode(jnp.asarray(syms)),
                               np.float32))
    payload, scales = comp._compress_values(x, tables, cfg)

    dec = jax.jit(
        lambda p, s: comp._decompress_values(p, s, tables, cfg)[0])
    jax.block_until_ready(dec(payload, scales))           # compile
    best = math.inf
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(dec(payload, scales))
        best = min(best, time.perf_counter() - t0)
    return 4.0 * m / best, best


def measure_wire_Bps(mesh, axis: str, payload_bytes: int = 1 << 22, *,
                     repeats: int = 3) -> Tuple[float, float]:
    """Measure per-hop wire bandwidth over one mesh axis.

    Times a jitted single-hop neighbor ``ppermute`` of a
    ``payload_bytes`` per-device f32 buffer over ``axis`` — the
    alpha-beta model's per-link-class beta_wire constant, in payload
    bytes per second per device. This is how ``Channel.autotune``
    learns that the pod (DCN) axis is slower than the local (ICI) one
    instead of assuming the class defaults in ``roofline.hw``. Returns
    ``(wire_Bps, seconds_per_hop)``.

    On a simulated multi-host mesh (fake CPU devices) the number is a
    memcpy rate, not a network rate — meaningful for exercising the
    plumbing, not for real tuning.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.parallel import sharding as shd
    d = int(mesh.shape[axis])
    if d < 2:
        raise ValueError(f"axis {axis!r} has size {d}; nothing to probe")
    n = max(1, int(payload_bytes) // 4)
    perm = [(j, (j + 1) % d) for j in range(d)]
    spec = PartitionSpec(axis)
    hop = jax.jit(shd.shard_map_compat(
        lambda a: jax.lax.ppermute(a, axis, perm),
        mesh=mesh, in_specs=spec, out_specs=spec))
    x = jax.device_put(jnp.zeros((d, n), jnp.float32),
                       NamedSharding(mesh, spec))
    jax.block_until_ready(hop(x))                         # compile
    best = math.inf
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(hop(x))
        best = min(best, time.perf_counter() - t0)
    return 4.0 * n / best, best


def open_channels(registry, mesh=None, spec_overrides=None, *,
                  axis: Optional[str] = None,
                  pod_axis: Optional[str] = None,
                  transport: Any = None,
                  use_kernels: Optional[bool] = None,
                  model: Optional[AlphaBetaModel] = None
                  ) -> Dict[str, "Channel"]:
    """Open one :class:`Channel` per registry tensor type.

    Returns ``{name: Channel}`` for every registered name. Defaults
    (``axis``/``pod_axis``/``transport``/``use_kernels``) apply to all
    channels; ``spec_overrides`` maps names to a :class:`ChannelSpec`
    (or a dict of ChannelSpec kwargs) overriding them per type.
    ``axis_size`` / ``pod_axis_size`` are filled in from
    ``mesh.shape`` whenever a spec names an axis without a size.

        channels = open_channels(reg, mesh, axis="data",
                                 transport="auto",
                                 spec_overrides={"params":
                                     {"transport": "oneshot"}})
        seg, valid, ok = channels["grads"].reduce_scatter(g)
    """
    overrides = dict(spec_overrides or {})
    out = {}
    for name in registry.names():
        spec = overrides.get(name)
        if spec is None:
            spec = ChannelSpec(codec=name, transport=transport, axis=axis,
                               pod_axis=pod_axis, use_kernels=use_kernels)
        elif isinstance(spec, dict):
            kw = dict(codec=name, transport=transport, axis=axis,
                      pod_axis=pod_axis, use_kernels=use_kernels)
            kw.update(spec)
            spec = ChannelSpec(**kw)
        elif not isinstance(spec, ChannelSpec):
            raise TypeError(f"spec_overrides[{name!r}] must be a "
                            f"ChannelSpec or dict, got {type(spec).__name__}")
        if spec.codec is None:
            spec = dataclasses.replace(spec, codec=name)
        if spec.axis is not None and spec.axis_size is None \
                and mesh is not None and spec.axis in mesh.shape:
            spec = dataclasses.replace(spec,
                                       axis_size=int(mesh.shape[spec.axis]))
        if spec.pod_axis is not None and spec.pod_axis_size is None \
                and mesh is not None and spec.pod_axis in mesh.shape:
            spec = dataclasses.replace(
                spec, pod_axis_size=int(mesh.shape[spec.pod_axis]))
        out[name] = Channel(spec, registry=registry, model=model)
    return out


# --------------------------------------------------------------------------
# ChannelSpec JSON (manifest round-trip for serving handoff)
# --------------------------------------------------------------------------

def transport_to_json(transport):
    """Transport policy -> JSON-able form (inverse of
    :func:`transport_from_json`)."""
    if transport is None:
        return None
    if isinstance(transport, str):
        return transport
    if isinstance(transport, TransportConfig):
        return {"kind": transport.kind, "hop_chunks": transport.hop_chunks}
    raise TypeError(f"bad transport spec: {transport!r}")


def transport_from_json(d):
    if d is None or isinstance(d, str):
        return d
    return TransportConfig(kind=d["kind"],
                           hop_chunks=int(d.get("hop_chunks", 1)))


def spec_to_json(spec: ChannelSpec) -> Dict:
    """Placement/policy fields of a spec as JSON (the codec itself
    travels separately — registry JSON / container headers)."""
    out = {
        "transport": transport_to_json(spec.transport),
        "axis": spec.axis,
        "axis_size": spec.axis_size,
        "use_kernels": spec.use_kernels,
        "enabled": spec.enabled,
        "scale_dtype": spec.scale_dtype,
    }
    # Only emitted when bound, so flat-channel manifests keep their
    # pre-pod shape byte for byte.
    if spec.pod_axis is not None:
        out["pod_axis"] = spec.pod_axis
        out["pod_axis_size"] = spec.pod_axis_size
    return out


def spec_from_json(d: Dict, codec=None, cfg=None) -> ChannelSpec:
    return ChannelSpec(
        codec=codec, cfg=cfg,
        transport=transport_from_json(d.get("transport")),
        axis=d.get("axis"),
        axis_size=(None if d.get("axis_size") is None
                   else int(d["axis_size"])),
        pod_axis=d.get("pod_axis"),
        pod_axis_size=(None if d.get("pod_axis_size") is None
                       else int(d["pod_axis_size"])),
        use_kernels=d.get("use_kernels"),
        enabled=d.get("enabled"),
        scale_dtype=d.get("scale_dtype"),
    )
