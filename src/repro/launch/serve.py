"""Production serving launcher: batched greedy generation.

``--wire qlc`` serves from QLC-compressed weights: the parameter stack
is stored as block-32 e4m3 + QLC words and opened in-graph through a
channel-bound fused decode (``repro.comm.channel`` + the serving wire
codec) — the production path where weight bytes move compressed.

Example:
  python -m repro.launch.serve --arch musicgen-medium --reduced \\
      --batch 8 --new-tokens 32 --wire qlc
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import init_params
from repro.parallel import sharding as shd
from repro.serving import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--wire", default="none", choices=["none", "qlc"],
                    help="'qlc' stores weights as QLC wire and decodes "
                         "them in-graph via a bound channel")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        import dataclasses
        cfg = make_reduced(cfg)
        cfg = dataclasses.replace(cfg, frontend=None,
                                  frontend_prefix_len=0)
        mesh = make_test_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    with shd.use_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        serve_cfg = ServeConfig(
            max_seq_len=args.prompt_len + args.new_tokens + 8,
            max_new_tokens=args.new_tokens)
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size)
        if args.wire == "qlc":
            from repro.comm.calibrate import histogram_of_tree
            from repro.core import CodecRegistry
            from repro.serving import (compress_params_for_serving,
                                       open_params)
            reg = CodecRegistry()
            reg.register("default", histogram_of_tree(params))
            wired, wc = compress_params_for_serving(params, reg)
            ch = wc.channel()          # local open, fused kernel decode
            print(f"weight wire: {len(wc.meta)} compressed leaves, "
                  f"channel {ch}")
            gen = jax.jit(lambda w, pr: generate(
                open_params(w, wc, channel=ch), cfg, pr, serve_cfg))
            params = wired
        else:
            gen = jax.jit(lambda p, pr: generate(p, cfg, pr, serve_cfg))
        out = jax.block_until_ready(gen(params, prompts))
        t0 = time.time()
        out = jax.block_until_ready(gen(params, prompts))
        dt = time.time() - t0

    print(f"{args.batch}x{args.new_tokens} tokens in {dt*1e3:.0f}ms "
          f"({args.batch * args.new_tokens / dt:.0f} tok/s)")
    print("first sequence:", np.asarray(out[0])[:16])


if __name__ == "__main__":
    main()
