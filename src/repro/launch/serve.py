"""Production serving launcher: batched greedy generation.

``--wire qlc`` serves from QLC-compressed weights: the parameter stack
is stored as block-32 e4m3 + QLC words and opened in-graph through a
channel-bound fused decode (``repro.comm.channel`` + the serving wire
codec) — the production path where weight bytes move compressed.

``--kv-cache qlc`` block-pages the decode states through the
compressed KV cache (``repro.serving.kv_cache``): per-layer codecs
calibrated from a prefill snapshot, blocks encoded to QLC containers
on eviction, decoded on access — losslessly, so tokens match the
dense cache. ``--kv-block`` sets the block size.

Example:
  python -m repro.launch.serve --arch musicgen-medium --reduced \\
      --batch 8 --new-tokens 32 --wire qlc --kv-cache qlc
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import init_params
from repro.parallel import sharding as shd
from repro.serving import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--wire", default="none", choices=["none", "qlc"],
                    help="'qlc' stores weights as QLC wire and decodes "
                         "them in-graph via a bound channel")
    ap.add_argument("--kv-cache", default="none",
                    choices=["none", "qlc", "e4m3"],
                    help="page decode states through QLC containers "
                         "('qlc' lossless, 'e4m3' quantized)")
    ap.add_argument("--kv-block", type=int, default=128,
                    help="tokens per paged-cache block")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        import dataclasses
        cfg = make_reduced(cfg)
        cfg = dataclasses.replace(cfg, frontend=None,
                                  frontend_prefix_len=0)
        mesh = make_test_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    with shd.use_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        serve_cfg = ServeConfig(
            max_seq_len=args.prompt_len + args.new_tokens + 8,
            max_new_tokens=args.new_tokens)
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size)
        if args.wire == "qlc":
            from repro.comm.calibrate import histogram_of_tree
            from repro.core import CodecRegistry
            from repro.serving import (compress_params_for_serving,
                                       open_params)
            reg = CodecRegistry()
            reg.register("default", histogram_of_tree(params))
            wired, wc = compress_params_for_serving(params, reg)
            ch = wc.channel()          # local open, fused kernel decode
            print(f"weight wire: {len(wc.meta)} compressed leaves, "
                  f"channel {ch}")
            gen = jax.jit(lambda w, pr: generate(
                open_params(w, wc, channel=ch), cfg, pr, serve_cfg))
            params = wired
        else:
            gen = jax.jit(lambda p, pr: generate(p, cfg, pr, serve_cfg))
        out = jax.block_until_ready(gen(params, prompts))
        t0 = time.time()
        out = jax.block_until_ready(gen(params, prompts))
        dt = time.time() - t0

        if args.kv_cache != "none":
            from repro.core import CodecRegistry
            from repro.models import init_decode_states
            from repro.serving import (KVCacheSpec, PagedKVCache,
                                       calibrate_cache, generate_paged,
                                       prefill)
            dense_params = (params if args.wire != "qlc"
                            else jax.jit(lambda w: open_params(
                                w, wc, channel=ch))(params))
            states = init_decode_states(cfg, args.batch,
                                        serve_cfg.max_seq_len)
            _, states = prefill(dense_params, cfg, prompts, states)
            kv_reg = reg if args.wire == "qlc" else CodecRegistry()
            spec = KVCacheSpec(block_tokens=args.kv_block,
                               mode=args.kv_cache)
            calibrate_cache(kv_reg, cfg, states, args.prompt_len, spec)
            cache = PagedKVCache(spec, cfg, kv_reg)
            paged = generate_paged(dense_params, cfg, prompts, serve_cfg,
                                   cache)
            stats = cache.stats()
            print(f"kv-cache={args.kv_cache}: "
                  f"{stats['compressed_bytes_per_token']:.0f} vs "
                  f"{stats['dense_bytes_per_token']:.0f} dense B/token "
                  f"(ratio {stats['compressed_vs_dense_ratio']:.3f})")
            if args.kv_cache == "qlc":
                dense = generate_paged(dense_params, cfg, prompts,
                                       serve_cfg, None)
                assert np.array_equal(np.asarray(paged),
                                      np.asarray(dense)), \
                    "qlc KV cache must be token-identical"

    print(f"{args.batch}x{args.new_tokens} tokens in {dt*1e3:.0f}ms "
          f"({args.batch * args.new_tokens / dt:.0f} tok/s)")
    print("first sequence:", np.asarray(out[0])[:16])


if __name__ == "__main__":
    main()
