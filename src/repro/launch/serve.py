"""Production serving launcher: continuous-batching request engine.

Requests go through ``repro.serving.Engine`` (PR 6): submit
``GenerationRequest``s, drive ``step()``, ``poll()`` the tokens. The
engine owns one padded decode batch that requests join and leave
mid-flight — the legacy one-``generate``-call-per-batch path is gone
from the launcher (the deprecated wrappers remain in ``repro.serving``
for callers mid-migration).

``--wire qlc`` serves from QLC-compressed weights: the parameter stack
is stored as block-32 e4m3 + QLC words and opened through a
channel-bound fused decode (``repro.comm.channel`` + the serving wire
codec) before the engine starts — the production path where weight
bytes move compressed.

``--kv-cache qlc`` block-pages every resident sequence's decode states
through ONE shared compressed block pool
(``repro.serving.BlockPool``): per-layer codecs calibrated lazily from
the first prefill, blocks encoded to QLC containers on eviction,
decoded from the (prefix-deduped) pooled bytes on access — losslessly,
so tokens match the dense run. ``--kv-block`` sets the block size.

Example:
  python -m repro.launch.serve --arch musicgen-medium --reduced \\
      --batch 8 --new-tokens 32 --wire qlc --kv-cache qlc
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import init_params
from repro.parallel import sharding as shd
from repro.serving import BlockPool, Engine, GenerationRequest, KVCacheSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="engine slots (max concurrent sequences)")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests to submit (default: batch + 2)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--wire", default="none", choices=["none", "qlc"],
                    help="'qlc' stores weights as QLC wire and decodes "
                         "them through a bound channel")
    ap.add_argument("--kv-cache", default="none",
                    choices=["none", "qlc", "e4m3"],
                    help="page decode states through a shared compressed "
                         "block pool ('qlc' lossless, 'e4m3' quantized)")
    ap.add_argument("--kv-block", type=int, default=128,
                    help="tokens per paged-cache block")
    ap.add_argument("--kv-paging", default="sync",
                    choices=["sync", "async"],
                    help="'async' keeps evicted blocks in a device-"
                         "resident arena and decodes them via DMA "
                         "prefetch under a jitted window scan "
                         "(requires --kv-cache qlc)")
    args = ap.parse_args()
    if args.kv_paging == "async" and args.kv_cache != "qlc":
        ap.error("--kv-paging async requires --kv-cache qlc")
    n_req = args.requests or args.batch + 2

    cfg = get_config(args.arch)
    if args.reduced:
        import dataclasses
        cfg = make_reduced(cfg)
        cfg = dataclasses.replace(cfg, frontend=None,
                                  frontend_prefix_len=0)
        mesh = make_test_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    with shd.use_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        max_seq_len = args.prompt_len + args.new_tokens + 8
        if args.wire == "qlc":
            from repro.comm.calibrate import histogram_of_tree
            from repro.core import CodecRegistry
            from repro.serving import (compress_params_for_serving,
                                       open_params)
            reg = CodecRegistry()
            reg.register("default", histogram_of_tree(params))
            wired, wc = compress_params_for_serving(params, reg)
            ch = wc.channel()          # local open, fused kernel decode
            print(f"weight wire: {len(wc.meta)} compressed leaves, "
                  f"channel {ch}")
            params = jax.jit(
                lambda w: open_params(w, wc, channel=ch))(wired)

        kv_spec = pool = None
        if args.kv_cache != "none":
            kv_spec = KVCacheSpec(
                block_tokens=args.kv_block, mode=args.kv_cache,
                # async needs compile-time container offsets
                exact_capacity=args.kv_paging != "async")
            pool = BlockPool(1 << 30)
        eng = Engine(params, cfg, max_seq_len=max_seq_len,
                     max_batch=args.batch, kv_spec=kv_spec, pool=pool,
                     kv_paging=args.kv_paging,
                     mesh=mesh if not args.reduced else None)

        prompts = np.asarray(jax.random.randint(
            jax.random.PRNGKey(1), (n_req, args.prompt_len), 0,
            cfg.vocab_size))
        t0 = time.time()
        handles = [eng.submit(GenerationRequest(
            prompt=p, max_new_tokens=args.new_tokens)) for p in prompts]
        eng.run()
        dt = time.time() - t0
        outs = [eng.poll(h) for h in handles]
        assert all(s.state == "finished" for s in outs), \
            [(s.request_id, s.state, s.error) for s in outs]

        st = eng.stats()
        if args.kv_cache == "qlc":
            # the lossless contract: pooled compressed paging is
            # token-identical to a dense single-request run
            solo = Engine(params, cfg, max_seq_len=max_seq_len,
                          max_batch=1)
            h = solo.submit(GenerationRequest(
                prompt=prompts[0], max_new_tokens=args.new_tokens))
            solo.run()
            assert np.array_equal(outs[0].tokens, solo.poll(h).tokens), \
                "qlc KV cache must be token-identical"
            ps = st["pool"]
            print(f"kv-cache=qlc: peak {ps['peak_referenced_bytes']} "
                  f"compressed B pinned vs "
                  f"{st['peak_dense_logical_bytes']} dense B, "
                  f"{ps['dedup_hits']} dedup hits")
            if args.kv_paging == "async":
                pf = st["prefetch"]
                print(f"async paging: {st['async']['windows']} windows, "
                      f"prefetch {pf['hits']}/{pf['scheduled']} hits, "
                      f"{pf['stalled']} stalled, "
                      f"overlap {pf['overlap_fraction']:.3f}")

    toks = sum(len(s.tokens) for s in outs)
    print(f"{n_req} requests / {toks} tokens in {dt*1e3:.0f}ms "
          f"({st['ms_per_token_prefill']:.1f} ms/tok prefill, "
          f"{st['ms_per_token_decode']:.1f} ms/tok decode)")
    print("first sequence:", np.asarray(outs[0].tokens)[:16])


if __name__ == "__main__":
    main()
