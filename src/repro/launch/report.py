"""Render EXPERIMENTS.md roofline/dry-run tables from results/dryrun."""
from __future__ import annotations

import glob
import json
import os
import sys


def load(out_dir: str):
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def fmt_bytes(x):
    if x is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}EB"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(cells, mesh_filter="single_pod_16x16",
                   comm="baseline") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | bound | "
        "useful/HLO | roofline frac | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if not c.get("ok") or c.get("mesh") != mesh_filter:
            continue
        if c.get("comm", "baseline") != comm:
            continue
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant'][:4]}** | {r['useful_flops_fraction']:.3f} | "
            f"{r['roofline_fraction']:.4f} | "
            f"{fmt_bytes(r.get('peak_memory_per_device'))} |")
    return "\n".join(rows)


def dryrun_table(cells) -> str:
    rows = [
        "| arch | shape | mesh | compile | args/dev | temp/dev | "
        "coll bytes/dev | dominant coll |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if not c.get("ok") or c.get("comm", "baseline") != "baseline":
            continue
        r = c["roofline"]
        mem = c.get("memory", {})
        br = r.get("coll_breakdown") or {}
        top = max(br, key=br.get) if br else "-"
        mesh_short = "2x16x16" if "multi" in c["mesh"] else "16x16"
        rows.append(
            f"| {c['arch']} | {c['shape']} | {mesh_short} | "
            f"{c.get('compile_s', '-')}s | "
            f"{fmt_bytes(mem.get('argument_size_in_bytes'))} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
            f"{fmt_bytes(r['coll_bytes_per_device'])} | {top} |")
    return "\n".join(rows)


def summary(cells) -> str:
    n_ok = sum(1 for c in cells if c.get("ok"))
    per_mesh = {}
    for c in cells:
        key = (c.get("mesh"), bool(c.get("ok")))
        per_mesh[key] = per_mesh.get(key, 0) + 1
    return (f"{n_ok}/{len(cells)} cells compiled. "
            + "; ".join(f"{m}: {'ok' if ok else 'FAIL'}x{n}"
                        for (m, ok), n in sorted(per_mesh.items())))


if __name__ == "__main__":
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    cells = load(out_dir)
    print(summary(cells))
    print("\n## Roofline (single pod)\n")
    print(roofline_table(cells))
    print("\n## Dry-run\n")
    print(dryrun_table(cells))
