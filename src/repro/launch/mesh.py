"""Production mesh definitions.

A function (never a module-level constant) so importing this module
never touches jax device state. Single pod = 256 chips as (16 data,
16 model); multi-pod adds a leading "pod" axis (2 pods = 512 chips).
The "pod" axis is the DCN tier: the hierarchical transport
(``ChannelSpec(pod_axis="pod")``) rings over "data" within a pod and
bridges pods with one compressed exchange per hop group.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pods: int = None):
    """The 256-chip single-pod mesh, or a pod-major multi-pod one.

    ``pods`` sets the leading "pod" axis size explicitly (``--pods``);
    ``multi_pod`` is the legacy 2-pod switch. Device order is pod-major
    so the combined (pod, data) rank ``q * 16 + l`` matches the
    channel layer's pod-major convention.
    """
    if pods is None:
        pods = 2 if multi_pod else 1
    shape = (pods, 16, 16) if pods > 1 else (16, 16)
    axes = ("pod", "data", "model") if pods > 1 else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, devices=None, model: int = 2, pods: int = 1):
    """Small mesh over whatever devices exist (tests/examples).

    ``pods > 1`` simulates a multi-host topology on fake devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``): the
    device grid gains a leading "pod" axis, e.g. 8 CPU devices with
    ``pods=2, model=2`` make a (2, 2, 2) pod x data x model mesh.
    """
    import numpy as np
    devs = devices if devices is not None else jax.devices()
    n = len(devs)
    model = min(model, n)
    pods = max(1, int(pods))
    data = n // (model * pods)
    if data < 1:
        raise ValueError(
            f"{n} devices cannot shape a pods={pods} x model={model} "
            "mesh with a non-empty data axis")
    if pods > 1:
        return jax.sharding.Mesh(
            np.array(devs[:pods * data * model]).reshape(
                pods, data, model),
            ("pod", "data", "model"))
    return jax.sharding.Mesh(
        np.array(devs[:data * model]).reshape(data, model),
        ("data", "model"))
