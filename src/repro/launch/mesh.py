"""Production mesh definitions.

A function (never a module-level constant) so importing this module
never touches jax device state. Single pod = 256 chips as (16 data,
16 model); multi-pod adds a leading "pod" axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, devices=None, model: int = 2):
    """Small mesh over whatever devices exist (tests/examples)."""
    import numpy as np
    devs = devices if devices is not None else jax.devices()
    n = len(devs)
    model = min(model, n)
    data = n // model
    return jax.sharding.Mesh(
        np.array(devs[:data * model]).reshape(data, model),
        ("data", "model"))
