import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST precede every other import (jax locks the
# device count at first init). 512 placeholder CPU devices back the
# production meshes: 16x16 single pod, 2x16x16 multi-pod.

import argparse          # noqa: E402
import gzip              # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ASSIGNED, get_config, shapes_for)  # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig       # noqa: E402
from repro.data.synthetic import input_shape_structs          # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.models import (decode_states_specs, decode_step,   # noqa: E402
                          init_decode_states, init_params,
                          param_specs, prefill_logits)
from repro.parallel import sharding as shd                    # noqa: E402
from repro.roofline import analysis                           # noqa: E402
from repro.training import (OptConfig, TrainConfig,           # noqa: E402
                            make_baseline_step,
                            make_compressed_step,
                            init_compressed_opt_state)
from repro.training import optimizer as optm                  # noqa: E402


def cell_rules(cfg: ModelConfig, shape: ShapeConfig, mesh) -> shd.ShardingRules:
    """Per-cell sharding rules (DESIGN.md: rules, not model code, change
    with the layout)."""
    extra = {}
    fsdp = True
    if shape.kind == "decode" and cfg.serve_params_tp_only:
        fsdp = False
    if shape.kind == "decode":
        model_size = mesh.shape["model"]
        if cfg.num_kv_heads % model_size != 0:
            # GQA kv heads don't divide TP: shard the cache sequence dim
            # instead (flash-decode style partial attention + combine).
            extra["kv_seq"] = "model"
        if shape.global_batch == 1:
            # long-context: batch can't shard; spread cache over dp too.
            extra["kv_seq"] = ("data", "model")
            extra["batch"] = None
    return shd.make_rules(fsdp_params=fsdp, extra=extra)


def _param_sds(cfg: ModelConfig, mesh):
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    specs = param_specs(cfg)
    rules = shd.get_rules()

    def mk(leaf, spec):
        ns = NamedSharding(mesh, rules.spec(spec, shape=leaf.shape,
                                            param=True))
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=ns)

    return _tree_mk(shapes, specs, mk)


def _tree_mk(shapes, specs, mk):
    flat_shapes, treedef = jax.tree.flatten(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=shd.is_spec_leaf)
    assert len(flat_shapes) == len(flat_specs), (
        len(flat_shapes), len(flat_specs))
    return jax.tree.unflatten(
        treedef, [mk(l, s) for l, s in zip(flat_shapes, flat_specs)])


def _batch_sds(cfg: ModelConfig, shape: ShapeConfig, mesh):
    structs = input_shape_structs(
        cfg.vocab_size, shape.seq_len, shape.global_batch,
        prefix_len=cfg.frontend_prefix_len, d_model=cfg.d_model,
        dtype=jnp.dtype(cfg.dtype))
    rules = shd.get_rules()

    def mk(leaf):
        spec = rules.spec(("batch",) + (None,) * (len(leaf.shape) - 1),
                          shape=leaf.shape)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return {k: mk(v) for k, v in structs.items()}


def _microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.axis_names]))
    local_b = max(1, shape.global_batch // dp)
    # target <= 2 sequences per microbatch per rank for the 4k trains
    n = max(1, min(local_b, local_b // 2))
    while local_b % n:
        n -= 1
    return n


def build_lowering(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   comm: str = "baseline"):
    """Returns (jitted, example_args) ready to .lower()."""
    rules = cell_rules(cfg, shape, mesh)
    shd.set_rules(rules)

    if shape.kind == "train":
        opt_cfg = OptConfig(moment_dtype="bfloat16")
        train_cfg = TrainConfig(microbatches=_microbatches(cfg, shape, mesh))
        params_sds = _param_sds(cfg, mesh)
        batch_sds = _batch_sds(cfg, shape, mesh)
        if comm in ("qlc", "e4m3"):
            from repro.comm import CommConfig, plan_for_tables
            from repro.core import TABLE1, build_tables, distributions
            counts = distributions.grad_counts(1 << 20)
            tables = build_tables(counts, TABLE1)
            plan = plan_for_tables(tables, counts, chunk_symbols=1024)
            comm_cfg = CommConfig.from_plan(plan)
            if comm == "e4m3":
                comm_cfg = dataclasses.replace(comm_cfg, enabled=False)
            # compressed mode: params dp-replicated (TP only)
            shd.set_rules(shd.make_rules(fsdp_params=False))
            params_sds = _param_sds(cfg, mesh)
            step = make_compressed_step(cfg, opt_cfg, train_cfg, mesh,
                                        tables, comm_cfg)
            opt_shapes = jax.eval_shape(
                lambda: init_compressed_opt_state(
                    cfg, mesh, train_cfg, comm_cfg, opt_cfg))
            dp_axes = tuple(a for a in ("pod", "data")
                            if a in mesh.axis_names)
            opt_sds = {
                "m": jax.ShapeDtypeStruct(
                    opt_shapes["m"].shape, opt_shapes["m"].dtype,
                    sharding=NamedSharding(
                        mesh, P(*(dp_axes + ("model", None))))),
                "v": jax.ShapeDtypeStruct(
                    opt_shapes["v"].shape, opt_shapes["v"].dtype,
                    sharding=NamedSharding(
                        mesh, P(*(dp_axes + ("model", None))))),
                "step": jax.ShapeDtypeStruct(
                    (), jnp.int32, sharding=NamedSharding(mesh, P())),
            }
        else:
            step = make_baseline_step(cfg, opt_cfg, train_cfg)
            opt_shapes = jax.eval_shape(
                lambda p: optm.init_state(p, opt_cfg), params_sds)
            specs = param_specs(cfg)
            rules_ = shd.get_rules()

            def mk_opt(leaf, spec):
                ns = NamedSharding(mesh, rules_.spec(
                    spec, shape=leaf.shape, param=True))
                return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                            sharding=ns)

            opt_sds = {
                "m": _tree_mk(opt_shapes["m"], specs, mk_opt),
                "v": _tree_mk(opt_shapes["v"], specs, mk_opt),
                "step": jax.ShapeDtypeStruct(
                    (), jnp.int32, sharding=NamedSharding(mesh, P())),
            }
        return jax.jit(step), (params_sds, opt_sds, batch_sds)

    if shape.kind == "prefill":
        params_sds = _param_sds(cfg, mesh)
        batch_sds = _batch_sds(cfg, shape, mesh)

        def prefill_step(params, batch):
            return prefill_logits(params, cfg, batch["tokens"],
                                  batch.get("prefix_emb"))

        return jax.jit(prefill_step), (params_sds, batch_sds)

    # decode: one new token against a seq_len-deep cache/state
    params_sds = _param_sds(cfg, mesh)
    weight_codec = None
    if comm in ("qlc", "e4m3"):
        # paper technique on serving: weight gathers move QLC/e4m3 wire
        from repro.comm import plan_for_tables
        from repro.comm.weights import wire_shape_structs
        from repro.core import TABLE1, build_tables, distributions
        counts = distributions.ffn1_counts(1 << 20)
        w_tables = build_tables(counts, TABLE1)
        w_plan = plan_for_tables(w_tables, counts, chunk_symbols=1024)
        wired, weight_codec = wire_shape_structs(
            jax.eval_shape(lambda k: init_params(cfg, k),
                           jax.random.PRNGKey(0))["groups"],
            w_tables, w_plan.capacity_words, mode=comm, mesh=mesh)
        params_sds = dict(params_sds)
        params_sds["groups"] = wired
    b = shape.global_batch
    states_shapes = jax.eval_shape(
        lambda: init_decode_states(cfg, b, shape.seq_len))
    kinds_specs = decode_states_specs(cfg)
    rules_ = shd.get_rules()

    def mk_state(leaf, spec):
        ns = NamedSharding(mesh, rules_.spec(spec, shape=leaf.shape))
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=ns)

    states_sds = _tree_mk(states_shapes, kinds_specs, mk_state)
    dp_spec = rules_.spec(("batch", None), shape=(b, 1))
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32,
                                   sharding=NamedSharding(mesh, dp_spec))
    pos_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32,
                                   sharding=NamedSharding(mesh, dp_spec))

    def serve_step(params, states, tokens, positions):
        return decode_step(params, cfg, tokens, states, positions,
                           weight_codec=weight_codec)

    return (jax.jit(serve_step, donate_argnums=(1,)),
            (params_sds, states_sds, tok_sds, pos_sds))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             comm: str = "baseline", overrides: dict | None = None,
             hlo_out: str | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        moe_ov = {k[4:]: v for k, v in overrides.items()
                  if k.startswith("moe.")}
        top = {k: v for k, v in overrides.items()
               if not k.startswith("moe.")}
        if moe_ov:
            top["moe"] = dataclasses.replace(cfg.moe, **moe_ov)
        cfg = dataclasses.replace(cfg, **top)
    shape = {s.name: s for s in shapes_for(cfg)}[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    chips = int(np.prod(mesh.devices.shape))

    t0 = time.time()
    with shd.use_mesh(mesh):
        jitted, args = build_lowering(cfg, shape, mesh, comm)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        print("memory_analysis:", mem)              # proves it fits
        cost = compiled.cost_analysis()
        print("cost_analysis flops:", cost.get("flops"),
              "bytes:", cost.get("bytes accessed"))
        hlo = compiled.as_text()
        if hlo_out:
            with gzip.open(hlo_out, "wt") as f:
                f.write(hlo)

    mem_stats = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_stats[attr] = int(v)

    terms = analysis.from_compiled(arch, shape, mesh_name, chips, cost,
                                   hlo, cfg, mem_stats)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "comm": comm, "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_stats,
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed")},
        "roofline": terms.to_dict(),
        "ok": True,
    }
    shd.set_rules(None)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--comm", default="baseline",
                    choices=["baseline", "qlc", "e4m3"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (python literal)")
    ap.add_argument("--sweep", action="store_true",
                    help="run every (arch x shape) cell in subprocesses")
    ap.add_argument("--out-dir", default="results/dryrun")
    args = ap.parse_args()

    if args.sweep:
        os.makedirs(args.out_dir, exist_ok=True)
        cells = []
        for arch in ASSIGNED:
            for s in shapes_for(get_config(arch)):
                cells.append((arch, s.name))
        for arch, shape in cells:
            tag = f"{arch}__{shape}__" + (
                "multi" if args.multi_pod else "single")
            if args.comm != "baseline":
                tag += f"__{args.comm}"
            out = os.path.join(args.out_dir, tag + ".json")
            if os.path.exists(out):
                print("skip", tag)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--comm", args.comm,
                   "--out", out]
            if args.multi_pod:
                cmd.append("--multi-pod")
            print(">>>", tag, flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True,
                               env={**os.environ,
                                    "PYTHONPATH": "src"})
            if r.returncode != 0:
                err = {"arch": arch, "shape": shape, "ok": False,
                       "mesh": ("multi_pod_2x16x16" if args.multi_pod
                                else "single_pod_16x16"),
                       "comm": args.comm,
                       "error": r.stderr[-4000:]}
                with open(out, "w") as f:
                    json.dump(err, f, indent=1)
                print("FAIL", tag)
                print(r.stderr[-2000:])
            else:
                print(r.stdout[-400:])
        return

    import ast
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    hlo_out = args.out.replace(".json", ".hlo.gz") if args.out else None
    result = run_cell(args.arch, args.shape, args.multi_pod, args.comm,
                      overrides, hlo_out=hlo_out)
    result["overrides"] = overrides
    print(json.dumps({k: v for k, v in result.items()
                      if k not in ("memory",)}, indent=1, default=str))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, default=str)


if __name__ == "__main__":
    main()
