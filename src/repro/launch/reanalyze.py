"""Re-run the roofline analysis over saved .hlo.gz dumps (no recompile).
Usage: python -m repro.launch.reanalyze results/dryrun"""
import glob
import gzip
import json
import sys

from repro.configs import get_config, shapes_for
from repro.roofline import analysis


def main(out_dir: str):
    for jf in sorted(glob.glob(out_dir + "/*.json")):
        hf = jf.replace(".json", ".hlo.gz")
        try:
            d = json.load(open(jf))
            if not d.get("ok"):
                continue
            import os
            if not os.path.exists(hf):
                continue
            with gzip.open(hf, "rt") as f:
                hlo = f.read()
            cfg = get_config(d["arch"])
            shape = {s.name: s for s in shapes_for(cfg)}[d["shape"]]
            terms = analysis.from_compiled(
                d["arch"], shape, d["mesh"], d["chips"],
                d.get("cost", {}), hlo, cfg, d.get("memory"))
            d["roofline"] = terms.to_dict()
            json.dump(d, open(jf, "w"), indent=1, default=str)
            print("reanalyzed", jf)
        except Exception as e:
            print("skip", jf, e)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
