"""Production training launcher.

On a real TPU cluster this is the per-host entry point (jax distributed
init -> production mesh -> trainer). On CPU it runs reduced configs for
verification. The dry-run (``repro.launch.dryrun``) is the compile-only
counterpart for the full-size cells.

Examples:
  python -m repro.launch.train --arch deepseek-moe-16b --reduced \\
      --steps 50 --comm qlc
  python -m repro.launch.train --arch nemotron-4-340b --multi-pod \\
      --steps 100000   # real cluster
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp

from repro.comm import calibrate_for_gradients
from repro.comm.calibrate import calibrate_moe_entries, histogram_of_tree
from repro.comm.channel import Channel, ChannelSpec
from repro.configs import get_config, reduced as make_reduced
from repro.core import CodecRegistry
from repro.data import DataConfig, SyntheticDataset
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import init_params
from repro.parallel import sharding as shd
from repro.training import (OptConfig, Trainer, TrainerConfig, TrainConfig,
                            init_compressed_opt_state, make_baseline_step,
                            make_compressed_step)
from repro.training import optimizer as optm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="small same-family config (CPU verification)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--pods", type=int, default=1,
                    help="leading 'pod' (DCN-tier) mesh axis size. With "
                         "--pods N > 1 the compressed gradient wire "
                         "runs ONE pod-bound collective per phase over "
                         "the combined pod x data group (hierarchical "
                         "transport: intra-pod ring + one compressed "
                         "inter-pod bridge per hop group) instead of "
                         "the sequential per-axis collectives. On CPU, "
                         "simulate hosts with "
                         "XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--comm", default="baseline",
                    choices=["baseline", "qlc"])
    ap.add_argument("--transport", default="auto",
                    choices=["auto", "oneshot", "ring", "hierarchical"],
                    help="compressed-collective transport: 'auto' lets "
                         "the planner's per-link-class alpha-beta model "
                         "pick one-shot vs ring/hierarchical (+ hop "
                         "chunking) per collective/axis; 'hierarchical' "
                         "(with --pods > 1) forces the intra-pod ring + "
                         "inter-pod bridge schedule")
    ap.add_argument("--moe-wire", default="auto",
                    choices=["auto", "qlc", "raw"],
                    help="expert all_to_all wire for shardmap_a2a MoE "
                         "configs: 'qlc' calibrates moe/dispatch + "
                         "moe/combine codecs from the first batch's "
                         "routed traffic and sends QLC containers over "
                         "the expert axis; 'raw' sends uncompressed "
                         "activations; 'auto' follows --comm")
    ap.add_argument("--moe-transport", default="auto",
                    choices=["auto", "oneshot", "ring"],
                    help="a2a transport for the compressed MoE wire "
                         "('auto' = planner's distance-charged ring "
                         "vs one-shot choice per payload)")
    ap.add_argument("--autotune", action="store_true",
                    help="measure this host's decode throughput and "
                         "autotune the per-axis transport "
                         "(Channel.autotune); tunings are cached in the "
                         "codec registry and picked up by --transport "
                         "auto")
    ap.add_argument("--adapt", action="store_true",
                    help="online codec adaptation (--comm qlc): the "
                         "step emits fused encode-pass histograms, a "
                         "drift policy watches measured vs planned "
                         "bits/symbol, and a drifted codec is "
                         "recalibrated + hot-swapped under a new "
                         "scheme-id (repro.adaptive)")
    ap.add_argument("--adapt-every", type=int, default=10,
                    help="steps between drift checks with --adapt")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (cluster)")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    if args.distributed:
        jax.distributed.initialize()

    if args.pods < 1:
        raise SystemExit(f"--pods must be >= 1, got {args.pods}")
    if args.transport == "hierarchical" and args.pods == 1:
        raise SystemExit(
            "--transport hierarchical needs --pods > 1 (a pod axis to "
            "bridge); with one pod it would just be the ring")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
        mesh = make_test_mesh(pods=args.pods)
    else:
        mesh = make_production_mesh(
            multi_pod=args.multi_pod,
            pods=args.pods if args.pods > 1 else None)
    if args.moe_wire == "qlc" and cfg.moe is not None:
        # an explicit compressed expert wire implies real expert-
        # parallel dispatch (the other impls never touch the wire)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, impl="shardmap_a2a"))

    seq = args.seq_len or (128 if args.reduced else 4096)
    batch = args.global_batch or (8 if args.reduced else 256)

    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(10, args.steps // 20))
    train_cfg = TrainConfig(
        microbatches=args.microbatches,
        batch_axes=tuple(a for a in ("pod", "data")
                         if a in mesh.axis_names))
    data = SyntheticDataset(
        DataConfig(vocab_size=cfg.vocab_size,
                   seq_len=seq - cfg.frontend_prefix_len,
                   global_batch=batch),
        host_index=jax.process_index(), host_count=jax.process_count())

    with shd.use_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        b0 = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

        # Expert-parallel MoE wire: one calibrated codec + Channel per
        # a2a direction, bound on the expert ("model") axis.
        moe_channels = None
        moe_wire = args.moe_wire
        if moe_wire == "auto":
            moe_wire = "qlc" if args.comm == "qlc" else "raw"
        if (moe_wire == "qlc" and cfg.moe is not None
                and cfg.moe.impl == "shardmap_a2a"
                and "model" in mesh.axis_names):
            moe_registry = CodecRegistry()
            calibrate_moe_entries(moe_registry, cfg, params, b0)
            dm = int(mesh.shape["model"])
            moe_channels = {}
            for name in ("moe/dispatch", "moe/combine"):
                moe_channels[name] = Channel(
                    ChannelSpec(codec=name, transport=args.moe_transport,
                                axis="model", axis_size=dm),
                    registry=moe_registry)
                logging.info(
                    "moe codec %s: scheme-id %s, %.2f bits/sym", name,
                    moe_registry[name].scheme_id,
                    moe_registry[name].plan.expected_bits_per_symbol)

        baseline = jax.jit(make_baseline_step(
            cfg, opt_cfg, train_cfg, moe_channels=moe_channels))
        on_step = None
        if args.comm == "qlc":
            # per-tensor-type registry: the gradient reduce-scatter and
            # the parameter all-gather get separately calibrated codecs
            tables, plan = calibrate_for_gradients(cfg, params, b0)
            registry = CodecRegistry()
            registry.register_tables("grads", tables, plan)
            registry.register("params", histogram_of_tree(params),
                              chunk_symbols=plan.chunk_symbols)
            hierarchical = args.pods > 1 and "pod" in mesh.axis_names
            if args.autotune:
                _autotune_transports(registry, cfg, mesh, train_cfg,
                                     hierarchical=hierarchical)

            def build_step():
                return jax.jit(make_compressed_step(
                    cfg, opt_cfg, train_cfg, mesh, registry,
                    transport=args.transport,
                    hierarchical_wire=hierarchical,
                    moe_channels=moe_channels,
                    telemetry=args.adapt))

            step = build_step()
            opt_state = init_compressed_opt_state(
                cfg, mesh, train_cfg, registry, opt_cfg)
            if args.adapt:
                from repro.adaptive import (AdaptiveController,
                                            TrainingAdapter)
                controller = AdaptiveController(registry)
                on_step = TrainingAdapter(
                    controller, build_step,
                    grad_key="grads", param_key="params",
                    check_every=args.adapt_every,
                    on_swap=lambda ev: logging.info(
                        "codec hot-swap %s: scheme-id %d -> %d "
                        "(%.2f measured vs %.2f planned bits/sym; "
                        "new plan %.2f)", ev.name, ev.old_scheme_id,
                        ev.new_scheme_id, ev.measured_bits,
                        ev.old_expected_bits, ev.new_expected_bits))
        else:
            step = baseline
            opt_state = optm.init_state(params, opt_cfg)

        trainer = Trainer(
            TrainerConfig(total_steps=args.steps,
                          checkpoint_dir=args.checkpoint_dir),
            step, fallback_step_fn=None, on_step=on_step)
        params, opt_state, start = trainer.restore_or(params, opt_state)
        trainer.run(params, opt_state, data, start_step=start)

    losses = [h["loss"] for h in trainer.history]
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


def _autotune_transports(registry, model_cfg, mesh, train_cfg,
                         hierarchical: bool = False):
    """Autotune the step's per-axis transports into the registry.

    Builds one ``transport="auto"`` channel per (tensor type, dp axis)
    — the same binding ``make_compressed_step`` opens — and runs
    ``Channel.autotune`` at the flat-gradient payload each axis
    actually moves, probing each axis's WIRE bandwidth on the real mesh
    (``mesh=`` — one timed ppermute per axis, cached per link class in
    the registry) alongside decode throughput. The tuned
    ``TransportConfig``s land in the registry's cache, which the
    step's auto channels consult first.

    ``hierarchical=True`` mirrors the ``--pods`` wire: one POD-BOUND
    channel per tensor type over the combined pod x data group (the
    wire probe then measures both the ICI "data" hop and the DCN "pod"
    bridge) instead of per-axis flat channels.
    """
    from repro.comm.channel import Channel, ChannelSpec
    from repro.training.train_step import dp_axes_in, flat_geometry
    dp_axes = dp_axes_in(mesh, train_cfg)
    _, n_padded, _, _ = flat_geometry(
        model_cfg, mesh, train_cfg, registry["grads"].config())
    n = n_padded
    if hierarchical and "pod" in dp_axes and "data" in dp_axes:
        ld, pd = int(mesh.shape["data"]), int(mesh.shape["pod"])
        for name, is_reduce in (("grads", True), ("params", False)):
            ch = Channel(ChannelSpec(codec=name, transport="auto",
                                     axis="data", axis_size=ld,
                                     pod_axis="pod", pod_axis_size=pd),
                         registry=registry)
            tuned = ch.autotune(4 * (n // (ld * pd)),
                                is_reduce=is_reduce, mesh=mesh)
            logging.info("autotuned %s over pod x data (%d x %d): %s",
                         name, pd, ld, tuned.transport)
        return
    for ax in (a for a in ("data", "pod") if a in dp_axes):
        d = int(mesh.shape[ax])
        # grads feed the reduce-scatter (charged its per-rank
        # accumulate dispatches), params the all-gather
        for name, is_reduce in (("grads", True), ("params", False)):
            ch = Channel(ChannelSpec(codec=name, transport="auto",
                                     axis=ax, axis_size=d),
                         registry=registry)
            tuned = ch.autotune(4 * (n // d), is_reduce=is_reduce,
                                mesh=mesh,
                                axis_link="dcn" if ax == "pod" else "ici")
            logging.info("autotuned %s over %s (d=%d): %s",
                         name, ax, d, tuned.transport)
        n //= d


if __name__ == "__main__":
    main()
