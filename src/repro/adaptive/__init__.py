"""Online codec adaptation: telemetry -> drift detection -> hot-swap.

Calibration elsewhere in the repo is one-shot: a codec frozen at
startup slowly loses bits/symbol as training reshapes the e4m3
distribution. This subsystem closes the loop:

1. **Telemetry** (:class:`TrafficMonitor`): per-channel 256-bin symbol
   histograms ride the fused encode pass for free (the kernel's
   ``emit_hist`` side output — ``Channel.compress(with_hist=True)`` /
   collective ``with_hist=`` taps), accumulated per
   ``(name, scheme_id)`` together with measured bits/symbol and
   escape-pool pressure.
2. **Drift detection** (:class:`DriftPolicy`): an entry is flagged when
   its EMA'd measured bits/symbol exceeds the plan's
   ``expected_bits_per_symbol`` by more than the plan's own
   ``drift_margin_bits`` (or escape/overflow rates spike), with
   hysteresis + cooldown so noise can't thrash.
3. **Recalibration + hot-swap** (:class:`Recalibrator`,
   :class:`AdaptiveController`): off the hot path, re-run
   ``select_scheme``/``optimal_scheme``/``empirical_plan`` on the
   accumulated histogram, register the result under a NEW scheme-id
   (``CodecRegistry.register_revision``), and atomically rebind the
   affected channels. Old entries are retained, never mutated —
   containers are self-describing, so payloads written under the old
   scheme-id decode forever.
"""
from repro.adaptive.monitor import ChannelTraffic, TrafficMonitor
from repro.adaptive.drift import DriftConfig, DriftPolicy
from repro.adaptive.recalibrate import Recalibrator
from repro.adaptive.controller import (AdaptiveChannel, AdaptiveController,
                                       SwapEvent, TrainingAdapter)

__all__ = [
    "ChannelTraffic", "TrafficMonitor",
    "DriftConfig", "DriftPolicy",
    "Recalibrator",
    "AdaptiveChannel", "AdaptiveController", "SwapEvent",
    "TrainingAdapter",
]
