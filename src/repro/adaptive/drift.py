"""Drift detection over the traffic monitor's ledgers.

A binding drifts when the codec's measured bits/symbol exceeds what its
calibration plan promised by more than the plan's OWN
``drift_margin_bits`` — the same per-entry headroom the slot sizing
consumed (``empirical_plan``), so slot capacity and recalibration
trigger at a consistent threshold. Escape-pool or container-overflow
spikes trigger independently: a shifted distribution can keep its mean
code length while growing tails that blow the pool.

Noise control: the per-binding signal is EMA'd, a flag needs
``hysteresis`` consecutive over-threshold updates, and a fresh binding
(post-swap) is immune for ``cooldown`` updates — so one noisy batch
can't thrash codecs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.adaptive.monitor import TrafficMonitor


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    #: Override of the per-entry ``plan.drift_margin_bits`` threshold;
    #: None reads each entry's own intended headroom.
    margin_bits: Optional[float] = None
    #: EMA smoothing of the measured-bits signal (weight of the newest
    #: observation).
    ema_alpha: float = 0.3
    #: Minimum (decayed) symbols in the ledger before judging.
    min_symbols: float = 4096.0
    #: Minimum observations before judging.
    min_events: int = 2
    #: Escape-rate trigger: measured escape rate beyond
    #: ``factor * plan.escape_prob_bound`` flags drift on its own.
    escape_rate_factor: float = 8.0
    #: Container-overflow-rate trigger (overflows are the lossless
    #: fallback — already a paid regression, so the bar is low).
    overflow_rate_limit: float = 0.05
    #: Consecutive over-threshold updates required to flag.
    hysteresis: int = 2
    #: Updates a fresh (just-swapped) binding is immune for.
    cooldown: int = 3


@dataclasses.dataclass
class _State:
    ema_bits: Optional[float] = None
    over: int = 0
    cooldown: int = 0


class DriftPolicy:
    """Stateful per-binding drift decision over a :class:`TrafficMonitor`."""

    def __init__(self, monitor: TrafficMonitor,
                 config: DriftConfig = DriftConfig()):
        self.monitor = monitor
        self.config = config
        self._state: Dict[Tuple[str, int], _State] = {}

    def _state_for(self, name: str, sid: int) -> _State:
        return self._state.setdefault((name, sid), _State())

    def update(self, name: str) -> bool:
        """Fold the latest ledger into the EMA; True = drift flagged."""
        cfg = self.config
        entry = self.monitor.registry[name]
        t = self.monitor.traffic(name)
        st = self._state_for(name, entry.scheme_id)
        if st.cooldown > 0:
            st.cooldown -= 1
            return False
        if t is None or t.symbols < cfg.min_symbols \
                or t.events < cfg.min_events:
            return False

        measured = t.measured_bits_per_symbol(entry.tables.enc_len)
        st.ema_bits = measured if st.ema_bits is None else \
            (1 - cfg.ema_alpha) * st.ema_bits + cfg.ema_alpha * measured

        margin = cfg.margin_bits if cfg.margin_bits is not None \
            else entry.plan.drift_margin_bits
        bits_over = (st.ema_bits
                     > entry.plan.expected_bits_per_symbol + margin)
        escapes_over = (t.chunks > 0 and t.escape_rate
                        > cfg.escape_rate_factor
                        * max(entry.plan.escape_prob_bound, 1e-9))
        overflow_over = (t.containers > 0
                         and t.overflow_rate > cfg.overflow_rate_limit)

        if bits_over or escapes_over or overflow_over:
            st.over += 1
        else:
            st.over = 0
        return st.over >= cfg.hysteresis

    def notify_swapped(self, name: str):
        """Arm the post-swap cooldown on the NEW binding."""
        entry = self.monitor.registry[name]
        st = self._state_for(name, entry.scheme_id)
        st.ema_bits = None
        st.over = 0
        st.cooldown = self.config.cooldown
