"""Off-hot-path recalibration + registry hot-swap registration.

Given a drifted binding's accumulated histogram, rebuild the codec the
same way the original calibration did — ``select_scheme`` (optionally
the exhaustive quad-constrained ``optimal_scheme`` search), LUT build,
iid ``plan_for_tables`` sizing, then ``empirical_plan`` against a
synthetic stream drawn from the histogram — and register the result
under a NEW scheme-id via ``CodecRegistry.register_revision``.

Geometry contract: the revision KEEPS the old plan's ``chunk_symbols``
(jitted consumers bake the chunk grid into their geometry — ZeRO-1's
``flat_geometry``, the KV page layout), while ``capacity_words`` and
the escape pool may change; consumers that trace over the plan must
re-jit after a swap (``TrainingAdapter`` rebuilds the train step).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import adapt
from repro.comm.calibrate import empirical_plan
from repro.comm.planner import plan_for_tables


class Recalibrator:
    """Rebuilds codec + plan from measured traffic and registers it.

    ``allow_search=True`` runs the beyond-paper exhaustive scheme
    search (a few ms for 3 prefix bits — fine off the hot path);
    False restricts to the paper's Table 1/2 choice.
    """

    def __init__(self, registry, *, allow_search: bool = True,
                 target_escape_prob: float = 1e-6,
                 max_pool_slots_per_1k: Optional[int] = 64,
                 sample_symbols: int = 1 << 16, seed: int = 0):
        self.registry = registry
        self.allow_search = bool(allow_search)
        self.target_escape_prob = float(target_escape_prob)
        self.max_pool_slots_per_1k = max_pool_slots_per_1k
        self.sample_symbols = int(sample_symbols)
        self.seed = int(seed)

    def _synthetic_stream(self, counts: np.ndarray) -> np.ndarray:
        """Deterministic symbol stream matching the histogram's PMF —
        the empirical sizing input (the monitor keeps counts, not the
        raw stream; iid draw is the right null model for chunk sums
        once the mixture is already folded into the histogram)."""
        pmf = np.asarray(counts, np.float64)
        pmf = pmf / pmf.sum()
        rng = np.random.default_rng(self.seed)
        return rng.choice(256, size=self.sample_symbols,
                          p=pmf).astype(np.uint8)

    def recalibrate(self, name: str, counts: np.ndarray):
        """Histogram -> new revision entry bound to ``name``.

        Returns the (possibly unchanged — ``register_revision`` no-ops
        when recalibration converges onto the deployed codec) entry.
        """
        counts = np.asarray(counts, np.float64)
        if counts.sum() <= 0:
            raise ValueError(f"empty histogram for {name!r}")
        cur = self.registry[name]
        tables = adapt.calibrate_tables(counts,
                                        allow_search=self.allow_search)
        plan0 = plan_for_tables(
            tables, counts,
            chunk_symbols=cur.plan.chunk_symbols,
            target_escape_prob=self.target_escape_prob,
            pool_slots_per_1k=cur.plan.pool_slots_per_1k,
            drift_margin_bits=cur.plan.drift_margin_bits)
        plan = empirical_plan(
            tables, self._synthetic_stream(counts), plan0,
            chunk_symbols=cur.plan.chunk_symbols,
            target_escape_prob=self.target_escape_prob,
            max_pool_slots_per_1k=self.max_pool_slots_per_1k)
        return self.registry.register_revision(name, tables, plan,
                                               counts=counts)
