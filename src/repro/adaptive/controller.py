"""The adaptation loop: wrap channels, watch traffic, hot-swap codecs.

:class:`AdaptiveController` wires the three stages together:

    monitor (histograms)  ->  policy (drift?)  ->  recalibrator
                                                       |
    AdaptiveChannel.rebind(new entry)  <--  registry.register_revision

:class:`AdaptiveChannel` is the atomic-rebind seam for EAGER consumers
(the paged KV cache encodes per call): it forwards every attribute to
an underlying immutable ``Channel`` and swaps that reference in one
assignment — in-flight work keeps the old channel object, new calls
see the new codec. JITTED consumers (the compressed train step bakes
channels at trace time) instead rebuild their step function after a
swap — :class:`TrainingAdapter` packages that as a ``Trainer.on_step``
hook.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.adaptive.monitor import TrafficMonitor
from repro.adaptive.drift import DriftConfig, DriftPolicy
from repro.adaptive.recalibrate import Recalibrator


@dataclasses.dataclass(frozen=True)
class SwapEvent:
    """One completed hot-swap (for logs / tests)."""
    name: str
    old_scheme_id: int
    new_scheme_id: int
    measured_bits: float        # EMA'd traffic cost under the OLD codec
    old_expected_bits: float    # what the old plan promised
    new_expected_bits: float    # what the new plan promises


class AdaptiveChannel:
    """Attribute-forwarding proxy over a ``Channel`` with atomic rebind.

    Everything a ``Channel`` exposes works unchanged (``compress``,
    ``all_gather``, ``entry``, ...); ``rebind(entry)`` swaps the
    underlying channel to a new codec entry in a single reference
    assignment. Callers that captured the previous channel (or its
    tables) keep a consistent old view — entries are never mutated.
    """

    __slots__ = ("_chan",)

    def __init__(self, channel):
        object.__setattr__(self, "_chan", channel)

    @property
    def channel(self):
        """The current underlying immutable ``Channel``."""
        return self._chan

    def rebind(self, entry):
        """Atomically rebind to ``entry`` (a ``CodecEntry``)."""
        object.__setattr__(self, "_chan", self._chan.replace(codec=entry))

    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "_chan"), name)

    def __repr__(self):
        return f"AdaptiveChannel({self._chan!r})"


class AdaptiveController:
    """Owns the monitor/policy/recalibrator and the rebind fan-out.

    Usage::

        ctl = AdaptiveController(registry)
        ch = ctl.wrap(Channel(ChannelSpec(codec="kv/k"), registry=reg))
        ...
        payload, scales, hist = ch.compress(x, with_hist=True)
        ctl.observe("kv/k", hist)
        events = ctl.check()          # [] or the swaps just performed

    ``check`` runs the drift policy per observed name; a flagged name
    is recalibrated on its accumulated histogram, registered under a
    new scheme-id, and every wrapped channel bound to that name is
    atomically rebound. Old entries stay in the registry — payloads
    encoded before the swap decode forever.
    """

    def __init__(self, registry, *,
                 monitor: Optional[TrafficMonitor] = None,
                 policy: Optional[DriftPolicy] = None,
                 recalibrator: Optional[Recalibrator] = None,
                 drift: Optional[DriftConfig] = None):
        self.registry = registry
        self.monitor = monitor or TrafficMonitor(registry)
        self.policy = policy or DriftPolicy(self.monitor,
                                            drift or DriftConfig())
        self.recalibrator = recalibrator or Recalibrator(registry)
        self._channels: Dict[str, List[AdaptiveChannel]] = {}
        self.events: List[SwapEvent] = []

    # ---- binding --------------------------------------------------------

    def wrap(self, channel, name: Optional[str] = None) -> AdaptiveChannel:
        """Wrap ``channel`` for rebinding, tracked under its entry name
        (or an explicit ``name`` — the registry key swaps target)."""
        if name is None:
            if channel.entry is None:
                raise ValueError("channel has no registry entry; pass "
                                 "wrap(channel, name=...)")
            name = channel.entry.name
        ach = channel if isinstance(channel, AdaptiveChannel) \
            else AdaptiveChannel(channel)
        self._channels.setdefault(name, []).append(ach)
        return ach

    # ---- telemetry ------------------------------------------------------

    def observe(self, name: str, hist, **kw):
        """Forward one encode pass's histogram to the monitor."""
        return self.monitor.observe(name, hist, **kw)

    # ---- the loop -------------------------------------------------------

    def check(self, names=None) -> List[SwapEvent]:
        """Run drift detection (+ swap) over ``names`` (default: every
        name with traffic). Returns the swaps performed this call."""
        if names is None:
            names = self.monitor.names()
        swapped: List[SwapEvent] = []
        for name in names:
            if not self.policy.update(name):
                continue
            swapped.extend(self._swap(name))
        return swapped

    def _swap(self, name: str) -> List[SwapEvent]:
        old = self.registry[name]
        t = self.monitor.traffic(name)
        counts = np.asarray(t.counts, np.float64)
        new = self.recalibrator.recalibrate(name, counts)
        if new.scheme_id == old.scheme_id:
            # Recalibration converged onto the deployed codec: the
            # drift was a plan mis-estimate, not a codec mismatch.
            # Reset the policy so the same ledger can't re-flag
            # immediately.
            self.policy.notify_swapped(name)
            return []
        for ach in self._channels.get(name, []):
            ach.rebind(new)
        ev = SwapEvent(
            name=name,
            old_scheme_id=old.scheme_id,
            new_scheme_id=new.scheme_id,
            measured_bits=t.measured_bits_per_symbol(old.tables.enc_len),
            old_expected_bits=old.plan.expected_bits_per_symbol,
            new_expected_bits=new.plan.expected_bits_per_symbol)
        self.events.append(ev)
        self.monitor.reset(name, old.scheme_id)
        self.policy.notify_swapped(name)
        return [ev]


class TrainingAdapter:
    """``Trainer.on_step`` hook: feed step-metric histograms to the
    controller and rebuild the jitted step after a swap.

    The compressed train step captures its channels at trace time, so
    a rebind cannot reach inside the jitted function — instead the
    adapter calls ``build_step()`` (a caller closure re-running
    ``make_compressed_step`` against the updated registry) and returns
    the new step function for the trainer to install.

    ``make_compressed_step(..., telemetry=True)`` emits the grads/params
    encode histograms in the step metrics under ``"adapt/grads_hist"``
    / ``"adapt/params_hist"`` — exactly the keys consumed here.
    """

    GRADS_HIST = "adapt/grads_hist"
    PARAMS_HIST = "adapt/params_hist"

    def __init__(self, controller: AdaptiveController,
                 build_step: Callable[[], Callable], *,
                 grad_key: str = "grads", param_key: Optional[str] = None,
                 check_every: int = 10,
                 on_swap: Optional[Callable[[SwapEvent], None]] = None):
        self.controller = controller
        self.build_step = build_step
        self.grad_key = grad_key
        self.param_key = param_key
        self.check_every = max(1, int(check_every))
        self.on_swap = on_swap

    def __call__(self, step: int, metrics: dict) -> Optional[Callable]:
        c = self.controller
        if self.GRADS_HIST in metrics:
            c.observe(self.grad_key, np.asarray(metrics[self.GRADS_HIST]))
        if self.param_key is not None and self.PARAMS_HIST in metrics:
            c.observe(self.param_key,
                      np.asarray(metrics[self.PARAMS_HIST]))
        if (step + 1) % self.check_every:
            return None
        events = c.check()
        if not events:
            return None
        if self.on_swap is not None:
            for ev in events:
                self.on_swap(ev)
        return self.build_step()
