"""Traffic telemetry: accumulate encode-pass histograms per channel.

The fused Pallas encode already counts symbols (``emit_hist`` — the
symbols are in registers anyway), so observing a channel costs one
i32[256] device->host transfer per observation, nothing on the hot
path. The monitor turns those raw histograms into the quantities the
drift policy consumes: measured bits/symbol under the DEPLOYED codec,
escape-chunk rate, and container-overflow rate, all per
``(name, scheme_id)`` so a hot-swap naturally starts a fresh ledger.

Accumulation is exponentially decayed (per observation), so after a
distribution shift the old phase's mass washes out and a recalibration
on :attr:`ChannelTraffic.counts` converges to the NEW distribution
instead of a stale mixture.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

NUM_SYMBOLS = 256


@dataclasses.dataclass
class ChannelTraffic:
    """Decayed traffic ledger of one ``(name, scheme_id)`` binding."""
    name: str
    scheme_id: int
    counts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(NUM_SYMBOLS, np.float64))
    symbols: float = 0.0          # decayed total of counts.sum()
    escaped_chunks: float = 0.0   # decayed escape-pool occupancy
    chunks: float = 0.0           # decayed chunk count (escape basis)
    overflows: float = 0.0        # decayed container-overflow events
    containers: float = 0.0       # decayed container count
    events: int = 0               # raw observation count (not decayed)

    def measured_bits_per_symbol(self, enc_len: np.ndarray) -> float:
        """Average code length of the observed traffic under ``enc_len``
        (the deployed codec's per-symbol bit table)."""
        if self.symbols <= 0:
            return 0.0
        return float(np.dot(self.counts,
                            np.asarray(enc_len, np.float64))
                     / self.symbols)

    def entropy_bits_per_symbol(self) -> float:
        """Shannon bound of the observed traffic (the best ANY codec
        could do) — the recalibration headroom reference."""
        if self.symbols <= 0:
            return 0.0
        p = self.counts / self.counts.sum()
        nz = p[p > 0]
        return float(-(nz * np.log2(nz)).sum())

    @property
    def escape_rate(self) -> float:
        return self.escaped_chunks / self.chunks if self.chunks > 0 else 0.0

    @property
    def overflow_rate(self) -> float:
        return (self.overflows / self.containers
                if self.containers > 0 else 0.0)


class TrafficMonitor:
    """Accumulates encode-side histograms per ``(name, scheme_id)``.

    ``registry`` resolves a channel name to its CURRENT binding, so
    ``observe(name, hist)`` files the histogram under the deployed
    scheme-id; after a hot-swap new traffic lands in a fresh ledger
    while the old one stays readable for post-mortems.
    """

    def __init__(self, registry, *, decay: float = 0.97):
        if not (0.0 < decay <= 1.0):
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.registry = registry
        self.decay = float(decay)
        self._traffic: Dict[Tuple[str, int], ChannelTraffic] = {}

    # ---- ingest ---------------------------------------------------------

    def observe(self, name: str, hist, *,
                escaped_chunks: Optional[float] = None,
                chunks: Optional[float] = None,
                overflow: bool = False,
                containers: float = 0.0,
                scheme_id: Optional[int] = None) -> ChannelTraffic:
        """File one encode pass's histogram (i32[256], any array type).

        ``escaped_chunks``/``chunks`` record escape-pool pressure when
        the caller has it (payload ``pool_count``); ``overflow`` marks
        a container-level pool overflow (lossless fallback taken).
        """
        hist = np.asarray(hist, np.float64).reshape(-1)
        if hist.shape[0] != NUM_SYMBOLS:
            raise ValueError(f"hist must have {NUM_SYMBOLS} bins, "
                             f"got {hist.shape}")
        if scheme_id is None:
            scheme_id = self.registry[name].scheme_id
        key = (name, int(scheme_id))
        t = self._traffic.get(key)
        if t is None:
            t = self._traffic[key] = ChannelTraffic(name=name,
                                                    scheme_id=key[1])
        d = self.decay
        t.counts = t.counts * d + hist
        t.symbols = t.symbols * d + float(hist.sum())
        t.escaped_chunks = t.escaped_chunks * d + float(escaped_chunks or 0)
        t.chunks = t.chunks * d + float(chunks or 0)
        t.overflows = t.overflows * d + (1.0 if overflow else 0.0)
        t.containers = t.containers * d + float(containers)
        t.events += 1
        return t

    # ---- query ----------------------------------------------------------

    def traffic(self, name: str,
                scheme_id: Optional[int] = None) -> Optional[ChannelTraffic]:
        """Ledger of ``name`` under its current (or given) scheme-id."""
        if scheme_id is None:
            scheme_id = self.registry[name].scheme_id
        return self._traffic.get((name, int(scheme_id)))

    def names(self) -> List[str]:
        return sorted({n for n, _ in self._traffic})

    def measured_bits(self, name: str) -> Optional[float]:
        """Measured bits/symbol of ``name``'s current binding, or None
        before any traffic."""
        entry = self.registry[name]
        t = self.traffic(name)
        if t is None or t.symbols <= 0:
            return None
        return t.measured_bits_per_symbol(entry.tables.enc_len)

    def excess_bits(self, name: str) -> Optional[float]:
        """measured - plan expectation (positive = paying drift tax)."""
        m = self.measured_bits(name)
        if m is None:
            return None
        return m - self.registry[name].plan.expected_bits_per_symbol

    def reset(self, name: str, scheme_id: Optional[int] = None):
        """Drop the ledger of one binding (post-swap hygiene)."""
        if scheme_id is None:
            scheme_id = self.registry[name].scheme_id
        self._traffic.pop((name, int(scheme_id)), None)

    def snapshot(self) -> List[dict]:
        """Loggable summary rows, one per tracked binding."""
        rows = []
        for (name, sid), t in sorted(self._traffic.items()):
            entry = self.registry._by_id.get(sid)
            row = {"name": name, "scheme_id": sid, "events": t.events,
                   "symbols": t.symbols,
                   "escape_rate": t.escape_rate,
                   "overflow_rate": t.overflow_rate,
                   "entropy_bits": t.entropy_bits_per_symbol()}
            if entry is not None:
                row["measured_bits"] = t.measured_bits_per_symbol(
                    entry.tables.enc_len)
                row["expected_bits"] = entry.plan.expected_bits_per_symbol
            rows.append(row)
        return rows
