"""e4m3 quantization (paper §3: eXmY e4m3, all 256 encodings finite).

Two flavors:
  * eXmY all-finite (paper's analysis dtype): S.EEEE.MMM, bias 7,
    max = 2^8 * 1.875 = 480, no NaN/Inf. Implemented via a 256-entry
    value table + round-to-nearest-even grid search (exact, vectorized).
  * OCP e4m3fn (jnp.float8_e4m3fn): hardware-native cast fast path used
    in the comm hot loop; 2 encodings are NaN (paper notes the PMF effect
    is negligible).

The codec itself is dtype-agnostic over raw uint8 symbols, so both
flavors round-trip losslessly through QLC.

Block scaling: block size 32 (paper §3), scale = amax / max_representable.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

E4M3_BIAS = 7
E4M3_MAX_FINITE = 480.0   # eXmY all-finite variant
E4M3_MAX_FN = 448.0       # OCP e4m3fn
BLOCK = 32


def _build_decode_table() -> np.ndarray:
    """value of each of the 256 eXmY e4m3 codes. code = S EEEE MMM."""
    codes = np.arange(256, dtype=np.uint32)
    sign = np.where(codes & 0x80, -1.0, 1.0)
    exp = ((codes >> 3) & 0xF).astype(np.int32)
    man = (codes & 0x7).astype(np.float64)
    sub = exp == 0
    mag = np.where(sub,
                   (man / 8.0) * 2.0 ** (1 - E4M3_BIAS),
                   (1.0 + man / 8.0) * 2.0 ** (exp - E4M3_BIAS))
    return (sign * mag).astype(np.float32)


_DECODE_TABLE = _build_decode_table()
# Non-negative magnitudes (codes 0..127), strictly increasing.
_POS_VALUES = _DECODE_TABLE[:128].copy()


def decode_table() -> np.ndarray:
    return _DECODE_TABLE.copy()


def e4m3_decode(codes: jnp.ndarray) -> jnp.ndarray:
    """uint8 codes -> float32 values (all-finite variant)."""
    table = jnp.asarray(_DECODE_TABLE)
    return jnp.take(table, codes.astype(jnp.int32), axis=0)


def e4m3_encode(x: jnp.ndarray) -> jnp.ndarray:
    """float32 -> uint8 codes, round-to-nearest-even on the e4m3 grid.

    Values beyond +-480 saturate. NaN maps to +max (all-finite variant has
    no NaN; upstream block scaling keeps inputs in range anyway).
    """
    pos = jnp.asarray(_POS_VALUES)
    mag = jnp.abs(x)
    mag = jnp.where(jnp.isnan(mag), E4M3_MAX_FINITE, mag)
    mag = jnp.minimum(mag, E4M3_MAX_FINITE)
    # hi = first index with pos[hi] >= mag  (pos is sorted ascending)
    hi = jnp.searchsorted(pos, mag, side="left").astype(jnp.int32)
    hi = jnp.clip(hi, 0, 127)
    lo = jnp.maximum(hi - 1, 0)
    dhi = jnp.take(pos, hi) - mag
    dlo = mag - jnp.take(pos, lo)
    # Nearest; ties -> even code (LSB 0).
    pick_lo = (dlo < dhi) | ((dlo == dhi) & (lo % 2 == 0))
    code = jnp.where(pick_lo, lo, hi).astype(jnp.uint8)
    neg = jnp.signbit(x)  # signed zero preserved: -0.0 -> code 0x80
    return jnp.where(neg, code | jnp.uint8(0x80), code).astype(jnp.uint8)


def quantize_block32(x: jnp.ndarray, block: int = BLOCK,
                     max_val: float = E4M3_MAX_FINITE
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-scaled e4m3 quantization along the last axis.

    Returns (codes uint8 same shape as x, scales float32 [..., n_blocks]).
    The last axis must be divisible by ``block``.
    """
    *lead, n = x.shape
    if n % block != 0:
        raise ValueError(f"last axis {n} not divisible by block {block}")
    xb = x.reshape(*lead, n // block, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    # Explicit f32 reciprocal multiply (not amax / max_val): XLA rewrites
    # constant division to reciprocal multiplication under jit but not in
    # eager dispatch; pinning the multiply keeps this bit-identical in
    # both AND against the fused Pallas kernel (qlc_fused).
    inv = np.float32(1.0) / np.float32(max_val)
    scale = jnp.where(amax > 0, amax * inv, 1.0)
    codes = e4m3_encode(xb / scale)
    return codes.reshape(*lead, n), scale[..., 0]


def dequantize_block32(codes: jnp.ndarray, scales: jnp.ndarray,
                       block: int = BLOCK) -> jnp.ndarray:
    *lead, n = codes.shape
    cb = codes.reshape(*lead, n // block, block)
    vals = e4m3_decode(cb) * scales[..., None]
    return vals.reshape(*lead, n)


# ---- OCP fn fast path (hardware cast) ------------------------------------

def e4m3fn_encode(x: jnp.ndarray) -> jnp.ndarray:
    """float -> uint8 via the native float8_e4m3fn cast (TPU fast path)."""
    f8 = x.astype(jnp.float8_e4m3fn)
    return jax.lax.bitcast_convert_type(f8, jnp.uint8)


def e4m3fn_decode(codes: jnp.ndarray) -> jnp.ndarray:
    f8 = jax.lax.bitcast_convert_type(codes, jnp.float8_e4m3fn)
    return f8.astype(jnp.float32)


def quantize_block32_fn(x: jnp.ndarray, block: int = BLOCK
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-scaled quantization using the native fn cast (2 NaN codes)."""
    *lead, n = x.shape
    xb = x.reshape(*lead, n // block, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / E4M3_MAX_FN, 1.0)
    codes = e4m3fn_encode(xb / scale)
    return codes.reshape(*lead, n), scale[..., 0]


def dequantize_block32_fn(codes: jnp.ndarray, scales: jnp.ndarray,
                          block: int = BLOCK) -> jnp.ndarray:
    *lead, n = codes.shape
    cb = codes.reshape(*lead, n // block, block)
    vals = e4m3fn_decode(cb) * scales[..., None]
    return vals.reshape(*lead, n)
