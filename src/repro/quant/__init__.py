from repro.quant.e4m3 import (  # noqa: F401
    E4M3_MAX_FINITE,
    E4M3_MAX_FN,
    decode_table,
    dequantize_block32,
    e4m3_decode,
    e4m3_encode,
    quantize_block32,
)
