"""Fault-tolerant checkpointing.

Design (DESIGN.md §8):
  * one .npy blob per pytree leaf (path-keyed), written to a temp dir,
    fsync'd, then atomically renamed into place — a crash mid-save never
    corrupts the previous checkpoint;
  * a manifest.json with tree structure, shapes, dtypes and per-leaf
    checksums, verified on restore;
  * a ``latest`` pointer file updated by atomic rename;
  * restore is mesh-agnostic: leaves are re-placed under whatever
    shardings the caller provides (elastic restart across pod counts);
  * data-iterator state (step) and RNG key are part of the checkpoint;
  * byte-width leaves (uint8 / int8 / fp8 — i.e. e4m3-quantized
    weights and cached symbol streams) are QLC-compressed losslessly on
    disk as **self-describing containers** (``repro.comm.container``)
    through the Pallas kernel entry points, with per-leaf calibrated
    tables registered in a per-checkpoint
    :class:`~repro.core.registry.CodecRegistry` stored as
    ``registry.json`` alongside the manifest. Each leaf's container
    header carries its scheme-id + wire geometry, so restore needs only
    the blob + the registry (leaves with bit-identical tables share one
    scheme-id). The checksum covers the ORIGINAL bytes, so decode
    corruption is caught.
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

SEP = "/"
REGISTRY_FILE = "registry.json"

QLC_CHUNK = 1024                 # symbols per QLC chunk on disk
QLC_MIN_BYTES = 4096             # below this, headers beat the savings


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 qlc_codes: bool = True, qlc_min_bytes: int = QLC_MIN_BYTES):
        self.dir = directory
        self.keep = keep
        self.qlc_codes = qlc_codes
        self.qlc_min_bytes = qlc_min_bytes
        os.makedirs(directory, exist_ok=True)

    # ---- save -----------------------------------------------------------

    def save(self, step: int, state: Any, extra: Optional[Dict] = None):
        """Atomically save a pytree checkpoint for ``step``."""
        from repro.core.registry import CodecRegistry
        flat = _flatten_with_paths(state)
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=f".tmp_{step}_")
        manifest = {"step": int(step), "leaves": {}, "extra": extra or {}}
        registry = CodecRegistry()
        try:
            for key, leaf in flat.items():
                arr = np.asarray(leaf)
                fname = hashlib.md5(key.encode()).hexdigest() + ".npy"
                fpath = os.path.join(tmp, fname)
                meta = {
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sum": _checksum(arr),
                }
                blob, qlc_meta = self._maybe_qlc(arr, key, registry)
                if qlc_meta is not None:
                    meta["qlc"] = qlc_meta
                    arr = blob
                with open(fpath, "wb") as f:
                    np.save(f, arr)
                    f.flush()
                    os.fsync(f.fileno())
                manifest["leaves"][key] = meta
            if len(registry):
                # per-checkpoint codec registry: containers name their
                # scheme-id; the registry supplies the tables on restore
                rpath = os.path.join(tmp, REGISTRY_FILE)
                with open(rpath, "w") as f:
                    json.dump(registry.to_json_dict(), f)
                    f.flush()
                    os.fsync(f.fileno())
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(self.dir, f"step_{step:010d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                       # atomic commit
            self._update_latest(step)
            self._gc()
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    def _maybe_qlc(self, arr: np.ndarray, key: str, registry):
        """Losslessly QLC-compress a byte-width leaf, if it shrinks.

        Returns ``(blob, meta)`` — a self-describing container (uint32
        words; see ``repro.comm.container``) whose codec is registered
        in the per-checkpoint ``registry`` under the leaf's path
        (identical tables dedupe onto one scheme-id) — or
        ``(arr, None)`` when the leaf is ineligible or incompressible
        (kept raw).
        """
        if (not self.qlc_codes or arr.dtype.hasobject
                or arr.dtype.itemsize != 1
                or arr.nbytes < self.qlc_min_bytes):
            return arr, None
        syms = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        counts = np.bincount(syms, minlength=256)

        from repro.comm import container as qc
        from repro.comm.channel import Channel, ChannelSpec
        from repro.comm.compressed import CommConfig
        from repro.core import adapt

        # Decide compressibility BEFORE registering, so raw leaves do
        # not pollute the checkpoint registry with dead entries.
        # calibrate_tables is the same deterministic construction
        # register() uses, so the sizing estimate matches exactly.
        tables = adapt.calibrate_tables(
            np.maximum(counts.astype(np.float64), 1e-6))
        n = syms.size
        n_chunks = -(-n // QLC_CHUNK)
        padded = np.zeros(n_chunks * QLC_CHUNK, dtype=np.uint8)
        padded[:n] = syms
        lens = tables.enc_len[padded]   # uint8 fancy-index: no int64 copy
        cap = max(1, math.ceil(
            int(lens.reshape(n_chunks, QLC_CHUNK).sum(axis=1).max()) / 32))
        # Exact measured capacity => zero escapes; the minimal 1-slot
        # pool is container overhead only.
        cfg = CommConfig(chunk_symbols=QLC_CHUNK, capacity_words=cap,
                         pool_slots_per_1k=1)
        pool_slots = cfg.pool_slots(n_chunks)
        container_words = (qc.HEADER_WORDS + n_chunks * cap
                           + -(-n_chunks // 4)
                           + pool_slots * (QLC_CHUNK // 4) + 1)
        if container_words * 4 >= syms.nbytes:    # incompressible leaf
            return arr, None
        entry = registry.register(key, counts.astype(np.float64),
                                  chunk_symbols=QLC_CHUNK)
        # One local (axis-less) channel binds the leaf's codec + exact
        # measured wire config + kernel toggle, encodes the symbol
        # stream, and the container frames it self-describingly.
        ch = Channel(ChannelSpec(codec=entry, cfg=cfg, use_kernels=True))
        payload = ch.compress_codes(jax.numpy.asarray(padded))
        blob = qc.pack_payload(payload, None, scheme_id=entry.scheme_id,
                               cfg=ch.cfg, n_valid=n,
                               prefix_bits=entry.tables.prefix_bits)
        meta = {"scheme_id": int(entry.scheme_id), "n": int(n)}
        return blob, meta

    @staticmethod
    def _decode_qlc(words: np.ndarray, qlc_meta: Dict, registry
                    ) -> np.ndarray:
        """Inverse of ``_maybe_qlc``: container words + registry -> u8.

        The container header supplies geometry + scheme-id; the
        checkpoint registry supplies the tables. Checkpoints written
        before the container format (manifest meta carries the
        histogram in-line) decode through the legacy path. Any
        parse/decode failure surfaces as IOError (corrupt blob)."""
        if "counts" in qlc_meta:          # pre-container checkpoint
            from repro.core import TABLE1, build_tables
            from repro.kernels import ops as kops
            tables = build_tables(
                np.asarray(qlc_meta["counts"], dtype=np.float64), TABLE1)
            syms = kops.decode(jax.numpy.asarray(words), tables,
                               qlc_meta["chunk"])
            return np.asarray(syms).reshape(-1)[:qlc_meta["n"]]
        from repro.comm import container as qc
        try:
            syms, ok, _ = qc.decode_codes(np.asarray(words), registry,
                                          use_kernels=True)
            if not bool(ok):
                raise ValueError("escape pool overflow on restore")
        except Exception as e:
            raise IOError(f"corrupt QLC container: {e}") from e
        return np.asarray(syms).reshape(-1)[:qlc_meta["n"]]

    def _update_latest(self, step: int):
        tmp = os.path.join(self.dir, ".latest_tmp")
        with open(tmp, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.dir, "latest"))

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---- restore ----------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "latest")
        if not os.path.exists(path):
            steps = self.all_steps()
            return steps[-1] if steps else None
        with open(path) as f:
            return int(f.read().strip())

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``. ``shardings`` (same
        structure, NamedShardings) re-places leaves on the current mesh
        — the elastic-restart path: the saved mesh is irrelevant."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        cdir = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(cdir, "manifest.json")) as f:
            manifest = json.load(f)

        registry = None
        rpath = os.path.join(cdir, REGISTRY_FILE)
        if os.path.exists(rpath):
            from repro.core.registry import CodecRegistry
            with open(rpath) as f:
                registry = CodecRegistry.from_json_dict(json.load(f))

        flat_like = _flatten_with_paths(like)
        flat_sh = (_flatten_with_paths(shardings)
                   if shardings is not None else {})
        out = {}
        for key, leaf in flat_like.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(cdir, meta["file"]))
            if "qlc" in meta:
                if registry is None and "counts" not in meta["qlc"]:
                    raise IOError(
                        f"checkpoint has QLC leaves but no {REGISTRY_FILE}")
                arr = self._decode_qlc(arr, meta["qlc"], registry).reshape(
                    meta["shape"])
            if _checksum(arr) != meta["sum"]:
                raise IOError(f"checksum mismatch for {key}")
            # np.load returns void dtypes for ml_dtypes arrays (bf16,
            # fp8); re-view with the recorded dtype.
            want = np.dtype(meta["dtype"])
            if arr.dtype != want:
                arr = arr.view(want)
            if list(arr.shape) != list(np.shape(leaf)):
                raise ValueError(
                    f"shape mismatch for {key}: {arr.shape} vs "
                    f"{np.shape(leaf)}")
            sh = flat_sh.get(key)
            out[key] = (jax.device_put(arr, sh) if sh is not None
                        else jax.device_put(arr))
        # rebuild tree in like's structure
        leaves_paths = jax.tree_util.tree_flatten_with_path(like)
        treedef = leaves_paths[1]
        ordered = [out[SEP.join(_path_str(p) for p in path)]
                   for path, _ in leaves_paths[0]]
        return jax.tree_util.tree_unflatten(treedef, ordered), \
            manifest.get("extra", {})


def _checksum(arr: np.ndarray) -> str:
    return hashlib.md5(np.ascontiguousarray(arr).tobytes()).hexdigest()
