"""Fault-tolerant checkpointing.

Design (DESIGN.md §8):
  * one .npy blob per pytree leaf (path-keyed), written to a temp dir,
    fsync'd, then atomically renamed into place — a crash mid-save never
    corrupts the previous checkpoint;
  * a manifest.json with tree structure, shapes, dtypes and per-leaf
    checksums, verified on restore;
  * a ``latest`` pointer file updated by atomic rename;
  * restore is mesh-agnostic: leaves are re-placed under whatever
    shardings the caller provides (elastic restart across pod counts);
  * data-iterator state (step) and RNG key are part of the checkpoint;
  * byte-width leaves (uint8 / int8 / fp8 — i.e. e4m3-quantized
    weights and cached symbol streams) are QLC-compressed losslessly on
    disk through the Pallas kernel entry points (``repro.kernels.ops``)
    with per-leaf calibrated tables; the histogram rides in the
    manifest and tables are rebuilt deterministically on restore. The
    checksum covers the ORIGINAL bytes, so decode corruption is caught.
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

SEP = "/"

QLC_CHUNK = 1024                 # symbols per QLC chunk on disk
QLC_MIN_BYTES = 4096             # below this, headers beat the savings


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 qlc_codes: bool = True, qlc_min_bytes: int = QLC_MIN_BYTES):
        self.dir = directory
        self.keep = keep
        self.qlc_codes = qlc_codes
        self.qlc_min_bytes = qlc_min_bytes
        os.makedirs(directory, exist_ok=True)

    # ---- save -----------------------------------------------------------

    def save(self, step: int, state: Any, extra: Optional[Dict] = None):
        """Atomically save a pytree checkpoint for ``step``."""
        flat = _flatten_with_paths(state)
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=f".tmp_{step}_")
        manifest = {"step": int(step), "leaves": {}, "extra": extra or {}}
        try:
            for key, leaf in flat.items():
                arr = np.asarray(leaf)
                fname = hashlib.md5(key.encode()).hexdigest() + ".npy"
                fpath = os.path.join(tmp, fname)
                meta = {
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sum": _checksum(arr),
                }
                blob, qlc_meta = self._maybe_qlc(arr)
                if qlc_meta is not None:
                    meta["qlc"] = qlc_meta
                    arr = blob
                with open(fpath, "wb") as f:
                    np.save(f, arr)
                    f.flush()
                    os.fsync(f.fileno())
                manifest["leaves"][key] = meta
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(self.dir, f"step_{step:010d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                       # atomic commit
            self._update_latest(step)
            self._gc()
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    def _maybe_qlc(self, arr: np.ndarray):
        """Losslessly QLC-compress a byte-width leaf, if it shrinks.

        Returns ``(blob, meta)`` — the uint32 word array plus the
        manifest entry (symbol histogram, geometry) needed to rebuild
        the tables and decode on restore — or ``(arr, None)`` when the
        leaf is ineligible or incompressible (kept raw).
        """
        if (not self.qlc_codes or arr.dtype.hasobject
                or arr.dtype.itemsize != 1
                or arr.nbytes < self.qlc_min_bytes):
            return arr, None
        syms = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        counts = np.bincount(syms, minlength=256)

        from repro.core import TABLE1, build_tables
        from repro.kernels import ops as kops
        tables = build_tables(counts.astype(np.float64), TABLE1)

        n = syms.size
        n_chunks = -(-n // QLC_CHUNK)
        padded = np.zeros(n_chunks * QLC_CHUNK, dtype=np.uint8)
        padded[:n] = syms
        lens = tables.enc_len[padded]   # uint8 fancy-index: no int64 copy
        cap = max(1, math.ceil(
            int(lens.reshape(n_chunks, QLC_CHUNK).sum(axis=1).max()) / 32))
        if n_chunks * cap * 4 >= syms.nbytes:     # incompressible leaf
            return arr, None
        words, _ = kops.encode(
            jax.numpy.asarray(padded.reshape(n_chunks, QLC_CHUNK)),
            tables, cap)
        meta = {"counts": counts.tolist(), "n": int(n),
                "chunk": QLC_CHUNK, "capacity_words": int(cap)}
        return np.asarray(words), meta

    @staticmethod
    def _decode_qlc(words: np.ndarray, qlc_meta: Dict) -> np.ndarray:
        """Inverse of ``_maybe_qlc``: words + manifest meta -> uint8."""
        from repro.core import TABLE1, build_tables
        from repro.kernels import ops as kops
        tables = build_tables(
            np.asarray(qlc_meta["counts"], dtype=np.float64), TABLE1)
        syms = kops.decode(jax.numpy.asarray(words), tables,
                           qlc_meta["chunk"])
        return np.asarray(syms).reshape(-1)[:qlc_meta["n"]]

    def _update_latest(self, step: int):
        tmp = os.path.join(self.dir, ".latest_tmp")
        with open(tmp, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.dir, "latest"))

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---- restore ----------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "latest")
        if not os.path.exists(path):
            steps = self.all_steps()
            return steps[-1] if steps else None
        with open(path) as f:
            return int(f.read().strip())

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``. ``shardings`` (same
        structure, NamedShardings) re-places leaves on the current mesh
        — the elastic-restart path: the saved mesh is irrelevant."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        cdir = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(cdir, "manifest.json")) as f:
            manifest = json.load(f)

        flat_like = _flatten_with_paths(like)
        flat_sh = (_flatten_with_paths(shardings)
                   if shardings is not None else {})
        out = {}
        for key, leaf in flat_like.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(cdir, meta["file"]))
            if "qlc" in meta:
                arr = self._decode_qlc(arr, meta["qlc"]).reshape(
                    meta["shape"])
            if _checksum(arr) != meta["sum"]:
                raise IOError(f"checksum mismatch for {key}")
            # np.load returns void dtypes for ml_dtypes arrays (bf16,
            # fp8); re-view with the recorded dtype.
            want = np.dtype(meta["dtype"])
            if arr.dtype != want:
                arr = arr.view(want)
            if list(arr.shape) != list(np.shape(leaf)):
                raise ValueError(
                    f"shape mismatch for {key}: {arr.shape} vs "
                    f"{np.shape(leaf)}")
            sh = flat_sh.get(key)
            out[key] = (jax.device_put(arr, sh) if sh is not None
                        else jax.device_put(arr))
        # rebuild tree in like's structure
        leaves_paths = jax.tree_util.tree_flatten_with_path(like)
        treedef = leaves_paths[1]
        ordered = [out[SEP.join(_path_str(p) for p in path)]
                   for path, _ in leaves_paths[0]]
        return jax.tree_util.tree_unflatten(treedef, ordered), \
            manifest.get("extra", {})


def _checksum(arr: np.ndarray) -> str:
    return hashlib.md5(np.ascontiguousarray(arr).tobytes()).hexdigest()
