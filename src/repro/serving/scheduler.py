"""Continuous-batching serving engine over a shared compressed block pool.

This is the request-based serving API the ROADMAP's millions-of-users
north star needs: ``generate``-style per-call batches cannot express
requests that join and leave mid-flight, so the engine owns ONE padded
active set of ``max_batch`` slots and drives it step by step:

    Engine.submit(GenerationRequest) -> handle     (enqueue, no compute)
    Engine.step()                                  (admit + one batched
                                                    decode step + paging)
    Engine.poll(handle) -> RequestStatus           (tokens so far)

Scheduling model (all host-side, fully deterministic):

* **Admission** — waiting requests claim free slots in submit order,
  subject to a per-tenant fairness cap (``fairness_cap`` × max_batch
  concurrent slots per tenant) and, under a bounded
  :class:`~repro.comm.blockpool.BlockPool` with host spill disabled, a
  projected-bytes admission check that rejects with a typed
  ``PoolExhausted`` instead of OOMing mid-decode. Each admitted prompt
  prefills at batch 1 on fresh states and scatters into its slot row.
* **Decode** — ONE jitted ``decode_step`` over the whole padded slot
  set per engine step (free slots feed token 0 at position 0; every
  per-row op in the decode path is row-independent, so padding rows
  cannot perturb active rows — the engine's output is token-identical
  to running each request alone, asserted in tests).
* **Paging** — each slot pages its completed blocks through the shared
  :class:`~repro.serving.kv_cache.PagedKVCache` block codec into the
  global :class:`~repro.comm.blockpool.BlockPool`. Pool capacity is
  compressed bytes, so the codec's ratio is literally the number of
  extra concurrent sequences per device; identical prompt prefixes
  dedup by container digest (prefix sharing) and diverge copy-on-write
  (immutable blocks, new digests past the split point). Every decoded
  block is read back FROM the pooled container, so shared bytes are on
  the token hot path, not a shadow copy.

The legacy ``generate`` / ``generate_paged`` / ``generate_from_wire``
functions are deprecated wrappers building a one-engine run
(``repro.serving.engine``), asserted token-identical to the scan-based
oracle they replaced.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.blockpool import (ArenaExhausted, BlockArena, BlockPool,
                                  PoolExhausted)
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import init_decode_states, ssm
from repro.serving.engine import (_paged_step, _prefill_fn,
                                  _prefill_from_fn, _window_step)
from repro.serving.kv_cache import (KVCacheSpec, PagedKVCache,
                                    SSMBoundaryTracker, calibrate_cache)

_rid_counter = itertools.count()


@dataclasses.dataclass
class GenerationRequest:
    """One generation request: a prompt (1-D token array), a budget,
    and a tenant for fairness accounting."""
    prompt: Any
    max_new_tokens: int = 32
    tenant: str = "default"
    request_id: Optional[str] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{self.max_new_tokens}")
        if self.request_id is None:
            self.request_id = f"req{next(_rid_counter)}"


@dataclasses.dataclass(frozen=True)
class RequestStatus:
    """Snapshot of a request's lifecycle (``Engine.poll``)."""
    request_id: str
    tenant: str
    state: str                  # waiting | running | finished | rejected
    tokens: np.ndarray          # generated tokens so far, int32 [<= budget]
    error: Optional[str] = None


@dataclasses.dataclass
class _Seq:
    """Engine-internal per-request state."""
    req: GenerationRequest
    state: str = "waiting"
    slot: Optional[int] = None
    toks: List[int] = dataclasses.field(default_factory=list)
    evicted: int = 0            # tokens behind this sequence's cold blocks
    digests: List[str] = dataclasses.field(default_factory=list)
    snap_digests: Dict[str, str] = dataclasses.field(default_factory=dict)
    error: Optional[str] = None

    @property
    def rid(self) -> str:
        return self.req.request_id

    @property
    def prompt_len(self) -> int:
        return int(self.req.prompt.size)

    @property
    def absorbed(self) -> int:
        """Tokens written into this sequence's cache so far (the last
        generated token has not been fed back yet)."""
        return self.prompt_len + max(0, len(self.toks) - 1)


def _slot_view(states, b: int):
    """Batch-row ``b`` of a decode-states pytree (every leaf is
    ``[n_groups, batch, ...]`` — batch is axis 1 throughout)."""
    return jax.tree.map(lambda a: a[:, b:b + 1], states)


def _slot_write(states, b: int, row):
    return jax.tree.map(lambda dst, src: dst.at[:, b:b + 1].set(src),
                        states, row)


class Engine:
    """Continuous-batching engine (see module docstring).

    ``kv_spec`` switches on compressed block paging: blocks go through
    the :class:`PagedKVCache` codec into ``pool`` (a
    :class:`~repro.comm.blockpool.BlockPool`; default: an effectively
    unbounded one). ``registry`` is calibrated lazily from the FIRST
    admitted request's prefill states when it lacks the
    ``kv/layer{i}`` entries. ``fairness_cap`` (0 < cap <= 1) bounds any
    one tenant to ``ceil(cap * max_batch)`` concurrent slots.

    ``kv_paging="async"`` (requires ``KVCacheSpec(mode="qlc",
    exact_capacity=False)``) moves paging device-resident: evicted
    block containers live in a :class:`~repro.comm.blockpool.BlockArena`
    of ``arena_slots`` slots, block decodes are DMA-prefetched at
    window boundaries, and decode runs as one jitted scan per
    admission window (constant host transfers per window). Token
    output is identical to ``"sync"``; both paging modes share one
    pool (device-framed containers are byte-identical to host ones).
    """

    def __init__(self, params, cfg: ModelConfig, *, max_seq_len: int,
                 max_batch: int = 4, kv_spec: Optional[KVCacheSpec] = None,
                 registry=None, pool: Optional[BlockPool] = None,
                 fairness_cap: Optional[float] = None, mesh=None,
                 kv_paging: str = "sync", arena_slots: int = 256):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if kv_paging not in ("sync", "async"):
            raise ValueError(f"kv_paging must be 'sync' or 'async', got "
                             f"{kv_paging!r}")
        if kv_paging == "async":
            if kv_spec is None or kv_spec.mode != "qlc" \
                    or kv_spec.exact_capacity:
                raise ValueError(
                    "kv_paging='async' needs KVCacheSpec(mode='qlc', "
                    "exact_capacity=False): the fixed plan geometry is "
                    "what makes block containers compile-time-constant "
                    "frames the device encode/decode can share")
        self.params = params
        self.cfg = cfg
        self.max_seq_len = int(max_seq_len)
        self.max_batch = int(max_batch)
        self.kv_spec = kv_spec
        if kv_spec is not None and registry is None:
            from repro.core.registry import CodecRegistry
            registry = CodecRegistry()
        self.registry = registry
        if kv_spec is not None and pool is None:
            pool = BlockPool(1 << 50)       # effectively unbounded
        self.pool = pool
        self._mesh = mesh
        self._codec: Optional[PagedKVCache] = None
        self._kinds = cfg.layer_kinds()
        self._tenant_cap = (None if fairness_cap is None
                            else max(1, math.ceil(fairness_cap * max_batch)))
        self._seqs: Dict[str, _Seq] = {}
        self._waiting: List[str] = []
        self._slots: List[Optional[str]] = [None] * self.max_batch
        self._states = init_decode_states(cfg, self.max_batch,
                                          self.max_seq_len)
        self._step_fn = _paged_step(cfg)
        self._prefill = _prefill_fn(cfg)
        self._prefill_from = _prefill_from_fn(cfg)
        self.kv_paging = kv_paging
        self._arena_slots = int(arena_slots)
        #: boundary-state snapshots for SSM re-basing (qlc only)
        self._snaps = SSMBoundaryTracker()
        self._rebase = (kv_spec is not None and kv_spec.ssm_rebase
                        and any(k != "attention" for k in self._kinds))
        #: prefetch handles scheduled at the last block boundary,
        #: consumed after the NEXT window's dispatch: (rid, handle)
        self._pending: List[tuple] = []
        self._windows = 0
        self._window_h2d = 0        # host->device uploads per async run
        self._window_d2h = 0        # device->host reads per async run
        #: deterministic scheduling trace: (step, event, request_id)
        self.events: List[tuple] = []
        self._step_idx = 0
        self._prefill_s = 0.0
        self._prefill_tokens = 0
        self._decode_s = 0.0
        self._decode_tokens = 0
        self._dense_of: Dict[str, int] = {}     # digest -> dense bytes
        self._dense_logical = 0
        self.peak_dense_logical_bytes = 0

    # ---- request lifecycle ----------------------------------------------

    def submit(self, req: GenerationRequest) -> str:
        """Enqueue a request; returns its handle (no compute happens
        until :meth:`step`)."""
        rid = req.request_id
        if rid in self._seqs:
            raise ValueError(f"duplicate request_id {rid!r}")
        if req.prompt.size + req.max_new_tokens > self.max_seq_len:
            raise ValueError(
                f"request {rid!r} needs {req.prompt.size} prompt + "
                f"{req.max_new_tokens} new tokens > max_seq_len="
                f"{self.max_seq_len}")
        self._seqs[rid] = _Seq(req=req)
        self._waiting.append(rid)
        self._log("submit", rid)
        return rid

    def poll(self, handle: str) -> RequestStatus:
        seq = self._seqs[handle]
        return RequestStatus(request_id=seq.rid, tenant=seq.req.tenant,
                             state=seq.state,
                             tokens=np.asarray(seq.toks, np.int32),
                             error=seq.error)

    def step(self) -> int:
        """Admit what fits, run ONE batched decode step over the padded
        active set (one admission *window* of steps under
        ``kv_paging="async"``), page completed blocks. Returns the
        number of requests still in flight (waiting + running)."""
        if self.kv_paging == "async":
            return self._step_async()
        self._step_idx += 1
        self._admit()
        active = [(b, rid) for b, rid in enumerate(self._slots)
                  if rid is not None]
        if active:
            tokens = np.zeros((self.max_batch, 1), np.int32)
            pos = np.zeros((self.max_batch, 1), np.int32)
            for b, rid in active:
                seq = self._seqs[rid]
                tokens[b, 0] = seq.toks[-1]
                pos[b, 0] = seq.prompt_len + len(seq.toks) - 1
            t0 = time.perf_counter()
            lg, self._states = self._step_fn(
                self.params, jnp.asarray(tokens), self._states,
                jnp.asarray(pos))
            lg_np = np.asarray(lg)          # forces the dispatch
            self._decode_s += time.perf_counter() - t0
            self._decode_tokens += len(active)
            for b, rid in active:
                seq = self._seqs[rid]
                seq.toks.append(int(np.argmax(lg_np[b, 0])))
                self._note_boundary(seq)
                try:
                    self._page(seq)
                except PoolExhausted as e:
                    self._reject(seq, e)
                    continue
                if len(seq.toks) >= seq.req.max_new_tokens:
                    self._finish(seq)
        return sum(1 for s in self._seqs.values()
                   if s.state in ("waiting", "running"))

    def _step_async(self) -> int:
        """One *admission window* of decode steps as a single jitted
        scan (``engine._window_step``): the host uploads one seed token
        + position per slot, the greedy feedback stays on device, and
        one array of generated tokens comes back — host transfers per
        window are constant (2 up, 1 down), independent of the window
        length. The window ends exactly at the nearest block boundary
        or budget across active slots, so evictions (and SSM boundary
        snapshots) only ever happen between windows; the prefetch
        decodes scheduled there are consumed after the NEXT window's
        result lands, which is what hides them behind model compute."""
        self._step_idx += 1
        self._admit()
        active = [(b, rid) for b, rid in enumerate(self._slots)
                  if rid is not None]
        if active:
            bt = self.kv_spec.block_tokens
            hot = self.kv_spec.hot_blocks
            window = None
            for _, rid in active:
                seq = self._seqs[rid]
                to_finish = seq.req.max_new_tokens - len(seq.toks)
                to_boundary = (seq.evicted + (1 + hot) * bt
                               - seq.absorbed)
                w = min(to_finish, to_boundary)
                if self._rebase:
                    # also stop at recording boundaries (multiples of
                    # bt) so SSM boundary snapshots are never skipped
                    w = min(w, bt - seq.absorbed % bt)
                window = w if window is None else min(window, w)
            window = max(1, window)
            tokens = np.zeros((self.max_batch, 1), np.int32)
            pos = np.zeros((self.max_batch, 1), np.int32)
            for b, rid in active:
                seq = self._seqs[rid]
                tokens[b, 0] = seq.toks[-1]
                pos[b, 0] = seq.prompt_len + len(seq.toks) - 1
            t0 = time.perf_counter()
            tok_dev = jnp.asarray(tokens)
            pos_dev = jnp.asarray(pos)
            self._window_h2d += 2
            wf = _window_step(self.cfg, window)
            with jax.transfer_guard("disallow"):
                # The probe: any per-token host callback inside the
                # scan would raise here.
                gen_dev, self._states = wf(self.params, tok_dev,
                                           pos_dev, self._states)
            gen = np.asarray(gen_dev)       # ONE d2h for the window
            self._window_d2h += 1
            self._windows += 1
            # Last boundary's prefetch decodes ran behind this window
            # on the in-order device stream — wait on them now (timed:
            # a stall here is the cost prefetch failed to hide) ...
            ready = self._consume_pending()
            self._decode_s += time.perf_counter() - t0
            self._decode_tokens += len(active) * window
            # ... and apply them untimed, like the sync path's _page.
            self._apply_pending(ready)
            for b, rid in active:
                seq = self._seqs[rid]
                if seq.state != "running":      # rejected at consume
                    continue
                seq.toks.extend(int(t) for t in gen[b, :window])
                self._note_boundary(seq)
                try:
                    self._page(seq)
                except PoolExhausted as e:
                    self._reject(seq, e)
                    continue
                if len(seq.toks) >= seq.req.max_new_tokens:
                    self._finish(seq)
        return sum(1 for s in self._seqs.values()
                   if s.state in ("waiting", "running"))

    def run(self):
        """Drive :meth:`step` until every submitted request finished or
        was rejected."""
        while self.step():
            pass

    # ---- admission -------------------------------------------------------

    def _admit(self):
        for rid in list(self._waiting):
            if None not in self._slots:
                break
            seq = self._seqs[rid]
            tenant = seq.req.tenant
            if self._tenant_cap is not None and \
                    self._tenant_active(tenant) >= self._tenant_cap:
                self._log("defer_fairness", rid)
                continue
            if self.pool is not None and self.kv_spec is not None:
                try:
                    self.pool.check_admission(self._projected_bytes(seq))
                except PoolExhausted as e:
                    self._waiting.remove(rid)
                    self._reject(seq, e, event="reject_admission")
                    continue
            self._waiting.remove(rid)
            try:
                self._start(seq)
            except PoolExhausted as e:
                self._reject(seq, e)

    def _tenant_active(self, tenant: str) -> int:
        return sum(1 for rid in self._slots if rid is not None
                   and self._seqs[rid].req.tenant == tenant)

    def _projected_bytes(self, seq: _Seq) -> float:
        """Projected compressed footprint of a request, in the pool's
        measured mean-block-bytes unit (0 before any block pooled —
        the first request always gets to run and establish the unit)."""
        if self.kv_spec is None or self.pool is None:
            return 0.0
        mean = self.pool.mean_block_bytes()
        if not mean:
            return 0.0
        bt = self.kv_spec.block_tokens
        total = seq.prompt_len + seq.req.max_new_tokens - 1
        n_blocks = max(0, total // bt - self.kv_spec.hot_blocks)
        return mean * n_blocks * len(self._kinds)

    def _start(self, seq: _Seq):
        b = self._slots.index(None)
        t0 = time.perf_counter()
        row = init_decode_states(self.cfg, 1, self.max_seq_len)
        if self._rebase:
            # Segmented prefill: pause at every block boundary to
            # capture the recurrent layers' boundary states (the
            # re-basing snapshots). State-identical to one whole-prompt
            # prefill — same scan body, same positions.
            bt = self.kv_spec.block_tokens
            prompt = seq.req.prompt
            logits, pos = None, 0
            while pos < seq.prompt_len:
                end = min(seq.prompt_len, (pos // bt + 1) * bt)
                seg = jnp.asarray(prompt[None, pos:end])
                logits, row = self._prefill_from(
                    self.params, seg, row, jnp.int32(pos))
                pos = end
                if pos % bt == 0:
                    self._record_boundary_states(seq, row, pos)
        else:
            prompts = jnp.asarray(seq.req.prompt[None, :])
            logits, row = self._prefill(self.params, prompts, row)
        first = int(np.argmax(np.asarray(logits)[0]))
        self._prefill_s += time.perf_counter() - t0
        self._prefill_tokens += seq.prompt_len
        if self.kv_spec is not None and self._codec is None:
            self._ensure_codec(row, seq.prompt_len)
        self._states = _slot_write(self._states, b, row)
        self._slots[b] = seq.rid
        seq.slot = b
        seq.state = "running"
        seq.toks = [first]
        self._log("admit", seq.rid)
        self._page(seq)                     # prompt blocks page out now
        if len(seq.toks) >= seq.req.max_new_tokens:
            self._finish(seq)

    def _ensure_codec(self, row_states, tokens: int):
        """Build the shared block codec, calibrating the registry's
        ``kv/layer{i}`` entries from the first prefill when absent."""
        base = self.kv_spec.layer_codec(0)
        have = any(n == base or n.startswith(base + "/")
                   for n in self.registry.names())
        if not have:
            calibrate_cache(self.registry, self.cfg, row_states, tokens,
                            self.kv_spec)
        self._codec = PagedKVCache(self.kv_spec, self.cfg, self.registry,
                                   mesh=self._mesh)

    # ---- paging through the shared pool ---------------------------------

    def _page(self, seq: _Seq):
        if self._codec is None:
            return
        bt = self.kv_spec.block_tokens
        hot = self.kv_spec.hot_blocks
        evict = (self._evict_slot_async if self.kv_paging == "async"
                 else self._evict_slot)
        while seq.evicted + (1 + hot) * bt <= seq.absorbed:
            t0 = seq.evicted
            evict(seq, t0, t0 + bt)
            seq.evicted = t0 + bt

    def _record_boundary_states(self, seq: _Seq, row, t: int):
        """Snapshot every recurrent layer's state at boundary ``t``
        (the state after absorbing exactly ``t`` tokens) for later
        re-based eviction."""
        snap = {f"l{i}": tuple(ssm.state_snapshot(row[f"l{i}"]))
                for i, kind in enumerate(self._kinds)
                if kind != "attention"}
        if snap:
            self._snaps.record(seq.rid, t, snap)

    def _note_boundary(self, seq: _Seq):
        """Capture boundary states the moment a running slot's absorbed
        count lands on a block boundary (no-op unless re-basing)."""
        if not self._rebase or seq.slot is None:
            return
        if seq.absorbed > 0 and seq.absorbed % self.kv_spec.block_tokens == 0:
            self._record_boundary_states(
                seq, _slot_view(self._states, seq.slot), seq.absorbed)

    def _evict_slot(self, seq: _Seq, t0: int, t1: int):
        """Encode one completed block of ``seq``'s slot row into the
        pool, then restore the row from the POOLED container — shared
        (deduped) bytes are what the model attends over."""
        row = _slot_view(self._states, seq.slot)
        new_row = dict(row)
        bsnap = (self._snaps.take(seq.rid, t1) if self._rebase else None)
        for i, kind in enumerate(self._kinds):
            key = f"l{i}"
            name = self.kv_spec.layer_codec(i)
            st = row[key]
            if kind == "attention":
                k, v = attn.kv_block_slice(st, t0, t1)
                block = self._codec.encode_block_arrays(
                    name, key, (k, v), start=t0, tokens=t1 - t0)
                digest = self._pool_put(seq, block)
                k2, v2 = self._codec.decode_block_arrays(
                    self.pool.get(digest))
                new_row[key] = attn.kv_block_restore(
                    st, t0, t1, jnp.asarray(k2), jnp.asarray(v2))
            elif bsnap is not None and key in bsnap:
                # Re-based snapshot: the state AT boundary t1 — depends
                # only on tokens < t1, so shared prompt prefixes pool
                # to identical digests. The live state (which has
                # absorbed tokens past t1) is left untouched; the
                # decode still runs so an overflowing container
                # surfaces here, not on a later reader.
                block = self._codec.encode_block_arrays(
                    name, key, bsnap[key], start=t1, tokens=t1 - t0)
                digest = self._pool_put(seq, block)
                self._codec.decode_block_arrays(self.pool.get(digest))
                old = seq.snap_digests.get(key)
                if old is not None:
                    self._pool_release(seq, old)
                seq.snap_digests[key] = digest
            else:
                arrays = ssm.state_snapshot(st)
                block = self._codec.encode_block_arrays(
                    name, key, arrays, start=t1, tokens=t1 - t0)
                digest = self._pool_put(seq, block)
                decoded = [jnp.asarray(a) for a in
                           self._codec.decode_block_arrays(
                               self.pool.get(digest))]
                new_row[key] = ssm.state_restore(st, decoded)
                # the newest snapshot supersedes the previous one
                old = seq.snap_digests.get(key)
                if old is not None:
                    self._pool_release(seq, old)
                seq.snap_digests[key] = digest
        self._states = _slot_write(self._states, seq.slot, new_row)

    # ---- async paging (device-resident arena + prefetch) -----------------

    def _ensure_arena(self, slot_words: int) -> BlockArena:
        if self._codec.arena is None:
            arena = BlockArena(self._arena_slots, slot_words)
            self._codec.arena = arena
            if self.pool is not None and self.pool.arena is None:
                self.pool.arena = arena
        return self._codec.arena

    def _evict_slot_async(self, seq: _Seq, t0: int, t1: int):
        """Async twin of :meth:`_evict_slot`: frame every layer's block
        on device, park the words in the arena, and SCHEDULE the
        prefetch decode — consumed after the next window lands
        (:meth:`_consume_pending`), so the decode runs behind model
        compute instead of on the block-boundary critical path. Escape
        overflow under the plan capacity falls back to the sync host
        path for the whole boundary (counted as a prefetch miss)."""
        row = _slot_view(self._states, seq.slot)
        bsnap = (self._snaps.take(seq.rid, t1) if self._rebase else None)
        devs = []
        for i, kind in enumerate(self._kinds):
            key = f"l{i}"
            name = self.kv_spec.layer_codec(i)
            st = row[key]
            if kind == "attention":
                arrays = attn.kv_block_slice(st, t0, t1)
                start = t0
            elif bsnap is not None and key in bsnap:
                arrays = bsnap[key]
                start = t1
            else:
                arrays = ssm.state_snapshot(st)
                start = t1
            dev = self._codec.encode_block_device(
                name, key, arrays, start=start, tokens=t1 - t0)
            if dev is None:
                # plan-capacity escape overflow: redo this boundary on
                # the host sync path (re-wires the section raw there)
                self._codec.prefetcher.miss()
                if bsnap is not None:
                    self._snaps.record(seq.rid, t1, bsnap)  # un-take
                self._evict_slot(seq, t0, t1)
                return
            devs.append(dev)
        arena = self._ensure_arena(max(d.plan.total_words for d in devs))
        for dev in devs:
            try:
                slot, gen = arena.alloc()
                arena.write(slot, dev.words)
                dev.slot, dev.gen = slot, gen
            except ArenaExhausted:
                dev.slot = None     # decode straight from the HBM words
            self._pending.append(
                (seq.rid, self._codec.prefetcher.schedule(dev)))

    def _consume_pending(self):
        """Wait on the prefetch decodes scheduled at the last boundary:
        arena staleness check, then block until the decoded arrays are
        ready (a no-op when the prefetch overlapped — the stall time is
        what ``BlockPrefetcher`` meters). This is the only paging cost
        on the decode critical path, so it runs INSIDE the timed decode
        region; the restore + pool accounting (:meth:`_apply_pending`)
        is bookkeeping the sync path also does untimed in ``_page``."""
        pending, self._pending = self._pending, []
        ready = []
        for rid, handle in pending:
            seq = self._seqs[rid]
            if seq.state != "running":
                continue            # rejected/finished since scheduled
            ready.append((seq, handle,
                          self._codec.prefetcher.consume(handle)))
        return ready

    def _apply_pending(self, ready):
        """Apply consumed prefetches: attention-window restore from the
        decoded (pooled) bytes plus deferred pool/digest accounting.
        Deferring the attention restore by one window is exact: the
        ``"qlc"`` round trip is bit-identical, and the window never
        touches cache rows behind the eviction horizon."""
        for seq, handle, arrays in ready:
            if seq.state != "running":
                continue
            try:
                self._apply_consumed(seq, handle, arrays)
            except PoolExhausted as e:
                self._reject(seq, e)

    def _apply_consumed(self, seq: _Seq, handle, arrays):
        dev = handle.block
        block = dev.host_block()    # D2H started at schedule time
        digest = self._pool_put(seq, block)
        if dev.slot is not None:
            if not self.pool.attach_arena_slot(digest, dev.slot, dev.gen):
                # dedup hit: the pooled entry already owns an arena
                # copy of these bytes — recycle ours
                self._codec.arena.free(dev.slot)
        i = int(dev.layer[1:])
        if self._kinds[i] == "attention":
            full = dict(_slot_view(self._states, seq.slot))
            k2, v2 = arrays
            full[dev.layer] = attn.kv_block_restore(
                full[dev.layer], dev.start, dev.start + dev.tokens,
                k2, v2)
            self._states = _slot_write(self._states, seq.slot, full)
        else:
            # SSM: never restore — the live state has advanced past the
            # snapshot boundary. Supersede the previous snapshot.
            old = seq.snap_digests.get(dev.layer)
            if old is not None:
                self._pool_release(seq, old)
            seq.snap_digests[dev.layer] = digest

    def _flush_pending(self, seq: _Seq):
        """Consume (or drop, if no longer running) every pending
        prefetch of ``seq`` right now — called before finish/reject so
        deferred pool accounting can't outlive the request."""
        keep = []
        for rid, handle in self._pending:
            if rid != seq.rid:
                keep.append((rid, handle))
                continue
            if seq.state == "running":
                arrays = self._codec.prefetcher.consume(handle)
                self._apply_consumed(seq, handle, arrays)
        self._pending = keep

    def _pool_put(self, seq: _Seq, block) -> str:
        digest = self.pool.put(block)
        seq.digests.append(digest)
        self._dense_of[digest] = block.dense_bytes
        self._dense_logical += block.dense_bytes
        self.peak_dense_logical_bytes = max(self.peak_dense_logical_bytes,
                                            self._dense_logical)
        return digest

    def _pool_release(self, seq: _Seq, digest: str):
        self.pool.release(digest)
        seq.digests.remove(digest)
        self._dense_logical -= self._dense_of.get(digest, 0)

    def _release_all(self, seq: _Seq):
        for digest in list(seq.digests):
            self._pool_release(seq, digest)
        seq.snap_digests.clear()

    # ---- completion / rejection -----------------------------------------

    def _finish(self, seq: _Seq):
        if self._pending:
            try:
                self._flush_pending(seq)
            except PoolExhausted as e:
                self._reject(seq, e)
                return
        seq.state = "finished"
        if seq.slot is not None:
            self._slots[seq.slot] = None
            seq.slot = None
        if self.pool is not None:
            self._release_all(seq)      # zero-ref blocks stay cached
        self._snaps.drop(seq.rid)
        self._log("finish", seq.rid)

    def _reject(self, seq: _Seq, err: Exception, event: str = "reject"):
        seq.state = "rejected"
        seq.error = f"{type(err).__name__}: {err}"
        if self._pending:
            self._flush_pending(seq)    # drops (state != running)
        if seq.slot is not None:
            self._slots[seq.slot] = None
            seq.slot = None
        if self.pool is not None:
            self._release_all(seq)
        self._snaps.drop(seq.rid)
        self._log(event, seq.rid)

    def _log(self, event: str, rid: str):
        self.events.append((self._step_idx, event, rid))

    # ---- accounting ------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """Engine accounting: request states, ms/token prefill + decode
        (the speed.md reporting format), KV codec counters, and the
        pool's byte-level stats (with ``dense_logical`` rows so the
        capacity win — dense bytes a dense cache would pin vs pooled
        compressed bytes — is one division away)."""
        by_state: Dict[str, int] = {}
        for s in self._seqs.values():
            by_state[s.state] = by_state.get(s.state, 0) + 1
        out: Dict[str, Any] = {
            "steps": self._step_idx,
            "requests": {st: by_state.get(st, 0) for st in
                         ("waiting", "running", "finished", "rejected")},
            "prefill_tokens": self._prefill_tokens,
            "decode_tokens": self._decode_tokens,
            "ms_per_token_prefill": (1e3 * self._prefill_s
                                     / max(1, self._prefill_tokens)),
            "ms_per_token_decode": (1e3 * self._decode_s
                                    / max(1, self._decode_tokens)),
            "dense_logical_bytes": self._dense_logical,
            "peak_dense_logical_bytes": self.peak_dense_logical_bytes,
        }
        if self._codec is not None:
            out["kv"] = {
                "overflow_sections": self._codec.overflow_sections,
                "raw_sections": self._codec.raw_sections,
            }
        if self.kv_paging == "async":
            out["async"] = {
                "windows": self._windows,
                "window_h2d": self._window_h2d,
                "window_d2h": self._window_d2h,
                "h2d_per_window": (self._window_h2d
                                   / max(1, self._windows)),
                "d2h_per_window": (self._window_d2h
                                   / max(1, self._windows)),
            }
            if self._codec is not None:
                out["prefetch"] = self._codec.prefetcher.stats()
                if self._codec.arena is not None:
                    out["arena"] = self._codec.arena.stats()
        if self.pool is not None:
            out["pool"] = self.pool.stats()
        return out
