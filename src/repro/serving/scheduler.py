"""Continuous-batching serving engine over a shared compressed block pool.

This is the request-based serving API the ROADMAP's millions-of-users
north star needs: ``generate``-style per-call batches cannot express
requests that join and leave mid-flight, so the engine owns ONE padded
active set of ``max_batch`` slots and drives it step by step:

    Engine.submit(GenerationRequest) -> handle     (enqueue, no compute)
    Engine.step()                                  (admit + one batched
                                                    decode step + paging)
    Engine.poll(handle) -> RequestStatus           (tokens so far)

Scheduling model (all host-side, fully deterministic):

* **Admission** — waiting requests claim free slots in submit order,
  subject to a per-tenant fairness cap (``fairness_cap`` × max_batch
  concurrent slots per tenant) and, under a bounded
  :class:`~repro.comm.blockpool.BlockPool` with host spill disabled, a
  projected-bytes admission check that rejects with a typed
  ``PoolExhausted`` instead of OOMing mid-decode. Each admitted prompt
  prefills at batch 1 on fresh states and scatters into its slot row.
* **Decode** — ONE jitted ``decode_step`` over the whole padded slot
  set per engine step (free slots feed token 0 at position 0; every
  per-row op in the decode path is row-independent, so padding rows
  cannot perturb active rows — the engine's output is token-identical
  to running each request alone, asserted in tests).
* **Paging** — each slot pages its completed blocks through the shared
  :class:`~repro.serving.kv_cache.PagedKVCache` block codec into the
  global :class:`~repro.comm.blockpool.BlockPool`. Pool capacity is
  compressed bytes, so the codec's ratio is literally the number of
  extra concurrent sequences per device; identical prompt prefixes
  dedup by container digest (prefix sharing) and diverge copy-on-write
  (immutable blocks, new digests past the split point). Every decoded
  block is read back FROM the pooled container, so shared bytes are on
  the token hot path, not a shadow copy.

The legacy ``generate`` / ``generate_paged`` / ``generate_from_wire``
functions are deprecated wrappers building a one-engine run
(``repro.serving.engine``), asserted token-identical to the scan-based
oracle they replaced.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.blockpool import BlockPool, PoolExhausted
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import init_decode_states, ssm
from repro.serving.engine import _paged_step, _prefill_fn
from repro.serving.kv_cache import (KVCacheSpec, PagedKVCache,
                                    calibrate_cache)

_rid_counter = itertools.count()


@dataclasses.dataclass
class GenerationRequest:
    """One generation request: a prompt (1-D token array), a budget,
    and a tenant for fairness accounting."""
    prompt: Any
    max_new_tokens: int = 32
    tenant: str = "default"
    request_id: Optional[str] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{self.max_new_tokens}")
        if self.request_id is None:
            self.request_id = f"req{next(_rid_counter)}"


@dataclasses.dataclass(frozen=True)
class RequestStatus:
    """Snapshot of a request's lifecycle (``Engine.poll``)."""
    request_id: str
    tenant: str
    state: str                  # waiting | running | finished | rejected
    tokens: np.ndarray          # generated tokens so far, int32 [<= budget]
    error: Optional[str] = None


@dataclasses.dataclass
class _Seq:
    """Engine-internal per-request state."""
    req: GenerationRequest
    state: str = "waiting"
    slot: Optional[int] = None
    toks: List[int] = dataclasses.field(default_factory=list)
    evicted: int = 0            # tokens behind this sequence's cold blocks
    digests: List[str] = dataclasses.field(default_factory=list)
    snap_digests: Dict[str, str] = dataclasses.field(default_factory=dict)
    error: Optional[str] = None

    @property
    def rid(self) -> str:
        return self.req.request_id

    @property
    def prompt_len(self) -> int:
        return int(self.req.prompt.size)

    @property
    def absorbed(self) -> int:
        """Tokens written into this sequence's cache so far (the last
        generated token has not been fed back yet)."""
        return self.prompt_len + max(0, len(self.toks) - 1)


def _slot_view(states, b: int):
    """Batch-row ``b`` of a decode-states pytree (every leaf is
    ``[n_groups, batch, ...]`` — batch is axis 1 throughout)."""
    return jax.tree.map(lambda a: a[:, b:b + 1], states)


def _slot_write(states, b: int, row):
    return jax.tree.map(lambda dst, src: dst.at[:, b:b + 1].set(src),
                        states, row)


class Engine:
    """Continuous-batching engine (see module docstring).

    ``kv_spec`` switches on compressed block paging: blocks go through
    the :class:`PagedKVCache` codec into ``pool`` (a
    :class:`~repro.comm.blockpool.BlockPool`; default: an effectively
    unbounded one). ``registry`` is calibrated lazily from the FIRST
    admitted request's prefill states when it lacks the
    ``kv/layer{i}`` entries. ``fairness_cap`` (0 < cap <= 1) bounds any
    one tenant to ``ceil(cap * max_batch)`` concurrent slots.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_seq_len: int,
                 max_batch: int = 4, kv_spec: Optional[KVCacheSpec] = None,
                 registry=None, pool: Optional[BlockPool] = None,
                 fairness_cap: Optional[float] = None, mesh=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.params = params
        self.cfg = cfg
        self.max_seq_len = int(max_seq_len)
        self.max_batch = int(max_batch)
        self.kv_spec = kv_spec
        if kv_spec is not None and registry is None:
            from repro.core.registry import CodecRegistry
            registry = CodecRegistry()
        self.registry = registry
        if kv_spec is not None and pool is None:
            pool = BlockPool(1 << 50)       # effectively unbounded
        self.pool = pool
        self._mesh = mesh
        self._codec: Optional[PagedKVCache] = None
        self._kinds = cfg.layer_kinds()
        self._tenant_cap = (None if fairness_cap is None
                            else max(1, math.ceil(fairness_cap * max_batch)))
        self._seqs: Dict[str, _Seq] = {}
        self._waiting: List[str] = []
        self._slots: List[Optional[str]] = [None] * self.max_batch
        self._states = init_decode_states(cfg, self.max_batch,
                                          self.max_seq_len)
        self._step_fn = _paged_step(cfg)
        self._prefill = _prefill_fn(cfg)
        #: deterministic scheduling trace: (step, event, request_id)
        self.events: List[tuple] = []
        self._step_idx = 0
        self._prefill_s = 0.0
        self._prefill_tokens = 0
        self._decode_s = 0.0
        self._decode_tokens = 0
        self._dense_of: Dict[str, int] = {}     # digest -> dense bytes
        self._dense_logical = 0
        self.peak_dense_logical_bytes = 0

    # ---- request lifecycle ----------------------------------------------

    def submit(self, req: GenerationRequest) -> str:
        """Enqueue a request; returns its handle (no compute happens
        until :meth:`step`)."""
        rid = req.request_id
        if rid in self._seqs:
            raise ValueError(f"duplicate request_id {rid!r}")
        if req.prompt.size + req.max_new_tokens > self.max_seq_len:
            raise ValueError(
                f"request {rid!r} needs {req.prompt.size} prompt + "
                f"{req.max_new_tokens} new tokens > max_seq_len="
                f"{self.max_seq_len}")
        self._seqs[rid] = _Seq(req=req)
        self._waiting.append(rid)
        self._log("submit", rid)
        return rid

    def poll(self, handle: str) -> RequestStatus:
        seq = self._seqs[handle]
        return RequestStatus(request_id=seq.rid, tenant=seq.req.tenant,
                             state=seq.state,
                             tokens=np.asarray(seq.toks, np.int32),
                             error=seq.error)

    def step(self) -> int:
        """Admit what fits, run ONE batched decode step over the padded
        active set, page completed blocks. Returns the number of
        requests still in flight (waiting + running)."""
        self._step_idx += 1
        self._admit()
        active = [(b, rid) for b, rid in enumerate(self._slots)
                  if rid is not None]
        if active:
            tokens = np.zeros((self.max_batch, 1), np.int32)
            pos = np.zeros((self.max_batch, 1), np.int32)
            for b, rid in active:
                seq = self._seqs[rid]
                tokens[b, 0] = seq.toks[-1]
                pos[b, 0] = seq.prompt_len + len(seq.toks) - 1
            t0 = time.perf_counter()
            lg, self._states = self._step_fn(
                self.params, jnp.asarray(tokens), self._states,
                jnp.asarray(pos))
            lg_np = np.asarray(lg)          # forces the dispatch
            self._decode_s += time.perf_counter() - t0
            self._decode_tokens += len(active)
            for b, rid in active:
                seq = self._seqs[rid]
                seq.toks.append(int(np.argmax(lg_np[b, 0])))
                try:
                    self._page(seq)
                except PoolExhausted as e:
                    self._reject(seq, e)
                    continue
                if len(seq.toks) >= seq.req.max_new_tokens:
                    self._finish(seq)
        return sum(1 for s in self._seqs.values()
                   if s.state in ("waiting", "running"))

    def run(self):
        """Drive :meth:`step` until every submitted request finished or
        was rejected."""
        while self.step():
            pass

    # ---- admission -------------------------------------------------------

    def _admit(self):
        for rid in list(self._waiting):
            if None not in self._slots:
                break
            seq = self._seqs[rid]
            tenant = seq.req.tenant
            if self._tenant_cap is not None and \
                    self._tenant_active(tenant) >= self._tenant_cap:
                self._log("defer_fairness", rid)
                continue
            if self.pool is not None and self.kv_spec is not None:
                try:
                    self.pool.check_admission(self._projected_bytes(seq))
                except PoolExhausted as e:
                    self._waiting.remove(rid)
                    self._reject(seq, e, event="reject_admission")
                    continue
            self._waiting.remove(rid)
            try:
                self._start(seq)
            except PoolExhausted as e:
                self._reject(seq, e)

    def _tenant_active(self, tenant: str) -> int:
        return sum(1 for rid in self._slots if rid is not None
                   and self._seqs[rid].req.tenant == tenant)

    def _projected_bytes(self, seq: _Seq) -> float:
        """Projected compressed footprint of a request, in the pool's
        measured mean-block-bytes unit (0 before any block pooled —
        the first request always gets to run and establish the unit)."""
        if self.kv_spec is None or self.pool is None:
            return 0.0
        mean = self.pool.mean_block_bytes()
        if not mean:
            return 0.0
        bt = self.kv_spec.block_tokens
        total = seq.prompt_len + seq.req.max_new_tokens - 1
        n_blocks = max(0, total // bt - self.kv_spec.hot_blocks)
        return mean * n_blocks * len(self._kinds)

    def _start(self, seq: _Seq):
        b = self._slots.index(None)
        t0 = time.perf_counter()
        prompts = jnp.asarray(seq.req.prompt[None, :])
        row = init_decode_states(self.cfg, 1, self.max_seq_len)
        logits, row = self._prefill(self.params, prompts, row)
        first = int(np.argmax(np.asarray(logits)[0]))
        self._prefill_s += time.perf_counter() - t0
        self._prefill_tokens += seq.prompt_len
        if self.kv_spec is not None and self._codec is None:
            self._ensure_codec(row, seq.prompt_len)
        self._states = _slot_write(self._states, b, row)
        self._slots[b] = seq.rid
        seq.slot = b
        seq.state = "running"
        seq.toks = [first]
        self._log("admit", seq.rid)
        self._page(seq)                     # prompt blocks page out now
        if len(seq.toks) >= seq.req.max_new_tokens:
            self._finish(seq)

    def _ensure_codec(self, row_states, tokens: int):
        """Build the shared block codec, calibrating the registry's
        ``kv/layer{i}`` entries from the first prefill when absent."""
        base = self.kv_spec.layer_codec(0)
        have = any(n == base or n.startswith(base + "/")
                   for n in self.registry.names())
        if not have:
            calibrate_cache(self.registry, self.cfg, row_states, tokens,
                            self.kv_spec)
        self._codec = PagedKVCache(self.kv_spec, self.cfg, self.registry,
                                   mesh=self._mesh)

    # ---- paging through the shared pool ---------------------------------

    def _page(self, seq: _Seq):
        if self._codec is None:
            return
        bt = self.kv_spec.block_tokens
        hot = self.kv_spec.hot_blocks
        while seq.evicted + (1 + hot) * bt <= seq.absorbed:
            t0 = seq.evicted
            self._evict_slot(seq, t0, t0 + bt)
            seq.evicted = t0 + bt

    def _evict_slot(self, seq: _Seq, t0: int, t1: int):
        """Encode one completed block of ``seq``'s slot row into the
        pool, then restore the row from the POOLED container — shared
        (deduped) bytes are what the model attends over."""
        row = _slot_view(self._states, seq.slot)
        new_row = dict(row)
        for i, kind in enumerate(self._kinds):
            key = f"l{i}"
            name = self.kv_spec.layer_codec(i)
            st = row[key]
            if kind == "attention":
                k, v = attn.kv_block_slice(st, t0, t1)
                block = self._codec.encode_block_arrays(
                    name, key, (k, v), start=t0, tokens=t1 - t0)
                digest = self._pool_put(seq, block)
                k2, v2 = self._codec.decode_block_arrays(
                    self.pool.get(digest))
                new_row[key] = attn.kv_block_restore(
                    st, t0, t1, jnp.asarray(k2), jnp.asarray(v2))
            else:
                arrays = ssm.state_snapshot(st)
                block = self._codec.encode_block_arrays(
                    name, key, arrays, start=t1, tokens=t1 - t0)
                digest = self._pool_put(seq, block)
                decoded = [jnp.asarray(a) for a in
                           self._codec.decode_block_arrays(
                               self.pool.get(digest))]
                new_row[key] = ssm.state_restore(st, decoded)
                # the newest snapshot supersedes the previous one
                old = seq.snap_digests.get(key)
                if old is not None:
                    self._pool_release(seq, old)
                seq.snap_digests[key] = digest
        self._states = _slot_write(self._states, seq.slot, new_row)

    def _pool_put(self, seq: _Seq, block) -> str:
        digest = self.pool.put(block)
        seq.digests.append(digest)
        self._dense_of[digest] = block.dense_bytes
        self._dense_logical += block.dense_bytes
        self.peak_dense_logical_bytes = max(self.peak_dense_logical_bytes,
                                            self._dense_logical)
        return digest

    def _pool_release(self, seq: _Seq, digest: str):
        self.pool.release(digest)
        seq.digests.remove(digest)
        self._dense_logical -= self._dense_of.get(digest, 0)

    def _release_all(self, seq: _Seq):
        for digest in list(seq.digests):
            self._pool_release(seq, digest)
        seq.snap_digests.clear()

    # ---- completion / rejection -----------------------------------------

    def _finish(self, seq: _Seq):
        seq.state = "finished"
        if seq.slot is not None:
            self._slots[seq.slot] = None
            seq.slot = None
        if self.pool is not None:
            self._release_all(seq)      # zero-ref blocks stay cached
        self._log("finish", seq.rid)

    def _reject(self, seq: _Seq, err: Exception, event: str = "reject"):
        seq.state = "rejected"
        seq.error = f"{type(err).__name__}: {err}"
        if seq.slot is not None:
            self._slots[seq.slot] = None
            seq.slot = None
        if self.pool is not None:
            self._release_all(seq)
        self._log(event, seq.rid)

    def _log(self, event: str, rid: str):
        self.events.append((self._step_idx, event, rid))

    # ---- accounting ------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """Engine accounting: request states, ms/token prefill + decode
        (the speed.md reporting format), KV codec counters, and the
        pool's byte-level stats (with ``dense_logical`` rows so the
        capacity win — dense bytes a dense cache would pin vs pooled
        compressed bytes — is one division away)."""
        by_state: Dict[str, int] = {}
        for s in self._seqs.values():
            by_state[s.state] = by_state.get(s.state, 0) + 1
        out: Dict[str, Any] = {
            "steps": self._step_idx,
            "requests": {st: by_state.get(st, 0) for st in
                         ("waiting", "running", "finished", "rejected")},
            "prefill_tokens": self._prefill_tokens,
            "decode_tokens": self._decode_tokens,
            "ms_per_token_prefill": (1e3 * self._prefill_s
                                     / max(1, self._prefill_tokens)),
            "ms_per_token_decode": (1e3 * self._decode_s
                                    / max(1, self._decode_tokens)),
            "dense_logical_bytes": self._dense_logical,
            "peak_dense_logical_bytes": self.peak_dense_logical_bytes,
        }
        if self._codec is not None:
            out["kv"] = {
                "overflow_sections": self._codec.overflow_sections,
                "raw_sections": self._codec.raw_sections,
            }
        if self.pool is not None:
            out["pool"] = self.pool.stats()
        return out
