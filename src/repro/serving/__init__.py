from repro.serving.engine import (  # noqa: F401
    ServeConfig, codec_from_manifest, compress_params_for_serving,
    generate, generate_from_wire, generate_paged, open_params, prefill,
    serving_manifest)
from repro.serving.kv_cache import (  # noqa: F401
    BlockPrefetcher, DeviceBlock, KVBlock, KVCacheOverflowError,
    KVCacheSpec, LayerFramePlan, PagedKVCache, SSMBoundaryTracker,
    all_gather_block_wire, calibrate_cache, kv_cache_manifest,
    kv_spec_from_manifest, open_kv_channels)
from repro.serving.scheduler import (  # noqa: F401
    Engine, GenerationRequest, RequestStatus)
from repro.comm.blockpool import (  # noqa: F401
    ArenaExhausted, ArenaStale, BlockArena, BlockPool, PoolExhausted)
