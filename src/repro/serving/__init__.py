from repro.serving.engine import (  # noqa: F401
    ServeConfig, compress_params_for_serving, generate, generate_from_wire,
    open_params, prefill)
