from repro.serving.engine import ServeConfig, generate, prefill  # noqa: F401
