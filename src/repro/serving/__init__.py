from repro.serving.engine import (  # noqa: F401
    ServeConfig, codec_from_manifest, compress_params_for_serving,
    generate, generate_from_wire, open_params, prefill, serving_manifest)
