"""Compressed block-paged KV / SSM-state cache for decode-step serving.

The decode-step state stream is the last bandwidth-bound tensor family
the repo did not compress (paper §7: per-tensor-type LUTs beyond
weights / grads / activations; ZipServ-style serving stacks live or die
on exactly this stream). This module pages it:

    hot window (dense tail) ──evict──▶ e4m3/byte symbols ──QLC──▶
    self-describing container (cold block) ──decode on access──▶
    dense values the decode step attends over

* :class:`KVCacheSpec` declares the paging policy: tokens per block,
  symbol mode, kernel toggle, codec prefix, optional cache mesh axis.
* :class:`PagedKVCache` owns the cold blocks. At every block boundary
  the completed block (attention K/V slice via
  ``models.attention.kv_block_slice``; the whole carried SSM state via
  ``models.ssm.state_snapshot``) is encoded through its layer's bound
  :class:`~repro.comm.channel.Channel` into a container
  (``repro.comm.container``), then decoded back into the resident
  window — the model only ever attends over values that round-tripped
  the wire, so the compressed path is genuinely on the token hot path,
  not a shadow copy.

Symbol modes (:func:`repro.comm.calibrate.kv_symbol_stream`):

``"qlc"`` (default, lossless)
    The block's raw bytes are the symbols — the checkpoint manager's
    byte-width trick extended to bf16/f32 states. Encode→decode is
    bit-exact, so serving output is **token-identical** to a dense
    cache while the wire moves fewer bytes (exponent/sign bytes of
    float states are highly skewed).
``"e4m3"``
    Blocks are block-32 e4m3-quantized on eviction and the QLC symbols
    are coded losslessly on top (the paper's native regime). The
    quantization is lossy — the standard fp8-KV-cache trade; the QLC
    coding itself adds zero further error (tested bit-exact against
    the quantize→dequantize reference).

Per-layer codecs are calibrated into the :class:`CodecRegistry` under
``kv/layer{i}`` (``repro.comm.calibrate.calibrate_kv_entries``;
bit-identical tables dedupe onto one scheme-id) and opened as channels
via :func:`open_kv_channels` — the same ``open_channels`` seam the
train/serve wires use, so cross-rank cache migration is one
``all_gather`` of container words over the channel's cache axis
(:func:`all_gather_block_wire`): compressed bytes are what cross the
wire, and the receiver decodes them from the registry alone.

Escape-pool overflow never corrupts a block: an overflowing encode
falls back to a raw (uncoded) container and is counted in
``stats()["overflow_sections"]``; a coded container whose pool
overflowed on the wire raises :class:`KVCacheOverflowError` at decode
instead of returning garbage.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import container as qc
from repro.comm.calibrate import (_layer_index, byte_planes,
                                  calibrate_kv_entries, kv_symbol_stream)
from repro.comm.compressed import (_compress_codes, _quantize,
                                   pad_to_multiple)
from repro.configs.base import ModelConfig
from repro.core import codec as _codec
from repro.models import attention as attn
from repro.models import ssm


class KVCacheOverflowError(RuntimeError):
    """A coded cache block's escape pool overflowed — decoding it would
    silently corrupt the cache, so the paged cache refuses."""


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Paging policy of a :class:`PagedKVCache`.

    ``block_tokens``
        Tokens per cold block (the encode/evict unit).
    ``hot_blocks``
        Extra *completed* blocks kept dense behind the write head
        (the filling block is always dense; 0 = encode at completion).
    ``mode``
        ``"qlc"`` (lossless byte symbols) or ``"e4m3"`` (quantize on
        eviction) — see the module docstring.
    ``use_kernels``
        Route block encode/decode through the fused Pallas dispatches.
    ``codec_prefix``
        Registry key prefix; layer *i*'s codec is
        ``f"{codec_prefix}/layer{i}"``.
    ``chunk_symbols``
        KV codec chunk size. Smaller than the collectives' 1024 because
        a cache block's container carries at least one pool slot of
        this size — 256 keeps the framing overhead small at realistic
        block sizes.
    ``exact_capacity``
        Cold blocks are static once completed (like weights), so by
        default each container's slot capacity is the block's measured
        max chunk size — zero escapes, unconditionally lossless.
        ``False`` uses the calibrated plan capacity + escape pool (the
        collectives' wire shape) instead.
    ``axis``
        Optional mesh axis cold blocks migrate over
        (:func:`all_gather_block_wire`).
    """
    block_tokens: int = 128
    hot_blocks: int = 0
    mode: str = "qlc"
    use_kernels: bool = False
    codec_prefix: str = "kv"
    chunk_symbols: int = 256
    exact_capacity: bool = True
    axis: Optional[str] = None

    def __post_init__(self):
        if self.block_tokens < 1:
            raise ValueError(f"block_tokens must be >= 1, got "
                             f"{self.block_tokens}")
        if self.mode not in ("qlc", "e4m3"):
            raise ValueError(f"unknown KV cache mode {self.mode!r}")

    def layer_codec(self, i: int) -> str:
        return f"{self.codec_prefix}/layer{i}"

    def to_json(self) -> Dict:
        return {"block_tokens": self.block_tokens,
                "hot_blocks": self.hot_blocks,
                "mode": self.mode,
                "use_kernels": self.use_kernels,
                "codec_prefix": self.codec_prefix,
                "chunk_symbols": self.chunk_symbols,
                "exact_capacity": self.exact_capacity,
                "axis": self.axis}

    @classmethod
    def from_json(cls, d: Dict) -> "KVCacheSpec":
        return cls(block_tokens=int(d["block_tokens"]),
                   hot_blocks=int(d.get("hot_blocks", 0)),
                   mode=d.get("mode", "qlc"),
                   use_kernels=bool(d.get("use_kernels", False)),
                   codec_prefix=d.get("codec_prefix", "kv"),
                   chunk_symbols=int(d.get("chunk_symbols", 256)),
                   exact_capacity=bool(d.get("exact_capacity", True)),
                   axis=d.get("axis"))


@dataclasses.dataclass(frozen=True)
class KVBlock:
    """One cold block: a self-describing container plus the geometry to
    rebuild its arrays."""
    layer: str                      # state slot key ("l0", "l1", ...)
    start: int                      # first token of the block (attn)
    tokens: int                     # tokens covered
    container: np.ndarray           # uint32 container words
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    coded: bool                     # any section QLC-coded (False =>
    #   all raw: calibration verdict or escape-pool overflow fallback)

    @property
    def wire_bytes(self) -> int:
        return qc.container_bytes(self.container)

    @property
    def dense_bytes(self) -> int:
        return int(sum(int(np.prod(s)) * np.dtype(d).itemsize
                       for s, d in zip(self.shapes, self.dtypes)))


def codec_wins(entry) -> bool:
    """Whether a calibrated KV entry actually beats the raw wire.

    A byte stream dominated by high-entropy mantissa planes calibrates
    to >= 8 expected bits/symbol (or an escape bound so large the pool
    stops being an exception path) — QLC cannot win there, so the paged
    cache wires such layers as raw containers instead of coding every
    chunk into the escape pool."""
    plan = entry.plan
    return (plan.expected_bits_per_symbol < 8.0
            and plan.escape_prob_bound < 0.25)


def open_kv_channels(registry, mesh=None, *, prefix: str = "kv",
                     axis: Optional[str] = None, transport: Any = None,
                     use_kernels: Optional[bool] = None) -> Dict[str, Any]:
    """Open one bound :class:`~repro.comm.channel.Channel` per
    ``f"{prefix}/..."`` registry entry — the KV slice of
    :func:`repro.comm.channel.open_channels`, sharing its axis-size
    resolution and autotune-cache plumbing."""
    from repro.comm.channel import open_channels
    chans = open_channels(registry, mesh, axis=axis, transport=transport,
                          use_kernels=use_kernels)
    return {n: c for n, c in chans.items() if n.startswith(prefix + "/")}


def all_gather_block_wire(words: jnp.ndarray, channel) -> jnp.ndarray:
    """Cross-rank cache migration body (call inside ``shard_map`` over
    the channel's cache axis): all-gather one cold block's container
    words ``u32 [W] -> u32 [D, W]``.

    Block geometry must be identical on every rank for the gather's
    static shape: same spec, same calibrated plan, and
    ``KVCacheSpec(exact_capacity=False)`` — the plan capacity is
    rank-independent where the per-block measured capacity is not.
    The *compressed* bytes are what cross the wire; each gathered row
    decodes on the receiver from the registry alone
    (:meth:`PagedKVCache.decode_block_arrays`)."""
    if channel.axis is None:
        raise ValueError("cache migration needs a channel with a mesh "
                         "axis; pass KVCacheSpec(axis=...)")
    return jax.lax.all_gather(jnp.asarray(words, jnp.uint32), channel.axis)


class PagedKVCache:
    """Block-paged compressed decode-state cache (host-driven paging
    around the jitted decode step — see
    :func:`repro.serving.engine.generate_paged`).

    ``registry`` must already hold the per-layer ``kv/layer{i}``
    entries (:func:`calibrate_cache` /
    :func:`repro.comm.calibrate.calibrate_kv_entries`); ``channels``
    defaults to :func:`open_kv_channels` over them.
    """

    def __init__(self, spec: KVCacheSpec, cfg: ModelConfig, registry,
                 channels: Optional[Dict[str, Any]] = None, mesh=None):
        self.spec = spec
        self.cfg = cfg
        self.registry = registry
        self.kinds = cfg.layer_kinds()
        if channels is None:
            channels = open_kv_channels(
                registry, mesh, prefix=spec.codec_prefix, axis=spec.axis,
                use_kernels=spec.use_kernels)
        self.channels = channels
        for i in range(len(self.kinds)):
            base = spec.layer_codec(i)
            if not any(n == base or n.startswith(base + "/")
                       for n in channels):
                raise KeyError(
                    f"no channel for {base!r}; calibrate the registry "
                    "first (calibrate_cache)")
        self.cold: List[KVBlock] = []          # attention blocks, ordered
        self.snapshots: Dict[str, KVBlock] = {}  # latest SSM state/layer
        self.tokens = 0                        # tokens absorbed
        self.evicted_tokens = 0                # tokens behind cold blocks
        self.overflow_sections = 0             # pool overflows (-> raw)
        self.raw_sections = 0                  # calibration said raw wins
        self._split_cache: Dict[str, bool] = {}

    # ---- paging ----------------------------------------------------------

    def note_tokens(self, states, total_tokens: int):
        """Advance the write head to ``total_tokens`` and page out every
        newly completed block (encode → container → decode back into
        the resident window). Returns the updated states pytree —
        bit-identical in ``"qlc"`` mode, e4m3-rounded in ``"e4m3"``."""
        total_tokens = int(total_tokens)
        if total_tokens < self.tokens:
            raise ValueError(f"token counter moved backwards: "
                             f"{self.tokens} -> {total_tokens}")
        self.tokens = total_tokens
        bt = self.spec.block_tokens
        while (self.evicted_tokens + (1 + self.spec.hot_blocks) * bt
               <= self.tokens):
            t0 = self.evicted_tokens
            states = self._evict(states, t0, t0 + bt)
            self.evicted_tokens = t0 + bt
        return states

    def _evict(self, states, t0: int, t1: int):
        new_states = dict(states)
        for i, kind in enumerate(self.kinds):
            key = f"l{i}"
            name = self.spec.layer_codec(i)
            st = states[key]
            if kind == "attention":
                k, v = attn.kv_block_slice(st, t0, t1)
                block = self.encode_block_arrays(name, key, (k, v),
                                                 start=t0, tokens=t1 - t0)
                k2, v2 = self.decode_block_arrays(block)
                new_states[key] = attn.kv_block_restore(
                    st, t0, t1, jnp.asarray(k2), jnp.asarray(v2))
                self.cold.append(block)
            else:
                arrays = ssm.state_snapshot(st)
                block = self.encode_block_arrays(name, key, arrays,
                                                 start=t1, tokens=t1 - t0)
                decoded = [jnp.asarray(a)
                           for a in self.decode_block_arrays(block)]
                new_states[key] = ssm.state_restore(st, decoded)
                self.snapshots[key] = block
        return new_states

    # ---- block codec -----------------------------------------------------

    def encode_block_arrays(self, name: str, layer: str,
                            arrays: Sequence[jnp.ndarray], *, start: int,
                            tokens: int) -> KVBlock:
        """Encode one block's arrays into a self-describing container
        through the layer's bound channel. Escape-pool overflow falls
        back to a raw (uncoded) container — surfaced in ``stats()``,
        never silently corrupted."""
        shapes = tuple(tuple(int(d) for d in a.shape) for a in arrays)
        dtypes = tuple(str(np.dtype(
            a.dtype if hasattr(a, "dtype") else np.asarray(a).dtype))
            for a in arrays)

        if self.spec.mode == "e4m3":
            ch = self.channels[name]
            flat = jnp.concatenate(
                [jnp.asarray(a, jnp.float32).reshape(-1) for a in arrays])
            padded, n = pad_to_multiple(flat, ch.cfg.chunk_symbols)
            codes, scales = _quantize(padded, ch.cfg)
            buf, coded = self._encode_section(name, codes, scales, n)
        elif self._plane_split(name):
            # One container per byte plane (mixed-scheme stream): the
            # compressible sign/exponent planes code under their own
            # LUT + measured capacity, mantissa planes ride raw.
            bufs, coded = [], False
            for (isz, j), plane in byte_planes(arrays).items():
                pname = f"{name}/w{isz}b{j}"
                ch = self.channels[pname]
                codes, n = pad_to_multiple(jnp.asarray(plane),
                                           ch.cfg.chunk_symbols)
                b, c = self._encode_section(pname, codes, None, n)
                bufs.append(b)
                coded = coded or c
            buf = qc.pack_stream(bufs)
        else:
            # tiny layer: one interleaved byte stream (calibration
            # found plane framing would cost more than it saves)
            ch = self.channels[name]
            syms = kv_symbol_stream(arrays, "qlc")
            codes, n = pad_to_multiple(jnp.asarray(syms),
                                       ch.cfg.chunk_symbols)
            buf, coded = self._encode_section(name, codes, None, n)
        return KVBlock(layer=layer, start=start, tokens=tokens,
                       container=buf, shapes=shapes, dtypes=dtypes,
                       coded=coded)

    def _plane_split(self, base: str) -> bool:
        """Whether calibration chose per-plane codecs for this layer
        (recorded by which registry names exist)."""
        cached = self._split_cache.get(base)
        if cached is None:
            cached = any(n.startswith(base + "/w")
                         for n in self.registry.names())
            self._split_cache[base] = cached
        return cached

    def _encode_section(self, name: str, codes, scales, n_valid: int
                        ) -> Tuple[np.ndarray, bool]:
        """Encode one symbol stream into a container section through
        its bound channel. A section is only coded when that actually
        shrinks it: the calibration verdict (:func:`codec_wins`) is a
        cheap pre-filter, and the measured slot capacity is compared
        against the raw wire per block — a drifted distribution can
        never expand the cache past raw + header."""
        ch = self.channels[name]
        entry = self.registry[name]
        k = ch.cfg.chunk_symbols
        n_chunks = int(codes.size) // k
        coded = codec_wins(entry)
        if coded:
            cfg = self._block_cfg(ch, codes)
            coded_words = (n_chunks * cfg.capacity_words
                           + cfg.pool_slots(n_chunks) * (k // 4))
            coded = coded_words < n_chunks * (k // 4)
        if coded:
            payload = _compress_codes(codes, ch.tables, cfg)
            coded, payload, cfg = self._overflow_fallback(
                payload, cfg, ch=ch, codes=codes)
        else:
            self.raw_sections += 1
            coded, payload, cfg = self._raw_wire(ch, codes)
        return qc.pack_payload(
            payload, scales, scheme_id=entry.scheme_id, cfg=cfg,
            n_valid=n_valid,
            prefix_bits=entry.tables.prefix_bits), coded

    def _block_cfg(self, ch, codes):
        """Wire config for one coded block. With
        ``spec.exact_capacity`` the slot capacity is this block's
        measured max chunk size (the weight wire's zero-escape trick —
        cold blocks are equally static); otherwise the calibrated plan
        capacity + escape pool."""
        if not self.spec.exact_capacity:
            return ch.cfg
        chunks = codes.reshape(-1, ch.cfg.chunk_symbols)
        nbits = _codec.encode_chunk_bits(
            chunks, jnp.asarray(ch.tables.enc_len, jnp.uint32))
        cap = max(1, int(np.ceil(float(jnp.max(nbits)) / 32)))
        return dataclasses.replace(ch.cfg, capacity_words=cap,
                                   pool_slots_per_1k=1)

    def _raw_wire(self, ch, codes):
        """Uncoded (``enabled=False``) wire form of a block. The raw
        decode path never touches the escape pool, so the container
        carries zero pool slots — pure payload + header."""
        raw_cfg = dataclasses.replace(ch.cfg, enabled=False)
        payload = _compress_codes(codes, ch.tables, raw_cfg)
        payload = payload._replace(
            pool=jnp.zeros(payload.pool.shape[:-2]
                           + (0, payload.pool.shape[-1]), jnp.uint32))
        return False, payload, raw_cfg

    def _overflow_fallback(self, payload, cfg, *, ch, codes):
        """ok-check one encoded payload; on pool overflow re-wire the
        block raw (``enabled=False``) instead of dropping escapes.
        (Unreachable with ``exact_capacity`` — zero escapes by
        construction.)"""
        pool_slots = payload.pool.shape[-2]
        if int(np.asarray(payload.pool_count).reshape(-1)[0]) <= pool_slots:
            return True, payload, cfg
        self.overflow_sections += 1
        return self._raw_wire(ch, codes)

    def decode_block_arrays(self, block: KVBlock) -> List[np.ndarray]:
        """Container stream -> the block's arrays, exactly as encoded
        (byte planes in ``"qlc"`` mode, dequantized e4m3 values in
        ``"e4m3"``). Raises :class:`KVCacheOverflowError` when a coded
        section's escape pool overflowed (decoding would corrupt
        silently)."""
        if self.spec.mode == "e4m3":
            vals, ok, _ = qc.decode_values(
                block.container, self.registry,
                use_kernels=self.spec.use_kernels)
            if not bool(ok):
                raise KVCacheOverflowError(
                    f"block {block.layer}@{block.start}: escape pool "
                    "overflow")
            vals = np.asarray(vals)
            out, pos = [], 0
            for s, d in zip(block.shapes, block.dtypes):
                n = int(np.prod(s))
                out.append(vals[pos:pos + n].astype(np.dtype(d))
                           .reshape(s))
                pos += n
            return out
        base = self.spec.layer_codec(_layer_index(block.layer))
        if not self._plane_split(base):
            syms, ok, _ = qc.decode_codes(
                block.container, self.registry,
                use_kernels=self.spec.use_kernels)
            if not bool(ok):
                raise KVCacheOverflowError(
                    f"block {block.layer}@{block.start}: escape pool "
                    "overflow")
            raw = np.asarray(syms)
            out, pos = [], 0
            for s, d in zip(block.shapes, block.dtypes):
                nb = int(np.prod(s)) * np.dtype(d).itemsize
                out.append(raw[pos:pos + nb].view(np.dtype(d)).reshape(s))
                pos += nb
            return out
        # plane-split layer: one section per byte plane, in byte_planes
        # order (itemsize ascending, then byte index) — fully determined
        # by the block's shapes/dtypes, nothing extra on the wire. All
        # coded sections decode in ONE batched multi-LUT dispatch
        # (container.decode_codes_stream) — this is the decode-on-access
        # hot path.
        sections = qc.decode_codes_stream(
            block.container, self.registry,
            use_kernels=self.spec.use_kernels)
        order = self._plane_order(block.dtypes)
        assert len(sections) == len(order), (len(sections), len(order))
        planes: Dict[Tuple[int, int], np.ndarray] = {}
        for (isz, j), (syms, ok) in zip(order, sections):
            if not bool(ok):
                raise KVCacheOverflowError(
                    f"block {block.layer}@{block.start} plane "
                    f"w{isz}b{j}: escape pool overflow")
            planes[(isz, j)] = np.asarray(syms)
        return self._unplane(planes, block.shapes, block.dtypes)

    @staticmethod
    def _plane_order(dtypes) -> List[Tuple[int, int]]:
        sizes = sorted({np.dtype(d).itemsize for d in dtypes})
        return [(isz, j) for isz in sizes for j in range(isz)]

    @staticmethod
    def _unplane(planes, shapes, dtypes) -> List[np.ndarray]:
        """Inverse of :func:`repro.comm.calibrate.byte_planes`."""
        mats, cursor = {}, {}
        for isz in sorted({np.dtype(d).itemsize for d in dtypes}):
            n = sum(int(np.prod(s)) for s, d in zip(shapes, dtypes)
                    if np.dtype(d).itemsize == isz)
            mats[isz] = np.stack(
                [planes[(isz, j)][:n] for j in range(isz)], axis=1)
            cursor[isz] = 0
        out = []
        for s, d in zip(shapes, dtypes):
            dt = np.dtype(d)
            n = int(np.prod(s))
            c = cursor[dt.itemsize]
            rows = np.ascontiguousarray(mats[dt.itemsize][c:c + n])
            cursor[dt.itemsize] = c + n
            out.append(rows.reshape(-1).view(dt).reshape(s))
        return out

    # ---- accounting / migration -----------------------------------------

    def stats(self) -> Dict[str, float]:
        """Wire accounting of the cold cache: compressed vs dense bytes
        per evicted token (attention blocks + latest SSM snapshots)."""
        blocks = self.cold + list(self.snapshots.values())
        wire = sum(b.wire_bytes for b in blocks)
        dense = sum(b.dense_bytes for b in blocks)
        toks = max(1, self.evicted_tokens)
        return {
            "tokens": self.tokens,
            "evicted_tokens": self.evicted_tokens,
            "cold_blocks": len(self.cold),
            "overflow_sections": self.overflow_sections,
            "raw_sections": self.raw_sections,
            "cold_wire_bytes": wire,
            "cold_dense_bytes": dense,
            "compressed_bytes_per_token": wire / toks,
            "dense_bytes_per_token": dense / toks,
            "compressed_vs_dense_ratio": (wire / dense) if dense else 0.0,
        }

    def block_wire(self, block: KVBlock) -> jnp.ndarray:
        """A cold block's container words as a device array — the
        migration payload for :func:`all_gather_block_wire`."""
        return jnp.asarray(block.container)


# --------------------------------------------------------------------------
# Calibration glue (decode states -> per-layer registry entries)
# --------------------------------------------------------------------------

def calibration_arrays(cfg: ModelConfig, states, tokens: int
                       ) -> Dict[str, List[jnp.ndarray]]:
    """Per-layer-slot state arrays of a (e.g. prefill) decode-states
    snapshot — the histogram source for
    :func:`~repro.comm.calibrate.calibrate_kv_entries`. Attention slots
    contribute their filled ``[0, tokens)`` K/V slice; SSM slots their
    whole carried state."""
    out: Dict[str, List[jnp.ndarray]] = {}
    for i, kind in enumerate(cfg.layer_kinds()):
        st = states[f"l{i}"]
        if kind == "attention":
            k, v = attn.kv_block_slice(st, 0, tokens)
            out[f"l{i}"] = [k, v]
        else:
            out[f"l{i}"] = list(ssm.state_snapshot(st))
    return out


def calibrate_cache(registry, cfg: ModelConfig, states, tokens: int,
                    spec: KVCacheSpec, **kw):
    """Calibrate ``kv/layer{i}`` codecs for a model's decode states into
    ``registry`` (layers with bit-identical tables share a scheme-id).
    Returns ``{name: CodecEntry}``."""
    kw.setdefault("chunk_symbols", spec.chunk_symbols)
    return calibrate_kv_entries(
        registry, calibration_arrays(cfg, states, tokens),
        mode=spec.mode, prefix=spec.codec_prefix, **kw)


# --------------------------------------------------------------------------
# Manifest round-trip (serving handoff, next to the weight placement)
# --------------------------------------------------------------------------

def kv_cache_manifest(spec: KVCacheSpec, registry) -> Dict:
    """JSON-able KV recipe: the paging spec + per-layer scheme-ids (the
    tables themselves ride the registry JSON, shared with the weight
    wire)."""
    names = sorted(n for n in registry.names()
                   if n.startswith(spec.codec_prefix + "/"))
    return {"spec": spec.to_json(),
            "scheme_ids": {n: registry[n].scheme_id for n in names}}


def kv_spec_from_manifest(d: Dict) -> Tuple[KVCacheSpec, Dict[str, int]]:
    """Inverse of :func:`kv_cache_manifest`."""
    return (KVCacheSpec.from_json(d["spec"]),
            {str(k): int(v) for k, v in d.get("scheme_ids", {}).items()})
