"""Compressed block-paged KV / SSM-state cache for decode-step serving.

The decode-step state stream is the last bandwidth-bound tensor family
the repo did not compress (paper §7: per-tensor-type LUTs beyond
weights / grads / activations; ZipServ-style serving stacks live or die
on exactly this stream). This module pages it:

    hot window (dense tail) ──evict──▶ e4m3/byte symbols ──QLC──▶
    self-describing container (cold block) ──decode on access──▶
    dense values the decode step attends over

* :class:`KVCacheSpec` declares the paging policy: tokens per block,
  symbol mode, kernel toggle, codec prefix, optional cache mesh axis.
* :class:`PagedKVCache` owns the cold blocks. At every block boundary
  the completed block (attention K/V slice via
  ``models.attention.kv_block_slice``; the whole carried SSM state via
  ``models.ssm.state_snapshot``) is encoded through its layer's bound
  :class:`~repro.comm.channel.Channel` into a container
  (``repro.comm.container``), then decoded back into the resident
  window — the model only ever attends over values that round-tripped
  the wire, so the compressed path is genuinely on the token hot path,
  not a shadow copy.

Symbol modes (:func:`repro.comm.calibrate.kv_symbol_stream`):

``"qlc"`` (default, lossless)
    The block's raw bytes are the symbols — the checkpoint manager's
    byte-width trick extended to bf16/f32 states. Encode→decode is
    bit-exact, so serving output is **token-identical** to a dense
    cache while the wire moves fewer bytes (exponent/sign bytes of
    float states are highly skewed).
``"e4m3"``
    Blocks are block-32 e4m3-quantized on eviction and the QLC symbols
    are coded losslessly on top (the paper's native regime). The
    quantization is lossy — the standard fp8-KV-cache trade; the QLC
    coding itself adds zero further error (tested bit-exact against
    the quantize→dequantize reference).

Per-layer codecs are calibrated into the :class:`CodecRegistry` under
``kv/layer{i}`` (``repro.comm.calibrate.calibrate_kv_entries``;
bit-identical tables dedupe onto one scheme-id) and opened as channels
via :func:`open_kv_channels` — the same ``open_channels`` seam the
train/serve wires use, so cross-rank cache migration is one
``all_gather`` of container words over the channel's cache axis
(:func:`all_gather_block_wire`): compressed bytes are what cross the
wire, and the receiver decodes them from the registry alone.

Escape-pool overflow never corrupts a block: an overflowing encode
falls back to a raw (uncoded) container and is counted in
``stats()["overflow_sections"]``; a coded container whose pool
overflowed on the wire raises :class:`KVCacheOverflowError` at decode
instead of returning garbage.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import container as qc
from repro.comm.blockpool import ArenaStale, BlockArena
from repro.comm.calibrate import (_layer_index, byte_planes,
                                  calibrate_kv_entries, kv_symbol_stream)
from repro.comm.compressed import (WirePayload, _compress_codes,
                                   _decompress_codes, _quantize,
                                   pad_to_multiple)
from repro.configs.base import ModelConfig
from repro.core import codec as _codec
from repro.models import attention as attn
from repro.models import ssm


class KVCacheOverflowError(RuntimeError):
    """A coded cache block's escape pool overflowed — decoding it would
    silently corrupt the cache, so the paged cache refuses."""


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Paging policy of a :class:`PagedKVCache`.

    ``block_tokens``
        Tokens per cold block (the encode/evict unit).
    ``hot_blocks``
        Extra *completed* blocks kept dense behind the write head
        (the filling block is always dense; 0 = encode at completion).
    ``mode``
        ``"qlc"`` (lossless byte symbols) or ``"e4m3"`` (quantize on
        eviction) — see the module docstring.
    ``use_kernels``
        Route block encode/decode through the fused Pallas dispatches.
    ``codec_prefix``
        Registry key prefix; layer *i*'s codec is
        ``f"{codec_prefix}/layer{i}"``.
    ``chunk_symbols``
        KV codec chunk size. Smaller than the collectives' 1024 because
        a cache block's container carries at least one pool slot of
        this size — 256 keeps the framing overhead small at realistic
        block sizes.
    ``exact_capacity``
        Cold blocks are static once completed (like weights), so by
        default each container's slot capacity is the block's measured
        max chunk size — zero escapes, unconditionally lossless.
        ``False`` uses the calibrated plan capacity + escape pool (the
        collectives' wire shape) instead.
    ``ssm_rebase``
        Segment-local SSM snapshot re-basing: recurrent layers snapshot
        the state AT each block boundary (captured by the engine during
        segmented prefill / at window boundaries) instead of the
        cumulative live state, so a boundary-``t`` container depends
        only on tokens ``< t`` and pooled dedup fires for shared prompt
        *prefixes*, not only fully identical prompts. Lossless
        (``"qlc"``) mode only — forced off under ``"e4m3"``, where the
        live state must round-trip the quantizer to stay the serving
        path's single source of truth.
    ``axis``
        Optional mesh axis cold blocks migrate over
        (:func:`all_gather_block_wire`).
    """
    block_tokens: int = 128
    hot_blocks: int = 0
    mode: str = "qlc"
    use_kernels: bool = False
    codec_prefix: str = "kv"
    chunk_symbols: int = 256
    exact_capacity: bool = True
    ssm_rebase: bool = True
    axis: Optional[str] = None

    def __post_init__(self):
        if self.block_tokens < 1:
            raise ValueError(f"block_tokens must be >= 1, got "
                             f"{self.block_tokens}")
        if self.mode not in ("qlc", "e4m3"):
            raise ValueError(f"unknown KV cache mode {self.mode!r}")
        if self.mode != "qlc" and self.ssm_rebase:
            object.__setattr__(self, "ssm_rebase", False)

    def layer_codec(self, i: int) -> str:
        return f"{self.codec_prefix}/layer{i}"

    def to_json(self) -> Dict:
        return {"block_tokens": self.block_tokens,
                "hot_blocks": self.hot_blocks,
                "mode": self.mode,
                "use_kernels": self.use_kernels,
                "codec_prefix": self.codec_prefix,
                "chunk_symbols": self.chunk_symbols,
                "exact_capacity": self.exact_capacity,
                "ssm_rebase": self.ssm_rebase,
                "axis": self.axis}

    @classmethod
    def from_json(cls, d: Dict) -> "KVCacheSpec":
        return cls(block_tokens=int(d["block_tokens"]),
                   hot_blocks=int(d.get("hot_blocks", 0)),
                   mode=d.get("mode", "qlc"),
                   use_kernels=bool(d.get("use_kernels", False)),
                   codec_prefix=d.get("codec_prefix", "kv"),
                   chunk_symbols=int(d.get("chunk_symbols", 256)),
                   exact_capacity=bool(d.get("exact_capacity", True)),
                   ssm_rebase=bool(d.get("ssm_rebase", True)),
                   axis=d.get("axis"))


@dataclasses.dataclass(frozen=True)
class KVBlock:
    """One cold block: a self-describing container plus the geometry to
    rebuild its arrays."""
    layer: str                      # state slot key ("l0", "l1", ...)
    start: int                      # first token of the block (attn)
    tokens: int                     # tokens covered
    container: np.ndarray           # uint32 container words
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    coded: bool                     # any section QLC-coded (False =>
    #   all raw: calibration verdict or escape-pool overflow fallback)

    @property
    def wire_bytes(self) -> int:
        return qc.container_bytes(self.container)

    @property
    def dense_bytes(self) -> int:
        return int(sum(int(np.prod(s)) * np.dtype(d).itemsize
                       for s, d in zip(self.shapes, self.dtypes)))


# --------------------------------------------------------------------------
# Device-resident framing (async paging): static frame plans
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SectionPlan:
    """Static geometry of ONE container section of a layer's block
    under the calibrated plan config. Because every field is fixed at
    plan time (``KVCacheSpec(exact_capacity=False)``), the container
    header is a compile-time constant and the decode can slice the
    section out of the arena words at a static offset — no host header
    parse on the async path."""
    name: str                         # registry/channel name
    plane: Optional[Tuple[int, int]]  # (itemsize, byte) or None
    offset: int                       # word offset within the block
    header: qc.ContainerHeader
    cfg: Any                          # CommConfig of the wire form


@dataclasses.dataclass(frozen=True)
class LayerFramePlan:
    """Fixed container geometry of one layer's block: the section table
    the device encode/decode pair shares. ``total_words`` sizes the
    arena slot."""
    name: str
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    split: bool
    sections: Tuple[SectionPlan, ...]
    total_words: int


@dataclasses.dataclass
class DeviceBlock:
    """A block framed on device: container words resident in HBM (and,
    once written, in the :class:`~repro.comm.blockpool.BlockArena`),
    never round-tripped through host numpy on the paging hot path."""
    layer: str
    start: int
    tokens: int
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    plan: LayerFramePlan
    words: jnp.ndarray              # u32 [plan.total_words], device
    coded: bool
    slot: Optional[int] = None      # arena slot once written
    gen: int = 0

    def host_block(self) -> KVBlock:
        """Materialize the host :class:`KVBlock` (pool accounting /
        digests). Call after ``copy_to_host_async`` had time to land —
        ideally behind the next window's dispatch."""
        return KVBlock(layer=self.layer, start=self.start,
                       tokens=self.tokens,
                       container=np.asarray(self.words),
                       shapes=self.shapes, dtypes=self.dtypes,
                       coded=self.coded)


def _device_bytes(a) -> jnp.ndarray:
    """Little-endian bytes of a device array, ``u8 [n_values,
    itemsize]`` — the device twin of numpy's ``.view(np.uint8)``."""
    a = jnp.asarray(a)
    isz = np.dtype(a.dtype).itemsize
    if isz == 1:
        return a.astype(jnp.uint8).reshape(-1, 1)
    return jax.lax.bitcast_convert_type(
        a.reshape(-1), jnp.uint8).reshape(-1, isz)


def device_byte_planes(arrays) -> Dict[Tuple[int, int], jnp.ndarray]:
    """Device twin of :func:`repro.comm.calibrate.byte_planes` — same
    plane order, same bytes, no host round trip."""
    groups: Dict[int, list] = {}
    for a in arrays:
        b = _device_bytes(a)
        groups.setdefault(b.shape[1], []).append(b)
    out: Dict[Tuple[int, int], jnp.ndarray] = {}
    for isz in sorted(groups):
        mat = jnp.concatenate(groups[isz], axis=0)
        for j in range(isz):
            out[(isz, j)] = mat[:, j]
    return out


def device_symbol_stream(arrays) -> jnp.ndarray:
    """Device twin of the lossless ``kv_symbol_stream``: the arrays'
    raw bytes, concatenated in order."""
    return jnp.concatenate([_device_bytes(a).reshape(-1) for a in arrays])


def _device_unplane(planes, shapes, dtypes) -> List[jnp.ndarray]:
    """Device twin of :meth:`PagedKVCache._unplane` (bitcast instead of
    numpy view)."""
    mats: Dict[int, jnp.ndarray] = {}
    cursor: Dict[int, int] = {}
    for isz in sorted({np.dtype(d).itemsize for d in dtypes}):
        n = sum(int(np.prod(s)) for s, d in zip(shapes, dtypes)
                if np.dtype(d).itemsize == isz)
        mats[isz] = jnp.stack(
            [planes[(isz, j)][:n] for j in range(isz)], axis=1)
        cursor[isz] = 0
    out = []
    for s, d in zip(shapes, dtypes):
        dt = np.dtype(d)
        n = int(np.prod(s))
        c = cursor[dt.itemsize]
        rows = mats[dt.itemsize][c:c + n]
        cursor[dt.itemsize] = c + n
        out.append(_bytes_to_dtype(rows, dt).reshape(s))
    return out


def _bytes_to_dtype(rows: jnp.ndarray, dt: np.dtype) -> jnp.ndarray:
    """u8 [n, itemsize] -> [n] values of ``dt`` (little-endian)."""
    if dt.itemsize == 1:
        return jax.lax.bitcast_convert_type(rows[:, 0], dt)
    return jax.lax.bitcast_convert_type(rows, dt)


class BlockPrefetcher:
    """Schedule/consume tracking for async block decodes — overlap is
    *measured* here, not assumed by construction.

    ``schedule`` dispatches a block's device decode (through the DMA
    prefetch kernel) and timestamps it; ``consume`` validates the
    result at its use point: arena generation check first (a block
    evicted between schedule and consume surfaces a typed
    :class:`~repro.comm.blockpool.ArenaStale`, never stale data), then
    the escape-pool ok flags (:class:`KVCacheOverflowError`), recording
    whether the decode was already finished (hit) or had to be waited
    on (stall). ``hidden_s / (hidden_s + stall_s)`` is the
    trace-derived overlap fraction the ``kv_prefetch_overlap`` bench
    row gates."""

    def __init__(self, cache: "PagedKVCache"):
        self.cache = cache
        self.scheduled = 0
        self.hits = 0
        self.stalled = 0
        self.misses = 0              # fell back to the host sync path
        self.bytes_prefetched = 0
        self.hidden_s = 0.0
        self.stall_s = 0.0

    def schedule(self, block: DeviceBlock) -> "PrefetchHandle":
        """Dispatch the block's decode from its (arena-resident) words
        and start the container's host copy (deferred digest/pool
        accounting)."""
        words = block.words
        if self.cache.arena is not None and block.slot is not None:
            words = self.cache.arena.read(block.slot, block.gen,
                                          n_words=block.words.shape[0])
        arrays, oks = self.cache.decode_block_device(block.plan, words)
        try:                       # start the D2H early; lands behind
            block.words.copy_to_host_async()   # the next window's work
        except AttributeError:
            pass
        self.scheduled += 1
        self.bytes_prefetched += int(block.words.shape[0]) * 4
        return PrefetchHandle(block=block, arrays=arrays, oks=oks,
                              t_sched=time.perf_counter())

    def consume(self, handle: "PrefetchHandle") -> List[jnp.ndarray]:
        block = handle.block
        if self.cache.arena is not None and block.slot is not None:
            # Typed staleness before touching data: raises ArenaStale.
            self.cache.arena.check(block.slot, block.gen)
        t0 = time.perf_counter()
        ready = all(a.is_ready() for a in handle.arrays)
        if ready:
            self.hits += 1
        else:
            self.stalled += 1
        for a in handle.arrays:
            a.block_until_ready()
        t1 = time.perf_counter()
        self.stall_s += t1 - t0
        self.hidden_s += max(0.0, t0 - handle.t_sched)
        for ok in handle.oks:
            if not bool(ok):
                raise KVCacheOverflowError(
                    f"block {block.layer}@{block.start}: escape pool "
                    "overflow")
        handle.consumed = True
        return handle.arrays

    def miss(self):
        self.misses += 1

    def overlap_fraction(self) -> float:
        tot = self.hidden_s + self.stall_s
        return (self.hidden_s / tot) if tot > 0 else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "scheduled": self.scheduled,
            "hits": self.hits,
            "misses": self.misses,
            "stalled": self.stalled,
            "bytes_prefetched": self.bytes_prefetched,
            "hidden_ms": 1e3 * self.hidden_s,
            "stall_ms": 1e3 * self.stall_s,
            "overlap_fraction": self.overlap_fraction(),
        }


@dataclasses.dataclass
class PrefetchHandle:
    """One scheduled async block decode (schedule -> consume)."""
    block: DeviceBlock
    arrays: List[jnp.ndarray]
    oks: List[jnp.ndarray]
    t_sched: float
    consumed: bool = False


class SSMBoundaryTracker:
    """Per-slot block-boundary snapshots for segment-local SSM state
    re-basing (``KVCacheSpec.ssm_rebase``).

    The engine records each recurrent layer's state arrays whenever a
    slot's absorbed-token count crosses a ``block_tokens`` boundary
    (during segmented prefill and between decode windows). Eviction of
    block ``[t0, t1)`` then encodes the **t1 snapshot** — whose bytes
    depend only on tokens ``< t1`` — instead of the cumulative live
    state, so two requests sharing a prompt prefix produce bit-identical
    snapshot containers and dedup in the pool (the ROADMAP small-gap
    item). The live state is never rewritten from a rebased snapshot:
    it has absorbed tokens past the boundary that the snapshot, by
    design, excludes."""

    def __init__(self):
        #: slot -> boundary t -> {layer key: tuple of state arrays}
        self._by_slot: Dict[int, Dict[int, Dict[str, tuple]]] = {}

    def record(self, slot: int, t: int, layer_arrays: Dict[str, tuple]):
        self._by_slot.setdefault(slot, {})[t] = layer_arrays

    def take(self, slot: int, t: int) -> Optional[Dict[str, tuple]]:
        """Pop the boundary-``t`` snapshot (and drop any older ones —
        a block's eviction retires every earlier boundary)."""
        snaps = self._by_slot.get(slot)
        if snaps is None:
            return None
        out = snaps.pop(t, None)
        for older in [b for b in snaps if b < t]:
            del snaps[older]
        return out

    def drop(self, slot: int):
        self._by_slot.pop(slot, None)


def codec_wins(entry) -> bool:
    """Whether a calibrated KV entry actually beats the raw wire.

    A byte stream dominated by high-entropy mantissa planes calibrates
    to >= 8 expected bits/symbol (or an escape bound so large the pool
    stops being an exception path) — QLC cannot win there, so the paged
    cache wires such layers as raw containers instead of coding every
    chunk into the escape pool."""
    plan = entry.plan
    return (plan.expected_bits_per_symbol < 8.0
            and plan.escape_prob_bound < 0.25)


def open_kv_channels(registry, mesh=None, *, prefix: str = "kv",
                     axis: Optional[str] = None, transport: Any = None,
                     use_kernels: Optional[bool] = None) -> Dict[str, Any]:
    """Open one bound :class:`~repro.comm.channel.Channel` per
    ``f"{prefix}/..."`` registry entry — the KV slice of
    :func:`repro.comm.channel.open_channels`, sharing its axis-size
    resolution and autotune-cache plumbing."""
    from repro.comm.channel import open_channels
    chans = open_channels(registry, mesh, axis=axis, transport=transport,
                          use_kernels=use_kernels)
    return {n: c for n, c in chans.items() if n.startswith(prefix + "/")}


def all_gather_block_wire(words: jnp.ndarray, channel) -> jnp.ndarray:
    """Cross-rank cache migration body (call inside ``shard_map`` over
    the channel's cache axis): all-gather one cold block's container
    words ``u32 [W] -> u32 [D, W]``.

    Block geometry must be identical on every rank for the gather's
    static shape: same spec, same calibrated plan, and
    ``KVCacheSpec(exact_capacity=False)`` — the plan capacity is
    rank-independent where the per-block measured capacity is not.
    The *compressed* bytes are what cross the wire; each gathered row
    decodes on the receiver from the registry alone
    (:meth:`PagedKVCache.decode_block_arrays`)."""
    if channel.axis is None:
        raise ValueError("cache migration needs a channel with a mesh "
                         "axis; pass KVCacheSpec(axis=...)")
    return jax.lax.all_gather(jnp.asarray(words, jnp.uint32), channel.axis)


class PagedKVCache:
    """Block-paged compressed decode-state cache (host-driven paging
    around the jitted decode step — see
    :func:`repro.serving.engine.generate_paged`).

    ``registry`` must already hold the per-layer ``kv/layer{i}``
    entries (:func:`calibrate_cache` /
    :func:`repro.comm.calibrate.calibrate_kv_entries`); ``channels``
    defaults to :func:`open_kv_channels` over them.
    """

    def __init__(self, spec: KVCacheSpec, cfg: ModelConfig, registry,
                 channels: Optional[Dict[str, Any]] = None, mesh=None,
                 arena: Optional[BlockArena] = None, monitor=None):
        self.spec = spec
        self.arena = arena
        self.cfg = cfg
        self.registry = registry
        #: optional ``repro.adaptive.TrafficMonitor``: every encoded
        #: section files its symbol histogram + escape/overflow
        #: pressure under the section's (name, scheme_id), feeding the
        #: drift policy. Hot-swap reaches the cache through the
        #: ``channels`` dict (wrap entries in ``AdaptiveChannel`` or
        #: swap them) — old blocks stay decodable, their containers
        #: carry the old scheme-id.
        self.monitor = monitor
        self.kinds = cfg.layer_kinds()
        if channels is None:
            channels = open_kv_channels(
                registry, mesh, prefix=spec.codec_prefix, axis=spec.axis,
                use_kernels=spec.use_kernels)
        self.channels = channels
        for i in range(len(self.kinds)):
            base = spec.layer_codec(i)
            if not any(n == base or n.startswith(base + "/")
                       for n in channels):
                raise KeyError(
                    f"no channel for {base!r}; calibrate the registry "
                    "first (calibrate_cache)")
        self.cold: List[KVBlock] = []          # attention blocks, ordered
        self.snapshots: Dict[str, KVBlock] = {}  # latest SSM state/layer
        self.tokens = 0                        # tokens absorbed
        self.evicted_tokens = 0                # tokens behind cold blocks
        self.overflow_sections = 0             # pool overflows (-> raw)
        self.raw_sections = 0                  # calibration said raw wins
        self._split_cache: Dict[str, bool] = {}
        self._plans: Dict[Tuple, LayerFramePlan] = {}
        self.prefetcher = BlockPrefetcher(self)

    # ---- paging ----------------------------------------------------------

    def note_tokens(self, states, total_tokens: int):
        """Advance the write head to ``total_tokens`` and page out every
        newly completed block (encode → container → decode back into
        the resident window). Returns the updated states pytree —
        bit-identical in ``"qlc"`` mode, e4m3-rounded in ``"e4m3"``."""
        total_tokens = int(total_tokens)
        if total_tokens < self.tokens:
            raise ValueError(f"token counter moved backwards: "
                             f"{self.tokens} -> {total_tokens}")
        self.tokens = total_tokens
        bt = self.spec.block_tokens
        while (self.evicted_tokens + (1 + self.spec.hot_blocks) * bt
               <= self.tokens):
            t0 = self.evicted_tokens
            states = self._evict(states, t0, t0 + bt)
            self.evicted_tokens = t0 + bt
        return states

    def _evict(self, states, t0: int, t1: int):
        new_states = dict(states)
        for i, kind in enumerate(self.kinds):
            key = f"l{i}"
            name = self.spec.layer_codec(i)
            st = states[key]
            if kind == "attention":
                k, v = attn.kv_block_slice(st, t0, t1)
                block = self.encode_block_arrays(name, key, (k, v),
                                                 start=t0, tokens=t1 - t0)
                k2, v2 = self.decode_block_arrays(block)
                new_states[key] = attn.kv_block_restore(
                    st, t0, t1, jnp.asarray(k2), jnp.asarray(v2))
                self.cold.append(block)
            else:
                arrays = ssm.state_snapshot(st)
                block = self.encode_block_arrays(name, key, arrays,
                                                 start=t1, tokens=t1 - t0)
                decoded = [jnp.asarray(a)
                           for a in self.decode_block_arrays(block)]
                new_states[key] = ssm.state_restore(st, decoded)
                self.snapshots[key] = block
        return new_states

    # ---- block codec -----------------------------------------------------

    def encode_block_arrays(self, name: str, layer: str,
                            arrays: Sequence[jnp.ndarray], *, start: int,
                            tokens: int) -> KVBlock:
        """Encode one block's arrays into a self-describing container
        through the layer's bound channel. Escape-pool overflow falls
        back to a raw (uncoded) container — surfaced in ``stats()``,
        never silently corrupted."""
        shapes = tuple(tuple(int(d) for d in a.shape) for a in arrays)
        dtypes = tuple(str(np.dtype(
            a.dtype if hasattr(a, "dtype") else np.asarray(a).dtype))
            for a in arrays)

        if self.spec.mode == "e4m3":
            ch = self.channels[name]
            flat = jnp.concatenate(
                [jnp.asarray(a, jnp.float32).reshape(-1) for a in arrays])
            padded, n = pad_to_multiple(flat, ch.cfg.chunk_symbols)
            codes, scales = _quantize(padded, ch.cfg)
            buf, coded = self._encode_section(name, codes, scales, n)
        elif self._plane_split(name):
            # One container per byte plane (mixed-scheme stream): the
            # compressible sign/exponent planes code under their own
            # LUT + measured capacity, mantissa planes ride raw.
            bufs, coded = [], False
            for (isz, j), plane in byte_planes(arrays).items():
                pname = f"{name}/w{isz}b{j}"
                ch = self.channels[pname]
                codes, n = pad_to_multiple(jnp.asarray(plane),
                                           ch.cfg.chunk_symbols)
                b, c = self._encode_section(pname, codes, None, n)
                bufs.append(b)
                coded = coded or c
            buf = qc.pack_stream(bufs)
        else:
            # tiny layer: one interleaved byte stream (calibration
            # found plane framing would cost more than it saves)
            ch = self.channels[name]
            syms = kv_symbol_stream(arrays, "qlc")
            codes, n = pad_to_multiple(jnp.asarray(syms),
                                       ch.cfg.chunk_symbols)
            buf, coded = self._encode_section(name, codes, None, n)
        return KVBlock(layer=layer, start=start, tokens=tokens,
                       container=buf, shapes=shapes, dtypes=dtypes,
                       coded=coded)

    def _plane_split(self, base: str) -> bool:
        """Whether calibration chose per-plane codecs for this layer
        (recorded by which registry names exist)."""
        cached = self._split_cache.get(base)
        if cached is None:
            cached = any(n.startswith(base + "/w")
                         for n in self.registry.names())
            self._split_cache[base] = cached
        return cached

    def _encode_section(self, name: str, codes, scales, n_valid: int
                        ) -> Tuple[np.ndarray, bool]:
        """Encode one symbol stream into a container section through
        its bound channel. A section is only coded when that actually
        shrinks it: the calibration verdict (:func:`codec_wins`) is a
        cheap pre-filter, and the measured slot capacity is compared
        against the raw wire per block — a drifted distribution can
        never expand the cache past raw + header."""
        ch = self.channels[name]
        entry = self.registry[name]
        k = ch.cfg.chunk_symbols
        n_chunks = int(codes.size) // k
        overflows0 = self.overflow_sections
        coded = codec_wins(entry)
        if coded:
            cfg = self._block_cfg(ch, codes)
            coded_words = (n_chunks * cfg.capacity_words
                           + cfg.pool_slots(n_chunks) * (k // 4))
            coded = coded_words < n_chunks * (k // 4)
        if coded:
            payload = _compress_codes(codes, ch.tables, cfg)
            coded, payload, cfg = self._overflow_fallback(
                payload, cfg, ch=ch, codes=codes)
        else:
            self.raw_sections += 1
            coded, payload, cfg = self._raw_wire(ch, codes)
        if self.monitor is not None:
            hist = np.bincount(
                np.asarray(codes).astype(np.uint8).reshape(-1)[:n_valid],
                minlength=256)[:256]
            escaped = (float(np.asarray(payload.pool_count).sum())
                       if coded else 0.0)
            self.monitor.observe(
                name, hist, escaped_chunks=escaped, chunks=n_chunks,
                overflow=self.overflow_sections > overflows0,
                containers=1.0, scheme_id=entry.scheme_id)
        return qc.pack_payload(
            payload, scales, scheme_id=entry.scheme_id, cfg=cfg,
            n_valid=n_valid,
            prefix_bits=entry.tables.prefix_bits), coded

    def _block_cfg(self, ch, codes):
        """Wire config for one coded block. With
        ``spec.exact_capacity`` the slot capacity is this block's
        measured max chunk size (the weight wire's zero-escape trick —
        cold blocks are equally static); otherwise the calibrated plan
        capacity + escape pool."""
        if not self.spec.exact_capacity:
            return ch.cfg
        chunks = codes.reshape(-1, ch.cfg.chunk_symbols)
        nbits = _codec.encode_chunk_bits(
            chunks, jnp.asarray(ch.tables.enc_len, jnp.uint32))
        cap = max(1, int(np.ceil(float(jnp.max(nbits)) / 32)))
        return dataclasses.replace(ch.cfg, capacity_words=cap,
                                   pool_slots_per_1k=1)

    def _raw_wire(self, ch, codes):
        """Uncoded (``enabled=False``) wire form of a block. The raw
        decode path never touches the escape pool, so the container
        carries zero pool slots — pure payload + header."""
        raw_cfg = dataclasses.replace(ch.cfg, enabled=False)
        payload = _compress_codes(codes, ch.tables, raw_cfg)
        payload = payload._replace(
            pool=jnp.zeros(payload.pool.shape[:-2]
                           + (0, payload.pool.shape[-1]), jnp.uint32))
        return False, payload, raw_cfg

    def _overflow_fallback(self, payload, cfg, *, ch, codes):
        """ok-check one encoded payload; on pool overflow re-wire the
        block raw (``enabled=False``) instead of dropping escapes.
        (Unreachable with ``exact_capacity`` — zero escapes by
        construction.)"""
        pool_slots = payload.pool.shape[-2]
        if int(np.asarray(payload.pool_count).reshape(-1)[0]) <= pool_slots:
            return True, payload, cfg
        self.overflow_sections += 1
        return self._raw_wire(ch, codes)

    def decode_block_arrays(self, block: KVBlock,
                            _prefetch: bool = False) -> List[np.ndarray]:
        """Container stream -> the block's arrays, exactly as encoded
        (byte planes in ``"qlc"`` mode, dequantized e4m3 values in
        ``"e4m3"``). Raises :class:`KVCacheOverflowError` when a coded
        section's escape pool overflowed (decoding would corrupt
        silently)."""
        if self.spec.mode == "e4m3":
            vals, ok, _ = qc.decode_values(
                block.container, self.registry,
                use_kernels=self.spec.use_kernels, prefetch=_prefetch)
            if not bool(ok):
                raise KVCacheOverflowError(
                    f"block {block.layer}@{block.start}: escape pool "
                    "overflow")
            vals = np.asarray(vals)
            out, pos = [], 0
            for s, d in zip(block.shapes, block.dtypes):
                n = int(np.prod(s))
                out.append(vals[pos:pos + n].astype(np.dtype(d))
                           .reshape(s))
                pos += n
            return out
        base = self.spec.layer_codec(_layer_index(block.layer))
        if not self._plane_split(base):
            syms, ok, _ = qc.decode_codes(
                block.container, self.registry,
                use_kernels=self.spec.use_kernels, prefetch=_prefetch)
            if not bool(ok):
                raise KVCacheOverflowError(
                    f"block {block.layer}@{block.start}: escape pool "
                    "overflow")
            raw = np.asarray(syms)
            out, pos = [], 0
            for s, d in zip(block.shapes, block.dtypes):
                nb = int(np.prod(s)) * np.dtype(d).itemsize
                out.append(raw[pos:pos + nb].view(np.dtype(d)).reshape(s))
                pos += nb
            return out
        # plane-split layer: one section per byte plane, in byte_planes
        # order (itemsize ascending, then byte index) — fully determined
        # by the block's shapes/dtypes, nothing extra on the wire. All
        # coded sections decode in ONE batched multi-LUT dispatch
        # (container.decode_codes_stream) — this is the decode-on-access
        # hot path.
        sections = qc.decode_codes_stream(
            block.container, self.registry,
            use_kernels=self.spec.use_kernels, prefetch=_prefetch)
        order = self._plane_order(block.dtypes)
        assert len(sections) == len(order), (len(sections), len(order))
        planes: Dict[Tuple[int, int], np.ndarray] = {}
        for (isz, j), (syms, ok) in zip(order, sections):
            if not bool(ok):
                raise KVCacheOverflowError(
                    f"block {block.layer}@{block.start} plane "
                    f"w{isz}b{j}: escape pool overflow")
            planes[(isz, j)] = np.asarray(syms)
        return self._unplane(planes, block.shapes, block.dtypes)

    @staticmethod
    def _plane_order(dtypes) -> List[Tuple[int, int]]:
        sizes = sorted({np.dtype(d).itemsize for d in dtypes})
        return [(isz, j) for isz in sizes for j in range(isz)]

    @staticmethod
    def _unplane(planes, shapes, dtypes) -> List[np.ndarray]:
        """Inverse of :func:`repro.comm.calibrate.byte_planes`."""
        mats, cursor = {}, {}
        for isz in sorted({np.dtype(d).itemsize for d in dtypes}):
            n = sum(int(np.prod(s)) for s, d in zip(shapes, dtypes)
                    if np.dtype(d).itemsize == isz)
            mats[isz] = np.stack(
                [planes[(isz, j)][:n] for j in range(isz)], axis=1)
            cursor[isz] = 0
        out = []
        for s, d in zip(shapes, dtypes):
            dt = np.dtype(d)
            n = int(np.prod(s))
            c = cursor[dt.itemsize]
            rows = np.ascontiguousarray(mats[dt.itemsize][c:c + n])
            cursor[dt.itemsize] = c + n
            out.append(rows.reshape(-1).view(dt).reshape(s))
        return out

    def decode_block_arrays_async(self, block: KVBlock) -> List[np.ndarray]:
        """:meth:`decode_block_arrays` with every coded section routed
        through the DMA double-buffered prefetch kernel
        (:func:`repro.kernels.ops.decode_block_async`) — bit-identical
        output, different word movement."""
        return self.decode_block_arrays(block, _prefetch=True)

    # ---- device-resident framing (async paging) --------------------------

    def frame_plan(self, name: str, shapes, dtypes) -> LayerFramePlan:
        """The static container geometry of one layer's block — cached
        per (layer, shapes, dtypes).

        Only legal under ``KVCacheSpec(mode="qlc",
        exact_capacity=False)``: plan capacity + escape pool is what
        makes every section's header (and so the whole frame) a
        compile-time constant the jitted encode/decode can share with
        the sync host path bit-for-bit."""
        shapes = tuple(tuple(int(d) for d in s) for s in shapes)
        dtypes = tuple(str(np.dtype(d)) for d in dtypes)
        key = (name, shapes, dtypes)
        cached = self._plans.get(key)
        if cached is not None:
            return cached
        if self.spec.mode != "qlc" or self.spec.exact_capacity:
            raise ValueError(
                "device framing needs KVCacheSpec(mode='qlc', "
                "exact_capacity=False): fixed plan geometry is what "
                "makes the container header a compile-time constant")
        split = self._plane_split(name)
        sections: List[SectionPlan] = []
        offset = 0
        if split:
            per_isz: Dict[int, int] = {}
            for s, d in zip(shapes, dtypes):
                isz = np.dtype(d).itemsize
                per_isz[isz] = per_isz.get(isz, 0) + int(np.prod(s))
            for isz, j in self._plane_order(dtypes):
                sp = self._section_plan(f"{name}/w{isz}b{j}", (isz, j),
                                        per_isz[isz], offset)
                sections.append(sp)
                offset += sp.header.total_words
        else:
            n = sum(int(np.prod(s)) * np.dtype(d).itemsize
                    for s, d in zip(shapes, dtypes))
            sp = self._section_plan(name, None, n, 0)
            sections.append(sp)
            offset = sp.header.total_words
        plan = LayerFramePlan(name=name, shapes=shapes, dtypes=dtypes,
                              split=split, sections=tuple(sections),
                              total_words=offset)
        self._plans[key] = plan
        return plan

    def _section_plan(self, pname: str, plane, n_valid: int,
                      offset: int) -> SectionPlan:
        """Plan one section: same coded/raw verdict and wire config the
        sync :meth:`_encode_section` reaches under
        ``exact_capacity=False``, evaluated on symbol *count* alone."""
        ch = self.channels[pname]
        entry = self.registry[pname]
        k = ch.cfg.chunk_symbols
        n_chunks = max(1, -(-n_valid // k))
        coded = codec_wins(entry)
        if coded:
            coded_words = (n_chunks * ch.cfg.capacity_words
                           + ch.cfg.pool_slots(n_chunks) * (k // 4))
            coded = coded_words < n_chunks * (k // 4)
        cfg = ch.cfg if coded else dataclasses.replace(ch.cfg,
                                                       enabled=False)
        h = qc.ContainerHeader(
            scheme_id=entry.scheme_id, coded=coded, chunk_symbols=k,
            capacity_words=ch.cfg.capacity_words if coded else k // 4,
            n_chunks=n_chunks,
            pool_slots=ch.cfg.pool_slots(n_chunks) if coded else 0,
            n_valid=n_valid, scale_dtype=None, n_scales=0,
            prefix_bits=entry.tables.prefix_bits)
        return SectionPlan(name=pname, plane=plane, offset=offset,
                           header=h, cfg=cfg)

    def encode_block_device(self, name: str, layer: str,
                            arrays: Sequence[jnp.ndarray], *, start: int,
                            tokens: int) -> Optional[DeviceBlock]:
        """Frame one block entirely on device: byte planes by bitcast,
        QLC encode per section, :func:`container.frame_block_device`
        assembly — the container words never visit host numpy. Returns
        ``None`` when a coded section's escape pool overflowed under
        the plan capacity (the caller falls back to the host sync
        path, which re-wires the block raw and counts the overflow)."""
        shapes = tuple(tuple(int(d) for d in a.shape) for a in arrays)
        dtypes = tuple(str(np.dtype(a.dtype)) for a in arrays)
        plan = self.frame_plan(name, shapes, dtypes)
        planes = device_byte_planes(arrays) if plan.split else None
        bufs: List[jnp.ndarray] = []
        pool_counts: List[jnp.ndarray] = []
        pool_slots: List[int] = []
        raw_in_block = 0
        any_coded = False
        for sp in plan.sections:
            stream = (planes[sp.plane] if plan.split
                      else device_symbol_stream(arrays))
            codes, _ = pad_to_multiple(stream, sp.cfg.chunk_symbols)
            ch = self.channels[sp.name]
            payload = _compress_codes(codes, ch.tables, sp.cfg)
            if sp.header.coded:
                any_coded = True
                pool_counts.append(
                    jnp.asarray(payload.pool_count, jnp.int32)
                    .reshape(-1)[:1])
                pool_slots.append(sp.header.pool_slots)
            else:
                raw_in_block += 1
                payload = payload._replace(
                    pool=jnp.zeros(payload.pool.shape[:-2]
                                   + (0, payload.pool.shape[-1]),
                                   jnp.uint32))
            bufs.append(qc.frame_block_device(
                payload, None, scheme_id=sp.header.scheme_id, cfg=sp.cfg,
                n_valid=sp.header.n_valid,
                prefix_bits=sp.header.prefix_bits))
        words = bufs[0] if len(bufs) == 1 else jnp.concatenate(bufs)
        if pool_counts:
            # The one host sync of the encode: a handful of int32
            # escape counts (not the container body).
            counts = np.asarray(jnp.concatenate(pool_counts))
            if any(int(c) > s for c, s in zip(counts, pool_slots)):
                return None
        self.raw_sections += raw_in_block
        return DeviceBlock(layer=layer, start=start, tokens=tokens,
                           shapes=shapes, dtypes=dtypes, plan=plan,
                           words=words, coded=any_coded)

    def decode_block_device(self, plan: LayerFramePlan,
                            words: jnp.ndarray
                            ) -> Tuple[List[jnp.ndarray],
                                       List[jnp.ndarray]]:
        """Decode a device-framed block straight from its (arena) words
        at the plan's static offsets — no host header parse. Coded
        sections decode through the DMA prefetch kernel. Returns the
        block's arrays plus per-coded-section device ok flags (checked
        at :meth:`BlockPrefetcher.consume`)."""
        streams: Dict[Any, jnp.ndarray] = {}
        oks: List[jnp.ndarray] = []
        for sp in plan.sections:
            h = sp.header
            body = words[sp.offset + qc.HEADER_WORDS:
                         sp.offset + h.total_words]
            pos = 0
            w = body[:h.words_len].reshape(h.n_chunks, h.capacity_words)
            pos += h.words_len
            fl = jax.lax.bitcast_convert_type(
                body[pos:pos + h.flags_len], jnp.uint8
            ).reshape(-1)[:h.n_chunks]
            pos += h.flags_len
            pool = body[pos:pos + h.pool_len].reshape(
                h.pool_slots, h.chunk_symbols // 4)
            pos += h.pool_len
            pc = body[pos:pos + 1].astype(jnp.int32)
            payload = WirePayload(words=w, flags=fl, pool=pool,
                                  pool_count=pc)
            ch = self.channels[sp.name]
            if h.coded:
                codes, ok = _decompress_codes(
                    payload, ch.tables, sp.cfg,
                    decode_fn=qc._prefetch_decode_fn())
                oks.append(ok)
            else:
                codes, _ = _decompress_codes(payload, ch.tables, sp.cfg)
            streams[sp.plane] = codes.reshape(-1)[:h.n_valid]
        if plan.split:
            return _device_unplane(streams, plan.shapes,
                                   plan.dtypes), oks
        raw = streams[None]
        out, pos = [], 0
        for s, d in zip(plan.shapes, plan.dtypes):
            dt = np.dtype(d)
            nb = int(np.prod(s)) * dt.itemsize
            rows = raw[pos:pos + nb].reshape(-1, dt.itemsize)
            pos += nb
            out.append(_bytes_to_dtype(rows, dt).reshape(s))
        return out, oks

    # ---- accounting / migration -----------------------------------------

    def stats(self) -> Dict[str, float]:
        """Wire accounting of the cold cache: compressed vs dense bytes
        per evicted token (attention blocks + latest SSM snapshots)."""
        blocks = self.cold + list(self.snapshots.values())
        wire = sum(b.wire_bytes for b in blocks)
        dense = sum(b.dense_bytes for b in blocks)
        toks = max(1, self.evicted_tokens)
        return {
            "tokens": self.tokens,
            "evicted_tokens": self.evicted_tokens,
            "cold_blocks": len(self.cold),
            "overflow_sections": self.overflow_sections,
            "raw_sections": self.raw_sections,
            "cold_wire_bytes": wire,
            "cold_dense_bytes": dense,
            "compressed_bytes_per_token": wire / toks,
            "dense_bytes_per_token": dense / toks,
            "compressed_vs_dense_ratio": (wire / dense) if dense else 0.0,
            "prefetch": self.prefetcher.stats(),
        }

    def block_wire(self, block: KVBlock) -> jnp.ndarray:
        """A cold block's container words as a device array — the
        migration payload for :func:`all_gather_block_wire`."""
        return jnp.asarray(block.container)


# --------------------------------------------------------------------------
# Calibration glue (decode states -> per-layer registry entries)
# --------------------------------------------------------------------------

def calibration_arrays(cfg: ModelConfig, states, tokens: int
                       ) -> Dict[str, List[jnp.ndarray]]:
    """Per-layer-slot state arrays of a (e.g. prefill) decode-states
    snapshot — the histogram source for
    :func:`~repro.comm.calibrate.calibrate_kv_entries`. Attention slots
    contribute their filled ``[0, tokens)`` K/V slice; SSM slots their
    whole carried state."""
    out: Dict[str, List[jnp.ndarray]] = {}
    for i, kind in enumerate(cfg.layer_kinds()):
        st = states[f"l{i}"]
        if kind == "attention":
            k, v = attn.kv_block_slice(st, 0, tokens)
            out[f"l{i}"] = [k, v]
        else:
            out[f"l{i}"] = list(ssm.state_snapshot(st))
    return out


def calibrate_cache(registry, cfg: ModelConfig, states, tokens: int,
                    spec: KVCacheSpec, **kw):
    """Calibrate ``kv/layer{i}`` codecs for a model's decode states into
    ``registry`` (layers with bit-identical tables share a scheme-id).
    Returns ``{name: CodecEntry}``."""
    kw.setdefault("chunk_symbols", spec.chunk_symbols)
    return calibrate_kv_entries(
        registry, calibration_arrays(cfg, states, tokens),
        mode=spec.mode, prefix=spec.codec_prefix, **kw)


# --------------------------------------------------------------------------
# Manifest round-trip (serving handoff, next to the weight placement)
# --------------------------------------------------------------------------

def kv_cache_manifest(spec: KVCacheSpec, registry) -> Dict:
    """JSON-able KV recipe: the paging spec + per-layer scheme-ids (the
    tables themselves ride the registry JSON, shared with the weight
    wire)."""
    names = sorted(n for n in registry.names()
                   if n.startswith(spec.codec_prefix + "/"))
    return {"spec": spec.to_json(),
            "scheme_ids": {n: registry[n].scheme_id for n in names}}


def kv_spec_from_manifest(d: Dict) -> Tuple[KVCacheSpec, Dict[str, int]]:
    """Inverse of :func:`kv_cache_manifest`."""
    return (KVCacheSpec.from_json(d["spec"]),
            {str(k): int(v) for k, v in d.get("scheme_ids", {}).items()})
