"""Batched serving: prefill + decode with per-layer state caches.

``prefill`` runs the full-sequence forward once per layer while
collecting KV/SSM states (token-by-token scan for recurrent blocks,
bulk write for attention); ``generate`` then decodes greedily. The
decode step is the function the decode_* dry-run cells lower.

Compressed-weight serving: ``compress_params_for_serving`` stores the
parameter stack as block-32 e4m3 + QLC words (``repro.comm.weights``)
and ``open_params`` / ``generate_from_wire`` decode them in-graph via
the fused decode→dequantize Pallas kernel — the production path where
FSDP weight gathers move QLC words instead of bf16 and the codec runs
right after the gather. The codec argument may be a per-tensor-type
``CodecRegistry`` (paper §7 multi-LUT): each leaf records its
scheme-id, and ``serving_manifest`` / ``codec_from_manifest``
round-trip the whole recipe (registry included) through JSON so a
serving host reloads it without out-of-band table agreement.

**Deprecation (PR 6)**: the per-call generation functions
(``generate`` / ``generate_paged`` / ``generate_from_wire``) are
superseded by the request-based :class:`repro.serving.scheduler.Engine`
(``submit`` / ``step`` / ``poll``). They remain as thin wrappers
building a one-run engine — token-identical to the scan-based oracle
they replaced (``_generate_scanned``, kept as the reference for tests)
— and emit a ``DeprecationWarning``, the same migration pattern the
PR-4 channel redesign used for the ``qlc_*`` collectives.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_decode_states


def _warn_legacy(old: str):
    warnings.warn(
        f"{old} is deprecated; use repro.serving.Engine — submit "
        "GenerationRequests and drive step()/poll()",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class ServeConfig:
    max_seq_len: int
    max_new_tokens: int = 32
    greedy: bool = True


def prefill(params, cfg: ModelConfig, tokens: jnp.ndarray,
            states, start_pos: int = 0):
    """Feed a prompt through the decode path token by token (reference
    implementation — correct for every block kind incl. recurrent).

    tokens: [B, S]. Returns (last_logits [B, V], states).
    """
    b, s = tokens.shape

    def body(carry, t):
        st = carry
        tok_t = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
        lg, st = _one(params, cfg, tok_t,
                      jnp.full((b, 1), start_pos, jnp.int32) + t, st)
        return st, lg[:, 0]

    states, logits_seq = jax.lax.scan(
        body, states, jnp.arange(s, dtype=jnp.int32))
    return logits_seq[-1], states


def _one(params, cfg, tok, pos, states):
    return decode_step(params, cfg, tok, states, pos)


def _generate_scanned(params, cfg: ModelConfig, prompts: jnp.ndarray,
                      serve_cfg: ServeConfig) -> jnp.ndarray:
    """Scan-based greedy generation — the reference oracle the engine
    and the deprecated wrappers are asserted token-identical against.

    prompts: [B, S] int32. Returns [B, max_new_tokens].
    """
    b, s = prompts.shape
    states = init_decode_states(cfg, b, serve_cfg.max_seq_len)
    logits, states = prefill(params, cfg, prompts, states)

    def body(carry, t):
        tok, st = carry
        lg, st = decode_step(params, cfg, tok, st,
                             jnp.full((b, 1), s, jnp.int32) + t)
        nxt = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return (nxt, st), nxt[:, 0]

    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    (_, _), toks = jax.lax.scan(
        body, (first, states),
        jnp.arange(serve_cfg.max_new_tokens - 1, dtype=jnp.int32))
    return jnp.concatenate([first, toks.T], axis=1)


def _engine_generate(params, cfg: ModelConfig, prompts, serve_cfg,
                     **engine_kw) -> jnp.ndarray:
    """One-run engine behind the deprecated batch-call wrappers: one
    request per prompt row, driven to completion."""
    from repro.serving.scheduler import Engine, GenerationRequest
    prompts = np.asarray(prompts)
    b, _ = prompts.shape
    engine_kw.setdefault("max_batch", b)
    eng = Engine(params, cfg, max_seq_len=serve_cfg.max_seq_len,
                 **engine_kw)
    handles = [eng.submit(GenerationRequest(
        prompt=prompts[i], max_new_tokens=serve_cfg.max_new_tokens))
        for i in range(b)]
    eng.run()
    return jnp.asarray(np.stack([eng.poll(h).tokens for h in handles]))


def generate(params, cfg: ModelConfig, prompts: jnp.ndarray,
             serve_cfg: ServeConfig, rng: Optional[jax.Array] = None
             ) -> jnp.ndarray:
    """Greedy generation for a batch of equal-length prompts.

    prompts: [B, S] int32. Returns [B, max_new_tokens].

    .. deprecated:: use :class:`repro.serving.Engine` — this wrapper
       builds a one-run engine (host-driven; not jit-able) and is
       token-identical to the scan oracle it replaced.
    """
    _warn_legacy("generate")
    return _engine_generate(params, cfg, prompts, serve_cfg)


# --------------------------------------------------------------------------
# Compressed-weight serving (QLC wire, fused kernel decode)
# --------------------------------------------------------------------------

def compress_params_for_serving(params, tables, mode: str = "qlc",
                                use_kernels: bool = True,
                                type_key_fn=None):
    """Wire a parameter tree for compressed serving.

    Large (≥64Ki-element-per-group) 2D+ leaves become block-32 e4m3
    symbols packed into QLC slots with exactly-measured capacity (zero
    escapes); everything else stays dense. ``tables`` is a single
    ``CodecTables`` or a per-tensor-type ``CodecRegistry`` (with
    optional ``type_key_fn(leaf_path) -> type name``); each leaf's
    scheme-id lands in the wire codec's manifest. Returns
    ``(wired_params, wire_codec)``; open with :func:`open_params`.
    """
    from repro.comm.weights import compress_groups
    return compress_groups(params, tables, mode=mode,
                           use_kernels=use_kernels,
                           type_key_fn=type_key_fn)


def serving_manifest(wire_codec, *, kv_spec=None, kv_registry=None) -> dict:
    """JSON-able manifest of a wired parameter tree: per-leaf geometry
    + scheme-ids + the codec registry + the channel placement
    (transport / axis / kernel toggle).

    With ``kv_spec`` (a :class:`~repro.serving.kv_cache.KVCacheSpec`),
    the compressed-KV-cache recipe rides along under ``"kv"`` — the
    paging spec plus per-layer ``kv/layer{i}`` scheme-ids, resolved
    against ``kv_registry`` (default: the wire codec's registry, the
    usual one-registry deployment)."""
    from repro.serving.kv_cache import kv_cache_manifest
    m = wire_codec.manifest()
    if kv_spec is not None:
        m["kv"] = kv_cache_manifest(
            kv_spec, kv_registry if kv_registry is not None
            else wire_codec.registry)
    return m


def codec_from_manifest(manifest: dict, use_kernels=None):
    """Rebuild a ``GroupWireCodec`` from :func:`serving_manifest` output
    (tables are re-derived bit-identically from the registry; the
    channel placement rides along). ``use_kernels=None`` keeps the
    manifest's recorded toggle; a bool overrides it. Manifests written
    before the channel placement existed keep this function's historic
    fused-kernel default."""
    from repro.comm.weights import GroupWireCodec
    if use_kernels is None and "channel" not in manifest:
        use_kernels = True          # pre-channel manifests: old default
    return GroupWireCodec.from_manifest(manifest, use_kernels=use_kernels)


def open_params(wired_params, wire_codec, *, channel=None, axis_name=None,
                axis_size=None, transport=None):
    """Decode a QLC-wired parameter tree back to dense arrays in-graph.

    With ``wire_codec.use_kernels`` each leaf is opened by the fused
    decode→dequantize Pallas kernel (one dispatch, symbols stay in
    VMEM); numerics are identical to the pure-JAX open either way.

    Mesh path: with a bound :class:`~repro.comm.channel.Channel` (or
    the loose ``axis_name``/``axis_size``/``transport`` kwargs — the
    channel is the preferred spelling, built once via
    ``wire_codec.channel(axis, axis_size)``), call inside ``shard_map``
    with each compressed leaf sharded along its chunk dim over the
    channel's axis: the wire streams through the transport layer
    instead of a bf16 gather — with the ring transport (default) every
    peer shard's containers decode while the next hop's compressed
    bytes are in flight (``repro.comm.transport`` semantics). Values
    are bit-identical to the unsharded open.
    """
    if channel is not None:
        if channel.axis is None:          # local placement: plain open
            return wire_codec.open_group(wired_params)
        return wire_codec.open_group_sharded(
            wired_params, transport=transport, channel=channel)
    if axis_name is None:
        return wire_codec.open_group(wired_params)
    if axis_size is None:
        raise ValueError("the sharded open needs the static axis_size")
    return wire_codec.open_group_sharded(
        wired_params, axis_name, int(axis_size), transport)


def generate_from_wire(wired_params, wire_codec, cfg: ModelConfig,
                       prompts: jnp.ndarray, serve_cfg: ServeConfig,
                       rng: Optional[jax.Array] = None) -> jnp.ndarray:
    """Greedy generation directly from QLC-compressed parameters.

    .. deprecated:: open the wire once (:func:`open_params`) and serve
       the dense tree through :class:`repro.serving.Engine`.
    """
    _warn_legacy("generate_from_wire")
    params = open_params(wired_params, wire_codec)
    return _engine_generate(params, cfg, prompts, serve_cfg)


# --------------------------------------------------------------------------
# Compressed KV-cache serving (block-paged decode states)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _paged_step(cfg: ModelConfig):
    """Jitted one-token decode step, cached per config — the engine and
    repeated ``generate_paged`` calls (dense baseline + paged run)
    reuse one compiled executable instead of re-tracing a fresh
    lambda."""
    return jax.jit(lambda p, tok, st, pos: decode_step(p, cfg, tok, st,
                                                       pos))


@functools.lru_cache(maxsize=8)
def _prefill_fn(cfg: ModelConfig):
    """Jitted prefill, cached per config (the engine's admission path;
    jit re-specializes per prompt length)."""
    return jax.jit(lambda p, tokens, st: prefill(p, cfg, tokens, st))


@functools.lru_cache(maxsize=8)
def _prefill_from_fn(cfg: ModelConfig):
    """Jitted prefill accepting a start position — the engine's
    *segmented* prefill, which pauses at block boundaries so the SSM
    boundary-state snapshots (``KVCacheSpec.ssm_rebase``) can be
    captured between segments. Feeding a prompt in segments through
    this is state-identical to one whole-prompt :func:`prefill` call
    (same scan body, same positions)."""
    return jax.jit(lambda p, tokens, st, start: prefill(
        p, cfg, tokens, st, start_pos=start))


@functools.lru_cache(maxsize=32)
def _window_step(cfg: ModelConfig, window: int):
    """Jitted greedy multi-token decode: ONE ``lax.scan`` over
    ``window`` tokens — the async engine's admission-window step.

    The greedy argmax feeds back *inside* the scan, so dispatching a
    window costs one host->device transfer (the seed token + positions)
    and one device->host transfer (the window's tokens), independent of
    ``window`` — the zero-per-token-host-transfer contract the
    transfer-count probe in the tests pins down.

    Returns ``(generated tokens [B, window], states)``.
    """

    def run(params, tok0, pos0, states):
        def body(carry, _):
            tok, st, pos = carry
            lg, st = decode_step(params, cfg, tok, st, pos)
            nxt = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)[:, None]
            return (nxt, st, pos + 1), nxt[:, 0]

        (_, states, _), gen = jax.lax.scan(
            body, (tok0, states, pos0), None, length=window)
        # gen row t = the token generated by step t (greedy argmax);
        # the carry already re-fed it, so the host only reads results.
        return jnp.moveaxis(gen, 0, 1), states

    return jax.jit(run)


def generate_paged(params, cfg: ModelConfig, prompts: jnp.ndarray,
                   serve_cfg: ServeConfig, kv_cache=None) -> jnp.ndarray:
    """Greedy generation with a host-driven decode loop paging the
    decode states through a
    :class:`~repro.serving.kv_cache.PagedKVCache`.

    Per-step math is exactly the scan oracle's (same ``decode_step``,
    same greedy argmax); between steps the paged cache evicts every
    completed block — encode to a QLC container, decode back into the
    resident window — so the attended cache content genuinely
    round-trips the compressed wire. With the lossless ``"qlc"`` mode
    the round trip is bit-exact and the output is token-identical to
    ``kv_cache=None``.

    prompts: [B, S] int32. Returns [B, max_new_tokens].

    .. deprecated:: use :class:`repro.serving.Engine` with
       ``kv_spec=``/``pool=`` — per-slot paging through the shared
       digest-addressed block pool. ``kv_cache=None`` already routes
       through the engine; an explicit ``kv_cache`` keeps the legacy
       batch-wide loop (the cache's ``cold``/``stats`` accounting is
       per-batch, which per-slot engine paging deliberately replaces).
    """
    _warn_legacy("generate_paged")
    if kv_cache is None:
        return _engine_generate(params, cfg, prompts, serve_cfg)
    return _paged_loop(params, cfg, prompts, serve_cfg, kv_cache)


def _paged_loop(params, cfg: ModelConfig, prompts: jnp.ndarray,
                serve_cfg: ServeConfig, kv_cache) -> jnp.ndarray:
    """Legacy batch-wide paged decode loop (kept behind the deprecated
    ``generate_paged(kv_cache=...)`` spelling and its tests)."""
    b, s = prompts.shape
    states = init_decode_states(cfg, b, serve_cfg.max_seq_len)
    logits, states = prefill(params, cfg, prompts, states)
    states = kv_cache.note_tokens(states, s)

    step = _paged_step(cfg)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    toks = [tok]
    for t in range(serve_cfg.max_new_tokens - 1):
        pos = jnp.full((b, 1), s + t, jnp.int32)
        lg, states = step(params, tok, states, pos)
        states = kv_cache.note_tokens(states, s + t + 1)
        tok = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)[:, None]
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)
