from repro.data.synthetic import (  # noqa: F401
    DataConfig,
    SyntheticDataset,
    input_shape_structs,
)
