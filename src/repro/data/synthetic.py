"""Deterministic synthetic token pipeline.

Seeded, stateless-resumable (the iterator state is just the step index,
checkpointed alongside the model), and host-shardable: every host
computes only its slice of the global batch from the same seed, so any
host is replaceable after a failure (straggler/elastic story, DESIGN §8).

The token stream is a mixture of Zipfian unigrams and short repeated
motifs so models have actual structure to learn in the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.5


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    p = ranks ** -cfg.zipf_a
    return p / p.sum()


class SyntheticDataset:
    """Batch generator; ``batch_at(step)`` is a pure function of
    (seed, step) => resumable and host-replaceable."""

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self._probs = _zipf_probs(cfg)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.host_index]))
        b, s = self.local_batch, cfg.seq_len + 1
        toks = rng.choice(cfg.vocab_size, size=(b, s), p=self._probs)
        # plant motifs: token t determined by token t-1 half the time
        shift = (toks[:, :-1] * 31 + 7) % cfg.vocab_size
        use = rng.random((b, s - 1)) < cfg.motif_prob
        toks[:, 1:] = np.where(use, shift, toks[:, 1:])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def input_shape_structs(vocab_size: int, seq_len: int, global_batch: int,
                        prefix_len: int = 0, d_model: int = 0,
                        dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for a training batch (dry-run)."""
    st = seq_len - prefix_len
    out = {
        "tokens": jax.ShapeDtypeStruct((global_batch, st), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, st), jnp.int32),
    }
    if prefix_len:
        out["prefix_emb"] = jax.ShapeDtypeStruct(
            (global_batch, prefix_len, d_model), dtype)
    return out
