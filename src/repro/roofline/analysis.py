"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh) cell, in seconds:

  compute    = HLO_FLOPs_total   / (chips × peak_FLOP/s)
  memory     = HLO_bytes_total   / (chips × HBM_bw)
  collective = coll_bytes_total  / (chips × link_bw)

``compiled.cost_analysis()`` (post-SPMD) reports per-device numbers, so
totals are per-device × chips — the two conventions cancel in the
per-term division, but we report totals for readability.

Collective bytes are NOT in cost_analysis: we parse the post-partition
HLO text and sum the RESULT-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute /
ragged-all-to-all instruction (bytes landing on each device per step —
the wire-traffic proxy; convention noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional


from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<type>\([^)]*\)|[\w\[\],{}\s/#:.]+?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)(?:-start|-done)?\(",
    re.MULTILINE)

_ARRAY_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _array_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes by collective op kind from post-SPMD HLO text.

    ``*-start`` ops are counted; their ``-done`` twins are not (the
    regex matches both but done ops have the same result type as start
    — we dedupe by only counting lines whose op name does not end in
    '-done')."""
    out: Dict[str, float] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        line = m.group(0)
        if "-done(" in line:
            continue
        op = m.group("op")
        out[op] = out.get(op, 0.0) + _array_bytes(m.group("type"))
    out["total"] = float(sum(v for k, v in out.items() if k != "total"))
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float            # 6·N(active)·tokens
    peak_memory_per_device: float = 0.0
    coll_breakdown: Optional[Dict[str, float]] = None

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / hw.ICI_LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs_total — remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound time — the score per cell."""
        useful_s = (self.model_flops / self.chips) / hw.PEAK_FLOPS_BF16
        return useful_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_flops_fraction=self.useful_flops_fraction,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops_for(cfg, shape, n_tokens: Optional[int] = None) -> float:
    """6·N_active·D for training; 2·N_active·D for inference steps."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def from_compiled(arch: str, shape, mesh_name: str, chips: int,
                  cost: dict, hlo_text: str, cfg,
                  memory_stats: Optional[dict] = None) -> RooflineTerms:
    """Build terms from the loop-aware HLO walker (XLA cost_analysis
    counts while bodies once — useless for scanned programs; the raw
    numbers are preserved in the dry-run JSON for reference)."""
    from repro.roofline import hlo_walk
    walked = hlo_walk.analyze(hlo_text)
    return RooflineTerms(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=walked.flops,
        bytes_per_device=walked.bytes,
        coll_bytes_per_device=walked.coll_total,
        model_flops=model_flops_for(cfg, shape),
        peak_memory_per_device=float(
            (memory_stats or {}).get("temp_size_in_bytes", 0.0)),
        coll_breakdown=dict(walked.coll),
    )
