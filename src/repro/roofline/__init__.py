from repro.roofline import analysis, hlo_walk, hw  # noqa: F401
