"""Loop-aware HLO cost walker.

``compiled.cost_analysis()`` visits each instruction once: a ``while``
body (every ``lax.scan`` — our layer stacks, seq scans, microbatch
accumulation) is counted a single time regardless of trip count, which
understates FLOPs/bytes/collective-bytes by orders of magnitude for
scanned programs. This walker parses the post-partition HLO text and
multiplies through loop trip counts:

  flops:  dot ops (2·batch·M·N·K, from operand shapes + contracting
          dims), recursing into fusions / called computations / while
          bodies (× trip).
  bytes:  HBM-traffic first-order model: per *top-level* instruction,
          operand bytes + result bytes (fusion internals are one kernel
          => internals don't touch HBM), × trip for loop bodies.
  coll:   result bytes of all-gather / all-reduce / reduce-scatter /
          all-to-all / collective-permute, × trip.

Trip counts are recovered from the loop condition computation
(``compare(gte, constant(T)), direction=LT`` pattern emitted for every
counted lax.scan/fori_loop). Numbers are per-device (the compiled
module is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "bf16": 2, "f16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute", "ragged-all-to-all")

def _comp_header_name(stripped: str) -> Optional[str]:
    """Computation headers end with '{' and contain '->'; the param list
    may hold nested parens (tuple types), so parse positionally."""
    if not stripped.endswith("{") or "->" not in stripped:
        return None
    s = stripped
    if s.startswith("ENTRY"):
        s = s[len("ENTRY"):].strip()
    head = s.split("(")[0].strip()
    if not head:
        return None
    return head.lstrip("%")
# Result types are either one array ("f32[2,4096]{1,0}") or a tuple;
# tuple types may contain "/*index=N*/" comments but never nested parens.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\(.*?\)|[\w\[\],{}\s]+?)\s*"
    r"(?P<op>[\w\-]+)\((?P<rest>.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_CONSTANT = re.compile(r"s(?:8|16|32|64)\[\]\s+constant\((\d+)\)")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    type_str: str
    rest: str

    @property
    def result_bytes(self) -> float:
        return _shape_bytes(self.type_str)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult

    @property
    def coll_total(self) -> float:
        return float(sum(self.coll.values()))


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            name = _comp_header_name(stripped)
            if name is not None:
                cur = Computation(name, [], {})
                if stripped.startswith("ENTRY"):
                    entry = cur.name
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group("name"), m.group("op"),
                        m.group("type"), m.group("rest"))
            cur.instrs.append(ins)
            cur.by_name[ins.name] = ins
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for d in _shape_dims(ins.type_str):
        out_elems *= d
    # contracted size from the lhs operand's shape
    ops = _OPERAND.findall(ins.rest.split(")", 1)[0] + ")")
    contract = _CONTRACT.search(ins.rest)
    if not ops or contract is None:
        return 2.0 * out_elems  # degenerate
    lhs = comp.by_name.get(ops[0])
    if lhs is None:
        return 2.0 * out_elems
    lhs_dims = _shape_dims(lhs.type_str)
    k = 1
    for idx in contract.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the condition computation."""
    best = 1
    for ins in cond.instrs:
        for m in _CONSTANT.finditer(ins.type_str + " " + ins.op + "(" +
                                    ins.rest):
            best = max(best, int(m.group(1)))
    return best


_FLOW_OPS = {"fusion", "call", "while", "conditional", "map",
             "reduce", "reduce-window", "sort", "scatter", "select-and-scatter"}


def _fusion_io_bytes(ins: Instr, comp: Computation,
                     comps: Dict[str, Computation]) -> float:
    """HBM traffic of one fusion kernel, slice-aware.

    XLA fuses the per-iteration dynamic-slice of a scan's stacked xs
    into the consumer kernel: the kernel READS only the slice, not the
    full array. Likewise a fusion whose root is dynamic-update-slice
    WRITES only the update (in-place aliasing). Charging full operand /
    result sizes over-counts scanned programs by ~trip_count x.
    """
    called_m = _CALLS.search(ins.rest)
    ccomp = comps.get(called_m.group(1)) if called_m else None
    if ccomp is None:
        return ins.result_bytes + _operand_bytes(ins, comp)

    # read side: parameters used only via (dynamic-)slice/gather are
    # charged at the sliced size
    params: Dict[str, float] = {}
    for ci in ccomp.instrs:
        if ci.op == "parameter":
            params[ci.name] = ci.result_bytes
    uses: Dict[str, list] = {name: [] for name in params}
    for ci in ccomp.instrs:
        if ci.op == "parameter":
            continue
        args = ci.rest.split(")", 1)[0]
        for nm in _OPERAND.findall(args):
            if nm in uses:
                uses[nm].append(ci)
    read = 0.0
    for nm, full in params.items():
        us = uses[nm]
        if us and all(u.op in ("dynamic-slice", "slice", "gather")
                      for u in us):
            read += min(full, sum(u.result_bytes for u in us))
        else:
            read += full

    # write side: a dynamic-update-slice root writes only the update
    root = ccomp.instrs[-1] if ccomp.instrs else None
    write = ins.result_bytes
    if root is not None and root.op == "dynamic-update-slice":
        ops = _OPERAND.findall(root.rest.split(")", 1)[0])
        if len(ops) >= 2:
            upd = ccomp.by_name.get(ops[1])
            if upd is not None:
                write = upd.result_bytes
    return read + write


def cost_of(comp_name: str, comps: Dict[str, Computation],
            memo: Dict[str, Costs], top_level: bool = True) -> Costs:
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    total = Costs()
    if comp is None:
        return total

    for ins in comp.instrs:
        if ins.op == "dot":
            total.flops += _dot_flops(ins, comp)
            total.bytes += ins.result_bytes + _operand_bytes(ins, comp)
        elif ins.op == "while":
            body_m = _CALLS.search(ins.rest)
            cond_m = _COND.search(ins.rest)
            trip = 1
            if cond_m and cond_m.group(1) in comps:
                trip = _trip_count(comps[cond_m.group(1)])
            if body_m:
                body_cost = cost_of(body_m.group(1), comps, memo,
                                    top_level=True)
                total.add(body_cost, mult=trip)
        elif ins.op in ("fusion", "call", "map", "reduce", "scatter",
                        "select-and-scatter", "reduce-window", "sort",
                        "conditional"):
            called = _CALLS.findall(ins.rest)
            for c in called:
                sub = cost_of(c, comps, memo, top_level=False)
                total.flops += sub.flops
                for k, v in sub.coll.items():
                    total.coll[k] = total.coll.get(k, 0.0) + v
            # fusion = one kernel; slice-aware HBM traffic
            if ins.op == "fusion":
                total.bytes += _fusion_io_bytes(ins, comp, comps)
            else:
                total.bytes += ins.result_bytes + _operand_bytes(ins, comp)
        elif any(ins.op.startswith(c) for c in COLLECTIVE_OPS):
            if ins.op.endswith("-done"):
                continue
            kind = next(c for c in COLLECTIVE_OPS if ins.op.startswith(c))
            total.coll[kind] = total.coll.get(kind, 0.0) + ins.result_bytes
            total.bytes += ins.result_bytes + _operand_bytes(ins, comp)
        elif ins.op in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "after-all"):
            continue
        elif ins.op in ("dynamic-slice", "slice", "gather"):
            total.bytes += 2 * ins.result_bytes      # read slice + write
        elif ins.op == "dynamic-update-slice":
            ops = _OPERAND.findall(ins.rest.split(")", 1)[0])
            upd = comp.by_name.get(ops[1]) if len(ops) >= 2 else None
            total.bytes += 2 * (upd.result_bytes if upd is not None
                                else ins.result_bytes)
        else:
            # copy / convert / broadcast / custom-call ...
            total.bytes += ins.result_bytes + _operand_bytes(ins, comp)
    memo[comp_name] = total
    return total


def _operand_bytes(ins: Instr, comp: Computation) -> float:
    total = 0.0
    args = ins.rest.split(")", 1)[0]
    for name in _OPERAND.findall(args):
        op_ins = comp.by_name.get(name)
        if op_ins is not None and op_ins.op != "constant":
            total += op_ins.result_bytes
    return total


def analyze(hlo_text: str) -> Costs:
    comps, entry = parse_hlo(hlo_text)
    if entry is None:
        return Costs()
    return cost_of(entry, comps, {})
