"""Target-hardware constants (TPU v5e) for the roofline analysis."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link (~ per the brief)
DCN_LINK_BW = 12.5e9            # bytes/s cross-pod per host (~100 Gb/s NIC)
DCN_LATENCY_S = 10e-6           # cross-pod first-byte latency (vs ~1us ICI)
VMEM_BYTES = 16 * 2 ** 20       # per-core VMEM (approx)
HBM_BYTES = 16 * 2 ** 30        # v5e HBM capacity
