"""Logical-axis sharding rules (MaxText-style) + helpers.

Every parameter/activation dimension gets a *logical* name; a rule table
maps logical names to mesh axes. Changing the parallelism layout (or
pod count) only changes the rules, never the model code.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

#: Default rules for the production meshes:
#:   single-pod  (16, 16)    axes ("data", "model")
#:   multi-pod   (2, 16, 16) axes ("pod", "data", "model")
#: "fsdp" dims shard params over the data axis (ZeRO-3 style); heads /
#: mlp / experts / vocab shard over the model axis; batch over pod+data.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "expert": "model",
    "vocab": "model",
    "fsdp": ("pod", "data"),   # parameter sharding dim (first non-sharded)
    "layers": None,
    "kv_seq": None,            # switched to ("data",) for seq-sharded decode
    "state": None,
    "conv": None,
    "blocks32": None,
}


@dataclasses.dataclass
class ShardingRules:
    """Activation rules + parameter-dim overrides (FSDP etc.).

    Specs are divisibility-aware: an axis (or tuple prefix) is only used
    for a dim it divides — e.g. 56 attention heads fall back to
    replicated on a 16-way model axis instead of failing to lower.
    """
    rules: Dict[str, MeshAxes]
    param_overrides: Dict[str, MeshAxes] = dataclasses.field(
        default_factory=dict)

    def _resolve(self, name: Optional[str], dim: Optional[int],
                 mesh, param: bool, used: set) -> MeshAxes:
        if name is None:
            return None
        ax = (self.param_overrides.get(name, self.rules.get(name))
              if param else self.rules.get(name))
        if ax is None:
            return None
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        if mesh is not None:
            axes = tuple(a for a in axes if a in mesh.axis_names)
        # a mesh axis may appear at most once per spec: first dim wins
        axes = tuple(a for a in axes if a not in used)
        if dim is not None and mesh is not None:
            # keep the maximal prefix whose total size divides the dim
            kept = []
            prod = 1
            for a in axes:
                size = mesh.shape[a]
                if dim % (prod * size) == 0:
                    kept.append(a)
                    prod *= size
                else:
                    break
            axes = tuple(kept)
        if not axes:
            return None
        used.update(axes)
        return axes[0] if len(axes) == 1 else axes

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None,
             param: bool = False) -> P:
        mesh = _current_mesh()
        dims = list(shape) if shape is not None else [None] * len(
            logical_axes)
        # Axes that are Manual in the current trace (inside shard_map)
        # or explicitly blocked (inside a spmd_axis_name'd vmap) cannot
        # appear in sharding constraints — treat them as taken.
        used: set = set(_manual_axes())
        used.update(getattr(_STATE, "blocked", frozenset()))
        parts = [self._resolve(name, d, mesh, param, used)
                 for name, d in zip(logical_axes, dims)]
        return P(*parts)


_STATE = threading.local()


def set_rules(rules: Optional[ShardingRules]):
    _STATE.rules = rules


def get_rules() -> ShardingRules:
    r = getattr(_STATE, "rules", None)
    return r if r is not None else ShardingRules(dict(DEFAULT_RULES))


@contextlib.contextmanager
def block_axes(axes):
    """Trace-time guard: keep ``axes`` out of emitted sharding specs.

    Needed around function bodies traced under ``jax.vmap(...,
    spmd_axis_name=axes)`` on older jax, where the vmapped axes are
    invisible to both the abstract mesh and the named-axis env but are
    still illegal in with_sharding_constraint specs.
    """
    old = getattr(_STATE, "blocked", frozenset())
    _STATE.blocked = frozenset(old) | frozenset(axes)
    try:
        yield
    finally:
        _STATE.blocked = old


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Enter a mesh context (framework-tracked + jax ``with mesh:``)."""
    old = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _STATE.mesh = old


def _abstract_mesh():
    """jax.sharding.get_abstract_mesh, absent on older jax (<0.5)."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        return None
    try:
        return fn()
    except Exception:
        return None


def _current_mesh() -> Optional[Mesh]:
    m = getattr(_STATE, "mesh", None)
    if m is not None:
        return m
    am = _abstract_mesh()
    if am is not None and am.axis_names:
        return am
    return None


def _manual_axes() -> frozenset:
    """Mesh axes currently under manual (shard_map) control."""
    am = _abstract_mesh()
    if am is None or not am.axis_names:
        # Older jax (<0.5) has no abstract mesh; fall back to the named
        # axis env. It cannot distinguish manual from auto axes, so be
        # conservative and treat every in-scope named axis as manual —
        # constraints lose at most a GSPMD layout hint, never
        # correctness.
        try:
            from jax._src import core as _jcore
            return frozenset(_jcore.get_axis_env().axis_sizes)
        except Exception:
            return frozenset()
    try:
        return frozenset(
            n for n, t in zip(am.axis_names, am.axis_types)
            if "Manual" in str(t))
    except Exception:
        return frozenset()


def shard_map_compat(f, *, mesh, in_specs, out_specs, manual_axes=None):
    """shard_map across jax versions (no replication checking).

    New jax exposes ``jax.shard_map(axis_names=..., check_vma=...)``;
    older releases have ``jax.experimental.shard_map.shard_map`` with
    the complementary ``auto=`` set and ``check_rep=``. Replication
    checking must stay off either way: the compressed collectives can
    run Pallas kernels, which have no replication rule.

    ``manual_axes=None`` means fully manual over every mesh axis — the
    only mode that works on BOTH jax lines (on older jax the partially
    -auto form trips the XLA SPMD partitioner; see the train step's
    stage-1 fallback).
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": False}
        if manual_axes is not None:
            kw["axis_names"] = set(manual_axes)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {"check_rep": False}
    if manual_axes is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def logical_constraint(x: jax.Array, logical_axes: Sequence[Optional[str]]
                       ) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op off-mesh)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = get_rules().spec(logical_axes, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, spec)


def is_spec_leaf(s) -> bool:
    return isinstance(s, tuple) and all(
        x is None or isinstance(x, str) for x in s)


def named_sharding(mesh: Mesh, logical_axes: Sequence[Optional[str]],
                   shape: Optional[Sequence[int]] = None,
                   param: bool = False) -> NamedSharding:
    with use_mesh(mesh):
        return NamedSharding(
            mesh, get_rules().spec(logical_axes, shape=shape, param=param))


def param_sharding(mesh: Mesh, specs_tree, shapes_tree):
    """Logical-axis tuples + leaf shapes -> NamedShardings (param rules)."""
    return jax.tree.map(
        lambda spec, leaf: named_sharding(
            mesh, spec, shape=leaf.shape, param=True),
        specs_tree, shapes_tree, is_leaf=is_spec_leaf)


#: FSDP parameter overrides: shard the param 'embed'/'mlp-in' dims over
#: the dp axes (ZeRO-3-style); activations keep embed replicated.
FSDP_PARAM_OVERRIDES: Dict[str, MeshAxes] = {
    "embed": ("pod", "data"),
}


def make_rules(fsdp_params: bool = True, decode_seq_shard: bool = False,
               extra: Optional[Dict[str, MeshAxes]] = None) -> ShardingRules:
    rules = dict(DEFAULT_RULES)
    if decode_seq_shard:
        # long-context decode with tiny batch: shard the KV cache /
        # sequence dim instead of batch.
        rules["kv_seq"] = ("data",)
        rules["batch"] = None
    if extra:
        rules.update(extra)
    return ShardingRules(
        rules=rules,
        param_overrides=dict(FSDP_PARAM_OVERRIDES) if fsdp_params else {})
