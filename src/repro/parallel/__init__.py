from repro.parallel.sharding import (  # noqa: F401
    FSDP_PARAM_OVERRIDES,
    is_spec_leaf,
    make_rules,
    DEFAULT_RULES,
    ShardingRules,
    get_rules,
    logical_constraint,
    named_sharding,
    param_sharding,
    set_rules,
    use_mesh,
)
