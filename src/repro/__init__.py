"""repro — Quad Length Codes (QLC) compressed-communication framework.

A multi-pod JAX training/serving framework where QLC-compressed e4m3
collectives are a first-class feature. See DESIGN.md.
"""
__version__ = "1.0.0"
