"""jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, table marshaling, and backend
dispatch: on TPU the compiled kernels run natively; elsewhere they run
in interpret mode (bit-exact semantics, Python-speed execution) so the
whole framework is runnable and testable on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut import CodecTables
from repro.kernels import qlc_decode, qlc_encode, histogram256 as _hist


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_default() -> bool:
    return not _on_tpu()


def _pad_rows(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


def decode(words: jnp.ndarray, tables: CodecTables, chunk_symbols: int,
           *, tile_chunks: int = 8, interpret: bool | None = None
           ) -> jnp.ndarray:
    """Decode [n_chunks, CW] u32 -> [n_chunks, K] u8 via the Pallas kernel."""
    if interpret is None:
        interpret = _interpret_default()
    n_chunks = words.shape[0]
    padded = _pad_rows(words, tile_chunks)
    out = qlc_decode.decode_pallas(
        padded,
        jnp.asarray(tables.dec_lut, dtype=jnp.int32),
        jnp.asarray(tables.area_symbol_bits, dtype=jnp.int32),
        jnp.asarray(tables.area_starts, dtype=jnp.int32),
        chunk_symbols=chunk_symbols,
        prefix_bits=tables.prefix_bits,
        tile_chunks=tile_chunks,
        interpret=interpret,
    )
    return out[:n_chunks]


def encode(symbols: jnp.ndarray, tables: CodecTables, capacity_words: int,
           *, tile_chunks: int = 8, interpret: bool | None = None):
    """Encode [n_chunks, K] u8 -> ([n_chunks, CW] u32, [n_chunks] u32)."""
    if interpret is None:
        interpret = _interpret_default()
    n_chunks = symbols.shape[0]
    padded = _pad_rows(symbols, tile_chunks)
    words, nbits = qlc_encode.encode_pallas(
        padded,
        jnp.asarray(tables.enc_code, dtype=jnp.uint32),
        jnp.asarray(tables.enc_len, dtype=jnp.uint32),
        capacity_words=capacity_words,
        tile_chunks=tile_chunks,
        interpret=interpret,
    )
    return words[:n_chunks], nbits[:n_chunks, 0]


def histogram(symbols: jnp.ndarray, *, tile_rows: int = 8,
              interpret: bool | None = None) -> jnp.ndarray:
    """uint8 array (any shape) -> [256] int32 counts via the Pallas kernel."""
    if interpret is None:
        interpret = _interpret_default()
    flat = symbols.reshape(-1)
    lanes = 128
    pad = (-flat.shape[0]) % (lanes * tile_rows)
    # Pad with zeros, then subtract the padding from bin 0.
    padded = jnp.pad(flat, (0, pad))
    mat = padded.reshape(-1, lanes)
    counts = _hist.histogram256_pallas(
        mat, tile_rows=tile_rows, interpret=interpret)
    return counts.at[0].add(-pad)
