"""jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, table marshaling, tile-size
autotuning, and backend dispatch: on TPU the compiled kernels run
natively; elsewhere they run in interpret mode (bit-exact semantics)
so the whole framework is runnable and testable on CPU.

Entry points
------------
  encode / decode / histogram      — single-stage kernels.
  quantize_encode                  — fused float -> (words, nbits,
                                     scales[, codes][, hist]); the
                                     e4m3 quantization happens inside
                                     the kernel, symbols stay in VMEM.
  decode_dequantize                — fused words+scales -> float.
  decode_dequantize_accumulate     — fused words+scales+acc ->
                                     acc + float: decode, dequantize,
                                     and running-sum in ONE dispatch
                                     (the ring reduce-scatter's
                                     per-hop inner loop).

Both decode entry points take **per-group LUT operands**: ``tables``
may be a single ``CodecTables`` or a sequence of them, and
``scheme_ids`` (int [n_chunks]) assigns each chunk its scheme — one
dispatch decodes a payload whose groups were encoded under different
schemes (paper §7 multi-LUT deployment; see ``repro.core.registry``).

The fused pair is what the compressed collectives
(``repro.comm.compressed``), the weight wire (``repro.comm.weights``)
and the serving/checkpoint layers call on their hot paths.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as _codec
from repro.core.lut import CodecTables
from repro.kernels import qlc_decode, qlc_encode, qlc_fused
from repro.kernels import qlc_prefetch
from repro.kernels import histogram256 as _hist
from repro.quant import e4m3


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_default() -> bool:
    return not _on_tpu()


# --------------------------------------------------------------------------
# Tile autotuning
# --------------------------------------------------------------------------

# tile_chunks per chunk-size bucket, from a VMEM working-set model
# (~20 B/symbol of per-chunk intermediates; target ≈512 KiB per program
# to leave headroom for double buffering). Measured interpret-mode and
# v5e numbers agree that more, smaller chunks per tile wins for short
# chunks while K=4096 must drop to 2 to stay under budget.
_TILE_CHUNKS_TABLE = {
    64: 32,
    128: 32,
    256: 16,
    512: 16,
    1024: 8,
    2048: 4,
    4096: 2,
}
_DEFAULT_TILE_CHUNKS = 8


def auto_tile_chunks(chunk_symbols: int, n_chunks: int | None = None) -> int:
    """Pick tile_chunks for a given chunk size (and optional row count).

    Looks up the nearest power-of-two bucket in the tuning table and
    caps the tile at the (padded) row count so tiny inputs don't pad
    8x. Callers can always override explicitly.
    """
    bucket = 1 << max(6, int(np.ceil(np.log2(max(chunk_symbols, 1)))))
    tile = _TILE_CHUNKS_TABLE.get(
        bucket,
        max(1, _TILE_CHUNKS_TABLE[1024] * 1024 // bucket))
    if n_chunks is not None and n_chunks > 0:
        cap = 1 << int(np.ceil(np.log2(n_chunks)))
        tile = min(tile, cap)
    return max(tile, 1)


def _pad_rows(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


# --------------------------------------------------------------------------
# Single-stage kernels
# --------------------------------------------------------------------------

def _stacked_luts(tables: CodecTables | Sequence[CodecTables]):
    """Marshal single or multiple CodecTables into stacked LUT operands."""
    tables_list = ([tables] if isinstance(tables, CodecTables)
                   else list(tables))
    dec, sb, st, prefix_bits = _codec.stack_decode_tables(tables_list)
    return (jnp.asarray(dec, dtype=jnp.int32),
            jnp.asarray(sb, dtype=jnp.int32),
            jnp.asarray(st, dtype=jnp.int32),
            prefix_bits, len(tables_list))


def _sid_rows(scheme_ids, n_chunks: int, n_schemes: int,
              tile_chunks: int) -> jnp.ndarray:
    """Per-chunk scheme slots as the kernels' [n_padded, 1] i32 operand."""
    if scheme_ids is None:
        sid = jnp.zeros((n_chunks,), jnp.int32)
    else:
        sid = jnp.asarray(scheme_ids, jnp.int32).reshape(-1)
        assert sid.shape[0] == n_chunks, (sid.shape, n_chunks)
    # Out-of-range slots clamp at the gather (jnp.take clips); callers
    # are expected to pass slots < n_schemes.
    del n_schemes
    return _pad_rows(sid[:, None], tile_chunks)


def decode(words: jnp.ndarray,
           tables: CodecTables | Sequence[CodecTables],
           chunk_symbols: int, *, scheme_ids=None,
           tile_chunks: int | None = None, interpret: bool | None = None
           ) -> jnp.ndarray:
    """Decode [n_chunks, CW] u32 -> [n_chunks, K] u8 via the Pallas kernel.

    ``tables`` may be a sequence of CodecTables with ``scheme_ids``
    (int [n_chunks]) selecting each chunk's scheme — multi-LUT batched
    decode in one dispatch.
    """
    if interpret is None:
        interpret = _interpret_default()
    n_chunks = words.shape[0]
    if tile_chunks is None:
        tile_chunks = auto_tile_chunks(chunk_symbols, n_chunks)
    dec, sb, st, prefix_bits, n_schemes = _stacked_luts(tables)
    padded = _pad_rows(words, tile_chunks)
    sid = _sid_rows(scheme_ids, n_chunks, n_schemes, tile_chunks)
    out = qlc_decode.decode_pallas(
        padded, sid, dec, sb, st,
        chunk_symbols=chunk_symbols,
        prefix_bits=prefix_bits,
        tile_chunks=tile_chunks,
        interpret=interpret,
    )
    return out[:n_chunks]


def decode_block_async(words: jnp.ndarray,
                       tables: CodecTables | Sequence[CodecTables],
                       chunk_symbols: int, *, scheme_ids=None,
                       tile_chunks: int | None = None,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Decode [n_chunks, CW] u32 -> [n_chunks, K] u8 via the DMA
    double-buffered prefetch kernel (``kernels/qlc_prefetch.py``).

    Bit-identical to :func:`decode`; the difference is word movement:
    the container words stay in HBM (``ANY`` memory space) and stream
    tile-by-tile through a two-slot VMEM scratch, so tile k+1's DMA
    runs under tile k's LUT decode. This is the device half of the
    serving prefetcher — the entry point `PagedKVCache` dispatches
    ahead of block use.
    """
    if interpret is None:
        interpret = _interpret_default()
    n_chunks = words.shape[0]
    if tile_chunks is None:
        tile_chunks = auto_tile_chunks(chunk_symbols, n_chunks)
    dec, sb, st, prefix_bits, n_schemes = _stacked_luts(tables)
    padded = _pad_rows(words, tile_chunks)
    sid = _sid_rows(scheme_ids, n_chunks, n_schemes, tile_chunks)
    out = qlc_prefetch.prefetch_decode_pallas(
        padded, sid, dec, sb, st,
        chunk_symbols=chunk_symbols,
        prefix_bits=prefix_bits,
        tile_chunks=tile_chunks,
        interpret=interpret,
    )
    return out[:n_chunks]


def encode(symbols: jnp.ndarray, tables: CodecTables, capacity_words: int,
           *, tile_chunks: int | None = None, interpret: bool | None = None):
    """Encode [n_chunks, K] u8 -> ([n_chunks, CW] u32, [n_chunks] u32)."""
    if interpret is None:
        interpret = _interpret_default()
    n_chunks, k = symbols.shape
    if tile_chunks is None:
        tile_chunks = auto_tile_chunks(k, n_chunks)
    padded = _pad_rows(symbols, tile_chunks)
    words, nbits = qlc_encode.encode_pallas(
        padded,
        jnp.asarray(tables.enc_code, dtype=jnp.uint32),
        jnp.asarray(tables.enc_len, dtype=jnp.uint32),
        capacity_words=capacity_words,
        tile_chunks=tile_chunks,
        interpret=interpret,
    )
    return words[:n_chunks], nbits[:n_chunks, 0]


def histogram(symbols: jnp.ndarray, *, tile_rows: int = 8,
              interpret: bool | None = None) -> jnp.ndarray:
    """uint8 array (any shape) -> [256] int32 counts via the Pallas kernel."""
    if interpret is None:
        interpret = _interpret_default()
    flat = symbols.reshape(-1)
    lanes = 128
    pad = (-flat.shape[0]) % (lanes * tile_rows)
    # Pad with zeros, then subtract the padding from bin 0.
    padded = jnp.pad(flat, (0, pad))
    mat = padded.reshape(-1, lanes)
    counts = _hist.histogram256_pallas(
        mat, tile_rows=tile_rows, interpret=interpret)
    return counts.at[0].add(-pad)


# --------------------------------------------------------------------------
# Fused pipeline
# --------------------------------------------------------------------------

def quantize_encode(x: jnp.ndarray, tables: CodecTables,
                    capacity_words: int, *, tile_chunks: int | None = None,
                    emit_codes: bool = False, emit_hist: bool = False,
                    interpret: bool | None = None):
    """Fused e4m3-quantize + QLC-encode of float chunks.

    Args:
      x: float [n_chunks, K] (f32/bf16; K divisible by 32).
      tables: codec tables.
      capacity_words: slot size per chunk in 32-bit words.
      emit_codes: also return the raw e4m3 symbols (escape-pool callers).
      emit_hist: also return the 256-bin symbol histogram.

    Returns:
      (words u32 [n, CW], nbits u32 [n], scales f32 [n, K/32]
       [, codes u8 [n, K]] [, hist i32 [256]]).
    """
    if interpret is None:
        interpret = _interpret_default()
    n_chunks, k = x.shape
    if tile_chunks is None:
        tile_chunks = auto_tile_chunks(k, n_chunks)
    padded = _pad_rows(x, tile_chunks)
    n_pad_rows = padded.shape[0] - n_chunks
    outs = qlc_fused.fused_encode_pallas(
        padded,
        jnp.asarray(tables.enc_code, dtype=jnp.uint32),
        jnp.asarray(tables.enc_len, dtype=jnp.uint32),
        capacity_words=capacity_words,
        tile_chunks=tile_chunks,
        emit_codes=emit_codes,
        emit_hist=emit_hist,
        interpret=interpret,
    )
    words, nbits, scales = outs[:3]
    result = [words[:n_chunks], nbits[:n_chunks, 0], scales[:n_chunks]]
    idx = 3
    if emit_codes:
        result.append(outs[idx][:n_chunks])
        idx += 1
    if emit_hist:
        # Padded rows are all-zero chunks => quantize to symbol 0.
        result.append(outs[idx].at[0].add(-n_pad_rows * k))
    return tuple(result)


def decode_dequantize(words: jnp.ndarray, scales: jnp.ndarray,
                      tables: CodecTables | Sequence[CodecTables],
                      chunk_symbols: int, *, scheme_ids=None,
                      tile_chunks: int | None = None,
                      out_dtype=jnp.float32,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Fused QLC-decode + e4m3-dequantize.

    Args:
      words: u32 [n_chunks, CW] packed slots.
      scales: f32 [n_chunks, K/32] block-32 scales (chunk-major).
      tables: codec tables — one ``CodecTables`` or a sequence of them
        (per-group LUT operands).
      chunk_symbols: K.
      scheme_ids: int [n_chunks] slot of each chunk's scheme into
        ``tables`` when a sequence is given (multi-LUT batched decode).
      out_dtype: output float dtype (f32 default; bf16 casts in-kernel).

    Returns:
      [n_chunks, K] dequantized values, bit-exact against ``decode``
      followed by ``e4m3.dequantize_block32`` (plus the output cast).
    """
    if interpret is None:
        interpret = _interpret_default()
    n_chunks = words.shape[0]
    if tile_chunks is None:
        tile_chunks = auto_tile_chunks(chunk_symbols, n_chunks)
    dec, sb, st, prefix_bits, n_schemes = _stacked_luts(tables)
    padded_w = _pad_rows(words, tile_chunks)
    padded_s = _pad_rows(scales.astype(jnp.float32), tile_chunks)
    sid = _sid_rows(scheme_ids, n_chunks, n_schemes, tile_chunks)
    out = qlc_fused.fused_decode_pallas(
        padded_w, padded_s, sid, dec, sb, st,
        jnp.asarray(e4m3.decode_table(), dtype=jnp.float32),
        chunk_symbols=chunk_symbols,
        prefix_bits=prefix_bits,
        tile_chunks=tile_chunks,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[:n_chunks]


def decode_dequantize_accumulate(acc: jnp.ndarray, words: jnp.ndarray,
                                 scales: jnp.ndarray,
                                 tables: CodecTables | Sequence[CodecTables],
                                 chunk_symbols: int, *, scheme_ids=None,
                                 tile_chunks: int | None = None,
                                 interpret: bool | None = None
                                 ) -> jnp.ndarray:
    """Fused QLC-decode + e4m3-dequantize + accumulate: one dispatch
    per ring reduce-scatter hop.

    Args:
      acc: f32 [n_chunks, K] running accumulator.
      words: u32 [n_chunks, CW] packed slots of the arriving hop.
      scales: f32 [n_chunks, K/32] block-32 scales of the hop.
      tables / scheme_ids: as in :func:`decode_dequantize`.

    Returns:
      [n_chunks, K] f32 ``acc + dequantize(decode(words))``. With a
      zero ``acc`` this is bit-exact against ``decode_dequantize``;
      with a live accumulator it matches a separate decode-then-add to
      one f32 ulp — the compiler may FMA-contract the in-kernel
      dequantize multiply into the add (excess precision), which no
      graph-level fence reliably prevents. Transport-level bit-identity
      therefore comes from running the SAME accumulate op sequence on
      every path (``transport._accumulate_row_pieces``), never from mixing
      this fused form with decode-then-add.
    """
    if interpret is None:
        interpret = _interpret_default()
    n_chunks = words.shape[0]
    assert acc.shape == (n_chunks, chunk_symbols), (
        acc.shape, n_chunks, chunk_symbols)
    if tile_chunks is None:
        tile_chunks = auto_tile_chunks(chunk_symbols, n_chunks)
    dec, sb, st, prefix_bits, n_schemes = _stacked_luts(tables)
    padded_w = _pad_rows(words, tile_chunks)
    padded_s = _pad_rows(scales.astype(jnp.float32), tile_chunks)
    padded_a = _pad_rows(acc.astype(jnp.float32), tile_chunks)
    sid = _sid_rows(scheme_ids, n_chunks, n_schemes, tile_chunks)
    out = qlc_fused.fused_decode_pallas(
        padded_w, padded_s, sid, dec, sb, st,
        jnp.asarray(e4m3.decode_table(), dtype=jnp.float32),
        padded_a,
        chunk_symbols=chunk_symbols,
        prefix_bits=prefix_bits,
        tile_chunks=tile_chunks,
        out_dtype=jnp.float32,
        interpret=interpret,
    )
    return out[:n_chunks]
