"""Pure-jnp oracles for every Pallas kernel in this package.

These are the single source of truth for kernel semantics; kernel tests
sweep shapes/dtypes and assert_allclose (bit-exact for integer codecs)
against these.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import codec
from repro.core.lut import CodecTables
from repro.quant import e4m3


def decode_ref(words: jnp.ndarray, tables: CodecTables,
               chunk_symbols: int) -> jnp.ndarray:
    """[n_chunks, capacity_words] u32 -> [n_chunks, K] u8."""
    return codec.decode_chunks(words, tables, chunk_symbols)


def encode_ref(symbols: jnp.ndarray, tables: CodecTables,
               capacity_words: int):
    """[n_chunks, K] u8 -> ([n_chunks, capacity_words] u32, [n_chunks] u32)."""
    return codec.encode_chunks(symbols, tables, capacity_words)


def histogram256_ref(symbols: jnp.ndarray) -> jnp.ndarray:
    """uint8 array (any shape) -> [256] int32 counts."""
    flat = symbols.reshape(-1).astype(jnp.int32)
    onehot = (flat[:, None] == jnp.arange(256, dtype=jnp.int32)[None, :])
    return jnp.sum(onehot.astype(jnp.int32), axis=0)


def quantize_encode_ref(x: jnp.ndarray, tables: CodecTables,
                        capacity_words: int):
    """Unfused oracle for the fused quantize->encode kernel.

    float [n_chunks, K] -> (words u32 [n, CW], nbits u32 [n],
    scales f32 [n, K/32], codes u8 [n, K]).
    """
    codes, scales = e4m3.quantize_block32(x.astype(jnp.float32))
    words, nbits = codec.encode_chunks(codes, tables, capacity_words)
    return words, nbits, scales, codes


def decode_dequantize_ref(words: jnp.ndarray, scales: jnp.ndarray,
                          tables: CodecTables, chunk_symbols: int
                          ) -> jnp.ndarray:
    """Unfused oracle for the fused decode->dequantize kernel."""
    sym = codec.decode_chunks(words, tables, chunk_symbols)
    return e4m3.dequantize_block32(sym, scales.astype(jnp.float32))
