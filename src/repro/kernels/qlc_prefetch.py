"""Pallas TPU kernel: DMA double-buffered prefetch-decode.

The serving hot path pages KV blocks out of an HBM-resident container
arena. A synchronous decode puts the whole LUT decode on the critical
path at every block boundary; this kernel instead streams container
words tile-by-tile through a two-slot VMEM scratch with explicit
``make_async_copy`` DMAs, so tile k+1's words are in flight from HBM
while tile k LUT-decodes out of VMEM — the same overlap contract the
ring transport proves for collectives, pushed down into one dispatch.

Pipeline (grid step i over word tiles)::

      DMA   [t0 ========][t1 ========][t2 ========]
      decode            [t0 ========][t1 ========][t2 ========]
                         ^ wait sem(0)            ^ slots alternate

Step i waits on slot ``i % 2``, starts the DMA for tile i+1 into slot
``(i+1) % 2`` *before* decoding tile i, then runs the same bit-window
area-code decode as ``qlc_decode._decode_kernel`` (stacked multi-LUT
operands, per-chunk scheme slots). The words operand therefore stays
in ``ANY`` (HBM) memory space — Pallas never auto-copies it — and only
2 * tile_chunks * capacity_words * 4 bytes of it are VMEM-resident at
a time, independent of container size.

On CPU the kernel runs in interpret mode where the DMAs are synchronous
copies: bit-exact semantics, no overlap. Overlap is *measured* (not
assumed) by the serving-level prefetcher, which dispatches this decode
ahead of use and reports a trace-derived overlap fraction
(``kv_prefetch_overlap`` benchmark row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _prefetch_decode_kernel(words_hbm_ref, sid_ref, dec_lut_ref,
                            area_sb_ref, area_starts_ref, out_ref,
                            vmem_ref, dma_sems, *, chunk_symbols: int,
                            prefix_bits: int, n_tiles: int):
    i = pl.program_id(0)
    slot = jax.lax.rem(i, 2)

    def tile_copy(tile, into_slot):
        return pltpu.make_async_copy(
            words_hbm_ref.at[tile], vmem_ref.at[into_slot],
            dma_sems.at[into_slot])

    # Warm-up: the first step issues its own DMA (no lookbehind exists).
    @pl.when(i == 0)
    def _():
        tile_copy(0, 0).start()

    # Prefetch: kick off tile i+1 into the other slot before we decode,
    # so the transfer runs under this tile's decode.
    @pl.when(i + 1 < n_tiles)
    def _():
        tile_copy(i + 1, jax.lax.rem(i + 1, 2)).start()

    tile_copy(i, slot).wait()
    words = vmem_ref[pl.dslice(slot, 1)][0]          # (TC, CW) uint32

    tc, cw = words.shape
    n_area = area_sb_ref.shape[-1]
    dec = dec_lut_ref[...].astype(jnp.uint32).reshape(-1)
    sb_t = area_sb_ref[...].astype(jnp.uint32).reshape(-1)
    st_t = area_starts_ref[...].astype(jnp.uint32).reshape(-1)
    sid = sid_ref[...][:, 0].astype(jnp.int32)       # (TC,) scheme slot
    pmask = jnp.uint32((1 << prefix_bits) - 1)
    pbits = jnp.uint32(prefix_bits)

    def body(k, bitpos):
        widx = (bitpos >> 5).astype(jnp.int32)
        shift = bitpos & jnp.uint32(31)
        w0 = jnp.take_along_axis(words, widx[:, None], axis=1)[:, 0]
        w1 = jnp.take_along_axis(
            words, jnp.minimum(widx + 1, cw - 1)[:, None], axis=1)[:, 0]
        window = (w0 >> shift) | jnp.where(
            shift == 0, jnp.uint32(0), w1 << (jnp.uint32(32) - shift))
        area = (window & pmask).astype(jnp.int32)
        sb = jnp.take(sb_t, sid * n_area + area)
        payload = (window >> pbits) & ((jnp.uint32(1) << sb) - jnp.uint32(1))
        rank = jnp.take(st_t, sid * n_area + area) + payload
        sym = jnp.take(
            dec,
            sid * 256 + jnp.minimum(rank, jnp.uint32(255)).astype(jnp.int32))
        out_ref[:, pl.dslice(k, 1)] = sym.astype(jnp.uint8)[:, None]
        return bitpos + pbits + sb

    bitpos0 = jnp.zeros((tc,), dtype=jnp.uint32)
    jax.lax.fori_loop(0, chunk_symbols, body, bitpos0)


@functools.partial(
    jax.jit,
    static_argnames=("chunk_symbols", "prefix_bits", "tile_chunks",
                     "interpret"))
def prefetch_decode_pallas(words: jnp.ndarray, scheme_ids: jnp.ndarray,
                           dec_lut: jnp.ndarray, area_sb: jnp.ndarray,
                           area_starts: jnp.ndarray,
                           *, chunk_symbols: int, prefix_bits: int = 3,
                           tile_chunks: int = 8,
                           interpret: bool = True) -> jnp.ndarray:
    """Decode [n_chunks, capacity_words] u32 slots -> [n_chunks, K] u8
    with the words streamed HBM -> VMEM through a double-buffered DMA.

    Bit-identical to :func:`repro.kernels.qlc_decode.decode_pallas`;
    only the word movement differs. n_chunks must be a multiple of
    tile_chunks (``ops.decode_block_async`` pads).
    """
    n_chunks, cw = words.shape
    assert n_chunks % tile_chunks == 0, (n_chunks, tile_chunks)
    assert dec_lut.ndim == 2 and area_sb.ndim == 2, (
        "stacked LUT operands required: dec_lut [S, 256], area_* [S, A]")
    s, a = area_sb.shape
    n_tiles = n_chunks // tile_chunks
    tiled = words.reshape(n_tiles, tile_chunks, cw)

    kernel = functools.partial(
        _prefetch_decode_kernel, chunk_symbols=chunk_symbols,
        prefix_bits=prefix_bits, n_tiles=n_tiles)

    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            # Words stay in HBM; the kernel DMAs tiles itself.
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec((tile_chunks, 1), lambda i: (i, 0)),
            pl.BlockSpec((s, dec_lut.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((s, a), lambda i: (0, 0)),
            pl.BlockSpec((s, a), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_chunks, chunk_symbols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, chunk_symbols), jnp.uint8),
        scratch_shapes=[
            pltpu.VMEM((2, tile_chunks, cw), jnp.uint32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(tiled, scheme_ids, dec_lut, area_sb, area_starts)
    return out
