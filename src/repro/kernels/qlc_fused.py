"""Fused Pallas TPU kernels: quantize→encode and decode→dequantize.

Design
------
The unfused pipeline runs four dispatches with HBM round-trips between
them::

    f32 --quantize--> u8 codes --(HBM)--> encode --> words
                               `--(HBM)--> histogram

The fused encode kernel performs block-32 e4m3 quantization AND the QLC
bit-pack in one ``pallas_call``: the uint8 symbol tile never leaves
VMEM. Per tile of ``TILE_CHUNKS`` chunks it

  1. computes block-32 amax scales (``scale = amax / 480``, the paper's
     §3 block scaling) and quantizes ``x / scale`` to eXmY e4m3 with a
     branch-free bit-trick encoder (exponent extraction + one
     round-to-nearest-even per element — bit-exact against the
     table-search oracle in ``repro.quant.e4m3``, which tests enforce);
  2. gathers (code, len) from the 256-entry encoder LUT, takes an
     exclusive prefix sum of lengths, and scatter-adds each ≤11-bit
     code into at most two consecutive 32-bit words of the chunk slot;
  3. optionally accumulates the 256-bin symbol histogram as a side
     output (revolving output block; used for on-line recalibration) and
     optionally emits the raw symbols (needed only when the caller
     maintains an escape pool, e.g. the compressed collectives).

The mirror decode kernel reads packed words, walks the chunk with the
paper's O(1) per-symbol step (3-bit area code → length, no tree walk),
and multiplies each decoded symbol's table value by its block scale
in-register, producing float output directly — decoded symbols also
never touch HBM. Its LUT operands are stacked per scheme with a
per-chunk scheme slot, so one dispatch decodes chunks encoded under
different schemes (paper §7 multi-LUT; see ``qlc_decode`` for the
operand layout).

VMEM per program (TILE_CHUNKS=8, K=1024, CW=384):
  x f32 32 KiB, words 12 KiB, codes+lens+offsets 3*32 KiB, scales
  1 KiB, LUTs ~4 KiB  ≈ 145 KiB — far under the ~16 MiB/core budget.

``ops.quantize_encode`` / ``ops.decode_dequantize`` are the public
entry points (padding, table marshaling, tile autotuning, CPU interpret
fallback).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.e4m3 import BLOCK, E4M3_MAX_FINITE

DEFAULT_TILE_CHUNKS = 8


# --------------------------------------------------------------------------
# In-kernel e4m3 quantization (bit-exact vs repro.quant.e4m3.e4m3_encode)
# --------------------------------------------------------------------------

def _e4m3_bits_encode(x: jnp.ndarray) -> jnp.ndarray:
    """float32 -> int32 e4m3 code, round-to-nearest-even, saturating.

    Branch-free equivalent of the oracle's 128-entry grid search: the
    float32 exponent field gives the e4m3 binade, one RTE rounding of
    ``mag / step`` gives the mantissa index (ties land on even codes
    because adjacent grid indices alternate parity, matching the
    oracle's tie-break). All-finite eXmY variant: NaN and overflow
    saturate to ±480; signed zero keeps its sign bit.
    """
    mag = jnp.abs(x)
    mag = jnp.where(jnp.isnan(mag), E4M3_MAX_FINITE, mag)
    mag = jnp.minimum(mag, E4M3_MAX_FINITE)
    bits = jax.lax.bitcast_convert_type(mag, jnp.uint32)
    e = (bits >> 23).astype(jnp.int32) - 127          # floor(log2(mag))
    e = jnp.maximum(e, -6)                            # subnormal binade
    step = jax.lax.bitcast_convert_type(
        ((e - 3 + 127) << 23).astype(jnp.uint32), jnp.float32)  # 2^(e-3)
    k = jnp.round(mag / step).astype(jnp.int32)       # RTE, k in [0, 16]
    carry = k == 16                                   # mantissa overflow
    e = jnp.where(carry, e + 1, e)
    k = jnp.where(carry, 8, k)
    code = jnp.where((e == -6) & (k < 8),             # subnormal codes 0..7
                     k, ((e + 7) << 3) | (k - 8))
    return jnp.where(jnp.signbit(x), code | 0x80, code)


def _quantize_tile(x: jnp.ndarray):
    """(TC, K) f32 -> (symbols i32 (TC, K), scales f32 (TC, K/BLOCK)).

    Identical arithmetic to ``e4m3.quantize_block32`` (amax over blocks
    of 32, ``scale = amax/480`` or 1 for zero blocks, one f32 divide),
    so the fused path is bit-exact against the unfused oracle.
    """
    tc, k = x.shape
    xb = x.reshape(tc, k // BLOCK, BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    # Same explicit reciprocal multiply as quantize_block32 (see the
    # comment there) — required for bit-exact fused/unfused parity.
    inv = np.float32(1.0) / np.float32(E4M3_MAX_FINITE)
    scale = jnp.where(amax > 0, amax * inv, 1.0)
    xs = (xb / scale).reshape(tc, k)
    return _e4m3_bits_encode(xs), scale[..., 0]


# --------------------------------------------------------------------------
# Fused quantize -> encode
# --------------------------------------------------------------------------

def _pack_codes(sym, enc_code, enc_len, capacity_words):
    """QLC bit-pack of a (TC, K) symbol tile (same math as qlc_encode)."""
    tc, k = sym.shape
    codes = jnp.take(enc_code, sym)                 # (TC, K) u32
    lens = jnp.take(enc_len, sym)                   # (TC, K) u32

    nbits = jnp.sum(lens, axis=1, dtype=jnp.uint32)
    offsets = jnp.cumsum(lens, axis=1, dtype=jnp.uint32) - lens

    word_idx = (offsets >> 5).astype(jnp.int32)
    shift = offsets & jnp.uint32(31)
    lo = codes << shift                             # u32 shift wraps
    hi = jnp.where(shift == 0, jnp.uint32(0),
                   codes >> (jnp.uint32(32) - shift))

    word_idx = jnp.minimum(word_idx, capacity_words - 1)
    hi_idx = jnp.minimum(word_idx + 1, capacity_words - 1)

    words = jnp.zeros((tc, capacity_words), dtype=jnp.uint32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (tc, k), 0)
    words = words.at[rows, word_idx].add(lo, mode="drop")
    words = words.at[rows, hi_idx].add(hi, mode="drop")
    return words, nbits


def _fused_encode_kernel(x_ref, enc_code_ref, enc_len_ref, *out_refs,
                         capacity_words: int, emit_codes: bool,
                         emit_hist: bool):
    words_ref, nbits_ref, scales_ref = out_refs[:3]
    rest = list(out_refs[3:])
    codes_ref = rest.pop(0) if emit_codes else None
    hist_ref = rest.pop(0) if emit_hist else None

    x = x_ref[...].astype(jnp.float32)
    sym, scale = _quantize_tile(x)
    scales_ref[...] = scale
    if emit_codes:
        codes_ref[...] = sym.astype(jnp.uint8)
    if emit_hist:
        @pl.when(pl.program_id(0) == 0)
        def _init():
            hist_ref[...] = jnp.zeros_like(hist_ref)
        bins = jax.lax.broadcasted_iota(jnp.int32, (256,), 0)
        onehot = (sym.reshape(-1)[:, None] == bins[None, :])
        hist_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)

    words, nbits = _pack_codes(sym, enc_code_ref[...], enc_len_ref[...],
                               capacity_words)
    words_ref[...] = words
    nbits_ref[...] = nbits[:, None]


@functools.partial(
    jax.jit,
    static_argnames=("capacity_words", "tile_chunks", "emit_codes",
                     "emit_hist", "interpret"))
def fused_encode_pallas(x: jnp.ndarray, enc_code: jnp.ndarray,
                        enc_len: jnp.ndarray, *, capacity_words: int,
                        tile_chunks: int = DEFAULT_TILE_CHUNKS,
                        emit_codes: bool = False, emit_hist: bool = False,
                        interpret: bool = True):
    """Quantize+encode [n_chunks, K] float -> packed QLC slots.

    Returns ``(words [n, CW] u32, nbits [n, 1] u32, scales [n, K/32]
    f32, *extras)`` where extras are ``codes [n, K] u8`` (if
    ``emit_codes``) then ``hist [256] i32`` (if ``emit_hist``).
    """
    n_chunks, k = x.shape
    assert n_chunks % tile_chunks == 0, (n_chunks, tile_chunks)
    assert k % BLOCK == 0, k
    grid = (n_chunks // tile_chunks,)

    kernel = functools.partial(
        _fused_encode_kernel, capacity_words=capacity_words,
        emit_codes=emit_codes, emit_hist=emit_hist)

    out_specs = [
        pl.BlockSpec((tile_chunks, capacity_words), lambda i: (i, 0)),
        pl.BlockSpec((tile_chunks, 1), lambda i: (i, 0)),
        pl.BlockSpec((tile_chunks, k // BLOCK), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((n_chunks, capacity_words), jnp.uint32),
        jax.ShapeDtypeStruct((n_chunks, 1), jnp.uint32),
        jax.ShapeDtypeStruct((n_chunks, k // BLOCK), jnp.float32),
    ]
    if emit_codes:
        out_specs.append(pl.BlockSpec((tile_chunks, k), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((n_chunks, k), jnp.uint8))
    if emit_hist:
        # Every grid step maps to the same block => accumulate in place.
        out_specs.append(pl.BlockSpec((256,), lambda i: (0,)))
        out_shape.append(jax.ShapeDtypeStruct((256,), jnp.int32))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_chunks, k), lambda i: (i, 0)),
            pl.BlockSpec((enc_code.shape[0],), lambda i: (0,)),
            pl.BlockSpec((enc_len.shape[0],), lambda i: (0,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, enc_code, enc_len)


# --------------------------------------------------------------------------
# Fused decode -> dequantize
# --------------------------------------------------------------------------

def _fused_decode_kernel(words_ref, scales_ref, sid_ref, dec_lut_ref,
                         area_sb_ref, area_starts_ref, value_tab_ref,
                         *rest_refs, chunk_symbols: int,
                         prefix_bits: int, out_dtype, accumulate: bool):
    if accumulate:
        acc_ref, out_ref, sym_ref = rest_refs
    else:
        out_ref, sym_ref = rest_refs
        acc_ref = None
    words = words_ref[...]                       # (TC, CW) uint32
    tc, cw = words.shape
    n_area = area_sb_ref.shape[-1]
    # Stacked per-scheme LUTs (S, 256)/(S, A), flattened: each chunk's
    # sid offsets every LUT gather, so one dispatch decodes a tile whose
    # chunks were encoded under different schemes (§7 multi-LUT).
    dec = dec_lut_ref[...].astype(jnp.uint32).reshape(-1)
    sb_t = area_sb_ref[...].astype(jnp.uint32).reshape(-1)
    st_t = area_starts_ref[...].astype(jnp.uint32).reshape(-1)
    sid = sid_ref[...][:, 0].astype(jnp.int32)   # (TC,) scheme slot
    vtab = value_tab_ref[...]                    # (256,) f32 e4m3 values
    pmask = jnp.uint32((1 << prefix_bits) - 1)
    pbits = jnp.uint32(prefix_bits)

    # The sequential loop carries only the bit cursor; symbols land in
    # a VMEM scratch via per-column stores (the same idiom as the
    # standalone decode kernel — cheaper than threading a (TC, K)
    # array through the loop carry). The dequantize (value-table
    # gather * block scale) then runs ONCE, fully vectorized, and the
    # float tile is written in one store.
    def body(i, bitpos):
        widx = (bitpos >> 5).astype(jnp.int32)               # (TC,)
        shift = bitpos & jnp.uint32(31)
        w0 = jnp.take_along_axis(words, widx[:, None], axis=1)[:, 0]
        w1 = jnp.take_along_axis(
            words, jnp.minimum(widx + 1, cw - 1)[:, None], axis=1)[:, 0]
        window = (w0 >> shift) | jnp.where(
            shift == 0, jnp.uint32(0), w1 << (jnp.uint32(32) - shift))
        area = (window & pmask).astype(jnp.int32)
        sb = jnp.take(sb_t, sid * n_area + area)
        payload = (window >> pbits) & ((jnp.uint32(1) << sb) - jnp.uint32(1))
        rank = jnp.take(st_t, sid * n_area + area) + payload
        sym = jnp.take(
            dec,
            sid * 256 + jnp.minimum(rank, jnp.uint32(255)).astype(jnp.int32))
        sym_ref[:, pl.dslice(i, 1)] = sym.astype(jnp.int32)[:, None]
        return bitpos + pbits + sb

    bitpos0 = jnp.zeros((tc,), dtype=jnp.uint32)
    jax.lax.fori_loop(0, chunk_symbols, body, bitpos0)

    vals = jnp.take(vtab, sym_ref[...])          # (TC, K) f32
    vb = vals.reshape(tc, chunk_symbols // BLOCK, BLOCK)
    vb = vb * scales_ref[...][..., None]
    flat = vb.reshape(tc, chunk_symbols)
    if accumulate:
        # In-register running sum: the ring reduce-scatter's per-hop
        # accumulate never materializes the hop's decoded values in HBM.
        # The barrier stops the compiler from contracting the dequant
        # multiply and this add into one FMA — the product must round
        # to f32 first, or the fused form drifts a ulp from the
        # decode-then-add paths it is tested bit-equal against.
        flat = acc_ref[...] + jax.lax.optimization_barrier(flat)
    out_ref[...] = flat.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("chunk_symbols", "prefix_bits", "tile_chunks",
                     "out_dtype", "interpret"))
def fused_decode_pallas(words: jnp.ndarray, scales: jnp.ndarray,
                        scheme_ids: jnp.ndarray, dec_lut: jnp.ndarray,
                        area_sb: jnp.ndarray, area_starts: jnp.ndarray,
                        value_tab: jnp.ndarray, acc: jnp.ndarray = None,
                        *, chunk_symbols: int, prefix_bits: int = 3,
                        tile_chunks: int = DEFAULT_TILE_CHUNKS,
                        out_dtype=jnp.float32,
                        interpret: bool = True) -> jnp.ndarray:
    """Decode+dequantize [n_chunks, CW] u32 slots -> [n_chunks, K] float.

    ``scales`` is [n_chunks, K/32] f32 (block-32 scales, chunk-major).
    ``scheme_ids`` is int32 [n_chunks, 1]: each chunk's slot into the
    stacked ``dec_lut [S, 256]`` / ``area_* [S, 2**prefix]`` operands
    (all-zero for single-scheme payloads). ``out_dtype`` (f32 default,
    bf16 for weight-wire consumers) is cast in-register before the
    store — same rounding as an external cast. n_chunks must be a
    multiple of tile_chunks (ops.py pads).

    ``acc`` ([n_chunks, K] f32, optional) switches the kernel to its
    fused decode→dequantize→accumulate form: the output becomes
    ``acc + decoded`` (f32 only) with the add performed in-register —
    the ring reduce-scatter's single-dispatch-per-hop inner loop.
    """
    n_chunks, cw = words.shape
    accumulate = acc is not None
    assert n_chunks % tile_chunks == 0, (n_chunks, tile_chunks)
    assert chunk_symbols % BLOCK == 0, chunk_symbols
    assert dec_lut.ndim == 2 and area_sb.ndim == 2, (
        "stacked LUT operands required: dec_lut [S, 256], area_* [S, A]")
    if accumulate:
        assert jnp.dtype(out_dtype) == jnp.dtype(jnp.float32), (
            "accumulate form is f32-only", out_dtype)
        assert acc.shape == (n_chunks, chunk_symbols), acc.shape
    s, a = area_sb.shape
    grid = (n_chunks // tile_chunks,)

    kernel = functools.partial(
        _fused_decode_kernel, chunk_symbols=chunk_symbols,
        prefix_bits=prefix_bits, out_dtype=out_dtype,
        accumulate=accumulate)

    in_specs = [
        pl.BlockSpec((tile_chunks, cw), lambda i: (i, 0)),
        pl.BlockSpec((tile_chunks, chunk_symbols // BLOCK),
                     lambda i: (i, 0)),
        pl.BlockSpec((tile_chunks, 1), lambda i: (i, 0)),
        pl.BlockSpec((s, dec_lut.shape[1]), lambda i: (0, 0)),
        pl.BlockSpec((s, a), lambda i: (0, 0)),
        pl.BlockSpec((s, a), lambda i: (0, 0)),
        pl.BlockSpec((value_tab.shape[0],), lambda i: (0,)),
    ]
    operands = [words, scales, scheme_ids, dec_lut, area_sb, area_starts,
                value_tab]
    if accumulate:
        in_specs.append(pl.BlockSpec((tile_chunks, chunk_symbols),
                                     lambda i: (i, 0)))
        operands.append(acc)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_chunks, chunk_symbols),
                               lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, chunk_symbols),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((tile_chunks, chunk_symbols),
                                   jnp.int32)],
        interpret=interpret,
    )(*operands)
