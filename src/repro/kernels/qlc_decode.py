"""Pallas TPU kernel: chunk-parallel QLC decode (multi-LUT capable).

TPU-native adaptation of the paper's hardware decoder (DESIGN.md §3):
the 3-bit area code read from the bit window gives the code length in
O(1) — no tree walk — and throughput comes from decoding a tile of
chunks in lockstep (chunks map to vector lanes; the fori_loop over the
K symbols of a chunk is the only sequential dimension).

The LUT operands are **stacked per scheme** — ``dec_lut [S, 256]``,
``area_sb/area_starts [S, 2**prefix]`` — and every chunk carries a
scheme slot index (``sid``), so ONE dispatch decodes groups encoded
under different schemes (the paper's §7 multi-LUT deployment: one LUT
per tensor type). Single-scheme callers pass S=1 and a zero sid; the
extra gather offset folds into the existing LUT gathers for free.

VMEM budget per program (defaults TILE_CHUNKS=8, K=1024, CW=384):
  words   8*384*4   = 12 KiB
  out     8*1024    =  8 KiB
  LUTs    S*256*4*3 =  3 KiB per scheme
well under the ~16 MiB/core VMEM of TPU v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_CHUNKS = 8


def _decode_kernel(words_ref, sid_ref, dec_lut_ref, area_sb_ref,
                   area_starts_ref, out_ref, *, chunk_symbols: int,
                   prefix_bits: int):
    words = words_ref[...]                       # (TC, CW) uint32
    tc, cw = words.shape
    n_area = area_sb_ref.shape[-1]
    # Stacked (S, 256)/(S, A) LUTs, flattened so the per-symbol gather
    # is a single indexed load at offset sid*len — the multi-LUT decode
    # costs nothing over the single-LUT one.
    dec = dec_lut_ref[...].astype(jnp.uint32).reshape(-1)
    sb_t = area_sb_ref[...].astype(jnp.uint32).reshape(-1)
    st_t = area_starts_ref[...].astype(jnp.uint32).reshape(-1)
    sid = sid_ref[...][:, 0].astype(jnp.int32)   # (TC,) scheme slot
    pmask = jnp.uint32((1 << prefix_bits) - 1)
    pbits = jnp.uint32(prefix_bits)

    def body(i, bitpos):
        widx = (bitpos >> 5).astype(jnp.int32)               # (TC,)
        shift = bitpos & jnp.uint32(31)
        w0 = jnp.take_along_axis(words, widx[:, None], axis=1)[:, 0]
        w1 = jnp.take_along_axis(
            words, jnp.minimum(widx + 1, cw - 1)[:, None], axis=1)[:, 0]
        window = (w0 >> shift) | jnp.where(
            shift == 0, jnp.uint32(0), w1 << (jnp.uint32(32) - shift))
        area = (window & pmask).astype(jnp.int32)
        sb = jnp.take(sb_t, sid * n_area + area)
        payload = (window >> pbits) & ((jnp.uint32(1) << sb) - jnp.uint32(1))
        rank = jnp.take(st_t, sid * n_area + area) + payload
        sym = jnp.take(
            dec,
            sid * 256 + jnp.minimum(rank, jnp.uint32(255)).astype(jnp.int32))
        out_ref[:, pl.dslice(i, 1)] = sym.astype(jnp.uint8)[:, None]
        return bitpos + pbits + sb

    bitpos0 = jnp.zeros((tc,), dtype=jnp.uint32)
    jax.lax.fori_loop(0, chunk_symbols, body, bitpos0)


@functools.partial(
    jax.jit,
    static_argnames=("chunk_symbols", "prefix_bits", "tile_chunks",
                     "interpret"))
def decode_pallas(words: jnp.ndarray, scheme_ids: jnp.ndarray,
                  dec_lut: jnp.ndarray, area_sb: jnp.ndarray,
                  area_starts: jnp.ndarray,
                  *, chunk_symbols: int, prefix_bits: int = 3,
                  tile_chunks: int = DEFAULT_TILE_CHUNKS,
                  interpret: bool = True) -> jnp.ndarray:
    """Decode [n_chunks, capacity_words] u32 slots -> [n_chunks, K] u8.

    ``scheme_ids`` is int32 [n_chunks, 1] — each chunk's slot into the
    stacked ``dec_lut [S, 256]`` / ``area_* [S, 2**prefix]`` operands
    (all-zero for single-scheme decode). n_chunks must be a multiple of
    tile_chunks (ops.py pads).
    """
    n_chunks, cw = words.shape
    assert n_chunks % tile_chunks == 0, (n_chunks, tile_chunks)
    assert dec_lut.ndim == 2 and area_sb.ndim == 2, (
        "stacked LUT operands required: dec_lut [S, 256], area_* [S, A]")
    s, a = area_sb.shape
    grid = (n_chunks // tile_chunks,)

    kernel = functools.partial(
        _decode_kernel, chunk_symbols=chunk_symbols, prefix_bits=prefix_bits)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_chunks, cw), lambda i: (i, 0)),
            pl.BlockSpec((tile_chunks, 1), lambda i: (i, 0)),
            pl.BlockSpec((s, dec_lut.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((s, a), lambda i: (0, 0)),
            pl.BlockSpec((s, a), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_chunks, chunk_symbols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, chunk_symbols), jnp.uint8),
        interpret=interpret,
    )(words, scheme_ids, dec_lut, area_sb, area_starts)
