"""Pallas TPU kernel: chunk-parallel QLC decode.

TPU-native adaptation of the paper's hardware decoder (DESIGN.md §3):
the 3-bit area code read from the bit window gives the code length in
O(1) — no tree walk — and throughput comes from decoding a tile of
chunks in lockstep (chunks map to vector lanes; the fori_loop over the
K symbols of a chunk is the only sequential dimension).

VMEM budget per program (defaults TILE_CHUNKS=8, K=1024, CW=384):
  words   8*384*4   = 12 KiB
  out     8*1024    =  8 KiB
  LUTs    256*4*3   =  3 KiB
well under the ~16 MiB/core VMEM of TPU v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_CHUNKS = 8


def _decode_kernel(words_ref, dec_lut_ref, area_sb_ref, area_starts_ref,
                   out_ref, *, chunk_symbols: int, prefix_bits: int):
    words = words_ref[...]                       # (TC, CW) uint32
    tc, cw = words.shape
    dec = dec_lut_ref[...].astype(jnp.uint32)    # (256,)
    sb_t = area_sb_ref[...].astype(jnp.uint32)   # (2**prefix,)
    st_t = area_starts_ref[...].astype(jnp.uint32)
    pmask = jnp.uint32((1 << prefix_bits) - 1)
    pbits = jnp.uint32(prefix_bits)

    def body(i, bitpos):
        widx = (bitpos >> 5).astype(jnp.int32)               # (TC,)
        shift = bitpos & jnp.uint32(31)
        w0 = jnp.take_along_axis(words, widx[:, None], axis=1)[:, 0]
        w1 = jnp.take_along_axis(
            words, jnp.minimum(widx + 1, cw - 1)[:, None], axis=1)[:, 0]
        window = (w0 >> shift) | jnp.where(
            shift == 0, jnp.uint32(0), w1 << (jnp.uint32(32) - shift))
        area = (window & pmask).astype(jnp.int32)
        sb = jnp.take(sb_t, area)
        payload = (window >> pbits) & ((jnp.uint32(1) << sb) - jnp.uint32(1))
        rank = jnp.take(st_t, area) + payload
        sym = jnp.take(dec, jnp.minimum(rank, jnp.uint32(255)).astype(jnp.int32))
        out_ref[:, pl.dslice(i, 1)] = sym.astype(jnp.uint8)[:, None]
        return bitpos + pbits + sb

    bitpos0 = jnp.zeros((tc,), dtype=jnp.uint32)
    jax.lax.fori_loop(0, chunk_symbols, body, bitpos0)


@functools.partial(
    jax.jit,
    static_argnames=("chunk_symbols", "prefix_bits", "tile_chunks",
                     "interpret"))
def decode_pallas(words: jnp.ndarray, dec_lut: jnp.ndarray,
                  area_sb: jnp.ndarray, area_starts: jnp.ndarray,
                  *, chunk_symbols: int, prefix_bits: int = 3,
                  tile_chunks: int = DEFAULT_TILE_CHUNKS,
                  interpret: bool = True) -> jnp.ndarray:
    """Decode [n_chunks, capacity_words] u32 slots -> [n_chunks, K] u8.

    n_chunks must be a multiple of tile_chunks (ops.py pads).
    """
    n_chunks, cw = words.shape
    assert n_chunks % tile_chunks == 0, (n_chunks, tile_chunks)
    grid = (n_chunks // tile_chunks,)

    kernel = functools.partial(
        _decode_kernel, chunk_symbols=chunk_symbols, prefix_bits=prefix_bits)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_chunks, cw), lambda i: (i, 0)),
            pl.BlockSpec((dec_lut.shape[0],), lambda i: (0,)),
            pl.BlockSpec((area_sb.shape[0],), lambda i: (0,)),
            pl.BlockSpec((area_starts.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_chunks, chunk_symbols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, chunk_symbols), jnp.uint8),
        interpret=interpret,
    )(words, dec_lut, area_sb, area_starts)
