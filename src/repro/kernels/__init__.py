"""Pallas TPU kernels for the QLC hot spots.

Single-stage kernels (decode, encode, histogram) plus the fused
quantize->encode / decode->dequantize pipeline (qlc_fused.py) that
keeps per-chunk symbols in VMEM. Each kernel ships with a pure-jnp
oracle in ref.py; ops.py exposes the padded/jit'd public API and
dispatches interpret mode off-TPU.
"""
from repro.kernels import ops, qlc_fused, ref  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    auto_tile_chunks, decode, decode_dequantize, encode, histogram,
    quantize_encode)
