"""Pallas TPU kernels for the QLC hot spots (decode, encode, histogram).

Each kernel ships with a pure-jnp oracle in ref.py; ops.py exposes the
padded/jit'd public API and dispatches interpret mode off-TPU.
"""
from repro.kernels import ops, ref  # noqa: F401
