"""Pallas TPU kernel: chunk-parallel QLC encode.

Per chunk: gather (code, len) from the 256-entry encoder LUT, exclusive
prefix-sum of lengths, then each <=11-bit code touches at most two
consecutive 32-bit words of the slot -> two scatter-adds (disjoint bit
ranges make add equivalent to or).

VMEM per program (TILE_CHUNKS=8, K=1024, CW=384):
  symbols 8 KiB, words 12 KiB, codes+lens+offsets 3*32 KiB ~= 116 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_CHUNKS = 8


def _encode_kernel(sym_ref, enc_code_ref, enc_len_ref, words_ref, nbits_ref,
                   *, capacity_words: int):
    sym = sym_ref[...].astype(jnp.int32)            # (TC, K)
    tc, k = sym.shape
    enc_code = enc_code_ref[...]                    # (256,) u32
    enc_len = enc_len_ref[...]                      # (256,) u32

    codes = jnp.take(enc_code, sym)                 # (TC, K) u32
    lens = jnp.take(enc_len, sym)                   # (TC, K) u32

    nbits = jnp.sum(lens, axis=1, dtype=jnp.uint32)         # (TC,)
    offsets = jnp.cumsum(lens, axis=1, dtype=jnp.uint32) - lens

    word_idx = (offsets >> 5).astype(jnp.int32)
    shift = offsets & jnp.uint32(31)
    lo = codes << shift                              # u32 shift wraps
    hi = jnp.where(shift == 0, jnp.uint32(0),
                   codes >> (jnp.uint32(32) - shift))

    word_idx = jnp.minimum(word_idx, capacity_words - 1)
    hi_idx = jnp.minimum(word_idx + 1, capacity_words - 1)

    words = jnp.zeros((tc, capacity_words), dtype=jnp.uint32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (tc, k), 0)
    words = words.at[rows, word_idx].add(lo, mode="drop")
    words = words.at[rows, hi_idx].add(hi, mode="drop")

    words_ref[...] = words
    nbits_ref[...] = nbits[:, None]


@functools.partial(
    jax.jit,
    static_argnames=("capacity_words", "tile_chunks", "interpret"))
def encode_pallas(symbols: jnp.ndarray, enc_code: jnp.ndarray,
                  enc_len: jnp.ndarray, *, capacity_words: int,
                  tile_chunks: int = DEFAULT_TILE_CHUNKS,
                  interpret: bool = True):
    """Encode [n_chunks, K] u8 -> ([n_chunks, CW] u32, [n_chunks, 1] u32)."""
    n_chunks, k = symbols.shape
    assert n_chunks % tile_chunks == 0, (n_chunks, tile_chunks)
    grid = (n_chunks // tile_chunks,)

    kernel = functools.partial(_encode_kernel, capacity_words=capacity_words)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_chunks, k), lambda i: (i, 0)),
            pl.BlockSpec((enc_code.shape[0],), lambda i: (0,)),
            pl.BlockSpec((enc_len.shape[0],), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_chunks, capacity_words), lambda i: (i, 0)),
            pl.BlockSpec((tile_chunks, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_chunks, capacity_words), jnp.uint32),
            jax.ShapeDtypeStruct((n_chunks, 1), jnp.uint32),
        ],
        interpret=interpret,
    )(symbols, enc_code, enc_len)
